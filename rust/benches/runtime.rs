//! PJRT runtime benchmarks: executable latency for fwd / eval / train-step
//! artifacts (the L3-visible cost of every L2 graph).
//!
//! Run: `cargo bench --bench runtime` (requires `make artifacts`).

use matquant::coordinator::trainer::init_params;
use matquant::model::{PrecisionAssignment, QuantizedModel, Tensor};
use matquant::runtime::{lit_i32, lit_scalar_i32, lit_tensor, Engine};
use matquant::util::bench::{bench, default_budget};

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::new(&dir).unwrap();
    let preset = "tiny";
    let info = engine.manifest().preset(preset).unwrap().clone();
    let seq = info.model.seq_len;
    let t1 = seq + 1;
    let b = info.train_batch;

    let params = init_params(&engine, preset, 1).unwrap();
    let model = QuantizedModel::build(&info, &params, None).unwrap();
    let (weights, biases) = model.materialize(&PrecisionAssignment::uniform(4)).unwrap();

    // ---- fwd_b{B} ----
    for bsz in [1usize, 4, 8] {
        if !info.fwd_batch_sizes.contains(&bsz) {
            continue;
        }
        let tokens = vec![1i32; bsz * seq];
        let name = format!("fwd_b{bsz}");
        engine.warmup(preset, &[&name]).unwrap();
        let r = bench(&format!("pjrt {name}"), default_budget(), || {
            let mut args: Vec<xla::Literal> = Vec::new();
            for w in &weights {
                args.push(lit_tensor(w).unwrap());
            }
            for bi in &biases {
                args.push(lit_tensor(bi).unwrap());
            }
            args.push(lit_i32(&[bsz, seq], &tokens).unwrap());
            engine.run(preset, &name, &args).unwrap();
        });
        println!(
            "{} | {:.1} tokens/s",
            r.report(),
            r.throughput((bsz * seq) as f64)
        );
    }

    // ---- eval ----
    {
        let tokens = vec![1i32; b * t1];
        let mask = Tensor::new(vec![b, seq], vec![1.0; b * seq]).unwrap();
        engine.warmup(preset, &["eval"]).unwrap();
        let r = bench("pjrt eval", default_budget(), || {
            let mut args: Vec<xla::Literal> = Vec::new();
            for w in &weights {
                args.push(lit_tensor(w).unwrap());
            }
            for bi in &biases {
                args.push(lit_tensor(bi).unwrap());
            }
            args.push(lit_i32(&[b, t1], &tokens).unwrap());
            args.push(lit_tensor(&mask).unwrap());
            engine.run(preset, "eval", &args).unwrap();
        });
        println!("{}", r.report());
    }

    // ---- train steps ----
    for name in ["train_qat_direct_b8", "train_qat_mat"] {
        let tokens = vec![1i32; b * t1];
        engine.warmup(preset, &[name]).unwrap();
        let pflat: Vec<&Tensor> = info
            .params
            .iter()
            .map(|(n, _)| params.get(n).unwrap())
            .collect();
        let zeros: Vec<Tensor> = pflat
            .iter()
            .map(|t| Tensor::zeros(t.shape.clone()))
            .collect();
        let r = bench(&format!("pjrt {name}"), default_budget(), || {
            let mut args: Vec<xla::Literal> = Vec::new();
            for p in &pflat {
                args.push(lit_tensor(p).unwrap());
            }
            for z in zeros.iter().chain(zeros.iter()) {
                args.push(lit_tensor(z).unwrap());
            }
            args.push(lit_scalar_i32(0));
            args.push(lit_i32(&[b, t1], &tokens).unwrap());
            if name.ends_with("mat") {
                args.push(
                    lit_tensor(&Tensor::new(vec![3], vec![0.1, 0.1, 1.0]).unwrap()).unwrap(),
                );
                args.push(lit_tensor(&Tensor::new(vec![3], vec![0.0; 3]).unwrap()).unwrap());
            }
            engine.run(preset, name, &args).unwrap();
        });
        println!("{}", r.report());
    }

    let st = engine.stats.borrow();
    println!(
        "engine: {} compiles ({:.0} ms total), {} executions ({:.1} ms mean)",
        st.compiles,
        st.compile_ms,
        st.executions,
        st.execute_ms / st.executions.max(1) as f64
    );
}
