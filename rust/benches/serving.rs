//! Serving-stack benchmarks: batcher mechanics (pure L3 overhead — must be
//! negligible vs PJRT compute) and end-to-end mixed-precision throughput.
//!
//! Run: `cargo bench --bench serving` (requires `make artifacts`).

use std::time::Instant;

use matquant::coordinator::trainer::init_params;
use matquant::data::{Corpus, Rng};
use matquant::model::{manifest::default_artifacts_dir, QuantizedModel};
use matquant::runtime::Engine;
use matquant::serve::{DynamicBatcher, PrecisionReq, Request, Server, ServerConfig};
use matquant::util::bench::{bench, default_budget};

fn main() {
    // ---- pure batcher overhead (no PJRT) ---------------------------------
    let budget = default_budget();
    let mut rng = Rng::new(1);
    let prompts: Vec<Vec<i32>> = (0..256)
        .map(|_| (0..32).map(|_| rng.below(256) as i32).collect())
        .collect();
    let r = bench("batcher push+pop 256 reqs", budget, || {
        let mut b = DynamicBatcher::new(vec![1, 2, 4, 8, 16], 0.0);
        for (i, p) in prompts.iter().enumerate() {
            b.push(Request::new(
                i as u64,
                p.clone(),
                PrecisionReq::Bits([2, 4, 8][i % 3]),
            ));
        }
        let now = Instant::now();
        while let Some(batch) = b.pop_ready(now) {
            std::hint::black_box(batch);
        }
    });
    println!(
        "{} | {:.0} ns/request",
        r.report(),
        r.mean_ns / 256.0
    );

    // ---- end-to-end serving throughput ------------------------------------
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping e2e serving: run `make artifacts`");
        return;
    }
    let preset = "tiny";
    let engine = Engine::new(&dir).unwrap();
    let info = engine.manifest().preset(preset).unwrap().clone();
    let model = QuantizedModel::build(&info, &init_params(&engine, preset, 1).unwrap(), None).unwrap();
    let seq = info.model.seq_len;
    drop(engine);
    let server = Server::start(
        default_artifacts_dir().canonicalize().unwrap_or(dir),
        model,
        ServerConfig {
            preset: preset.into(),
            max_wait_ms: 1.0,
            warm_bits: vec![8, 4, 2],
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let corpus = Corpus::new(3);
    let mut rng = Rng::new(3);
    // warm the executables with one request per precision
    for (i, bits) in [2u32, 4, 8].iter().enumerate() {
        let _ = server
            .infer(Request::new(
                1_000_000 + i as u64,
                corpus.sequence(&mut rng, seq.min(32)),
                PrecisionReq::Bits(*bits),
            ))
            .unwrap();
    }

    for &n in &[32usize, 128] {
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|id| {
                server
                    .submit(Request::new(
                        id as u64,
                        corpus.sequence(&mut rng, seq.min(32)),
                        PrecisionReq::Bits([2, 4, 8][id % 3]),
                    ))
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "e2e mixed-precision: {n} requests in {dt:.3}s = {:.1} req/s",
            n as f64 / dt
        );
    }
    println!("{}", server.metrics_report().unwrap());
    server.shutdown().unwrap();
}
