//! L3 quantization hot paths: pack/unpack, slicing, dequantization — the
//! per-request work of elastic serving.  Perf targets in DESIGN.md §Perf
//! (slicing ≥ 1 GB/s of codes on this single-core testbed).
//!
//! Run: `cargo bench --bench quant_hot_paths`

use matquant::data::Rng;
use matquant::kernels;
use matquant::model::registry::QuantizedTensor;
use matquant::model::Tensor;
use matquant::quant::{self, PackedTensor};
use matquant::util::bench::{bench, default_budget};

fn main() {
    let n = 1 << 20; // 1M weights ≈ one large FFN matrix
    let d_out = 1024;
    let d_in = n / d_out;
    let mut rng = Rng::new(1);
    let w: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let budget = default_budget();

    // ---- scales + quantize ----
    let r = bench("minmax_scales 1M", budget, || {
        std::hint::black_box(quant::minmax_scales(&w, d_in, d_out, 8));
    });
    println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);

    let scales = quant::minmax_scales(&w, d_in, d_out, 8);
    let r = bench("quantize 1M -> int8 codes", budget, || {
        std::hint::black_box(quant::quantize(&w, d_out, &scales));
    });
    println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);

    let codes = quant::quantize(&w, d_out, &scales);

    // ---- slicing (the serve-time Matryoshka op) ----
    let mut out = vec![0.0f32; n];
    for bits in [2u32, 4, 6] {
        let r = bench(&format!("slice 1M int8->int{bits}"), budget, || {
            quant::slice_codes_into(&codes, 8, bits, false, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{} | {:.2} GB/s of codes",
            r.report(),
            r.throughput(n as f64 * 4.0) / 1e9
        );
    }

    // ---- dequantize ----
    let r = bench("dequantize 1M", budget, || {
        quant::dequantize_into(&codes, d_out, &scales, &mut out);
        std::hint::black_box(&out);
    });
    println!(
        "{} | {:.2} GB/s out",
        r.report(),
        r.throughput(n as f64 * 4.0) / 1e9
    );

    // ---- bit packing ----
    for bits in [2u32, 4, 8] {
        let ids: Vec<f32> = codes
            .iter()
            .map(|&c| quant::slice_code(c, 8, bits, false) / (1u32 << (8 - bits)) as f32)
            .collect();
        let r = bench(&format!("pack 1M @ {bits}b"), budget, || {
            std::hint::black_box(PackedTensor::pack(&ids, bits));
        });
        println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);
        let packed = PackedTensor::pack(&ids, bits);
        let r = bench(&format!("unpack 1M @ {bits}b"), budget, || {
            packed.unpack_into(&mut out);
            std::hint::black_box(&out);
        });
        println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);
    }

    // ---- full materialize path (registry → servable weights) ----
    let fp = Tensor::new(vec![d_in, d_out], w.clone()).unwrap();
    let qt = QuantizedTensor::from_weight(fp, None, None, None).unwrap();
    for bits in [2u32, 4, 8] {
        let r = bench(&format!("materialize 1M @ int{bits}"), budget, || {
            std::hint::black_box(qt.materialize(bits, false).unwrap());
        });
        println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);
    }

    // ---- fused packed-domain dequant vs the two-pass walk ----
    // Acceptance target (ISSUE 1): fused ≥ 2× two-pass at 2- and 4-bit.
    let mut tmp = vec![0.0f32; n];
    for bits in [2u32, 3, 4, 8] {
        let (packed, _overlay) = qt.pack_sliced(bits, false);
        let rscales = quant::minmax_scales(&w, d_in, d_out, bits);
        // correctness guard: identical output before timing
        packed.unpack_into(&mut tmp);
        quant::dequantize_into(&tmp, d_out, &rscales, &mut out);
        let reference = out.clone();
        kernels::dequant_packed_into(&packed, None, &rscales, bits, d_out, &mut out);
        assert_eq!(reference, out, "fused/two-pass divergence at {bits}b");

        let two_pass = bench(&format!("two-pass unpack+dequant 1M @ {bits}b"), budget, || {
            packed.unpack_into(&mut tmp);
            quant::dequantize_into(&tmp, d_out, &rscales, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{} | {:.2} Melem/s",
            two_pass.report(),
            two_pass.throughput(n as f64) / 1e6
        );
        let fused = bench(&format!("fused dequant_packed 1M @ {bits}b"), budget, || {
            kernels::dequant_packed_into(&packed, None, &rscales, bits, d_out, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{} | {:.2} Melem/s | {:.2}x vs two-pass",
            fused.report(),
            fused.throughput(n as f64) / 1e6,
            two_pass.mean_ns / fused.mean_ns
        );
    }

    // ---- fused slice+dequant (Mix'n'Match path) vs the seed's three-pass ----
    let mut sliced_buf = vec![0.0f32; n];
    for bits in [2u32, 4, 6] {
        let three_pass = bench(
            &format!("unpack+slice+dequant 1M int8->int{bits}"),
            budget,
            || {
                qt.codes.unpack_into(&mut tmp);
                quant::slice_codes_into(&tmp, 8, bits, false, &mut sliced_buf);
                quant::dequantize_into(&sliced_buf, d_out, &qt.scales, &mut out);
                std::hint::black_box(&out);
            },
        );
        println!(
            "{} | {:.2} Melem/s",
            three_pass.report(),
            three_pass.throughput(n as f64) / 1e6
        );
        let fused = bench(
            &format!("fused slice_dequant 1M int8->int{bits}"),
            budget,
            || {
                kernels::slice_dequant_into(&qt.codes, bits, false, &qt.scales, d_out, &mut out);
                std::hint::black_box(&out);
            },
        );
        println!(
            "{} | {:.2} Melem/s | {:.2}x vs three-pass",
            fused.report(),
            fused.throughput(n as f64) / 1e6,
            three_pass.mean_ns / fused.mean_ns
        );
    }

    // ---- histogram (fig 1c machinery) ----
    let r = bench("code_histogram 1M @ int8", budget, || {
        std::hint::black_box(quant::code_histogram(&codes, 8));
    });
    println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);
}
