//! L3 quantization hot paths: pack/unpack, slicing, dequantization, and the
//! fused packed-domain matmuls — the per-request work of elastic serving.
//! Perf targets in DESIGN.md §Perf (slicing ≥ 1 GB/s of codes on this
//! single-core testbed); ISSUE 2 acceptance: fused matvec/matmul beats
//! materialize-then-matmul at int2/int4 on these shapes; ISSUE 3 adds the
//! host-forward tokens/sec rows (dense vs packed vs packed+i8 activations);
//! ISSUE 5 adds the continuous-batching rows (scheduler step rounds vs
//! per-session stepping at 1/4/16 concurrent sessions); ISSUE 6 adds the
//! nested-payload page-in rows, elastic precision-shift latency, and round
//! throughput at each watermark state; ISSUE 7 adds the self-speculative
//! decode rows (plain vs int2-draft/int8-verify tokens/sec at k ∈ {2,4,8},
//! c ∈ {1,4,16}, with accept rates); ISSUE 8 adds the paged-KV rows
//! (max concurrent streams at one fixed KV budget — analytic contiguous
//! reservation vs measured paged-f32 vs paged-int8 admission — plus the
//! paged-attend decode step latency per page geometry); ISSUE 9 adds the
//! front-door loadgen rows (client-side p50/p99 TTFT + tokens/sec at
//! 1/2/4 workers under the mixed-precision Poisson trace, plus the
//! elastic on-vs-off pair with shift counts and SLO attainment);
//! ISSUE 10 adds the MatGPTQ accuracy-frontier rows (minmax-vs-solver
//! distilled decode perplexity per rung with measured effective bits,
//! plus the Eq. 8 outlier-budget sweep to the ≈2.05-bit point) —
//! persisted as JSON when `MQ_BENCH_OUT` names a path
//! (`make bench-json` → `BENCH_10.json`).
//!
//! Run: `cargo bench --bench quant_hot_paths`

use std::sync::Arc;
use std::time::Instant;

use matquant::data::Rng;
use matquant::kernels;
use matquant::model::registry::QuantizedTensor;
use matquant::model::testing::toy_transformer;
use matquant::model::{manifest::ModelDims, PrecisionAssignment, Tensor};
use matquant::quant::{self, ActQuantConfig, PackedTensor};
use matquant::runtime::{
    advance_sessions, argmax_logit, speculative_round, DecodeSession, ForwardPlan,
    ForwardWeights, HostForward, KvConfig, PagePool, Sampling,
};
use matquant::serve::{
    projected_kv_bytes, Metrics, PlanKey, PrecisionReq, Request, Scheduler, SchedulerConfig,
    WeightStore,
};
use matquant::util::bench::{bench, default_budget};

fn main() {
    let n = 1 << 20; // 1M weights ≈ one large FFN matrix
    let d_out = 1024;
    let d_in = n / d_out;
    let mut rng = Rng::new(1);
    let w: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let budget = default_budget();

    // ---- scales + quantize ----
    let r = bench("minmax_scales 1M", budget, || {
        std::hint::black_box(quant::minmax_scales(&w, d_in, d_out, 8));
    });
    println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);

    let scales = quant::minmax_scales(&w, d_in, d_out, 8);
    let r = bench("quantize 1M -> int8 codes", budget, || {
        std::hint::black_box(quant::quantize(&w, d_out, &scales));
    });
    println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);

    let codes = quant::quantize(&w, d_out, &scales);

    // ---- slicing (the serve-time Matryoshka op) ----
    let mut out = vec![0.0f32; n];
    for bits in [2u32, 4, 6] {
        let r = bench(&format!("slice 1M int8->int{bits}"), budget, || {
            quant::slice_codes_into(&codes, 8, bits, false, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{} | {:.2} GB/s of codes",
            r.report(),
            r.throughput(n as f64 * 4.0) / 1e9
        );
    }

    // ---- dequantize ----
    let r = bench("dequantize 1M", budget, || {
        quant::dequantize_into(&codes, d_out, &scales, &mut out);
        std::hint::black_box(&out);
    });
    println!(
        "{} | {:.2} GB/s out",
        r.report(),
        r.throughput(n as f64 * 4.0) / 1e9
    );

    // ---- bit packing ----
    for bits in [2u32, 4, 8] {
        let ids: Vec<f32> = codes
            .iter()
            .map(|&c| quant::slice_code(c, 8, bits, false) / (1u32 << (8 - bits)) as f32)
            .collect();
        let r = bench(&format!("pack 1M @ {bits}b"), budget, || {
            std::hint::black_box(PackedTensor::pack(&ids, bits));
        });
        println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);
        let packed = PackedTensor::pack(&ids, bits);
        let r = bench(&format!("unpack 1M @ {bits}b"), budget, || {
            packed.unpack_into(&mut out);
            std::hint::black_box(&out);
        });
        println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);
    }

    // ---- full materialize path (registry → servable weights) ----
    let fp = Tensor::new(vec![d_in, d_out], w.clone()).unwrap();
    let qt = QuantizedTensor::from_weight(fp, None, None, None).unwrap();
    for bits in [2u32, 4, 8] {
        let r = bench(&format!("materialize 1M @ int{bits}"), budget, || {
            std::hint::black_box(qt.materialize(bits, false).unwrap());
        });
        println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);
    }

    // ---- fused packed-domain dequant vs the two-pass walk ----
    // Acceptance target (ISSUE 1): fused ≥ 2× two-pass at 2- and 4-bit.
    let mut tmp = vec![0.0f32; n];
    for bits in [2u32, 3, 4, 8] {
        let (packed, _overlay) = qt.pack_sliced(bits, false);
        let rscales = quant::minmax_scales(&w, d_in, d_out, bits);
        // correctness guard: identical output before timing
        packed.unpack_into(&mut tmp);
        quant::dequantize_into(&tmp, d_out, &rscales, &mut out);
        let reference = out.clone();
        kernels::dequant_packed_into(&packed, None, &rscales, bits, d_out, &mut out);
        assert_eq!(reference, out, "fused/two-pass divergence at {bits}b");

        let two_pass = bench(&format!("two-pass unpack+dequant 1M @ {bits}b"), budget, || {
            packed.unpack_into(&mut tmp);
            quant::dequantize_into(&tmp, d_out, &rscales, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{} | {:.2} Melem/s",
            two_pass.report(),
            two_pass.throughput(n as f64) / 1e6
        );
        let fused = bench(&format!("fused dequant_packed 1M @ {bits}b"), budget, || {
            kernels::dequant_packed_into(&packed, None, &rscales, bits, d_out, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{} | {:.2} Melem/s | {:.2}x vs two-pass",
            fused.report(),
            fused.throughput(n as f64) / 1e6,
            two_pass.mean_ns / fused.mean_ns
        );
    }

    // ---- fused slice+dequant (Mix'n'Match path) vs the seed's three-pass ----
    let mut sliced_buf = vec![0.0f32; n];
    for bits in [2u32, 4, 6] {
        let three_pass = bench(
            &format!("unpack+slice+dequant 1M int8->int{bits}"),
            budget,
            || {
                qt.codes.unpack_into(&mut tmp);
                quant::slice_codes_into(&tmp, 8, bits, false, &mut sliced_buf);
                quant::dequantize_into(&sliced_buf, d_out, &qt.scales, &mut out);
                std::hint::black_box(&out);
            },
        );
        println!(
            "{} | {:.2} Melem/s",
            three_pass.report(),
            three_pass.throughput(n as f64) / 1e6
        );
        let fused = bench(
            &format!("fused slice_dequant 1M int8->int{bits}"),
            budget,
            || {
                kernels::slice_dequant_into(&qt.codes, bits, false, &qt.scales, d_out, &mut out);
                std::hint::black_box(&out);
            },
        );
        println!(
            "{} | {:.2} Melem/s | {:.2}x vs three-pass",
            fused.report(),
            fused.throughput(n as f64) / 1e6,
            three_pass.mean_ns / fused.mean_ns
        );
    }

    // ---- fused dequant×matmul vs materialize-then-matmul ----
    // Acceptance target (ISSUE 2): fused beats materialize-then-matmul at
    // int2/int4 — the packed path reads `bits/32` of the weight bytes and
    // never writes the 4 MB f32 weight buffer.
    let x: Vec<f32> = (0..d_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut y = vec![0.0f32; d_out];
    let mut w_buf = vec![0.0f32; n];
    for bits in [2u32, 4, 8] {
        let (packed, _overlay) = qt.pack_sliced(bits, false);
        let mat = bench(
            &format!("materialize+matvec 1M @ int{bits}"),
            budget,
            || {
                kernels::dequant_packed_into(&packed, None, &qt.scales, 8, d_out, &mut w_buf);
                y.fill(0.0);
                for (i, row) in w_buf.chunks_exact(d_out).enumerate() {
                    let xv = x[i];
                    for (o, &wv) in y.iter_mut().zip(row) {
                        *o += xv * wv;
                    }
                }
                std::hint::black_box(&y);
            },
        );
        println!(
            "{} | {:.2} Melem/s",
            mat.report(),
            mat.throughput(n as f64) / 1e6
        );
        let fused = bench(&format!("fused matvec 1M @ int{bits}"), budget, || {
            kernels::matvec_packed_into(&packed, None, &qt.scales, 8, d_out, &x, None, &mut y);
            std::hint::black_box(&y);
        });
        println!(
            "{} | {:.2} Melem/s | {:.2}x vs materialize-then-matmul | {}B vs {}B weight bytes",
            fused.report(),
            fused.throughput(n as f64) / 1e6,
            mat.mean_ns / fused.mean_ns,
            packed.bytes(),
            n * 4
        );
    }

    // ---- batched fused GEMM (8 columns per packed-stream pass) ----
    let m = 8usize;
    let xs: Vec<f32> = (0..m * d_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut ys = vec![0.0f32; m * d_out];
    for bits in [2u32, 4] {
        let (packed, _overlay) = qt.pack_sliced(bits, false);
        let mat = bench(
            &format!("materialize+matmul 1M @ int{bits} m={m}"),
            budget,
            || {
                kernels::dequant_packed_into(&packed, None, &qt.scales, 8, d_out, &mut w_buf);
                ys.fill(0.0);
                for b in 0..m {
                    let yrow = &mut ys[b * d_out..(b + 1) * d_out];
                    for (i, row) in w_buf.chunks_exact(d_out).enumerate() {
                        let xv = xs[b * d_in + i];
                        for (o, &wv) in yrow.iter_mut().zip(row) {
                            *o += xv * wv;
                        }
                    }
                }
                std::hint::black_box(&ys);
            },
        );
        println!(
            "{} | {:.2} Melem/s",
            mat.report(),
            mat.throughput((m * n) as f64) / 1e6
        );
        let fused = bench(
            &format!("fused matmul 1M @ int{bits} m={m}"),
            budget,
            || {
                kernels::matmul_packed_into(
                    &packed,
                    None,
                    &qt.scales,
                    8,
                    d_out,
                    &xs,
                    m,
                    None,
                    &mut ys,
                );
                std::hint::black_box(&ys);
            },
        );
        println!(
            "{} | {:.2} Melem/s | {:.2}x vs materialize-then-matmul",
            fused.report(),
            fused.throughput((m * n) as f64) / 1e6,
            mat.mean_ns / fused.mean_ns
        );
    }

    // ---- integer-domain GEMV (i8 activations, i32 accumulate) ----
    let xq: Vec<i8> = (0..d_in).map(|i| (((i * 37) % 255) as i64 - 127) as i8).collect();
    for bits in [2u32, 4, 8] {
        let (packed, _overlay) = qt.pack_sliced(bits, false);
        let r = bench(&format!("fused i8 matvec 1M @ int{bits}"), budget, || {
            kernels::matvec_packed_i8_into(
                &packed,
                None,
                &qt.scales,
                8,
                d_out,
                &xq,
                0.01,
                None,
                &mut y,
            );
            std::hint::black_box(&y);
        });
        println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);
    }

    // ---- histogram (fig 1c machinery) ----
    let r = bench("code_histogram 1M @ int8", budget, || {
        std::hint::black_box(quant::code_histogram(&codes, 8));
    });
    println!("{} | {:.2} Melem/s", r.report(), r.throughput(n as f64) / 1e6);

    // ---- host forward pass: tokens/sec, dense vs packed vs packed+i8 ----
    // The serving-side figure of merit for the no-PJRT path: a whole
    // request batch through embedding → layers → logits.  Dense is the f32
    // reference; packed streams the fused r-bit matmuls (32/r× fewer
    // weight bytes); packed+i8 adds integer-domain activations.
    // tiny-preset-shaped (configs.py `tiny`: d=96, 4 layers, FFN quantized)
    let (preset, fwd_model) = toy_transformer(
        ModelDims {
            vocab: 256,
            d_model: 96,
            n_layers: 4,
            n_heads: 4,
            d_ff: 384,
            seq_len: 32,
            quantize_attn: false,
        },
        41,
    );
    let b = 4usize;
    let t = preset.model.seq_len;
    let tokens: Vec<i32> = (0..b * t)
        .map(|i| ((i * 11 + 5) % preset.model.vocab) as i32)
        .collect();
    let toks_per_iter = (b * t) as f64;
    for bits in [2u32, 4, 8] {
        let (weights, biases) = fwd_model
            .materialize(&PrecisionAssignment::uniform(bits))
            .unwrap();
        let dense = HostForward::new(
            &preset.model,
            &fwd_model,
            ForwardWeights::Dense {
                weights: &weights,
                biases: &biases,
            },
        )
        .unwrap();
        let r_dense = bench(&format!("host fwd dense b{b} @ int{bits}"), budget, || {
            std::hint::black_box(dense.forward(&tokens, b, t).unwrap());
        });
        println!(
            "{} | {:.0} tok/s",
            r_dense.report(),
            r_dense.throughput(toks_per_iter)
        );

        let handles = fwd_model.packed_weights(bits, false).unwrap();
        let packed = HostForward::new(
            &preset.model,
            &fwd_model,
            ForwardWeights::Packed {
                packed: &handles,
                int8: None,
            },
        )
        .unwrap();
        let r_packed = bench(&format!("host fwd packed b{b} @ int{bits}"), budget, || {
            std::hint::black_box(packed.forward(&tokens, b, t).unwrap());
        });
        println!(
            "{} | {:.0} tok/s | {:.2}x vs dense",
            r_packed.report(),
            r_packed.throughput(toks_per_iter),
            r_dense.mean_ns / r_packed.mean_ns
        );

        let packed_i8 = HostForward::new(
            &preset.model,
            &fwd_model,
            ForwardWeights::Packed {
                packed: &handles,
                int8: Some(ActQuantConfig::absmax()),
            },
        )
        .unwrap();
        let r_i8 = bench(
            &format!("host fwd packed+i8 b{b} @ int{bits}"),
            budget,
            || {
                std::hint::black_box(packed_i8.forward(&tokens, b, t).unwrap());
            },
        );
        println!(
            "{} | {:.0} tok/s | {:.2}x vs dense",
            r_i8.report(),
            r_i8.throughput(toks_per_iter),
            r_dense.mean_ns / r_i8.mean_ns
        );
    }

    // ---- incremental decode engine: prefill + KV-cached steps vs repeated
    // full re-forward (ISSUE 4 acceptance: cached decode tokens/sec must
    // measurably beat generating by re-running the full prefill per token).
    // Rows per precision × weight path: prefill tok/s (one O(t²) pass),
    // steady-state decode tok/s (O(n) per token), and the no-cache
    // re-forward baseline.
    let p_len = 16usize;
    let n_new = 16usize; // p_len + n_new == seq_len: decode to capacity
    let gen_prompt: Vec<i32> = (0..p_len)
        .map(|i| ((i * 13 + 2) % preset.model.vocab) as i32)
        .collect();
    let reps = 12usize;
    for bits in [2u32, 4, 8] {
        let plans: Vec<(&str, Arc<ForwardPlan>)> = vec![
            (
                "dense    ",
                ForwardPlan::dense_uniform(&preset.model, &fwd_model, bits, false).unwrap(),
            ),
            (
                "packed   ",
                ForwardPlan::packed_uniform(&preset.model, &fwd_model, bits, false, None, None)
                    .unwrap(),
            ),
            (
                "packed+i8",
                ForwardPlan::packed_uniform(
                    &preset.model,
                    &fwd_model,
                    bits,
                    false,
                    Some(ActQuantConfig::absmax()),
                    None,
                )
                .unwrap(),
            ),
        ];
        for (tag, plan) in &plans {
            let mut prefill_s = 0.0f64;
            let mut decode_s = 0.0f64;
            for _ in 0..reps {
                let t0 = Instant::now();
                let mut sess =
                    DecodeSession::new(plan.clone(), &gen_prompt, Sampling::Greedy).unwrap();
                prefill_s += t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                for _ in 0..n_new {
                    let (tok, _) = sess.sample();
                    sess.advance(tok).unwrap();
                }
                decode_s += t1.elapsed().as_secs_f64();
                std::hint::black_box(sess.logits());
            }
            let prefill_tps = (reps * p_len) as f64 / prefill_s;
            let decode_tps = (reps * n_new) as f64 / decode_s;
            // Baseline: the pre-decode-engine strategy — one full forward
            // over the growing stream per generated token.
            let v = preset.model.vocab;
            let t2 = Instant::now();
            for _ in 0..reps {
                let mut stream = gen_prompt.clone();
                for _ in 0..n_new {
                    let t = stream.len();
                    let logits = plan.forward(&stream, 1, t).unwrap();
                    let (tok, _) = argmax_logit(&logits.data[(t - 1) * v..t * v]);
                    stream.push(tok);
                }
                std::hint::black_box(&stream);
            }
            let reforward_s = t2.elapsed().as_secs_f64();
            let reforward_tps = (reps * n_new) as f64 / reforward_s;
            println!(
                "decode {tag} p{p_len}+n{n_new} @ int{bits}: prefill {prefill_tps:.0} tok/s | cached steps {decode_tps:.0} tok/s | re-forward {reforward_tps:.0} tok/s | {:.2}x vs re-forward",
                decode_tps / reforward_tps
            );
        }
    }

    // ---- continuous-batching scheduler: step rounds vs per-session
    // stepping (ISSUE 5 acceptance).  Aggregate tokens/sec at 1/4/16
    // concurrent sessions: "solo" is the pre-scheduler worker (each
    // session advanced alone — N fused matvec sweeps per token), "rounds"
    // is the scheduler's batched GEMM step round (ONE blocked fused GEMM
    // per layer across all members — the payload streams once per round,
    // so weight bytes per generated token shrink with occupancy; the
    // printed bytes are per-round vs summed per-session).
    let vocab = preset.model.vocab;
    let sp_len = 8usize;
    let sn_new = 16usize;
    let reps = 4usize;
    let sched_plans: Vec<(&str, Arc<ForwardPlan>)> = vec![
        (
            "dense    ",
            ForwardPlan::dense_uniform(&preset.model, &fwd_model, 4, false).unwrap(),
        ),
        (
            "packed   ",
            ForwardPlan::packed_uniform(&preset.model, &fwd_model, 4, false, None, None).unwrap(),
        ),
        (
            "packed+i8",
            ForwardPlan::packed_uniform(
                &preset.model,
                &fwd_model,
                4,
                false,
                Some(ActQuantConfig::absmax()),
                None,
            )
            .unwrap(),
        ),
    ];
    for (tag, plan) in &sched_plans {
        for conc in [1usize, 4, 16] {
            let prompts: Vec<Vec<i32>> = (0..conc)
                .map(|c| {
                    (0..sp_len)
                        .map(|i| ((i * 13 + 2 + 7 * c) % vocab) as i32)
                        .collect()
                })
                .collect();
            let specs: Vec<(&[i32], Sampling, usize)> = prompts
                .iter()
                .map(|p| (p.as_slice(), Sampling::Greedy, sn_new + 1))
                .collect();
            // per-session stepping (solo prefills, solo steps)
            let mut solo_s = 0.0f64;
            for _ in 0..reps {
                let mut sessions: Vec<DecodeSession> = prompts
                    .iter()
                    .map(|p| {
                        DecodeSession::with_budget(
                            plan.clone(),
                            p,
                            Sampling::Greedy,
                            sn_new + 1,
                        )
                        .unwrap()
                    })
                    .collect();
                let t0 = Instant::now();
                for _ in 0..sn_new {
                    for s in sessions.iter_mut() {
                        let (tok, _) = s.sample();
                        s.advance(tok).unwrap();
                    }
                }
                solo_s += t0.elapsed().as_secs_f64();
                std::hint::black_box(&sessions);
            }
            // scheduler-style rounds (batched prefill, batched steps)
            let mut round_s = 0.0f64;
            for _ in 0..reps {
                let mut sessions = DecodeSession::prefill_many(plan, &specs).unwrap();
                let t0 = Instant::now();
                for _ in 0..sn_new {
                    let tokens: Vec<i32> =
                        sessions.iter_mut().map(|s| s.sample().0).collect();
                    let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
                    advance_sessions(&mut refs, &tokens).unwrap();
                }
                round_s += t0.elapsed().as_secs_f64();
                std::hint::black_box(&sessions);
            }
            let total = (reps * conc * sn_new) as f64;
            println!(
                "sched {tag} c{conc:<2} p{sp_len}+n{sn_new} @ int4: solo {:.0} tok/s | rounds {:.0} tok/s | {:.2}x | weight bytes/step-round {}B vs {}B solo",
                total / solo_s,
                total / round_s,
                solo_s / round_s,
                plan.weight_bytes(),
                conc * plan.weight_bytes()
            );
        }
    }

    // ---- nested payload sharing + elastic precision shifts (ISSUE 6) ----
    // The ROADMAP-mandated perf-trajectory rows, persisted as JSON when
    // MQ_BENCH_OUT names a path (`make bench-json` → BENCH_6.json; CI runs
    // a smoke pass with a tiny MQ_BENCH_MS budget).  Honest caveat up
    // front: the host fused GEMMs stream the shared int8 master bytes
    // whatever the view's r, so a downshift is a paging/quality/headroom
    // knob, not a per-round speed win — the rows below quantify exactly
    // which bytes sharing removes and what a shift costs.
    let mut json_page_in: Vec<String> = Vec::new();
    let mut json_shift: Vec<String> = Vec::new();
    let mut json_rounds: Vec<String> = Vec::new();

    // Page-in bytes per precision, before/after nested sharing: one store
    // resolves int8 → int4 → int2.  The master payload pages once; every
    // lower precision binds MSB-prefix views of it, so paged bytes stay 0
    // below r_max while a per-r store would page each compact payload.
    {
        let mut store = WeightStore::new();
        let mut metrics = Metrics::default();
        for bits in [8u32, 4, 2] {
            let t0 = Instant::now();
            store
                .plan_packed(&fwd_model, &preset.model, bits, None, &mut metrics)
                .unwrap();
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let paged = metrics.page_in_bytes(bits);
            let saved = metrics.page_in_saved_bytes(bits);
            println!(
                "nested page-in @ int{bits}: {paged}B paged vs {}B per-r store ({saved}B saved) | plan resolve {ms:.2} ms",
                paged + saved
            );
            json_page_in.push(format!(
                "{{\"bits\": {bits}, \"paged_bytes\": {paged}, \"per_r_store_bytes\": {}, \"saved_bytes\": {saved}, \"plan_resolve_ms\": {ms:.3}}}",
                paged + saved
            ));
        }
    }

    // Precision-switch latency: live scheduler sessions through a full
    // elastic cycle — the int8 group shifted one rung down, then the
    // displaced members shifted back up to native.  A live swap is a
    // geometry check plus an Arc pointer swap (KV rows stay put), so the
    // cycle is pure group-map surgery; this row is the evidence.
    let plan8 =
        ForwardPlan::packed_uniform(&preset.model, &fwd_model, 8, false, None, None).unwrap();
    let plan4 =
        ForwardPlan::packed_uniform(&preset.model, &fwd_model, 4, false, None, None).unwrap();
    for conc in [1usize, 4, 16] {
        let mut sched = Scheduler::new(SchedulerConfig {
            max_prefills_per_round: conc,
            kv_capacity_bytes: None,
            kv: KvConfig::default(),
        });
        let mut metrics = Metrics::default();
        for c in 0..conc {
            let prompt: Vec<i32> = (0..sp_len)
                .map(|i| ((i * 13 + 2 + 7 * c) % vocab) as i32)
                .collect();
            sched.submit(
                PlanKey::Packed {
                    bits: 8,
                    int8: false,
                },
                plan8.clone(),
                8,
                false,
                Request::generate(
                    c as u64,
                    prompt,
                    PrecisionReq::Bits(8),
                    sn_new,
                    Sampling::Greedy,
                ),
                Instant::now(),
            );
        }
        // One round admits every submission (the fairness cap is conc);
        // nothing below advances a stream, so the members stay live for
        // the whole measurement.
        sched.run_round(&mut metrics, &mut |_, _| true);
        assert_eq!(sched.live_sessions(), conc);
        let r = bench(&format!("elastic shift cycle c{conc}"), budget, || {
            std::hint::black_box(sched.shift_uniform(8, false, 4, plan4.clone()).moved());
            std::hint::black_box(sched.shift_up_natives(&mut |_, _| Some(plan8.clone())).moved());
        });
        let per_switch_us = r.mean_ns / (2.0 * conc as f64) / 1e3;
        println!("{} | {per_switch_us:.2} us per session-switch", r.report());
        json_shift.push(format!(
            "{{\"sessions\": {conc}, \"down_up_cycle_us\": {:.3}, \"per_session_switch_us\": {per_switch_us:.3}}}",
            r.mean_ns / 1e3
        ));
    }

    // Round throughput at each watermark state: the same concurrent step
    // round the scheduler runs native (int8), after one downshift (int4),
    // and at the ladder floor (int2).  Near-equal figures here are the
    // honest result — on the host a shift buys KV/queue headroom and
    // memory, not round speed.
    let plan2 =
        ForwardPlan::packed_uniform(&preset.model, &fwd_model, 2, false, None, None).unwrap();
    let states = [
        ("native     ", &plan8, 8u32),
        ("downshifted", &plan4, 4u32),
        ("floor      ", &plan2, 2u32),
    ];
    let conc = 8usize;
    let prompts: Vec<Vec<i32>> = (0..conc)
        .map(|c| {
            (0..sp_len)
                .map(|i| ((i * 13 + 2 + 7 * c) % vocab) as i32)
                .collect()
        })
        .collect();
    let specs: Vec<(&[i32], Sampling, usize)> = prompts
        .iter()
        .map(|p| (p.as_slice(), Sampling::Greedy, sn_new + 1))
        .collect();
    for (state, plan, bits) in states {
        let mut round_s = 0.0f64;
        for _ in 0..reps {
            let mut sessions = DecodeSession::prefill_many(plan, &specs).unwrap();
            let t0 = Instant::now();
            for _ in 0..sn_new {
                let tokens: Vec<i32> = sessions.iter_mut().map(|s| s.sample().0).collect();
                let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
                advance_sessions(&mut refs, &tokens).unwrap();
            }
            round_s += t0.elapsed().as_secs_f64();
            std::hint::black_box(&sessions);
        }
        let tps = (reps * conc * sn_new) as f64 / round_s;
        println!("watermark {state} @ int{bits}: c{conc} rounds {tps:.0} tok/s");
        json_rounds.push(format!(
            "{{\"state\": \"{}\", \"bits\": {bits}, \"sessions\": {conc}, \"tok_per_s\": {tps:.1}}}",
            state.trim_end()
        ));
    }

    // ---- self-speculative decode: int2 draft / int8 verify (ISSUE 7) ----
    // Plain vs speculative tokens/sec at k ∈ {2, 4, 8}, c ∈ {1, 4, 16},
    // plus the draft accept rate and tokens per round.  Greedy output is
    // bit-identical to plain decode by construction (the scheduler tests
    // prove it), so the only open question is throughput: on this host both
    // the draft and the verify stream the same shared master bytes, so the
    // win tracks (accept rate × window width) against the k−1 extra draft
    // passes — these rows quantify exactly where that trade lands.
    let mut json_spec: Vec<String> = Vec::new();
    for conc in [1usize, 4, 16] {
        let prompts: Vec<Vec<i32>> = (0..conc)
            .map(|c| {
                (0..sp_len)
                    .map(|i| ((i * 13 + 2 + 7 * c) % vocab) as i32)
                    .collect()
            })
            .collect();
        // Plain baseline: the scheduler's batched single-token step rounds
        // on the target (int8) plan.
        let plain_specs: Vec<(&[i32], Sampling, usize)> = prompts
            .iter()
            .map(|p| (p.as_slice(), Sampling::Greedy, sn_new + 1))
            .collect();
        let mut plain_s = 0.0f64;
        for _ in 0..reps {
            let mut sessions = DecodeSession::prefill_many(&plan8, &plain_specs).unwrap();
            let t0 = Instant::now();
            for _ in 0..sn_new {
                let tokens: Vec<i32> = sessions.iter_mut().map(|s| s.sample().0).collect();
                let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
                advance_sessions(&mut refs, &tokens).unwrap();
            }
            plain_s += t0.elapsed().as_secs_f64();
            std::hint::black_box(&sessions);
        }
        let plain_tps = (reps * conc * sn_new) as f64 / plain_s;
        for k in [2usize, 4, 8] {
            let spec_specs: Vec<(&[i32], Sampling, usize)> = prompts
                .iter()
                .map(|p| (p.as_slice(), Sampling::Greedy, sn_new + k + 1))
                .collect();
            let mut tok_total = 0usize;
            let mut drafted = 0u64;
            let mut accepted = 0u64;
            let mut rounds_n = 0u64;
            let mut spec_s = 0.0f64;
            for _ in 0..reps {
                let mut sessions = DecodeSession::prefill_many(&plan8, &spec_specs).unwrap();
                let mut last: Vec<i32> = sessions.iter_mut().map(|s| s.sample().0).collect();
                let mut emitted = vec![0usize; conc];
                let t0 = Instant::now();
                while emitted.iter().any(|&e| e < sn_new)
                    && sessions.iter().all(|s| s.spec_window() >= k)
                {
                    let rounds = {
                        let mut refs: Vec<&mut DecodeSession> =
                            sessions.iter_mut().collect();
                        speculative_round(&mut refs, &plan2, &last, k).unwrap()
                    };
                    for (i, r) in rounds.iter().enumerate() {
                        emitted[i] += r.emitted.len();
                        tok_total += r.emitted.len();
                        drafted += r.drafted as u64;
                        accepted += r.accepted as u64;
                        last[i] = r.emitted.last().unwrap().0;
                    }
                    rounds_n += 1;
                }
                spec_s += t0.elapsed().as_secs_f64();
                std::hint::black_box(&sessions);
            }
            let spec_tps = tok_total as f64 / spec_s;
            let acc = if drafted > 0 {
                accepted as f64 / drafted as f64
            } else {
                0.0
            };
            let tpr = tok_total as f64 / (rounds_n.max(1) * conc as u64) as f64;
            println!(
                "speculative c{conc:<2} k{k} int2-draft/int8-verify: plain {plain_tps:.0} tok/s | spec {spec_tps:.0} tok/s | {:.2}x | accept {acc:.2} | {tpr:.2} tok/round",
                spec_tps / plain_tps
            );
            json_spec.push(format!(
                "{{\"sessions\": {conc}, \"k\": {k}, \"plain_tok_per_s\": {plain_tps:.1}, \"spec_tok_per_s\": {spec_tps:.1}, \"accept_rate\": {acc:.3}, \"tokens_per_round\": {tpr:.3}}}"
            ));
        }
    }

    // ---- paged KV: concurrent streams at one fixed KV budget (ISSUE 8) ----
    // The tentpole's capacity claim, measured.  One budget — enough for
    // exactly 4 contiguous full-window reservations (the pre-paging
    // accounting: every stream holds seq_len f32 rows for its whole
    // life) — then the same budget under page-granular admission with f32
    // and int8 pages.  Paged admission projects ceil(capacity/page_size)
    // pages per layer for the request's *actual* window and defers on
    // actually-resident pool bytes, so shorter windows and denser rows
    // both turn straight into admitted streams; the peak-concurrency
    // figures come from the live scheduler, not the formula.
    let dims = &preset.model;
    let contig_per_stream =
        (dims.n_layers as u64) * 2 * (dims.seq_len as u64) * (dims.d_model as u64) * 4;
    let kv_budget = 4 * contig_per_stream;
    let mut json_kv: Vec<String> = Vec::new();
    json_kv.push(format!(
        "{{\"kv\": \"contiguous f32 (analytic)\", \"per_stream_bytes\": {contig_per_stream}, \"max_streams\": {}, \"peak_streams\": {}}}",
        kv_budget / contig_per_stream,
        kv_budget / contig_per_stream
    ));
    let n_req = 48usize;
    for (tag, kv) in [
        ("paged f32 ps=8 ", KvConfig::f32_paged(8)),
        ("paged int8 ps=8", KvConfig::int8(8)),
    ] {
        let per_stream = projected_kv_bytes(dims, sp_len, sn_new, 0, &kv);
        let mut sched = Scheduler::new(SchedulerConfig {
            max_prefills_per_round: n_req,
            kv_capacity_bytes: Some(kv_budget),
            kv,
        });
        let mut metrics = Metrics::default();
        for c in 0..n_req {
            let prompt: Vec<i32> = (0..sp_len)
                .map(|i| ((i * 13 + 2 + 7 * c) % vocab) as i32)
                .collect();
            sched.submit(
                PlanKey::Packed {
                    bits: 8,
                    int8: false,
                },
                plan8.clone(),
                8,
                false,
                Request::generate(
                    c as u64,
                    prompt,
                    PrecisionReq::Bits(8),
                    sn_new,
                    Sampling::Greedy,
                ),
                Instant::now(),
            );
        }
        let mut done = 0usize;
        let mut peak = 0usize;
        let mut rounds = 0u64;
        let t0 = Instant::now();
        while done < n_req {
            sched.run_round(&mut metrics, &mut |_, r| {
                if r.done {
                    done += 1;
                }
                true
            });
            peak = peak.max(sched.live_sessions());
            rounds += 1;
            assert!(rounds < 10_000, "scheduler failed to drain the kv bench");
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "kv budget {kv_budget}B (= {} contiguous streams): {tag} projects {per_stream}B/stream ({} by projection) | peak {peak} concurrent, {n_req} streams drained in {rounds} rounds / {ms:.1} ms | peak pool {}B",
            kv_budget / contig_per_stream,
            kv_budget / per_stream,
            sched.pool().peak_bytes()
        );
        json_kv.push(format!(
            "{{\"kv\": \"{}\", \"per_stream_bytes\": {per_stream}, \"max_streams\": {}, \"peak_streams\": {peak}}}",
            tag.trim_end(),
            kv_budget / per_stream
        ));
    }

    // ---- paged-attend decode step latency (ISSUE 8) ----
    // The attend walk now strides page segments instead of one contiguous
    // row block.  Page-size sweep at f32 (identical math, different walk
    // granularity) plus int8 pages (inline per-row dequant): steady-state
    // single-stream decode on the int8 weight plan, prompt 16 + 16 steps
    // (to capacity).
    let mut json_attend: Vec<String> = Vec::new();
    for (tag, kv) in [
        ("f32 ps=16 (default)", KvConfig::default()),
        ("f32 ps=4           ", KvConfig::f32_paged(4)),
        ("f32 ps=32          ", KvConfig::f32_paged(32)),
        ("int8 ps=16         ", KvConfig::int8(16)),
    ] {
        let pool = PagePool::unbounded(kv);
        let mut decode_s = 0.0f64;
        for _ in 0..reps {
            let mut sess = DecodeSession::with_budget_pooled(
                plan8.clone(),
                &gen_prompt,
                Sampling::Greedy,
                usize::MAX,
                Some(&pool),
            )
            .unwrap();
            let t1 = Instant::now();
            for _ in 0..n_new {
                let (tok, _) = sess.sample();
                sess.advance(tok).unwrap();
            }
            decode_s += t1.elapsed().as_secs_f64();
            std::hint::black_box(sess.logits());
        }
        let tps = (reps * n_new) as f64 / decode_s;
        let step_us = decode_s / (reps * n_new) as f64 * 1e6;
        println!("paged attend {tag} @ int8 weights: {tps:.0} tok/s | {step_us:.1} us/step");
        json_attend.push(format!(
            "{{\"kv\": \"{}\", \"decode_tok_per_s\": {tps:.1}, \"step_us\": {step_us:.2}}}",
            tag.trim_end()
        ));
    }

    // ---- scale-out front door: trace-driven loadgen (ISSUE 9) ----
    // The new subsystem measured end to end: a real TCP socket, N workers
    // (each its own Scheduler + ElasticPlanner) sharing one WeightStore and
    // one fleet-global PagePool budget, driven by the deterministic Poisson
    // trace with the 70/20/10 int8/int4/int2 mix.  Client-side TTFT
    // (send → first chunk) and inter-token gaps, p50/p99, tokens/sec, and
    // SLO attainment at 1/2/4 workers — plus the elastic on-vs-off pair
    // under the same stressed trace, with the fleet's shift counters, to
    // show what the watermark downshifts buy in attainment.
    #[cfg(unix)]
    let (json_front, json_front_elastic) = {
        use matquant::loadgen::{run_trace, MixEntry, TraceConfig};
        use matquant::serve::frontend::{HttpFrontend, PoolConfig, WorkerPool};
        use matquant::serve::{ElasticConfig, ServerConfig};

        let front_dims = || ModelDims {
            vocab: 256,
            d_model: 96,
            n_layers: 4,
            n_heads: 4,
            d_ff: 384,
            seq_len: 32,
            quantize_attn: false,
        };
        // All precisions packed (no warm dense plans): every class streams
        // the shared nested payload, and native int8 groups stay eligible
        // for elastic downshifts.
        let base_server = || ServerConfig {
            preset: "bench".into(),
            max_wait_ms: 0.5,
            warm_bits: Vec::new(),
            ..ServerConfig::default()
        };
        let trace = TraceConfig {
            seed: 13,
            requests: 36,
            arrival_rate: 150.0,
            prompt_len: (4, 8),
            max_new_tokens: (2, 6),
            vocab: front_dims().vocab,
            mix: vec![
                MixEntry::uniform(0.7, 8),
                MixEntry::uniform(0.2, 4),
                MixEntry::uniform(0.1, 2),
            ],
            ttft_slo_ms: 250.0,
            tpot_slo_ms: 50.0,
        };
        let run_fleet = |workers: usize, server: ServerConfig, trace: &TraceConfig| {
            let (p, m) = toy_transformer(front_dims(), 41);
            let pool = WorkerPool::start(p, m, PoolConfig { workers, server }).unwrap();
            let frontend = HttpFrontend::bind(pool, "127.0.0.1:0").unwrap();
            let report = run_trace(&frontend.addr().to_string(), trace).unwrap();
            let metrics = frontend.pool().fleet_metrics();
            frontend.shutdown().unwrap();
            (report, metrics)
        };

        let mut json_front: Vec<String> = Vec::new();
        for workers in [1usize, 2, 4] {
            let (report, _) = run_fleet(workers, base_server(), &trace);
            let o = &report.overall;
            println!(
                "frontdoor w{workers} mix 70/20/10: ttft p50/p99 {:.2}/{:.2} ms | tpot p50/p99 {:.2}/{:.2} ms | {:.1} tok/s | slo {:.1}% | errors {}",
                o.ttft_p50_ms,
                o.ttft_p99_ms,
                o.tpot_p50_ms,
                o.tpot_p99_ms,
                report.tokens_per_sec,
                o.slo_attainment * 100.0,
                report.errors
            );
            assert_eq!(report.errors, 0, "bench trace must complete cleanly");
            let per_mix: Vec<String> = report
                .per_mix
                .iter()
                .map(|r| r.to_json().to_string())
                .collect();
            json_front.push(format!(
                "{{\"workers\": {workers}, \"ttft_p50_ms\": {:.3}, \"ttft_p99_ms\": {:.3}, \"tpot_p50_ms\": {:.3}, \"tpot_p99_ms\": {:.3}, \"tok_per_s\": {:.1}, \"slo_attainment\": {:.3}, \"per_mix\": [{}]}}",
                o.ttft_p50_ms,
                o.ttft_p99_ms,
                o.tpot_p50_ms,
                o.tpot_p99_ms,
                report.tokens_per_sec,
                o.slo_attainment,
                per_mix.join(", ")
            ));
        }

        // Elastic on vs off at 2 workers under pressure: a tight KV budget
        // plus a faster trace so the watermarks actually trip, shifting the
        // busiest native-int8 group down the nested ladder.
        let stress = TraceConfig {
            requests: 48,
            arrival_rate: 400.0,
            ..trace
        };
        let per_stream = projected_kv_bytes(&front_dims(), 8, 6, 0, &KvConfig::default());
        let cap = per_stream * 3;
        let mut json_front_elastic: Vec<String> = Vec::new();
        for elastic_on in [false, true] {
            let mut server = base_server();
            server.kv_capacity_bytes = Some(cap);
            if elastic_on {
                server.elastic = Some(ElasticConfig {
                    kv_high_bytes: cap / 2,
                    kv_low_bytes: cap / 4,
                    queue_high: 2,
                    queue_low: 0,
                    cooldown_rounds: 2,
                    ..ElasticConfig::default()
                });
            }
            let (report, metrics) = run_fleet(2, server, &stress);
            let o = &report.overall;
            let tag = if elastic_on { "on" } else { "off" };
            println!(
                "frontdoor elastic {tag} w2 kv-cap {cap}B: shifts {}down/{}up ({} sessions moved) | ttft p50/p99 {:.2}/{:.2} ms | {:.1} tok/s | slo {:.1}% | errors {}",
                metrics.shifts_down(),
                metrics.shifts_up(),
                metrics.shift_moved(),
                o.ttft_p50_ms,
                o.ttft_p99_ms,
                report.tokens_per_sec,
                o.slo_attainment * 100.0,
                report.errors
            );
            json_front_elastic.push(format!(
                "{{\"elastic\": \"{tag}\", \"kv_capacity_bytes\": {cap}, \"shifts_down\": {}, \"shifts_up\": {}, \"sessions_moved\": {}, \"ttft_p50_ms\": {:.3}, \"ttft_p99_ms\": {:.3}, \"tok_per_s\": {:.1}, \"slo_attainment\": {:.3}, \"errors\": {}}}",
                metrics.shifts_down(),
                metrics.shifts_up(),
                metrics.shift_moved(),
                o.ttft_p50_ms,
                o.ttft_p99_ms,
                report.tokens_per_sec,
                o.slo_attainment,
                report.errors
            ));
        }
        (json_front, json_front_elastic)
    };
    #[cfg(not(unix))]
    let (json_front, json_front_elastic): (Vec<String>, Vec<String>) = (Vec::new(), Vec::new());

    // ---- MatGPTQ post-training solver (ISSUE 10) ----
    // The accuracy-frontier rows: calibrate Grams on teacher-sampled rows,
    // re-round under the Hessian-weighted nested-MSB objective, then score
    // minmax vs solver masters per rung on the distilled decode metric
    // (CE against the int8 teacher's own samples — entropy + KL, so the
    // comparison is ordered by weight fidelity) with measured effective
    // bits, plus the Eq. 8 outlier-budget sweep landing the ≈2.05-bit
    // point.
    let mut json_solver: Vec<String> = Vec::new();
    let mut json_outlier: Vec<String> = Vec::new();
    {
        use matquant::eval::{distill_decode_log_perplexity, sample_decode_rows};
        use matquant::quant::solver::{sweep_outlier_budgets, SolverConfig};

        let sdims = ModelDims {
            vocab: 256,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ff: 64,
            seq_len: 16,
            quantize_attn: false,
        };
        let (sp, smodel) = toy_transformer(sdims, 11);
        let kv = KvConfig::f32_paged(8);
        let teacher =
            ForwardPlan::packed_uniform(&sp.model, &smodel, 8, false, None, None).unwrap();
        let seed = 5u64;
        let t0 = Instant::now();
        let calib = sample_decode_rows(&teacher, kv, seed ^ 0xCA11B, 24).unwrap();
        let mut grams = std::collections::BTreeMap::new();
        for row in &calib {
            teacher
                .accumulate_grams(&row[..sdims.seq_len], 1, sdims.seq_len, &mut grams)
                .unwrap();
        }
        let calib_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let (refined, report) = smodel.solve_refined(&grams, &SolverConfig::default()).unwrap();
        let solve_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "matgptq solve: {} grams over {} rows in {calib_ms:.1} ms | {} tensors refined in {solve_ms:.1} ms",
            grams.len(),
            calib.len(),
            report.tensors.len()
        );
        let n_q = smodel.quantized_params().max(1) as f64;
        for bits in [2u32, 4, 8] {
            let m_plan =
                ForwardPlan::packed_uniform(&sp.model, &smodel, bits, false, None, None).unwrap();
            let s_plan =
                ForwardPlan::packed_uniform(&sp.model, &refined, bits, false, None, None).unwrap();
            let ce_m = distill_decode_log_perplexity(&teacher, &m_plan, kv, seed, 8).unwrap();
            let ce_s = distill_decode_log_perplexity(&teacher, &s_plan, kv, seed, 8).unwrap();
            let eb = smodel.storage_bytes(&PrecisionAssignment::uniform(bits)) as f64 * 8.0 / n_q;
            println!(
                "matgptq int{bits}: distilled decode log pplx minmax {ce_m:.4} -> solver {ce_s:.4} | weighted rel err {:.5} -> {:.5} | {eb:.3} eff bits/w",
                report.mean_base_rel(bits),
                report.mean_solved_rel(bits)
            );
            json_solver.push(format!(
                "{{\"bits\": {bits}, \"minmax_log_pplx\": {ce_m:.5}, \"solver_log_pplx\": {ce_s:.5}, \"minmax_rel_err\": {:.6}, \"solver_rel_err\": {:.6}, \"eff_bits_per_weight\": {eb:.4}}}",
                report.mean_base_rel(bits),
                report.mean_solved_rel(bits)
            ));
        }
        let pts =
            sweep_outlier_budgets(&refined, &grams, 2, &[0.0, 0.02, 0.05, 0.1, 0.25]).unwrap();
        for p in &pts {
            println!(
                "matgptq outlier sweep @ int2: budget {:.3} -> {:.3} eff bits, rel err {:.5}, {} overlays",
                p.budget,
                p.effective_bits,
                p.rel_err,
                p.enabled.len()
            );
            json_outlier.push(format!(
                "{{\"budget\": {:.4}, \"effective_bits\": {:.4}, \"rel_err\": {:.6}, \"tensors_with_overlay\": {}}}",
                p.budget,
                p.effective_bits,
                p.rel_err,
                p.enabled.len()
            ));
        }
    }

    // Hand-rolled JSON (the build is offline — no serde); the Makefile
    // `bench-json` target and the CI smoke step point MQ_BENCH_OUT at
    // BENCH_10.json in the repo root.
    if let Ok(path) = std::env::var("MQ_BENCH_OUT") {
        let json = format!(
            "{{\n  \"pr\": 10,\n  \"bench\": \"quant_hot_paths\",\n  \"model\": \"toy tiny-shaped (vocab 256, d_model 96, 4 layers, d_ff 384); solver rows on vocab 256, d_model 32, 2 layers, d_ff 64\",\n  \"page_in_per_precision\": [\n    {}\n  ],\n  \"elastic_shift_latency\": [\n    {}\n  ],\n  \"round_throughput_per_watermark_state\": [\n    {}\n  ],\n  \"speculative_decode\": [\n    {}\n  ],\n  \"kv_concurrency_at_fixed_budget\": [\n    {}\n  ],\n  \"paged_attend_step_latency\": [\n    {}\n  ],\n  \"frontdoor_loadgen\": [\n    {}\n  ],\n  \"frontdoor_elastic_on_vs_off\": [\n    {}\n  ],\n  \"matgptq_minmax_vs_solver_per_rung\": [\n    {}\n  ],\n  \"matgptq_outlier_budget_sweep_int2\": [\n    {}\n  ]\n}}\n",
            json_page_in.join(",\n    "),
            json_shift.join(",\n    "),
            json_rounds.join(",\n    "),
            json_spec.join(",\n    "),
            json_kv.join(",\n    "),
            json_attend.join(",\n    "),
            json_front.join(",\n    "),
            json_front_elastic.join(",\n    "),
            json_solver.join(",\n    "),
            json_outlier.join(",\n    ")
        );
        std::fs::write(&path, &json).unwrap_or_else(|e| panic!("write bench json to {path}: {e}"));
        println!("bench rows persisted to {path}");
    }
}
