//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build is fully offline (see `util::mod` in the main crate), so this
//! crate re-implements exactly the surface `matquant` uses: [`Error`] with a
//! context chain, the [`Context`] extension trait for `Result`/`Option`, the
//! [`Result`] alias, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream `anyhow` where it matters:
//! * `{}` displays the outermost message, `{:#}` the full `a: b: c` chain,
//!   and `{:?}` a multi-line report with a `Caused by:` section.
//! * `Error` converts from any `std::error::Error + Send + Sync + 'static`
//!   (capturing its `source()` chain) and deliberately does **not** implement
//!   `std::error::Error` itself, so the blanket `From` stays coherent.

use std::fmt;

/// Error type: an ordered context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (upstream: `chain()`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Drop-in `anyhow::Result` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily-built context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().wrap(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().wrap(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err()).context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_build_errors() {
        fn inner(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(inner(5).unwrap(), 5);
        assert_eq!(format!("{:#}", inner(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", inner(11).unwrap_err()), "too big");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.root_cause(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn nested_context_orders_outermost_first() {
        let e: Error = Err::<(), _>(io_err())
            .context("inner")
            .context("outer")
            .unwrap_err();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "inner", "gone"]);
    }
}
