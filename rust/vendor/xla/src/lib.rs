//! Pure-Rust stub of the `xla-rs` PJRT bindings used by `matquant`.
//!
//! The real `xla` crate links the native `xla_extension` C++ runtime, which
//! cannot be fetched or built in this offline environment.  This stub keeps
//! the whole crate compiling and testable by providing the exact API surface
//! the runtime layer uses:
//!
//! * [`Literal`] is fully functional host storage (f32 / i32 arrays with a
//!   shape, plus tuples), so literal construction and conversion code paths
//!   are real.
//! * [`PjRtClient::cpu`] returns an error: there is no PJRT runtime here.
//!   Everything gated on `make artifacts` (which needs the real runtime)
//!   reports a clean skip/error instead of failing to link.
//!
//! Swapping the real bindings back in is a one-line `Cargo.toml` change; no
//! source edits are required because the signatures match `xla-rs`.

use std::fmt;

/// Stub error type; carries a plain message.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what} is unavailable: matquant was built against the vendored pure-Rust \
             `xla` stub (no PJRT runtime); see rust/vendor/xla"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone, PartialEq)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(v) => v.len(),
        }
    }
}

/// Host tensor literal: a shape plus typed storage.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    storage: Storage,
}

/// Array shape accessor, mirroring `xla-rs`.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Element types the stub can store (f32 and i32 are all matquant uses).
pub trait NativeType: Copy {
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal {
        Literal {
            dims,
            storage: Storage::F32(data),
        }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.storage {
            Storage::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal {
        Literal {
            dims,
            storage: Storage::I32(data),
        }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.storage {
            Storage::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        T::wrap(Vec::new(), vec![v])
    }

    /// Rank-1 literal.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::wrap(vec![v.len() as i64], v.to_vec())
    }

    /// Build a tuple literal (used by tests of the stub itself).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![parts.len() as i64],
            storage: Storage::Tuple(parts),
        }
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.storage.len() as i64;
        if matches!(self.storage, Storage::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        if want != have {
            return Err(Error(format!(
                "reshape {dims:?} wants {want} elements, literal has {have}"
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            storage: self.storage.clone(),
        })
    }

    /// Shape of an array literal; errors for tuples.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.storage {
            Storage::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
            _ => Ok(ArrayShape {
                dims: self.dims.clone(),
            }),
        }
    }

    /// Copy the elements out as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Decompose a tuple literal; errors for arrays.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.storage {
            Storage::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Borrow-or-owned literal arguments for `execute`, like `xla-rs`.
pub trait BorrowLiteral {
    fn borrow_literal(&self) -> &Literal;
}

impl BorrowLiteral for Literal {
    fn borrow_literal(&self) -> &Literal {
        self
    }
}

impl<'a> BorrowLiteral for &'a Literal {
    fn borrow_literal(&self) -> &Literal {
        self
    }
}

/// Device handle (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtDevice(());

/// Device buffer: in the stub, a host literal copy.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Parsed HLO module (opaque; parsing is not supported by the stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HLO text parsing"))
    }
}

/// Computation wrapper.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Compiled executable (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: BorrowLiteral>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PJRT execution"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PJRT execution"))
    }
}

/// PJRT client. `cpu()` fails fast in the stub so callers surface one clear
/// message instead of a late link/execution error.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("the PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("XLA compilation"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let shaped = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(shaped.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(shaped.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(shaped.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_has_empty_shape() {
        let lit = Literal::scalar(7i32);
        assert!(lit.array_shape().unwrap().dims().is_empty());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn bad_reshape_errors() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(lit.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn tuple_literals() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2.0f32)]);
        assert!(t.array_shape().is_err());
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        assert!(Literal::scalar(1.0f32).to_tuple().is_err());
    }

    #[test]
    fn client_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }
}
