//! End-to-end PJRT smoke: load the init artifact, run it, check shapes.
//! Requires `make artifacts` (reports `skipped:` otherwise).

mod common;

use matquant::runtime::{lit_scalar_i32, Engine};

#[test]
fn init_artifact_runs_and_is_deterministic() {
    let Some(dir) = common::artifact_or_skip("runtime_smoke", "manifest.json") else {
        return;
    };
    let engine = Engine::new(&dir).unwrap();
    let preset = engine.manifest().preset("tiny").unwrap().clone();
    let out = engine.run("tiny", "init", &[lit_scalar_i32(7)]).unwrap();
    assert_eq!(out.len(), preset.params.len());
    for (t, (name, shape)) in out.iter().zip(&preset.params) {
        assert_eq!(&t.shape, shape, "shape mismatch for {name}");
        assert!(t.data.iter().all(|x| x.is_finite()), "{name} not finite");
    }
    // determinism
    let out2 = engine.run("tiny", "init", &[lit_scalar_i32(7)]).unwrap();
    assert_eq!(out[2].data, out2[2].data);
    // different seed differs
    let out3 = engine.run("tiny", "init", &[lit_scalar_i32(8)]).unwrap();
    assert_ne!(out[0].data, out3[0].data);
}
