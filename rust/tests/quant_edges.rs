//! Quant-algebra edge cases the seed left uncovered: empty tensors,
//! constant columns on the EPS guard, and overlay roundtrips at every
//! supported width.  Runs unconditionally — no artifacts required.

use matquant::quant::{
    self, dequantize, minmax_scales, omni_scales, quantize, ExtraBitOverlay, PackedTensor, EPS,
};

#[test]
fn empty_packed_tensor_is_well_defined() {
    for bits in [1u32, 2, 3, 4, 6, 8] {
        let p = PackedTensor::pack(&[], bits);
        assert_eq!(p.len, 0);
        assert_eq!(p.bytes(), 0);
        // was a 0/0 division before the bits_per_entry guard
        assert_eq!(p.bits_per_entry(), 0.0, "bits={bits}");
        assert!(p.unpack().is_empty());
    }
}

#[test]
fn empty_slicing_and_effective_bits() {
    let empty: Vec<f32> = Vec::new();
    for r in [2u32, 4, 8] {
        assert!(quant::slice_codes(&empty, 8, r, false).is_empty());
        assert_eq!(quant::effective_bits(&empty, 8, r), r as f64);
        assert_eq!(quant::overflow_fraction(&empty, 8, r), 0.0);
    }
    let (ov, dense) = ExtraBitOverlay::split(&empty, 2);
    assert!(ov.is_empty());
    assert!(dense.is_empty());
    assert_eq!(ov.bytes(0), 0);
}

#[test]
fn constant_columns_hit_eps_guard() {
    // Every column constant (one positive, one zero, one negative): the
    // range collapses and alpha must pin at EPS, never zero or negative.
    let d_in = 6;
    let d_out = 3;
    let mut w = Vec::with_capacity(d_in * d_out);
    for _ in 0..d_in {
        w.extend_from_slice(&[0.75, 0.0, -1.25]);
    }
    for bits in [2u32, 4, 8] {
        let s = minmax_scales(&w, d_in, d_out, bits);
        for j in 0..d_out {
            assert_eq!(s.alpha[j], EPS, "bits={bits} j={j}");
            assert!(s.zero[j].is_finite());
        }
        let q = quantize(&w, d_out, &s);
        assert!(q.iter().all(|c| c.is_finite() && *c >= 0.0));
        let wq = dequantize(&q, d_out, &s);
        assert!(wq.iter().all(|x| x.is_finite()), "bits={bits}");
    }
}

#[test]
fn omni_clipping_to_zero_range_hits_eps_guard() {
    // gamma = beta = 0 collapses the clipped range to zero width even for a
    // non-constant column; the guard must still hold.
    let w: Vec<f32> = (0..16).map(|i| i as f32 / 15.0 - 0.5).collect();
    let zeros = vec![0.0f32];
    let s = omni_scales(&w, 16, 1, 4, Some(&zeros), Some(&zeros));
    assert_eq!(s.alpha[0], EPS);
    assert_eq!(s.zero[0], 0.0);
    let q = quantize(&w, 1, &s);
    assert!(q.iter().all(|c| c.is_finite()));
}

#[test]
fn overlay_split_apply_roundtrip_every_width() {
    for r in [1u32, 2, 3, 4, 6, 7] {
        let top = (1u32 << r) as f32;
        // mix of in-range ids and overflow, including consecutive overflow
        // and overflow at both ends
        let n = 50;
        let ids: Vec<f32> = (0..n)
            .map(|i| {
                if i == 0 || i == n - 1 || i % 7 == 3 || i % 7 == 4 {
                    top
                } else {
                    ((i as u32 * 5 + 1) % (1 << r)) as f32
                }
            })
            .collect();
        let (ov, dense) = ExtraBitOverlay::split(&ids, r);
        assert!(!ov.is_empty());
        assert!(ov.indices.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
        assert!(dense.iter().all(|&d| d < top), "dense ids clamped below top");
        let p = PackedTensor::pack(&dense, r);
        let mut back = p.unpack();
        ov.apply(&mut back, r);
        assert_eq!(back, ids, "r={r}");
    }
}

#[test]
fn overlay_storage_prefers_smaller_encoding() {
    // sparse list (4 bytes/entry) vs bitmap (n/8): crossover at n/32 entries
    let n = 320;
    let few: ExtraBitOverlay = ExtraBitOverlay {
        indices: (0..5).collect(),
    };
    assert_eq!(few.bytes(n), 20); // 5*4 < 320/8
    let many: ExtraBitOverlay = ExtraBitOverlay {
        indices: (0..100).collect(),
    };
    assert_eq!(many.bytes(n), 40); // bitmap wins
}

#[test]
fn pack_rejects_nothing_in_range_and_roundtrips_extremes() {
    for bits in [1u32, 2, 3, 4, 6, 8] {
        let top = (1u32 << bits) as f32 - 1.0;
        let ids = vec![0.0, top, 0.0, top, top];
        let p = PackedTensor::pack(&ids, bits);
        assert_eq!(p.unpack(), ids, "bits={bits}");
    }
}
