//! Shared helpers for artifact-gated integration tests.
//!
//! Tests that need `make artifacts` output call [`artifact_or_skip`] instead
//! of hand-rolling `eprintln!` early-returns, so every skip is reported in
//! one grep-able format: `skipped: <test>: missing artifacts/<file> ...`.

use std::path::PathBuf;

/// The crate's artifacts directory (`rust/artifacts`).
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Returns the artifacts directory if `artifacts/<gate_file>` exists;
/// otherwise reports a uniform skip line and returns `None` so the caller
/// can early-return.
pub fn artifact_or_skip(test: &str, gate_file: &str) -> Option<PathBuf> {
    let dir = artifacts_dir();
    if dir.join(gate_file).exists() {
        Some(dir)
    } else {
        eprintln!("skipped: {test}: missing artifacts/{gate_file} (run `make artifacts`)");
        None
    }
}
