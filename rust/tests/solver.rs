//! MatGPTQ solver integration — `cargo test --test solver`, artifact-free.
//!
//! Covers the PR-10 pipeline end to end on toy transformers:
//! Gram capture through the forward plan, Hessian-weighted nested-MSB
//! re-rounding ([`matquant::model::QuantizedModel::solve_refined`]),
//! bit-exact serving of the refined payload at every rung, the Eq. 8
//! outlier-budget sweep's servable points, Mix'n'Match driven by solver
//! residuals — and the acceptance comparison: solver int2 beats minmax
//! int2 on the distilled decode-path perplexity
//! ([`matquant::eval::distill_decode_log_perplexity`]), with calibration
//! rows sampled from the same int8 teacher the students are scored
//! against (the GPTQ protocol: calibration and eval share a
//! distribution).

use std::collections::BTreeMap;

use matquant::eval::{
    decode_log_perplexity, distill_decode_log_perplexity, sample_decode_rows, HostEvaluator,
};
use matquant::mixnmatch::{solver_sensitivity, suggest_assignment};
use matquant::model::manifest::ModelDims;
use matquant::model::testing::toy_transformer;
use matquant::model::QuantizedModel;
use matquant::quant::solver::{
    packed_views_with_outliers, sweep_outlier_budgets, Gram, RungWeights, SolverConfig,
    SolverReport,
};
use matquant::runtime::{arc_packed, plan_params, ForwardPlan, KvConfig};

fn solver_dims(quantize_attn: bool) -> ModelDims {
    ModelDims {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 16,
        quantize_attn,
    }
}

/// Calibrate per-linear Grams on rows sampled from the int8 teacher plan
/// — the distribution [`distill_decode_log_perplexity`] scores against.
fn teacher_grams(
    teacher: &std::sync::Arc<ForwardPlan>,
    kv: KvConfig,
    seed: u64,
    n_rows: usize,
) -> BTreeMap<String, Gram> {
    let t = teacher.dims.seq_len;
    let rows = sample_decode_rows(teacher, kv, seed, n_rows).unwrap();
    let mut grams = BTreeMap::new();
    for row in &rows {
        teacher
            .accumulate_grams(&row[..t], 1, t, &mut grams)
            .unwrap();
    }
    grams
}

fn refine(
    model: &QuantizedModel,
    grams: &BTreeMap<String, Gram>,
) -> (QuantizedModel, SolverReport) {
    model.solve_refined(grams, &SolverConfig::default()).unwrap()
}

#[test]
fn gram_capture_covers_every_packed_linear() {
    let dims = solver_dims(true);
    let (preset, model) = toy_transformer(dims, 3);
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let kv = KvConfig::f32_paged(8);
    let n_rows = 4;
    let grams = teacher_grams(&plan, kv, 17, n_rows);
    // one Gram per quantized tensor (= per packed linear in the plan),
    // under its manifest name, at its fan-in, with every row counted
    assert_eq!(
        grams.keys().cloned().collect::<std::collections::BTreeSet<_>>(),
        model.quantized_order.iter().cloned().collect(),
        "gram capture must cover exactly the packed linears"
    );
    for (qn, g) in &grams {
        let qt = &model.quantized[qn];
        assert_eq!(g.dim(), qt.d_in, "{qn}: gram at the wrong fan-in");
        assert_eq!(
            g.rows,
            n_rows * dims.seq_len,
            "{qn}: every calibration row must be pooled"
        );
        // H = ΣXᵀX is symmetric PSD: nonnegative diagonal, finite entries
        let h = g.entries();
        assert!(h.iter().all(|v| v.is_finite()), "{qn}: non-finite gram");
        for i in 0..g.dim() {
            assert!(h[i * g.dim() + i] >= 0.0, "{qn}: negative diagonal");
        }
    }
    // pooling more batches only adds rows — never resets
    let more = teacher_grams(&plan, kv, 17, 2 * n_rows);
    for (qn, g) in &more {
        assert_eq!(g.rows, 2 * n_rows * dims.seq_len, "{qn}");
    }
}

#[test]
fn single_rung_identity_solve_is_bit_exact_minmax() {
    // With no Grams (identity factor, no feedback) and a single-rung int8
    // objective, the LUT argmin degenerates to nearest-int8 rounding — the
    // refined masters must equal the minmax masters bit for bit.
    let (_, model) = toy_transformer(solver_dims(true), 5);
    let cfg = SolverConfig {
        rung_weights: RungWeights::single(8),
        damp_frac: 0.01,
    };
    let (refined, report) = model.solve_refined(&BTreeMap::new(), &cfg).unwrap();
    assert_eq!(report.tensors.len(), model.quantized_order.len());
    for t in &report.tensors {
        assert!(t.fallback, "{}: no gram → identity fallback", t.name);
    }
    for qn in &model.quantized_order {
        assert_eq!(
            model.quantized[qn].codes.unpack(),
            refined.quantized[qn].codes.unpack(),
            "{qn}: degenerate solve must be bit-exact minmax"
        );
    }
}

#[test]
fn refined_model_serves_bit_exactly_at_every_rung() {
    // The refined registry is only a better int8 master: the packed
    // serving path must decode it bit-identically to the dense
    // `materialize` reference at every rung ± Eq. 8, and the decode path
    // must reproduce the forward path on f32 pages.
    let dims = solver_dims(false);
    let (preset, model) = toy_transformer(dims, 7);
    let teacher = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let kv = KvConfig::f32_paged(8);
    let grams = teacher_grams(&teacher, kv, 23, 8);
    let (refined, _) = refine(&model, &grams);
    for &bits in &[2u32, 4, 8] {
        for &ep in &[false, true] {
            let packed =
                ForwardPlan::packed_uniform(&preset.model, &refined, bits, ep, None, None).unwrap();
            let dense = ForwardPlan::dense_uniform(&preset.model, &refined, bits, ep).unwrap();
            let a = HostEvaluator::new(packed.clone(), 2)
                .unwrap()
                .log_perplexity(11, 12, 1)
                .unwrap();
            let b = HostEvaluator::new(dense, 2)
                .unwrap()
                .log_perplexity(11, 12, 1)
                .unwrap();
            assert!(a.is_finite() && a > 0.0, "int{bits} ep={ep}: pplx {a}");
            assert!(
                (a - b).abs() < 0.05,
                "int{bits} ep={ep}: packed {a} vs dense {b}"
            );
            let fwd = HostEvaluator::new(packed.clone(), 1)
                .unwrap()
                .log_perplexity(11, 12, 2)
                .unwrap();
            let paged = decode_log_perplexity(packed, kv, 11, 12, 2).unwrap();
            assert_eq!(
                fwd,
                paged,
                "int{bits} ep={ep}: decode path must match forward bit for bit"
            );
        }
    }
}

#[test]
fn solver_int2_beats_minmax_int2_on_distilled_decode_perplexity() {
    // The PR-10 acceptance comparison.  Per seeded configuration: build a
    // toy transformer, calibrate Grams on rows sampled from its int8
    // teacher, refine, then score minmax-int2 vs solver-int2 students on
    // fresh teacher-sampled rows through the decode path.  The solver must
    // (a) cut the Hessian-weighted rung-2 residual on every configuration
    // and (b) win the decode perplexity comparison in aggregate.
    let dims = solver_dims(false);
    let kv = KvConfig::f32_paged(8);
    let mut delta_sum = 0.0f64;
    let mut base_sum = 0.0f64;
    for (model_seed, sample_seed) in [(11u64, 5u64), (12, 6), (13, 7)] {
        let (preset, model) = toy_transformer(dims, model_seed);
        let teacher =
            ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
        let grams = teacher_grams(&teacher, kv, sample_seed ^ 0xCA11B, 24);
        let (refined, report) = refine(&model, &grams);
        for t in &report.tensors {
            assert!(!t.fallback, "{}: calibrated gram must factorize", t.name);
        }
        assert!(
            report.mean_solved_rel(2) < report.mean_base_rel(2),
            "seed {model_seed}: rung-2 weighted residual must improve: {} vs {}",
            report.mean_solved_rel(2),
            report.mean_base_rel(2)
        );
        let minmax2 =
            ForwardPlan::packed_uniform(&preset.model, &model, 2, false, None, None).unwrap();
        let solver2 =
            ForwardPlan::packed_uniform(&preset.model, &refined, 2, false, None, None).unwrap();
        let ce_minmax =
            distill_decode_log_perplexity(&teacher, &minmax2, kv, sample_seed, 8).unwrap();
        let ce_solver =
            distill_decode_log_perplexity(&teacher, &solver2, kv, sample_seed, 8).unwrap();
        assert!(ce_minmax.is_finite() && ce_solver.is_finite());
        delta_sum += ce_minmax - ce_solver;
        base_sum += ce_minmax;
    }
    assert!(
        delta_sum > 0.0,
        "solver int2 must beat minmax int2 on distilled decode perplexity \
         (aggregate over 3 seeded configs): Δ = {delta_sum:.5} nats, minmax Σ = {base_sum:.5}"
    );
}

#[test]
fn outlier_sweep_points_are_servable_end_to_end() {
    let dims = solver_dims(false);
    let (preset, model) = toy_transformer(dims, 9);
    let teacher = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let kv = KvConfig::f32_paged(8);
    let grams = teacher_grams(&teacher, kv, 29, 8);
    let (refined, _) = refine(&model, &grams);
    let budgets = [0.0, 0.05, 0.25];
    let pts = sweep_outlier_budgets(&refined, &grams, 2, &budgets).unwrap();
    assert_eq!(pts.len(), budgets.len());
    for w in pts.windows(2) {
        assert!(w[1].rel_err <= w[0].rel_err + 1e-12, "budget must not hurt");
    }
    // every sweep point serves through the ordinary packed plan path
    for p in &pts {
        let views = packed_views_with_outliers(&refined, 2, &p.enabled).unwrap();
        let plan = std::sync::Arc::new(
            ForwardPlan::from_packed(
                &preset.model,
                &refined,
                &plan_params(&refined),
                &arc_packed(views),
                None,
                None,
            )
            .unwrap(),
        );
        let pplx = HostEvaluator::new(plan, 2)
            .unwrap()
            .log_perplexity(11, 12, 1)
            .unwrap();
        assert!(
            pplx.is_finite() && pplx > 0.0,
            "budget {}: pplx {pplx}",
            p.budget
        );
        assert!(
            p.effective_bits >= 2.0 && p.effective_bits < 2.0 + p.budget + 1e-9,
            "budget {}: effective bits {}",
            p.budget,
            p.effective_bits
        );
    }
}

#[test]
fn solver_residuals_drive_mixnmatch_assignment() {
    let dims = solver_dims(false);
    let (preset, model) = toy_transformer(dims, 13);
    let teacher = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let grams = teacher_grams(&teacher, KvConfig::f32_paged(8), 31, 8);
    let (_, report) = refine(&model, &grams);
    let rows = solver_sensitivity(&report);
    assert_eq!(rows.len(), report.tensors.len());
    let assign = suggest_assignment(&rows, dims.n_layers, 5.0);
    assert_eq!(assign.len(), dims.n_layers);
    assert!(assign.iter().all(|&b| (1..=8).contains(&b)));
}
