//! Cross-layer consistency: the Rust quant algebra must reproduce the L1
//! oracle outputs bit-for-bit.  This is the contract that lets Rust own
//! serving-time slicing/dequantization.
//!
//! Two golden sources share one checker:
//! * `tests/fixtures/goldens_small.json` — a small fixture generated once
//!   from `python/compile/kernels/ref.py` semantics (see
//!   `python/tools/gen_goldens_small.py`) and checked in, so this test runs
//!   **unconditionally** on every `cargo test`.
//! * `artifacts/goldens.json` — the full `make artifacts` sweep, when
//!   present.

mod common;

use matquant::quant;
use matquant::util::Json;

fn check_cases(g: &Json) {
    let cases = g.get("cases").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty(), "golden file has no cases");
    for case in cases {
        let w = case.get("w").unwrap().as_f32_vec().unwrap();
        let d_in = case.get("d_in").unwrap().as_usize().unwrap();
        let d_out = case.get("d_out").unwrap().as_usize().unwrap();
        let alpha8 = case.get("alpha8").unwrap().as_f32_vec().unwrap();
        let zero8 = case.get("zero8").unwrap().as_f32_vec().unwrap();
        let q8 = case.get("q8").unwrap().as_f32_vec().unwrap();

        // 8-bit master scales + codes
        let scales = quant::minmax_scales(&w, d_in, d_out, 8);
        for j in 0..d_out {
            assert!(
                (scales.alpha[j] - alpha8[j]).abs() <= 1e-6 * alpha8[j].abs().max(1e-3),
                "alpha[{j}]: {} vs {}",
                scales.alpha[j],
                alpha8[j]
            );
            assert!(
                (scales.zero[j] - zero8[j]).abs() <= 1e-4 * zero8[j].abs().max(1.0),
                "zero[{j}]: {} vs {}",
                scales.zero[j],
                zero8[j]
            );
        }
        let codes = quant::quantize(&w, d_out, &scales);
        let mismatches = codes.iter().zip(&q8).filter(|(a, b)| a != b).count();
        // codes are integers; tiny fp differences can flip a boundary code,
        // but the overwhelming majority must agree exactly
        assert!(
            mismatches * 1000 <= codes.len(),
            "{mismatches}/{} int8 code mismatches",
            codes.len()
        );

        for (bits_key, rec) in case.get("bits").unwrap().as_obj().unwrap() {
            let r: u32 = bits_key.parse().unwrap();
            let sliced = rec.get("sliced").unwrap().as_f32_vec().unwrap();
            let sliced_ep = rec.get("sliced_ep").unwrap().as_f32_vec().unwrap();
            let dequant = rec.get("dequant").unwrap().as_f32_vec().unwrap();
            let eb = rec.get("effective_bits").unwrap().as_f64().unwrap();

            // slicing operates on the *python* q8 codes (exact integers) so
            // this comparison is exact by construction
            let got = quant::slice_codes(&q8, 8, r, false);
            assert_eq!(got, sliced, "sliced r={r}");
            let got_ep = quant::slice_codes(&q8, 8, r, true);
            assert_eq!(got_ep, sliced_ep, "sliced_ep r={r}");

            let s8 = quant::Scales {
                bits: 8,
                alpha: alpha8.clone(),
                zero: zero8.clone(),
            };
            let deq = quant::dequantize(&got, d_out, &s8);
            for (i, (a, b)) in deq.iter().zip(&dequant).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-5 * b.abs().max(1e-2),
                    "dequant r={r} i={i}: {a} vs {b}"
                );
            }

            // the fused serving kernel must land on the same goldens
            let packed = quant::PackedTensor::pack(&q8, 8);
            let fused = matquant::kernels::slice_dequant(&packed, r, false, &s8, d_out);
            for (i, (a, b)) in fused.iter().zip(&deq).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "fused dequant r={r} i={i}");
            }

            // fused dequant×matmul against the L1 quantized_matmul golden
            // (key present only in the extended fixture)
            if let (Some(xj), Some(mv), Some(mv_ep)) =
                (case.opt("x"), rec.opt("matvec"), rec.opt("matvec_ep"))
            {
                let x = xj.as_f32_vec().unwrap();
                let want = mv.as_f32_vec().unwrap();
                let want_ep = mv_ep.as_f32_vec().unwrap();
                let step = (1u32 << (8 - r)) as f32;

                // Eq. 6 payload: sliced bucket ids packed at r bits
                let ids: Vec<f32> = q8
                    .iter()
                    .map(|&q| quant::slice_code(q, 8, r, false) / step)
                    .collect();
                let packed_r = quant::PackedTensor::pack(&ids, r);
                let got =
                    matquant::kernels::matvec_packed(&packed_r, None, &s8, 8, d_out, &x, None);

                // Eq. 8 payload: dense + overflow overlay
                let ids_ep: Vec<f32> = q8
                    .iter()
                    .map(|&q| quant::slice_code(q, 8, r, true) / step)
                    .collect();
                let (overlay, dense) = quant::ExtraBitOverlay::split(&ids_ep, r);
                let packed_ep = quant::PackedTensor::pack(&dense, r);
                let ov = if overlay.is_empty() {
                    None
                } else {
                    Some(&overlay)
                };
                let got_ep =
                    matquant::kernels::matvec_packed(&packed_ep, ov, &s8, 8, d_out, &x, None);

                // tolerance scaled by the accumulation magnitude (jnp's dot
                // and the fused hoisted-affine sum order their f32 ops
                // differently); `deq` holds the sliced-dequantized weights
                let check = |got: &[f32], want: &[f32], w: &[f32], label: &str| {
                    for j in 0..d_out {
                        let mut mag = 0.0f32;
                        for (i, &xv) in x.iter().enumerate() {
                            mag += (xv * w[i * d_out + j]).abs();
                        }
                        mag += zero8[j].abs() * alpha8[j].abs()
                            * x.iter().map(|v| v.abs()).sum::<f32>();
                        let tol = 1e-5 * mag + 1e-6;
                        assert!(
                            (got[j] - want[j]).abs() <= tol,
                            "{label} r={r} j={j}: {} vs {} (tol {tol})",
                            got[j],
                            want[j]
                        );
                    }
                };
                check(&got, &want, &deq, "matvec");
                let deq_ep = quant::dequantize(&quant::slice_codes(&q8, 8, r, true), d_out, &s8);
                check(&got_ep, &want_ep, &deq_ep, "matvec_ep");
            }

            let got_eb = quant::effective_bits(&q8, 8, r);
            assert!((got_eb - eb).abs() < 1e-9, "effective_bits r={r}");

            // direct per-bit baseline quantization
            let da = rec.get("direct_alpha").unwrap().as_f32_vec().unwrap();
            let dq = rec.get("direct_q").unwrap().as_f32_vec().unwrap();
            let ds = quant::minmax_scales(&w, d_in, d_out, r);
            for j in 0..d_out {
                assert!(
                    (ds.alpha[j] - da[j]).abs() <= 1e-6 * da[j].abs().max(1e-3),
                    "direct alpha r={r} j={j}"
                );
            }
            let dcodes = quant::quantize(&w, d_out, &ds);
            let dm = dcodes.iter().zip(&dq).filter(|(a, b)| a != b).count();
            assert!(dm * 1000 <= dcodes.len(), "direct codes r={r}: {dm} mismatches");
        }
    }
}

#[test]
fn rust_quant_matches_checked_in_fixture() {
    let g = Json::parse(include_str!("fixtures/goldens_small.json")).expect("fixture parses");
    check_cases(&g);
}

#[test]
fn rust_quant_matches_python_oracles() {
    let Some(dir) = common::artifact_or_skip("goldens", "goldens.json") else {
        return;
    };
    let text = std::fs::read_to_string(dir.join("goldens.json")).unwrap();
    check_cases(&Json::parse(&text).expect("goldens.json parses"));
}
