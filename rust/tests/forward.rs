//! Host-forward conformance — the end-to-end counterpart of
//! `kernel_conformance`: the packed host forward pass must match the dense
//! f32 reference forward (same quantized weights, decoded bit-for-bit;
//! only the matmul evaluation order differs) at every served bit-width,
//! and the serving worker must answer whole requests through it without
//! PJRT or artifacts.
//!
//! Everything here runs unconditionally — no `make artifacts` gate: the
//! whole point of the host path is that it needs none.

use matquant::model::manifest::ModelDims;
use matquant::model::testing::{toy_transformer, toy_transformer_params, toy_transformer_preset};
use matquant::model::{PrecisionAssignment, PresetInfo, QuantizedModel, Tensor};
use matquant::quant::ActQuantConfig;
use matquant::runtime::{ForwardWeights, HostForward};
use matquant::serve::{PrecisionReq, Request, Server, ServerConfig};

/// A small but complete transformer (pre-RMSNorm, FFN-quantized, learned
/// positions) from the shared fixture in `model::testing`.
fn toy_dims() -> ModelDims {
    ModelDims {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 12,
        quantize_attn: false,
    }
}

fn toy_model(seed: u64) -> (PresetInfo, QuantizedModel) {
    toy_transformer(toy_dims(), seed)
}

fn toy_tokens(preset: &PresetInfo, b: usize, salt: usize) -> Vec<i32> {
    let t = preset.model.seq_len;
    (0..b * t)
        .map(|i| ((i * 7 + salt) % preset.model.vocab) as i32)
        .collect()
}

fn host_cfg(warm: Vec<u32>) -> ServerConfig {
    ServerConfig {
        preset: "toy".into(),
        max_wait_ms: 0.5,
        warm_bits: warm,
        ..ServerConfig::default()
    }
}

// ---------------------------------------------------------------------------
// Forward-pass conformance (packed vs dense f32 reference)
// ---------------------------------------------------------------------------

#[test]
fn packed_forward_matches_dense_reference_per_bitwidth() {
    let (preset, model) = toy_model(11);
    let b = 2;
    let t = preset.model.seq_len;
    let tokens = toy_tokens(&preset, b, 3);
    for bits in [1u32, 2, 3, 4, 6, 8] {
        for ep in [false, true] {
            let (weights, biases) = model
                .materialize(&PrecisionAssignment::Uniform {
                    bits,
                    extra_precision: ep,
                })
                .unwrap();
            let dense = HostForward::new(
                &preset.model,
                &model,
                ForwardWeights::Dense {
                    weights: &weights,
                    biases: &biases,
                },
            )
            .unwrap();
            let want = dense.forward(&tokens, b, t).unwrap();
            assert_eq!(want.shape, vec![b, t, preset.model.vocab]);

            let handles = model.packed_weights(bits, ep).unwrap();
            let packed = HostForward::new(
                &preset.model,
                &model,
                ForwardWeights::Packed {
                    packed: &handles,
                    int8: None,
                },
            )
            .unwrap();
            let got = packed.forward(&tokens, b, t).unwrap();
            assert_eq!(got.shape, want.shape);
            // Same decoded weights (bit-for-bit per the registry tests);
            // only the fused kernels' accumulation order differs, so the
            // tolerance is accumulation-scaled (d_in ulps per matmul,
            // compounded across 2·n_layers + 1 quantized/dense products)
            // like `kernels::testing::assert_accum_close` — far below the
            // O(0.1) logit shifts a real bit-width defect produces.
            for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
                let tol = 2e-3f32 * (1.0 + w.abs());
                assert!(
                    (g - w).abs() <= tol,
                    "bits={bits} ep={ep} logit {i}: {g} vs {w}"
                );
            }
        }
    }
}

#[test]
fn bitwidths_actually_change_the_forward() {
    // int2 and int8 packed forwards must disagree (untrained weights, big
    // quantization gap) — otherwise the precision plumbing is inert.
    let (preset, model) = toy_model(13);
    let t = preset.model.seq_len;
    let tokens = toy_tokens(&preset, 1, 5);
    let h2 = model.packed_weights(2, false).unwrap();
    let h8 = model.packed_weights(8, false).unwrap();
    let f2 = HostForward::new(
        &preset.model,
        &model,
        ForwardWeights::Packed {
            packed: &h2,
            int8: None,
        },
    )
    .unwrap();
    let f8 = HostForward::new(
        &preset.model,
        &model,
        ForwardWeights::Packed {
            packed: &h8,
            int8: None,
        },
    )
    .unwrap();
    let a = f2.forward(&tokens, 1, t).unwrap();
    let b = f8.forward(&tokens, 1, t).unwrap();
    let max_diff = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff > 1e-3, "int2 and int8 logits identical ({max_diff})");
}

#[test]
fn int8_activation_forward_tracks_f32_within_quant_error() {
    let (preset, model) = toy_model(17);
    let b = 2;
    let t = preset.model.seq_len;
    let tokens = toy_tokens(&preset, b, 1);
    for bits in [4u32, 8] {
        let handles = model.packed_weights(bits, false).unwrap();
        let f32_fw = HostForward::new(
            &preset.model,
            &model,
            ForwardWeights::Packed {
                packed: &handles,
                int8: None,
            },
        )
        .unwrap();
        let i8_fw = HostForward::new(
            &preset.model,
            &model,
            ForwardWeights::Packed {
                packed: &handles,
                int8: Some(ActQuantConfig::absmax()),
            },
        )
        .unwrap();
        let want = f32_fw.forward(&tokens, b, t).unwrap();
        let got = i8_fw.forward(&tokens, b, t).unwrap();
        assert!(got.data.iter().all(|v| v.is_finite()));
        let num: f32 = got
            .data
            .iter()
            .zip(&want.data)
            .map(|(g, w)| (g - w) * (g - w))
            .sum();
        let den: f32 = want.data.iter().map(|w| w * w).sum::<f32>().max(1e-12);
        let rel = (num / den).sqrt();
        // int8 activations add real (bounded) quantization noise: the path
        // must be exercised (nonzero) but stay close to the f32 forward
        assert!(rel > 0.0, "bits={bits}: i8 path identical to f32 — inert?");
        assert!(rel < 0.15, "bits={bits}: i8 rel err {rel}");
    }
}

// ---------------------------------------------------------------------------
// End-to-end host serving (no artifacts, no PJRT)
// ---------------------------------------------------------------------------

#[test]
fn host_server_serves_every_bitwidth_without_artifacts() {
    let (preset, model) = toy_model(19);
    let seq = preset.model.seq_len;
    let vocab = preset.model.vocab;
    let server = Server::start_host(preset.clone(), model, host_cfg(vec![8])).unwrap();
    let widths = [1u32, 2, 3, 4, 6, 8];
    let rxs: Vec<_> = widths
        .iter()
        .enumerate()
        .map(|(i, &bits)| {
            server
                .submit(Request::new(
                    i as u64,
                    (0..seq.min(8)).map(|j| (j as i32 * 3 + i as i32) % vocab as i32).collect(),
                    PrecisionReq::Bits(bits),
                ))
                .unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap();
        assert_eq!(r.id, i as u64);
        assert_eq!(r.bits, widths[i]);
        assert!(!r.int8_acts);
        assert!((0..vocab as i32).contains(&r.next_token));
        assert!(r.batch_size >= 1);
    }
    let report = server.metrics_report().unwrap();
    assert!(report.contains("requests=6"), "{report}");
    server.shutdown().unwrap();
}

#[test]
fn host_server_response_matches_direct_forward() {
    let (preset, model) = toy_model(23);
    let seq = preset.model.seq_len;
    let prompt: Vec<i32> = (0..6).map(|i| 10 + i as i32).collect();
    // expected: run the packed forward directly over the padded prompt row
    let handles = model.packed_weights(4, false).unwrap();
    let fw = HostForward::new(
        &preset.model,
        &model,
        ForwardWeights::Packed {
            packed: &handles,
            int8: None,
        },
    )
    .unwrap();
    let mut padded = vec![0i32; seq];
    padded[..prompt.len()].copy_from_slice(&prompt);
    let logits = fw.forward(&padded, 1, seq).unwrap();
    let v = preset.model.vocab;
    let row = &logits.data[(prompt.len() - 1) * v..prompt.len() * v];
    let expected = matquant::runtime::argmax_logit(row);

    let server = Server::start_host(preset.clone(), model, host_cfg(vec![])).unwrap();
    let r = server
        .infer(Request::new(1, prompt, PrecisionReq::Bits(4)))
        .unwrap();
    assert_eq!(r.next_token, expected.0);
    assert_eq!(r.bits, 4);
    server.shutdown().unwrap();
}

#[test]
fn int8_requests_run_end_to_end_behind_the_flag() {
    let (preset, model) = toy_model(29);
    let vocab = preset.model.vocab;
    // bits 8 is warm (dense) → exercises the packed sibling build; bits 2
    // is lazy (paged) → exercises the paged handles directly.
    let server = Server::start_host(preset.clone(), model, host_cfg(vec![8])).unwrap();
    for (id, bits) in [(1u64, 8u32), (2, 2)] {
        let req = Request {
            int8_acts: true,
            ..Request::new(id, vec![5, 6, 7, 8], PrecisionReq::Bits(bits))
        };
        let r = server.infer(req).unwrap();
        assert_eq!(r.id, id);
        assert_eq!(r.bits, bits);
        assert!(r.int8_acts, "response must carry the activation mode");
        assert!((0..vocab as i32).contains(&r.next_token));
    }
    // f32 requests still work at the same precisions afterwards
    let r = server
        .infer(Request::new(3, vec![5, 6, 7, 8], PrecisionReq::Bits(8)))
        .unwrap();
    assert!(!r.int8_acts);
    server.shutdown().unwrap();
}

#[test]
fn empty_prompt_round_trips() {
    let (preset, model) = toy_model(31);
    let vocab = preset.model.vocab;
    let server = Server::start_host(preset, model, host_cfg(vec![4])).unwrap();
    let r = server
        .infer(Request::new(42, vec![], PrecisionReq::Bits(4)))
        .unwrap();
    assert_eq!(r.id, 42);
    assert!((0..vocab as i32).contains(&r.next_token));
    server.shutdown().unwrap();
}

#[test]
fn out_of_vocab_request_rejected_without_poisoning_batchmates() {
    // A malformed prompt is rejected at submit (its channel closes → recv
    // error) and never reaches a batch, so a co-submitted valid request at
    // the same precision still gets its answer.
    let (preset, model) = toy_model(43);
    let vocab = preset.model.vocab as i32;
    let server = Server::start_host(preset, model, host_cfg(vec![4])).unwrap();
    let bad = server
        .submit(Request::new(1, vec![vocab + 5], PrecisionReq::Bits(4)))
        .unwrap();
    let neg = server
        .submit(Request::new(2, vec![-3], PrecisionReq::Bits(4)))
        .unwrap();
    let good = server
        .submit(Request::new(3, vec![1, 2], PrecisionReq::Bits(4)))
        .unwrap();
    assert!(bad.recv().is_err(), "out-of-vocab request must error, not hang");
    assert!(neg.recv().is_err(), "negative-token request must error, not hang");
    let r = good.recv().expect("valid batchmate must still be answered");
    assert_eq!(r.id, 3);
    server.shutdown().unwrap();
}

#[test]
fn nan_logits_complete_instead_of_killing_the_worker() {
    // Poison the head projection: every logit becomes NaN.  The old
    // `partial_cmp(..).unwrap()` argmax aborted the worker thread on this;
    // now every request must still be answered and the worker must stay
    // alive for subsequent traffic.
    let preset = toy_transformer_preset(toy_dims());
    let mut params = toy_transformer_params(&preset, 37);
    let head_shape = params["head"].shape.clone();
    let n: usize = head_shape.iter().product();
    params.insert(
        "head".into(),
        Tensor::new(head_shape, vec![f32::NAN; n]).unwrap(),
    );
    let model = QuantizedModel::build(&preset, &params, None).unwrap();
    let server = Server::start_host(preset, model, host_cfg(vec![4])).unwrap();
    let rxs: Vec<_> = (0..3)
        .map(|id| {
            server
                .submit(Request::new(id, vec![1, 2, 3], PrecisionReq::Bits([2, 4, 8][id as usize % 3])))
                .unwrap()
        })
        .collect();
    for rx in rxs {
        let r = rx.recv().expect("NaN batch must still answer");
        assert!(r.logit.is_nan(), "poison should be visible in the response");
    }
    // worker survived: metrics and further requests still flow
    let report = server.metrics_report().unwrap();
    assert!(report.contains("requests=3"), "{report}");
    let r = server
        .infer(Request::new(99, vec![4, 5], PrecisionReq::Bits(4)))
        .unwrap();
    assert_eq!(r.id, 99);
    server.shutdown().unwrap();
}
