//! End-to-end train-loop integration: rust drives the PJRT train-step
//! artifacts and losses go down.  Requires `make artifacts` (reports
//! `skipped:` otherwise).

mod common;

use matquant::coordinator::{train, Mode, Objective, TrainSpec};
use matquant::runtime::Engine;

fn engine() -> Option<Engine> {
    let dir = common::artifact_or_skip("train_loop", "manifest.json")?;
    Some(Engine::new(dir).unwrap())
}

#[test]
fn qat_matquant_losses_decrease() {
    let Some(engine) = engine() else { return };
    let spec = TrainSpec::new("tiny", Mode::Qat, Objective::matquant_default(), 60);
    let out = train(&engine, &spec).unwrap();
    assert_eq!(out.loss_history.len(), 60);
    assert_eq!(out.loss_history[0].len(), 3);
    for l in &out.loss_history {
        assert!(l.iter().all(|x| x.is_finite()), "{l:?}");
    }
    let first = out.loss_history[..10].iter().map(|l| l[2]).sum::<f32>() / 10.0;
    let last = out.tail_loss(2, 10);
    assert!(last < first, "int2 loss {first} -> {last}");
}

#[test]
fn qat_direct_b4_losses_decrease() {
    let Some(engine) = engine() else { return };
    // 60 steps: the artifact bakes a 150-step LR warmup, so early steps
    // barely move — compare first-10 vs last-10 means to beat batch noise.
    let spec = TrainSpec::new("tiny", Mode::Qat, Objective::Direct { bits: 4 }, 60);
    let out = train(&engine, &spec).unwrap();
    assert_eq!(out.loss_history[0].len(), 1);
    let first: f32 = out.loss_history[..10].iter().map(|l| l[0]).sum::<f32>() / 10.0;
    let last = out.tail_loss(0, 10);
    assert!(last < first, "direct b4 loss {first} -> {last}");
}

#[test]
fn omni_matquant_aux_trains() {
    let Some(engine) = engine() else { return };
    let mut spec = TrainSpec::new("tiny", Mode::Omni, Objective::matquant_default(), 20);
    spec.seed = 7;
    let out = train(&engine, &spec).unwrap();
    let aux = out.aux.as_ref().expect("omni returns aux");
    let moved = aux
        .iter()
        .filter(|(n, t)| {
            let init = if n.ends_with("gamma_raw") || n.ends_with("beta_raw") {
                4.0
            } else {
                0.0
            };
            t.data.iter().any(|&x| (x - init).abs() > 1e-6)
        })
        .count();
    assert!(moved > 0, "no aux parameter moved");
    let first = out.loss_history[0][2];
    let last = out.tail_loss(2, 3);
    assert!(last <= first, "omni int2 recon {first} -> {last}");
}
