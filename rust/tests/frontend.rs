//! Front-door conformance: the TCP/HTTP streaming interface must be a
//! transparent skin over the in-process host backend.
//!
//! * **Byte identity** — a response streamed over the socket by a
//!   2-worker fleet carries the same token ids, bits, activation mode,
//!   and done flags as the same request served by `Server::start_host`
//!   on an identically-seeded model, across r ∈ {2, 4, 8} ± int8 ± a
//!   Mix'n'Match per-layer map.
//! * **Drain** — once `begin_drain` runs, new submits are rejected
//!   immediately (typed error in-process, HTTP 503 over TCP); no client
//!   ever hangs.
//! * **Worker death** — killing a worker fails its live streams cleanly
//!   (channel terminates, never silence), rehomes its queued requests to
//!   the survivors where they complete in full, and returns every page
//!   to the pool once the fleet drains.
//! * **Loadgen smoke** — the trace harness drives a real 2-worker fleet
//!   end to end with zero errors.
//!
//! Unix-only, like the frontend itself.
#![cfg(unix)]

use std::io::BufReader;
use std::net::TcpStream;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use matquant::loadgen::{MixEntry, TraceConfig};
use matquant::model::manifest::ModelDims;
use matquant::model::testing::toy_transformer;
use matquant::model::{PresetInfo, QuantizedModel};
use matquant::serve::frontend::{codec, HttpFrontend, PoolConfig, SubmitError, WorkerPool};
use matquant::serve::{
    projected_kv_bytes, PrecisionReq, Request, Response, Sampling, Server, ServerConfig,
};
use matquant::util::json::Json;

fn toy_dims() -> ModelDims {
    ModelDims {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 64,
        quantize_attn: false,
    }
}

fn toy(seed: u64) -> (PresetInfo, QuantizedModel) {
    toy_transformer(toy_dims(), seed)
}

fn cfg() -> ServerConfig {
    ServerConfig {
        preset: "toy".into(),
        max_wait_ms: 0.5,
        warm_bits: vec![8],
        ..ServerConfig::default()
    }
}

fn fleet(workers: usize, seed: u64, server: ServerConfig) -> HttpFrontend {
    let (preset, model) = toy(seed);
    let pool = WorkerPool::start(preset, model, PoolConfig { workers, server }).unwrap();
    HttpFrontend::bind(pool, "127.0.0.1:0").unwrap()
}

/// What one TCP generate call produced: the status, the error body (for
/// non-200s), and every parsed NDJSON event.
struct TcpRun {
    status: u16,
    body: Option<String>,
    events: Vec<Json>,
}

fn tcp_generate(addr: &str, req: &Request) -> TcpRun {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut w = stream.try_clone().unwrap();
    codec::write_generate(&mut w, &codec::request_to_json(req)).unwrap();
    let mut r = BufReader::new(stream);
    let (status, headers) = codec::read_response_head(&mut r).unwrap();
    if status != 200 {
        let body = codec::read_body(&mut r, &headers).unwrap();
        return TcpRun {
            status,
            body: Some(body),
            events: Vec::new(),
        };
    }
    let mut events = Vec::new();
    while let Some(line) = codec::read_chunk(&mut r).unwrap() {
        events.push(Json::parse(&line).unwrap());
    }
    TcpRun {
        status,
        body: None,
        events,
    }
}

/// One in-process stream, fully drained: (token, bits, int8, done) per
/// event plus the final accumulated token vector.
struct RefStream {
    events: Vec<(i32, u32, bool, bool)>,
    tokens: Vec<i32>,
}

fn drain_stream(rx: &Receiver<Response>) -> RefStream {
    let mut events = Vec::new();
    loop {
        let r = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("in-process stream stalled");
        events.push((r.next_token, r.bits, r.int8_acts, r.done));
        if r.done {
            return RefStream {
                events,
                tokens: r.tokens,
            };
        }
    }
}

/// r ∈ {2, 4, 8} × {f32, int8 activations}, plus one per-layer map.
fn request_matrix(preset: &PresetInfo) -> Vec<Request> {
    let vocab = preset.model.vocab as i32;
    let mut reqs = Vec::new();
    let mut id = 1u64;
    for &bits in &[2u32, 4, 8] {
        for &int8 in &[false, true] {
            let prompt: Vec<i32> = (0..6).map(|j| (j * 5 + id as i32 * 3) % vocab).collect();
            let mut r =
                Request::generate(id, prompt, PrecisionReq::Bits(bits), 4, Sampling::Greedy);
            r.int8_acts = int8;
            reqs.push(r);
            id += 1;
        }
    }
    let prompt: Vec<i32> = (0..6).map(|j| (j * 7 + 1) % vocab).collect();
    let mut r = Request::generate(id, prompt, PrecisionReq::Bits(8), 4, Sampling::Greedy);
    r.per_layer = Some(vec![8, 2]);
    reqs.push(r);
    reqs
}

#[test]
fn tcp_streams_are_byte_identical_to_the_in_process_host_backend() {
    let seed = 101;

    // Reference: the in-process host backend on the seeded toy model.
    let (preset, model) = toy(seed);
    let reqs = request_matrix(&preset);
    let server = Server::start_host(preset, model, cfg()).unwrap();
    let want: Vec<RefStream> = reqs
        .iter()
        .map(|req| drain_stream(&server.submit(req.clone()).unwrap()))
        .collect();
    server.shutdown().unwrap();

    // Same seed, same model — served over TCP by a 2-worker fleet.
    let frontend = fleet(2, seed, cfg());
    let addr = frontend.addr().to_string();
    for (req, reference) in reqs.iter().zip(&want) {
        let got = tcp_generate(&addr, req);
        assert_eq!(got.status, 200, "req {}: {:?}", req.id, got.body);
        assert_eq!(
            got.events.len(),
            reference.events.len(),
            "req {}: event count",
            req.id
        );
        for (i, e) in got.events.iter().enumerate() {
            let (token, bits, int8, done) = reference.events[i];
            assert_eq!(e.get("id").unwrap().as_f64().unwrap() as u64, req.id);
            assert_eq!(
                e.get("token").unwrap().as_f64().unwrap() as i32,
                token,
                "req {} event {i}: token id must be byte-identical",
                req.id
            );
            assert_eq!(e.get("bits").unwrap().as_u32().unwrap(), bits);
            assert_eq!(e.get("int8").unwrap().as_bool().unwrap(), int8);
            assert_eq!(
                e.get("done").unwrap().as_bool().unwrap(),
                done,
                "req {} event {i}: done flag",
                req.id
            );
        }
        let last = got.events.last().unwrap();
        let tokens: Vec<i32> = last
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(tokens, reference.tokens, "req {}: final token vector", req.id);
    }
    frontend.shutdown().unwrap();
}

#[test]
fn drain_rejects_new_submits_immediately_without_hanging_clients() {
    let frontend = fleet(2, 7, cfg());
    let addr = frontend.addr().to_string();
    frontend.pool().begin_drain();

    // In-process: the typed error, synchronously.
    let req = Request::generate(1, vec![1, 2, 3], PrecisionReq::Bits(4), 2, Sampling::Greedy);
    let err = frontend
        .pool()
        .submit(req.clone())
        .err()
        .expect("a draining pool must reject new submits");
    assert!(matches!(err, SubmitError::Draining), "{err}");

    // Over TCP: an immediate 503 — the client gets an answer, not a hang
    // and not a half-open stream.
    let t0 = Instant::now();
    let got = tcp_generate(&addr, &req);
    assert_eq!(got.status, 503);
    assert!(
        got.body.unwrap().contains("draining"),
        "the rejection must say why"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "drain rejection must be immediate"
    );
    frontend.shutdown().unwrap();
}

#[test]
fn oversized_kv_projection_is_rejected_synchronously_not_parked_forever() {
    let (preset, model) = toy(11);
    let mut server = cfg();
    let prompt_len = 8usize;
    let gen = 16usize;
    // Budget the fleet below ONE stream of this shape: such a request
    // could never pass the take-time gate, so it must be rejected at
    // submit — parking it would hang the client and wedge shutdown.
    let projected = projected_kv_bytes(&preset.model, prompt_len, gen, 0, &server.kv);
    server.kv_capacity_bytes = Some(projected - 1);
    let pool = WorkerPool::start(preset, model, PoolConfig { workers: 2, server }).unwrap();
    let frontend = HttpFrontend::bind(pool, "127.0.0.1:0").unwrap();
    let addr = frontend.addr().to_string();

    let req = Request::generate(
        1,
        (0..prompt_len as i32).collect(),
        PrecisionReq::Bits(4),
        gen,
        Sampling::Greedy,
    );

    // In-process: the typed error, synchronously.
    let err = frontend
        .pool()
        .submit(req.clone())
        .err()
        .expect("an over-budget projection must be rejected at submit");
    assert!(matches!(err, SubmitError::Rejected(_)), "{err}");
    assert!(err.to_string().contains("exceeds"), "{err}");

    // Over TCP: an immediate 400, never an accepted stream that hangs.
    let t0 = Instant::now();
    let got = tcp_generate(&addr, &req);
    assert_eq!(got.status, 400);
    assert!(
        got.body.unwrap().contains("exceeds"),
        "the rejection must say why"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "over-budget rejection must be immediate"
    );

    // Nothing was parked on the queue, so drain + join completes.
    frontend.shutdown().unwrap();
}

#[test]
fn worker_death_rebalances_queued_work_and_the_pool_gauge_returns_to_zero() {
    let (preset, model) = toy(33);
    let vocab = preset.model.vocab as i32;
    let prompt_len = 8usize;
    let gen = 40usize;
    let mut server = cfg();
    // Budget the fleet-global pool for EXACTLY one stream of this shape:
    // while the live stream holds any page, no queued entry passes the
    // take gate on any worker — the queue stays queued until pages free.
    let one_stream = projected_kv_bytes(&preset.model, prompt_len, gen, 0, &server.kv);
    server.kv_capacity_bytes = Some(one_stream);
    let pool = WorkerPool::start(
        preset,
        model,
        PoolConfig {
            workers: 2,
            server,
        },
    )
    .unwrap();

    let shape = |id: u64| {
        Request::generate(
            id,
            (0..prompt_len as i32).map(|j| (j * 3 + id as i32) % vocab).collect(),
            PrecisionReq::Bits(4),
            gen,
            Sampling::Greedy,
        )
    };

    // One live stream; wait for its first token so it is mid-flight.
    let live_req = shape(1);
    let live_rx = pool.submit(live_req.clone()).unwrap();
    let first = live_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("live stream must start");
    assert!(!first.done, "generation must still be in flight");
    let victim = pool.route_of(&live_req).expect("live key must have a route");

    // Queue four more same-key requests (affinity → the victim) — all
    // budget-gated behind the live stream's pages — then kill the victim.
    let queued: Vec<Receiver<Response>> = (0..4)
        .map(|i| pool.submit(shape(10 + i)).unwrap())
        .collect();
    pool.kill_worker(victim);

    // The live stream terminates cleanly: a final done event if its last
    // round won the race, otherwise a channel disconnect — never silence.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match live_rx.recv_timeout(Duration::from_millis(250)) {
            Ok(r) if r.done => break,
            Ok(_) => {}
            Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => assert!(
                Instant::now() < deadline,
                "live stream must terminate after its worker dies"
            ),
        }
    }

    // Every queued request completes IN FULL on the survivor.
    for (i, rx) in queued.into_iter().enumerate() {
        let deadline = Instant::now() + Duration::from_secs(120);
        let mut events = 0usize;
        loop {
            match rx.recv_timeout(Duration::from_millis(250)) {
                Ok(r) => {
                    events += 1;
                    if r.done {
                        assert_eq!(
                            r.tokens.len(),
                            gen,
                            "queued request {i} must generate every token"
                        );
                        break;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    panic!("queued request {i} was dropped instead of rebalanced")
                }
                Err(RecvTimeoutError::Timeout) => assert!(
                    Instant::now() < deadline,
                    "queued request {i} hung after rebalance"
                ),
            }
        }
        assert_eq!(events, gen, "queued request {i}: one event per token");
    }
    assert_eq!(pool.live_workers(), 1, "exactly the victim died");

    // Full drain: every page back in the pool.
    pool.shutdown().unwrap();
    assert_eq!(
        pool.page_pool().resident_bytes(),
        0,
        "KV pool gauge must return to zero after drain"
    );
}

#[test]
fn loadgen_smoke_drives_a_two_worker_fleet_with_zero_errors() {
    let frontend = fleet(2, 55, cfg());
    let addr = frontend.addr().to_string();
    let tcfg = TraceConfig {
        seed: 3,
        requests: 12,
        arrival_rate: 200.0,
        prompt_len: (2, 6),
        max_new_tokens: (1, 3),
        vocab: toy_dims().vocab,
        mix: vec![
            MixEntry::uniform(0.5, 8),
            MixEntry::uniform(0.3, 4),
            MixEntry::uniform(0.2, 2),
        ],
        ttft_slo_ms: 60_000.0,
        tpot_slo_ms: 60_000.0,
    };
    let report = matquant::loadgen::run_trace(&addr, &tcfg).unwrap();
    assert_eq!(report.errors, 0, "{}", report.render());
    assert_eq!(report.overall.requests, 12);
    assert_eq!(report.overall.completed, 12);
    assert!(report.overall.tokens >= 12, "at least one token each");
    assert!(report.overall.ttft_p50_ms > 0.0);
    assert!(
        (report.overall.slo_attainment - 1.0).abs() < 1e-9,
        "with infinite SLOs every completed request attains"
    );
    assert_eq!(report.per_mix.len(), 3);
    let mix_total: usize = report.per_mix.iter().map(|r| r.requests).sum();
    assert_eq!(mix_total, 12, "every request belongs to exactly one mix row");
    frontend.shutdown().unwrap();
}
