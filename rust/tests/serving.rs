//! Serving-stack integration: mixed-precision requests through the full
//! router → batcher → PJRT pipeline.  Requires `make artifacts` (reports
//! `skipped:` otherwise).

mod common;

use matquant::coordinator::trainer::init_params;
use matquant::model::QuantizedModel;
use matquant::runtime::Engine;
use matquant::serve::{PrecisionReq, Request, Server, ServerConfig};

fn boot() -> Option<(Server, usize, usize)> {
    let dir = common::artifact_or_skip("serving", "manifest.json")?;
    let engine = Engine::new(&dir).unwrap();
    let info = engine.manifest().preset("tiny").unwrap().clone();
    let params = init_params(&engine, "tiny", 9).unwrap();
    let model = QuantizedModel::build(&info, &params, None).unwrap();
    drop(engine);
    let server = Server::start(
        dir,
        model,
        ServerConfig {
            preset: "tiny".into(),
            max_wait_ms: 1.0,
            warm_bits: vec![4],
        },
    )
    .unwrap();
    Some((server, info.model.seq_len, info.model.vocab))
}

#[test]
fn mixed_precision_requests_all_answered() {
    let Some((server, seq, vocab)) = boot() else {
        return;
    };
    let n = 24;
    let rxs: Vec<_> = (0..n)
        .map(|id| {
            let bits = [2u32, 4, 8][id % 3];
            server
                .submit(Request {
                    id: id as u64,
                    prompt: (0..seq.min(16)).map(|i| 16 + (i as i32 % 9)).collect(),
                    precision: PrecisionReq::Bits(bits),
                })
                .unwrap()
        })
        .collect();
    let mut seen = std::collections::BTreeSet::new();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!((0..vocab as i32).contains(&r.next_token));
        assert!([2, 4, 8].contains(&r.bits));
        assert!(r.batch_size >= 1);
        seen.insert(r.id);
    }
    assert_eq!(seen.len(), n, "every request answered exactly once");
    let report = server.metrics_report().unwrap();
    assert!(report.contains("requests=24"), "{report}");
    server.shutdown().unwrap();
}

#[test]
fn same_prompt_same_precision_is_deterministic() {
    let Some((server, seq, _)) = boot() else {
        return;
    };
    let prompt: Vec<i32> = (0..seq.min(16)).map(|i| 20 + (i as i32 % 5)).collect();
    let a = server
        .infer(Request {
            id: 1,
            prompt: prompt.clone(),
            precision: PrecisionReq::Bits(4),
        })
        .unwrap();
    let b = server
        .infer(Request {
            id: 2,
            prompt,
            precision: PrecisionReq::Bits(4),
        })
        .unwrap();
    assert_eq!(a.next_token, b.next_token);
    server.shutdown().unwrap();
}

#[test]
fn precisions_can_disagree() {
    // int2 vs int8 weights genuinely differ — over several prompts the
    // argmax should diverge at least once (untrained weights, big gap).
    let Some((server, seq, _)) = boot() else {
        return;
    };
    let mut diverged = false;
    for s in 0..8 {
        let prompt: Vec<i32> = (0..seq.min(24))
            .map(|i| 16 + ((i as i32 + s) % 11))
            .collect();
        let a = server
            .infer(Request {
                id: 100 + s as u64,
                prompt: prompt.clone(),
                precision: PrecisionReq::Cheapest,
            })
            .unwrap();
        let b = server
            .infer(Request {
                id: 200 + s as u64,
                prompt,
                precision: PrecisionReq::Best,
            })
            .unwrap();
        if a.next_token != b.next_token {
            diverged = true;
            break;
        }
    }
    assert!(diverged, "int2 and int8 never disagreed — slicing inert?");
    server.shutdown().unwrap();
}
