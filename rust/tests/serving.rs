//! Serving-stack tests.
//!
//! The weight-paging half runs unconditionally: it exercises the worker's
//! `WeightStore` directly — lazy builds page the **nested** store (one
//! `Arc`-shared int8 master per tensor; every precision an MSB-prefix
//! bit-slice view, so any precision below an already-resident one pages in
//! zero new bytes) and the literal arguments a paged set produces must be
//! identical to the dense set's, which is what makes responses identical
//! before/after the paging switch (a response is a pure function of the
//! literals fed to the `fwd_b{B}` executable).
//!
//! The end-to-end half (mixed-precision requests through the full router →
//! batcher → PJRT pipeline) requires `make artifacts` and reports
//! `skipped:` otherwise.

mod common;

use std::collections::BTreeMap;

use matquant::coordinator::trainer::init_params;
use matquant::data::Rng;
use matquant::model::registry::QuantizedTensor;
use matquant::model::{QuantizedModel, Tensor};
use matquant::runtime::{tensor_from_literal, Engine};
use matquant::serve::{Metrics, PrecisionReq, Request, Server, ServerConfig, WeightStore};

/// A small artifact-free registry model (mirrors the planner's toy model).
fn toy_model(layers: usize, d_in: usize, d_out: usize) -> QuantizedModel {
    let mut rng = Rng::new(21);
    let mut params = BTreeMap::new();
    let mut quantized = BTreeMap::new();
    let mut order = Vec::new();
    for l in 0..layers {
        let name = format!("layer{l}.ffn.w_in");
        let data: Vec<f32> = (0..d_in * d_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let t = Tensor::new(vec![d_in, d_out], data).unwrap();
        params.insert(name.clone(), t.clone());
        quantized.insert(
            name.clone(),
            QuantizedTensor::from_weight(t, None, None, None).unwrap(),
        );
        order.push(name);
    }
    // one non-quantized param, as real presets have
    let emb = Tensor::new(vec![4, d_in], vec![0.5; 4 * d_in]).unwrap();
    params.insert("embed.table".into(), emb);
    let mut param_order = vec!["embed.table".to_string()];
    param_order.extend(order.iter().cloned());
    QuantizedModel::from_parts(params, quantized, param_order, order)
}

// ---------------------------------------------------------------------------
// Packed paging path (unconditional)
// ---------------------------------------------------------------------------

#[test]
fn lazy_builds_page_the_shared_master_not_f32() {
    let model = toy_model(3, 64, 32);
    let mut store = WeightStore::new();
    let mut metrics = Metrics::default();
    store.build_paged(&model, 2, &mut metrics).unwrap();

    assert_eq!(store.is_paged(2), Some(true));
    let paged = store.payload_bytes(2).unwrap();
    let master_bytes: usize = model
        .quantized
        .values()
        .map(|qt| qt.codes.bytes() + qt.d_out * 8)
        .sum();
    let f32_bytes: usize = model
        .quantized
        .values()
        .map(|qt| qt.d_in * qt.d_out * 4)
        .sum();
    // the nested store pages the int8 masters (+ scales) exactly once —
    // these are what every precision's view streams — never an f32 set
    assert_eq!(
        paged, master_bytes,
        "a view set's resident bytes are the shared masters"
    );
    assert!(paged * 3 < f32_bytes, "paged {paged}B vs f32 {f32_bytes}B");
    // the metrics byte counter records exactly the resident bytes
    assert_eq!(metrics.page_in_bytes(2), paged as u64);
    assert_eq!(metrics.page_in_bytes(8), 0);

    // warm builds stay dense and do not page
    store.build_warm(&model, 8, &mut metrics).unwrap();
    assert_eq!(store.is_paged(8), Some(false));
    assert_eq!(store.payload_bytes(8), None);
    assert_eq!(metrics.page_in_bytes(8), 0);

    // per-batch bytes-touched: the paged set touches the master payload,
    // the dense set touches full f32 bytes
    assert_eq!(store.batch_weight_bytes(2), paged);
    assert!(store.batch_weight_bytes(8) >= f32_bytes);

    let report = metrics.report();
    assert!(report.contains("paged=[int2:1x"), "{report}");
}

#[test]
fn nested_store_pages_zero_new_bytes_below_r_max() {
    // The PR-6 acceptance property: once any precision is resident, paging
    // in any other r ≤ 8 records ZERO new payload bytes — the store hands
    // out MSB-prefix views of the same Arc'd masters.
    let model = toy_model(3, 64, 32);
    let mut store = WeightStore::new();
    let mut metrics = Metrics::default();
    store.build_paged(&model, 8, &mut metrics).unwrap();
    let master_paged = metrics.page_in_bytes(8);
    assert!(master_paged > 0);
    for bits in [4u32, 2] {
        store.build_paged(&model, bits, &mut metrics).unwrap();
        assert_eq!(metrics.page_in_count(bits), 1);
        assert_eq!(
            metrics.page_in_bytes(bits),
            0,
            "int{bits} paged new bytes despite resident masters"
        );
        assert!(
            metrics.page_in_saved_bytes(bits) > 0,
            "int{bits} must credit the avoided compact payload"
        );
        // every precision's resident bytes ARE the shared master set
        assert_eq!(store.payload_bytes(bits), store.payload_bytes(8));
    }
    // total page-in traffic across all three precisions == one master set
    let total: u64 = [2u32, 4, 8].iter().map(|&b| metrics.page_in_bytes(b)).sum();
    assert_eq!(total, master_paged);
    // the avoided bytes match the compact payloads a per-r build would cut
    for bits in [4u32, 2] {
        let compact: usize = model
            .packed_weights(bits, false)
            .unwrap()
            .values()
            .map(|p| p.payload_bytes())
            .sum();
        assert_eq!(metrics.page_in_saved_bytes(bits), compact as u64);
    }
}

#[test]
fn shared_handles_page_in_once() {
    // Regression: `build_paged` (PJRT sets) and `ensure_handles` (host
    // plans) used to build the same payload independently — a precision
    // serving both paths held the bytes twice and counted two page-ins.
    let model = toy_model(2, 32, 16);
    let mut store = WeightStore::new();
    let mut metrics = Metrics::default();

    // PJRT set first, host handles second: one build, one page-in event.
    store.build_paged(&model, 2, &mut metrics).unwrap();
    assert_eq!(metrics.page_in_count(2), 1);
    let bytes2 = metrics.page_in_bytes(2);
    assert!(bytes2 > 0);
    store.ensure_handles(&model, 2, &mut metrics).unwrap();
    store.build_paged(&model, 2, &mut metrics).unwrap();
    assert_eq!(metrics.page_in_count(2), 1, "payload paged in twice");
    assert_eq!(metrics.page_in_bytes(2), bytes2, "payload bytes recounted");

    // Reverse order at another precision: host handles first, then the
    // PJRT set — still exactly one build.
    store.ensure_handles(&model, 4, &mut metrics).unwrap();
    assert_eq!(metrics.page_in_count(4), 1);
    let bytes4 = metrics.page_in_bytes(4);
    store.build_paged(&model, 4, &mut metrics).unwrap();
    assert_eq!(metrics.page_in_count(4), 1, "build_paged rebuilt handles");
    assert_eq!(metrics.page_in_bytes(4), bytes4, "payload bytes recounted");
    assert_eq!(store.is_paged(4), Some(true));
}

/// Assert two stores produce byte-identical batch args at every precision.
fn assert_args_identical(model: &QuantizedModel, dense: &WeightStore, paged: &WeightStore) {
    for bits in [2u32, 4, 8] {
        let a = dense.batch_args(model, bits).unwrap();
        let b = paged.batch_args(model, bits).unwrap();
        assert_eq!(a.len(), b.len(), "int{bits}: arg arity");
        for (k, (la, lb)) in a.iter().zip(&b).enumerate() {
            let ta = tensor_from_literal(la).unwrap();
            let tb = tensor_from_literal(lb).unwrap();
            assert_eq!(ta.shape, tb.shape, "int{bits} arg {k}: shape");
            assert_eq!(ta.data.len(), tb.data.len(), "int{bits} arg {k}: len");
            for (i, (x, y)) in ta.data.iter().zip(&tb.data).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "int{bits} arg {k} elem {i}: {x} vs {y}"
                );
            }
        }
    }
}

#[test]
fn paged_args_identical_to_dense_args() {
    // Response identity across the dense→paged switch: the literals fed to
    // the executable are bit-for-bit identical, so the responses are too.
    let model = toy_model(2, 48, 24);
    let mut metrics = Metrics::default();
    let mut dense = WeightStore::new();
    let mut paged = WeightStore::new();
    for bits in [2u32, 4, 8] {
        dense.build_warm(&model, bits, &mut metrics).unwrap();
        paged.build_paged(&model, bits, &mut metrics).unwrap();
    }
    assert_args_identical(&model, &dense, &paged);
}

#[test]
fn paged_args_identical_for_smoothed_models() {
    // OmniQuant smoothing folds a nonzero bias; the paged build must
    // reproduce the dense fold bit-for-bit too.
    let mut model = toy_model(2, 32, 16);
    let smoothed: Vec<(String, QuantizedTensor)> = model
        .quantized
        .iter()
        .map(|(name, qt)| {
            let s: Vec<f32> = (0..qt.d_in).map(|i| 0.9 + 0.01 * i as f32).collect();
            let mut delta = vec![0.0f32; qt.d_in];
            delta[3] = 0.5;
            delta[10] = -0.25;
            let fp = qt.fp.clone();
            (
                name.clone(),
                QuantizedTensor::from_weight(fp, None, None, Some((s, delta))).unwrap(),
            )
        })
        .collect();
    model.quantized = smoothed.into_iter().collect();
    let mut metrics = Metrics::default();
    let mut dense = WeightStore::new();
    let mut paged = WeightStore::new();
    for bits in [2u32, 4, 8] {
        dense.build_warm(&model, bits, &mut metrics).unwrap();
        paged.build_paged(&model, bits, &mut metrics).unwrap();
    }
    // the smoothing fold must actually be exercised (nonzero bias)
    let handles = model.packed_weights(4, false).unwrap();
    assert!(
        handles
            .values()
            .any(|p| p.bias.as_ref().is_some_and(|b| b.iter().any(|&v| v != 0.0))),
        "smoothing fold produced no bias — test is vacuous"
    );
    assert_args_identical(&model, &dense, &paged);
}

#[test]
fn paged_args_match_registry_materialization() {
    // The paged decode must reproduce the registry's materialize outputs —
    // weights in param order, then biases in quantized order.
    let model = toy_model(2, 32, 16);
    let mut metrics = Metrics::default();
    let mut store = WeightStore::new();
    store.build_paged(&model, 4, &mut metrics).unwrap();
    let args = store.batch_args(&model, 4).unwrap();
    let (weights, biases) = model
        .materialize(&matquant::model::PrecisionAssignment::uniform(4))
        .unwrap();
    assert_eq!(args.len(), weights.len() + biases.len());
    for (k, want) in weights.iter().chain(biases.iter()).enumerate() {
        let got = tensor_from_literal(&args[k]).unwrap();
        assert_eq!(got.data, want.data, "arg {k}");
    }
}

#[test]
fn missing_weight_set_is_an_error() {
    let model = toy_model(1, 16, 8);
    let store = WeightStore::new();
    assert!(store.batch_args(&model, 4).is_err());
    assert_eq!(store.is_paged(4), None);
    assert_eq!(store.batch_weight_bytes(4), 0);
}

// ---------------------------------------------------------------------------
// End-to-end pipeline (artifact-gated)
// ---------------------------------------------------------------------------

fn boot() -> Option<(Server, usize, usize)> {
    let dir = common::artifact_or_skip("serving", "manifest.json")?;
    let engine = Engine::new(&dir).unwrap();
    let info = engine.manifest().preset("tiny").unwrap().clone();
    let params = init_params(&engine, "tiny", 9).unwrap();
    let model = QuantizedModel::build(&info, &params, None).unwrap();
    drop(engine);
    let server = Server::start(
        dir,
        model,
        ServerConfig {
            preset: "tiny".into(),
            max_wait_ms: 1.0,
            warm_bits: vec![4],
            ..ServerConfig::default()
        },
    )
    .unwrap();
    Some((server, info.model.seq_len, info.model.vocab))
}

#[test]
fn mixed_precision_requests_all_answered() {
    let Some((server, seq, vocab)) = boot() else {
        return;
    };
    let n = 24;
    let rxs: Vec<_> = (0..n)
        .map(|id| {
            let bits = [2u32, 4, 8][id % 3];
            server
                .submit(Request::new(
                    id as u64,
                    (0..seq.min(16)).map(|i| 16 + (i as i32 % 9)).collect(),
                    PrecisionReq::Bits(bits),
                ))
                .unwrap()
        })
        .collect();
    let mut seen = std::collections::BTreeSet::new();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!((0..vocab as i32).contains(&r.next_token));
        assert!([2, 4, 8].contains(&r.bits));
        assert!(r.batch_size >= 1);
        seen.insert(r.id);
    }
    assert_eq!(seen.len(), n, "every request answered exactly once");
    let report = server.metrics_report().unwrap();
    assert!(report.contains("requests=24"), "{report}");
    server.shutdown().unwrap();
}

#[test]
fn same_prompt_same_precision_is_deterministic() {
    let Some((server, seq, _)) = boot() else {
        return;
    };
    let prompt: Vec<i32> = (0..seq.min(16)).map(|i| 20 + (i as i32 % 5)).collect();
    let a = server
        .infer(Request::new(1, prompt.clone(), PrecisionReq::Bits(4)))
        .unwrap();
    let b = server
        .infer(Request::new(2, prompt, PrecisionReq::Bits(4)))
        .unwrap();
    assert_eq!(a.next_token, b.next_token);
    server.shutdown().unwrap();
}

#[test]
fn precisions_can_disagree() {
    // int2 vs int8 weights genuinely differ — over several prompts the
    // argmax should diverge at least once (untrained weights, big gap).
    let Some((server, seq, _)) = boot() else {
        return;
    };
    let mut diverged = false;
    for s in 0..8 {
        let prompt: Vec<i32> = (0..seq.min(24))
            .map(|i| 16 + ((i as i32 + s) % 11))
            .collect();
        let a = server
            .infer(Request::new(
                100 + s as u64,
                prompt.clone(),
                PrecisionReq::Cheapest,
            ))
            .unwrap();
        let b = server
            .infer(Request::new(200 + s as u64, prompt, PrecisionReq::Best))
            .unwrap();
        if a.next_token != b.next_token {
            diverged = true;
            break;
        }
    }
    assert!(diverged, "int2 and int8 never disagreed — slicing inert?");
    server.shutdown().unwrap();
}
