//! Fused-kernel conformance suite: the single-pass kernels in
//! `matquant::kernels` must match the scalar reference path (the seed's
//! two-pass unpack → slice → dequantize walk) **bit for bit** across
//!
//! * every supported width (1/2/3/4/6/8 bits — LUT paths and the bit
//!   cursor),
//! * odd / word-straddling / empty lengths,
//! * Eq. 8 overflow overlays (including all-overflow and empty overlays),
//! * degenerate EPS-guarded channels and extreme zero-points.
//!
//! The fused dequant×matmul kernels (`kernels::matmul`) are additionally
//! checked — deterministically over the same width/shape grid and with
//! seeded property-based sweeps (`testing::run_prop`) over random (bits,
//! shape, overlay, degenerate-scale) cases — against the scalar `quant::`
//! dequant followed by a naive f32 matmul: inputs decode bit-for-bit, the
//! accumulations agree within the ulp-scaled tolerance of
//! `testing::assert_accum_close` (the fused path hoists the affine out of
//! the reduction, a different but equally valid f32 evaluation order).
//!
//! Runs unconditionally — no artifacts required.  The shared synthesis +
//! reference code lives in `matquant::kernels::testing` so new kernels
//! inherit the harness.

use matquant::kernels::{self, testing};
use matquant::model::registry::QuantizedTensor;
use matquant::model::{PackedPayload, Tensor};
use matquant::quant::{self, ExtraBitOverlay, PackedTensor};

const WIDTHS: [u32; 6] = [1, 2, 3, 4, 6, 8];

/// (n, d_out) shape grid: odd lengths, exact word multiples, word+1
/// straddles, single-channel, and ragged-channel splits.
fn shape_grid() -> Vec<(usize, usize)> {
    vec![
        (0, 1),
        (0, 4),
        (1, 1),
        (3, 1),
        (5, 1),
        (7, 7),
        (8, 2),
        (31, 1),
        (33, 3),
        (64, 8),
        (65, 5),
        (96, 12),
        (257, 1),
        (1000, 10),
        (1024, 128),
    ]
}

#[test]
fn dequant_packed_matches_reference_all_widths() {
    for &bits in &WIDTHS {
        for (case, &(n, d_out)) in shape_grid().iter().enumerate() {
            for degenerate in [false, true] {
                let seed = (case as u64) * 31 + bits as u64;
                let ids = testing::synth_ids(bits, n, seed);
                let packed = PackedTensor::pack(&ids, bits);
                let scales = testing::synth_scales(d_out, seed ^ 0x77, degenerate);
                let want = testing::reference_dequant_packed(&packed, None, &scales, 8, d_out);
                let got = kernels::dequant_packed(&packed, None, &scales, 8, d_out);
                testing::assert_bits_eq(
                    &got,
                    &want,
                    &format!("dequant_packed bits={bits} n={n} d_out={d_out} deg={degenerate}"),
                );
            }
        }
    }
}

#[test]
fn dequant_packed_native_width_matches_reference() {
    // master_bits == packed.bits (step = 1): plain unpack+dequant fusion.
    for &bits in &WIDTHS {
        let (n, d_out) = (129, 3);
        let ids = testing::synth_ids(bits, n, 9);
        let packed = PackedTensor::pack(&ids, bits);
        let scales = testing::synth_scales(d_out, 4, false);
        let want = testing::reference_dequant_packed(&packed, None, &scales, bits, d_out);
        let got = kernels::dequant_packed(&packed, None, &scales, bits, d_out);
        testing::assert_bits_eq(&got, &want, &format!("native bits={bits}"));
    }
}

#[test]
fn dequant_packed_overlay_matches_reference() {
    // Overlays only make sense below the master width (the Eq. 8 bucket is
    // one past the dense range).
    for &bits in &[1u32, 2, 3, 4, 6] {
        for &(n, d_out) in &[(7usize, 1usize), (33, 3), (96, 8), (1000, 10)] {
            let (packed, overlay) = testing::synth_overlayed(bits, n, n as u64 + bits as u64);
            let scales = testing::synth_scales(d_out, 21, false);
            let want =
                testing::reference_dequant_packed(&packed, Some(&overlay), &scales, 8, d_out);
            let got = kernels::dequant_packed(&packed, Some(&overlay), &scales, 8, d_out);
            testing::assert_bits_eq(
                &got,
                &want,
                &format!("overlay bits={bits} n={n} d_out={d_out}"),
            );
        }
    }
}

#[test]
fn dequant_packed_all_overflow_overlay() {
    // Every entry in the overflow bucket — the densest possible overlay.
    let bits = 2u32;
    let n = 40;
    let ids = vec![4.0f32; n]; // 2^2 everywhere
    let (overlay, dense) = ExtraBitOverlay::split(&ids, bits);
    assert_eq!(overlay.indices.len(), n);
    let packed = PackedTensor::pack(&dense, bits);
    let scales = testing::synth_scales(8, 2, false);
    let want = testing::reference_dequant_packed(&packed, Some(&overlay), &scales, 8, 8);
    let got = kernels::dequant_packed(&packed, Some(&overlay), &scales, 8, 8);
    testing::assert_bits_eq(&got, &want, "all-overflow");
}

#[test]
fn slice_dequant_matches_reference_exhaustive() {
    for &r in &WIDTHS {
        for ep in [false, true] {
            for (case, &(n, d_out)) in shape_grid().iter().enumerate() {
                for degenerate in [false, true] {
                    let seed = (case as u64) * 17 + r as u64;
                    let codes = testing::synth_master_codes(n, seed);
                    let packed = PackedTensor::pack(&codes, 8);
                    let scales = testing::synth_scales(d_out, seed ^ 0x55, degenerate);
                    let want =
                        testing::reference_slice_dequant(&packed, r, ep, &scales, d_out);
                    let got = kernels::slice_dequant(&packed, r, ep, &scales, d_out);
                    testing::assert_bits_eq(
                        &got,
                        &want,
                        &format!(
                            "slice_dequant r={r} ep={ep} n={n} d_out={d_out} deg={degenerate}"
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn slice_dequant_covers_every_master_code() {
    // All 256 master codes, every slice width, both Eq. 6 and Eq. 8.
    let codes: Vec<f32> = (0..256).map(|q| q as f32).collect();
    let packed = PackedTensor::pack(&codes, 8);
    let scales = testing::synth_scales(16, 99, false);
    for &r in &WIDTHS {
        for ep in [false, true] {
            let want = testing::reference_slice_dequant(&packed, r, ep, &scales, 16);
            let got = kernels::slice_dequant(&packed, r, ep, &scales, 16);
            testing::assert_bits_eq(&got, &want, &format!("all-codes r={r} ep={ep}"));
        }
    }
}

#[test]
fn registry_materialization_agrees_across_kernels() {
    // End-to-end: fused slice path (materialize) == fused packed-domain
    // path (materialize_packed) == the scalar reference, through real
    // minmax scales including a constant (EPS-guarded) column.
    let d_in = 32;
    let d_out = 12;
    let mut rng = matquant::data::Rng::new(42);
    let mut data: Vec<f32> = (0..d_in * d_out).map(|_| rng.range_f32(-1.5, 1.5)).collect();
    for row in 0..d_in {
        data[row * d_out + 5] = 0.25; // constant column → EPS guard
    }
    let fp = Tensor::new(vec![d_in, d_out], data).unwrap();
    let qt = QuantizedTensor::from_weight(fp, None, None, None).unwrap();
    for &bits in &WIDTHS {
        for ep in [false, true] {
            let (w_fused, _) = qt.materialize(bits, ep).unwrap();
            let (w_packed, _) = qt.materialize_packed(bits, ep).unwrap();
            let want = testing::reference_slice_dequant(&qt.codes, bits, ep, &qt.scales, d_out);
            testing::assert_bits_eq(
                &w_fused.data,
                &want,
                &format!("materialize bits={bits} ep={ep}"),
            );
            testing::assert_bits_eq(
                &w_packed.data,
                &want,
                &format!("materialize_packed bits={bits} ep={ep}"),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Fused dequant×matmul: deterministic grid
// ---------------------------------------------------------------------------

#[test]
fn matvec_matches_naive_reference_all_widths() {
    for &bits in &WIDTHS {
        for (case, &(n, d_out)) in shape_grid().iter().enumerate() {
            if d_out == 0 || n % d_out != 0 {
                continue;
            }
            let d_in = n / d_out;
            for degenerate in [false, true] {
                let seed = (case as u64) * 13 + bits as u64;
                let ids = testing::synth_ids(bits, n, seed);
                let packed = PackedTensor::pack(&ids, bits);
                let scales = testing::synth_scales(d_out, seed ^ 0x33, degenerate);
                let x = testing::synth_x(d_in, seed ^ 0x44);
                let got = kernels::matvec_packed(&packed, None, &scales, 8, d_out, &x, None);
                let (want, mag) =
                    testing::reference_matmul(&packed, None, &scales, 8, d_out, &x, 1, None);
                testing::assert_accum_close(
                    &got,
                    &want,
                    &mag,
                    d_in,
                    &format!("matvec bits={bits} n={n} d_out={d_out} deg={degenerate}"),
                );
            }
        }
    }
}

#[test]
fn matvec_overlay_matches_naive_reference() {
    for &bits in &[1u32, 2, 3, 4, 6] {
        for &(n, d_out) in &[(7usize, 7usize), (33, 3), (96, 8), (1000, 10)] {
            let d_in = n / d_out;
            let (packed, overlay) = testing::synth_overlayed(bits, n, n as u64 + bits as u64);
            let scales = testing::synth_scales(d_out, 17, false);
            let x = testing::synth_x(d_in, 5);
            let got =
                kernels::matvec_packed(&packed, Some(&overlay), &scales, 8, d_out, &x, None);
            let (want, mag) = testing::reference_matmul(
                &packed,
                Some(&overlay),
                &scales,
                8,
                d_out,
                &x,
                1,
                None,
            );
            testing::assert_accum_close(
                &got,
                &want,
                &mag,
                d_in,
                &format!("matvec-overlay bits={bits} n={n} d_out={d_out}"),
            );
        }
    }
}

#[test]
fn matvec_empty_tensor_returns_bias() {
    let packed = PackedTensor::pack(&[], 4);
    let scales = testing::synth_scales(5, 3, false);
    let bias = [0.5f32, -1.0, 0.0, 2.0, -0.25];
    let got = kernels::matvec_packed(&packed, None, &scales, 8, 5, &[], Some(&bias));
    assert_eq!(got, bias.to_vec());
    let no_bias = kernels::matvec_packed(&packed, None, &scales, 8, 5, &[], None);
    assert!(no_bias.iter().all(|&v| v == 0.0));
}

#[test]
fn matmul_batched_matches_naive_reference() {
    // Batch sizes around the GEMM block boundary, odd dims, bias on.
    for &(d_in, d_out, m) in &[
        (17usize, 5usize, 1usize),
        (16, 8, 7),
        (33, 3, 8),
        (20, 11, 9),
        (64, 4, 19),
    ] {
        let bits = 4;
        let ids = testing::synth_ids(bits, d_in * d_out, (d_in * m) as u64);
        let packed = PackedTensor::pack(&ids, bits);
        let scales = testing::synth_scales(d_out, 31, false);
        let xs = testing::synth_x(m * d_in, 71);
        let bias: Vec<f32> = (0..d_out).map(|j| j as f32 * 0.25 - 1.0).collect();
        let got =
            kernels::matmul_packed(&packed, None, &scales, 8, d_out, &xs, m, Some(&bias));
        let (want, mag) = testing::reference_matmul(
            &packed,
            None,
            &scales,
            8,
            d_out,
            &xs,
            m,
            Some(&bias),
        );
        testing::assert_accum_close(
            &got,
            &want,
            &mag,
            d_in,
            &format!("gemm d_in={d_in} d_out={d_out} m={m}"),
        );
    }
}

#[test]
fn matvec_i8_matches_naive_reference() {
    for &bits in &WIDTHS {
        let (d_in, d_out) = (37, 6);
        let ids = testing::synth_ids(bits, d_in * d_out, bits as u64 ^ 0x99);
        let packed = PackedTensor::pack(&ids, bits);
        let scales = testing::synth_scales(d_out, 23, false);
        let xq: Vec<i8> = (0..d_in)
            .map(|i| (((i * 37 + 11) % 255) as i64 - 127) as i8)
            .collect();
        let x_scale = 0.031f32;
        let got =
            kernels::matvec_packed_i8(&packed, None, &scales, 8, d_out, &xq, x_scale, None);
        let x_f: Vec<f32> = xq.iter().map(|&v| v as f32 * x_scale).collect();
        let (want, mag) =
            testing::reference_matmul(&packed, None, &scales, 8, d_out, &x_f, 1, None);
        testing::assert_accum_close(&got, &want, &mag, d_in, &format!("i8 bits={bits}"));
    }
}

#[test]
fn packed_weight_matvec_matches_registry_materialization() {
    // End-to-end through the registry handle: the fused matvec against the
    // naive product over the *materialized* weights (which are themselves
    // bit-for-bit conformant — see tests above).
    let d_in = 48;
    let d_out = 9;
    let mut rng = matquant::data::Rng::new(4242);
    let data: Vec<f32> = (0..d_in * d_out).map(|_| rng.range_f32(-2.0, 2.0)).collect();
    let fp = Tensor::new(vec![d_in, d_out], data).unwrap();
    let qt = QuantizedTensor::from_weight(fp, None, None, None).unwrap();
    let x = testing::synth_x(d_in, 1234);
    for &bits in &WIDTHS {
        for ep in [false, true] {
            let pw = qt.packed_weight(bits, ep).unwrap();
            let got = pw.matvec(&x).unwrap();
            let PackedPayload::Sliced { packed, overlay } = &pw.payload else {
                panic!("packed_weight must build a compact payload");
            };
            let (want, mag) = testing::reference_matmul(
                packed,
                if overlay.is_empty() {
                    None
                } else {
                    Some(overlay)
                },
                &pw.scales,
                8,
                d_out,
                &x,
                1,
                None,
            );
            testing::assert_accum_close(
                &got,
                &want,
                &mag,
                d_in,
                &format!("packed-weight bits={bits} ep={ep}"),
            );
        }
    }
}

#[test]
fn bit_slice_view_matvec_matches_compact_handle_bitwise() {
    // The nested handle must be indistinguishable from the compact one at
    // the kernel level: same registry tensor, same input, every width ±
    // extra precision — outputs bit-for-bit equal (not just close).
    let d_in = 48;
    let d_out = 9;
    let mut rng = matquant::data::Rng::new(2424);
    let data: Vec<f32> = (0..d_in * d_out).map(|_| rng.range_f32(-2.0, 2.0)).collect();
    let fp = Tensor::new(vec![d_in, d_out], data).unwrap();
    let qt = QuantizedTensor::from_weight(fp, None, None, None).unwrap();
    let x = testing::synth_x(d_in, 4321);
    for &bits in &WIDTHS {
        for ep in [false, true] {
            let compact = qt.packed_weight(bits, ep).unwrap();
            let view = qt.packed_view(bits, ep).unwrap();
            let want = compact.matvec(&x).unwrap();
            let got = view.matvec(&x).unwrap();
            testing::assert_bits_eq(&got, &want, &format!("view matvec bits={bits} ep={ep}"));
            let (wa, _) = compact.decode().unwrap();
            let (wb, _) = view.decode().unwrap();
            testing::assert_bits_eq(
                &wb.data,
                &wa.data,
                &format!("view decode bits={bits} ep={ep}"),
            );
        }
    }
}

#[test]
fn bit_slice_view_materialize_matches_pack_sliced() {
    // BitSliceView::materialize must reproduce the compact payload the
    // registry's pack_sliced emits — codes and overlay — exactly.
    let d_in = 31;
    let d_out = 7;
    let mut rng = matquant::data::Rng::new(777);
    let data: Vec<f32> = (0..d_in * d_out).map(|_| rng.range_f32(-1.5, 1.5)).collect();
    let fp = Tensor::new(vec![d_in, d_out], data).unwrap();
    let qt = QuantizedTensor::from_weight(fp, None, None, None).unwrap();
    for &bits in &WIDTHS {
        for ep in [false, true] {
            let (want_packed, want_ov) = qt.pack_sliced(bits, ep);
            let view = quant::BitSliceView::new(qt.codes.clone(), bits, ep);
            let (got_packed, got_ov) = view.materialize();
            assert_eq!(got_packed, want_packed, "codes bits={bits} ep={ep}");
            assert_eq!(
                got_ov.indices, want_ov.indices,
                "overlay bits={bits} ep={ep}"
            );
            assert_eq!(
                view.compact_bytes(),
                want_packed.bytes() + want_ov.bytes(d_in * d_out),
                "compact_bytes bits={bits} ep={ep}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Fused dequant×matmul: property-based sweeps
// ---------------------------------------------------------------------------

#[test]
fn prop_matvec_matches_naive_reference() {
    testing::run_prop(
        "fused matvec == naive dequant·matmul",
        testing::PropConfig {
            cases: 250,
            ..Default::default()
        },
        testing::gen_matmul_case,
        |case| {
            let (packed, overlay, scales) = testing::build_matmul_payload(case);
            let ov = if overlay.is_empty() {
                None
            } else {
                Some(&overlay)
            };
            let x = testing::synth_x(case.d_in, case.seed ^ 0x1);
            let bias: Option<Vec<f32>> = case
                .bias
                .then(|| (0..case.d_out).map(|j| (j as f32) * 0.5 - 1.0).collect());
            let got = kernels::matvec_packed(
                &packed,
                ov,
                &scales,
                8,
                case.d_out,
                &x,
                bias.as_deref(),
            );
            let (want, mag) = testing::reference_matmul(
                &packed,
                ov,
                &scales,
                8,
                case.d_out,
                &x,
                1,
                bias.as_deref(),
            );
            testing::assert_accum_close(&got, &want, &mag, case.d_in, "matvec");
        },
    );
}

#[test]
fn prop_matmul_batched_matches_naive_reference() {
    testing::run_prop(
        "fused batched matmul == naive dequant·matmul",
        testing::PropConfig {
            cases: 120,
            seed: 0xBA7C4,
        },
        testing::gen_matmul_case,
        |case| {
            let (packed, overlay, scales) = testing::build_matmul_payload(case);
            let ov = if overlay.is_empty() {
                None
            } else {
                Some(&overlay)
            };
            let xs = testing::synth_x(case.m * case.d_in, case.seed ^ 0x2);
            let got =
                kernels::matmul_packed(&packed, ov, &scales, 8, case.d_out, &xs, case.m, None);
            let (want, mag) = testing::reference_matmul(
                &packed, ov, &scales, 8, case.d_out, &xs, case.m, None,
            );
            testing::assert_accum_close(&got, &want, &mag, case.d_in, "gemm");
        },
    );
}

#[test]
fn prop_matvec_i8_matches_naive_reference() {
    testing::run_prop(
        "fused i8/i32 matvec == naive dequant·matmul",
        testing::PropConfig {
            cases: 120,
            seed: 0x18A7,
        },
        testing::gen_matmul_case,
        |case| {
            let (packed, overlay, scales) = testing::build_matmul_payload(case);
            let ov = if overlay.is_empty() {
                None
            } else {
                Some(&overlay)
            };
            let mut rng = matquant::data::Rng::new(case.seed ^ 0x3);
            let xq: Vec<i8> = (0..case.d_in)
                .map(|_| (rng.below(255) as i64 - 127) as i8)
                .collect();
            let x_scale = 0.017f32;
            let got = kernels::matvec_packed_i8(
                &packed,
                ov,
                &scales,
                8,
                case.d_out,
                &xq,
                x_scale,
                None,
            );
            let x_f: Vec<f32> = xq.iter().map(|&v| v as f32 * x_scale).collect();
            let (want, mag) =
                testing::reference_matmul(&packed, ov, &scales, 8, case.d_out, &x_f, 1, None);
            testing::assert_accum_close(&got, &want, &mag, case.d_in, "i8 matvec");
        },
    );
}

#[test]
fn prop_dequant_matches_reference() {
    // The dequant kernels ride the same generator: decode stays bit-exact
    // on every randomly drawn case.
    testing::run_prop(
        "fused dequant == scalar reference (bit-for-bit)",
        testing::PropConfig {
            cases: 150,
            seed: 0xDEC0,
        },
        testing::gen_matmul_case,
        |case| {
            let (packed, overlay, scales) = testing::build_matmul_payload(case);
            let ov = if overlay.is_empty() {
                None
            } else {
                Some(&overlay)
            };
            let want = testing::reference_dequant_packed(&packed, ov, &scales, 8, case.d_out);
            let got = kernels::dequant_packed(&packed, ov, &scales, 8, case.d_out);
            testing::assert_bits_eq(&got, &want, "dequant");
        },
    );
}

#[test]
fn fused_kernels_reject_bad_shapes() {
    let packed = PackedTensor::pack(&[1.0, 0.0, 1.0], 2);
    let scales = testing::synth_scales(2, 1, false);
    // 3 entries do not divide into 2 channels
    let err = std::panic::catch_unwind(|| {
        let mut out = vec![0.0f32; 3];
        kernels::dequant_packed_into(&packed, None, &scales, 8, 2, &mut out);
    });
    assert!(err.is_err(), "shape mismatch must panic");
    // wrong output length
    let err = std::panic::catch_unwind(|| {
        let mut out = vec![0.0f32; 5];
        kernels::dequant_packed_into(&packed, None, &scales, 8, 1, &mut out);
    });
    assert!(err.is_err(), "length mismatch must panic");
}

#[test]
fn slice_dequant_agrees_with_scalar_slice_code() {
    // Spot-check the fused path against the rawest possible oracle: one
    // scalar slice_code + affine per element, no *_into helpers involved.
    let n = 64;
    let d_out = 4;
    let codes = testing::synth_master_codes(n, 77);
    let packed = PackedTensor::pack(&codes, 8);
    let scales = testing::synth_scales(d_out, 13, false);
    for &r in &WIDTHS {
        let got = kernels::slice_dequant(&packed, r, false, &scales, d_out);
        for (i, &g) in got.iter().enumerate() {
            let j = i % d_out;
            let s = quant::slice_code(codes[i], 8, r, false);
            let want = (s - scales.zero[j]) * scales.alpha[j];
            assert_eq!(g.to_bits(), want.to_bits(), "r={r} i={i}");
        }
    }
}
