//! Fused-kernel conformance suite: the single-pass kernels in
//! `matquant::kernels` must match the scalar reference path (the seed's
//! two-pass unpack → slice → dequantize walk) **bit for bit** across
//!
//! * every supported width (1/2/3/4/6/8 bits — LUT paths and the bit
//!   cursor),
//! * odd / word-straddling / empty lengths,
//! * Eq. 8 overflow overlays (including all-overflow and empty overlays),
//! * degenerate EPS-guarded channels and extreme zero-points.
//!
//! Runs unconditionally — no artifacts required.  The shared synthesis +
//! reference code lives in `matquant::kernels::testing` so new kernels
//! inherit the harness.

use matquant::kernels::{self, testing};
use matquant::model::registry::QuantizedTensor;
use matquant::model::Tensor;
use matquant::quant::{self, ExtraBitOverlay, PackedTensor};

const WIDTHS: [u32; 6] = [1, 2, 3, 4, 6, 8];

/// (n, d_out) shape grid: odd lengths, exact word multiples, word+1
/// straddles, single-channel, and ragged-channel splits.
fn shape_grid() -> Vec<(usize, usize)> {
    vec![
        (0, 1),
        (0, 4),
        (1, 1),
        (3, 1),
        (5, 1),
        (7, 7),
        (8, 2),
        (31, 1),
        (33, 3),
        (64, 8),
        (65, 5),
        (96, 12),
        (257, 1),
        (1000, 10),
        (1024, 128),
    ]
}

#[test]
fn dequant_packed_matches_reference_all_widths() {
    for &bits in &WIDTHS {
        for (case, &(n, d_out)) in shape_grid().iter().enumerate() {
            for degenerate in [false, true] {
                let seed = (case as u64) * 31 + bits as u64;
                let ids = testing::synth_ids(bits, n, seed);
                let packed = PackedTensor::pack(&ids, bits);
                let scales = testing::synth_scales(d_out, seed ^ 0x77, degenerate);
                let want = testing::reference_dequant_packed(&packed, None, &scales, 8, d_out);
                let got = kernels::dequant_packed(&packed, None, &scales, 8, d_out);
                testing::assert_bits_eq(
                    &got,
                    &want,
                    &format!("dequant_packed bits={bits} n={n} d_out={d_out} deg={degenerate}"),
                );
            }
        }
    }
}

#[test]
fn dequant_packed_native_width_matches_reference() {
    // master_bits == packed.bits (step = 1): plain unpack+dequant fusion.
    for &bits in &WIDTHS {
        let (n, d_out) = (129, 3);
        let ids = testing::synth_ids(bits, n, 9);
        let packed = PackedTensor::pack(&ids, bits);
        let scales = testing::synth_scales(d_out, 4, false);
        let want = testing::reference_dequant_packed(&packed, None, &scales, bits, d_out);
        let got = kernels::dequant_packed(&packed, None, &scales, bits, d_out);
        testing::assert_bits_eq(&got, &want, &format!("native bits={bits}"));
    }
}

#[test]
fn dequant_packed_overlay_matches_reference() {
    // Overlays only make sense below the master width (the Eq. 8 bucket is
    // one past the dense range).
    for &bits in &[1u32, 2, 3, 4, 6] {
        for &(n, d_out) in &[(7usize, 1usize), (33, 3), (96, 8), (1000, 10)] {
            let (packed, overlay) = testing::synth_overlayed(bits, n, n as u64 + bits as u64);
            let scales = testing::synth_scales(d_out, 21, false);
            let want =
                testing::reference_dequant_packed(&packed, Some(&overlay), &scales, 8, d_out);
            let got = kernels::dequant_packed(&packed, Some(&overlay), &scales, 8, d_out);
            testing::assert_bits_eq(
                &got,
                &want,
                &format!("overlay bits={bits} n={n} d_out={d_out}"),
            );
        }
    }
}

#[test]
fn dequant_packed_all_overflow_overlay() {
    // Every entry in the overflow bucket — the densest possible overlay.
    let bits = 2u32;
    let n = 40;
    let ids = vec![4.0f32; n]; // 2^2 everywhere
    let (overlay, dense) = ExtraBitOverlay::split(&ids, bits);
    assert_eq!(overlay.indices.len(), n);
    let packed = PackedTensor::pack(&dense, bits);
    let scales = testing::synth_scales(8, 2, false);
    let want = testing::reference_dequant_packed(&packed, Some(&overlay), &scales, 8, 8);
    let got = kernels::dequant_packed(&packed, Some(&overlay), &scales, 8, 8);
    testing::assert_bits_eq(&got, &want, "all-overflow");
}

#[test]
fn slice_dequant_matches_reference_exhaustive() {
    for &r in &WIDTHS {
        for ep in [false, true] {
            for (case, &(n, d_out)) in shape_grid().iter().enumerate() {
                for degenerate in [false, true] {
                    let seed = (case as u64) * 17 + r as u64;
                    let codes = testing::synth_master_codes(n, seed);
                    let packed = PackedTensor::pack(&codes, 8);
                    let scales = testing::synth_scales(d_out, seed ^ 0x55, degenerate);
                    let want =
                        testing::reference_slice_dequant(&packed, r, ep, &scales, d_out);
                    let got = kernels::slice_dequant(&packed, r, ep, &scales, d_out);
                    testing::assert_bits_eq(
                        &got,
                        &want,
                        &format!(
                            "slice_dequant r={r} ep={ep} n={n} d_out={d_out} deg={degenerate}"
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn slice_dequant_covers_every_master_code() {
    // All 256 master codes, every slice width, both Eq. 6 and Eq. 8.
    let codes: Vec<f32> = (0..256).map(|q| q as f32).collect();
    let packed = PackedTensor::pack(&codes, 8);
    let scales = testing::synth_scales(16, 99, false);
    for &r in &WIDTHS {
        for ep in [false, true] {
            let want = testing::reference_slice_dequant(&packed, r, ep, &scales, 16);
            let got = kernels::slice_dequant(&packed, r, ep, &scales, 16);
            testing::assert_bits_eq(&got, &want, &format!("all-codes r={r} ep={ep}"));
        }
    }
}

#[test]
fn registry_materialization_agrees_across_kernels() {
    // End-to-end: fused slice path (materialize) == fused packed-domain
    // path (materialize_packed) == the scalar reference, through real
    // minmax scales including a constant (EPS-guarded) column.
    let d_in = 32;
    let d_out = 12;
    let mut rng = matquant::data::Rng::new(42);
    let mut data: Vec<f32> = (0..d_in * d_out).map(|_| rng.range_f32(-1.5, 1.5)).collect();
    for row in 0..d_in {
        data[row * d_out + 5] = 0.25; // constant column → EPS guard
    }
    let fp = Tensor::new(vec![d_in, d_out], data).unwrap();
    let qt = QuantizedTensor::from_weight(fp, None, None, None).unwrap();
    for &bits in &WIDTHS {
        for ep in [false, true] {
            let (w_fused, _) = qt.materialize(bits, ep).unwrap();
            let (w_packed, _) = qt.materialize_packed(bits, ep).unwrap();
            let want = testing::reference_slice_dequant(&qt.codes, bits, ep, &qt.scales, d_out);
            testing::assert_bits_eq(
                &w_fused.data,
                &want,
                &format!("materialize bits={bits} ep={ep}"),
            );
            testing::assert_bits_eq(
                &w_packed.data,
                &want,
                &format!("materialize_packed bits={bits} ep={ep}"),
            );
        }
    }
}

#[test]
fn fused_kernels_reject_bad_shapes() {
    let packed = PackedTensor::pack(&[1.0, 0.0, 1.0], 2);
    let scales = testing::synth_scales(2, 1, false);
    // 3 entries do not divide into 2 channels
    let err = std::panic::catch_unwind(|| {
        let mut out = vec![0.0f32; 3];
        kernels::dequant_packed_into(&packed, None, &scales, 8, 2, &mut out);
    });
    assert!(err.is_err(), "shape mismatch must panic");
    // wrong output length
    let err = std::panic::catch_unwind(|| {
        let mut out = vec![0.0f32; 5];
        kernels::dequant_packed_into(&packed, None, &scales, 8, 1, &mut out);
    });
    assert!(err.is_err(), "length mismatch must panic");
}

#[test]
fn slice_dequant_agrees_with_scalar_slice_code() {
    // Spot-check the fused path against the rawest possible oracle: one
    // scalar slice_code + affine per element, no *_into helpers involved.
    let n = 64;
    let d_out = 4;
    let codes = testing::synth_master_codes(n, 77);
    let packed = PackedTensor::pack(&codes, 8);
    let scales = testing::synth_scales(d_out, 13, false);
    for &r in &WIDTHS {
        let got = kernels::slice_dequant(&packed, r, false, &scales, d_out);
        for (i, &g) in got.iter().enumerate() {
            let j = i % d_out;
            let s = quant::slice_code(codes[i], 8, r, false);
            let want = (s - scales.zero[j]) * scales.alpha[j];
            assert_eq!(g.to_bits(), want.to_bits(), "r={r} i={i}");
        }
    }
}
