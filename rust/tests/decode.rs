//! Incremental-decode conformance — the KV-cache counterpart of
//! `tests/forward.rs`.
//!
//! The load-bearing property: **N KV-cached decode steps produce logits
//! bit-identical to N full re-forwards** over the growing token stream —
//! on the dense f32 path (exact by construction: every op is
//! row-independent and the attention kernel is shared) and on the packed
//! path, across every supported r ∈ {1, 2, 3, 4, 6, 8} with and without
//! Eq. 8 extra-precision overlays.  If this holds, the decode engine is
//! free speed: same answers, O(n) per token instead of O(n²).
//!
//! Also here: Mix'n'Match per-layer plans vs the per-layer dense
//! reference, plan caching/payload sharing in the `WeightStore`,
//! calibration persistence, and the host server's multi-token streaming
//! (validation, greedy/temperature determinism, capacity truncation).
//!
//! Everything runs unconditionally — no artifacts, no PJRT.

use std::sync::Arc;

use matquant::data::Rng;
use matquant::model::manifest::ModelDims;
use matquant::model::testing::toy_transformer;
use matquant::model::{PrecisionAssignment, PresetInfo, QuantizedModel};
use matquant::quant::{ActCalibration, ActQuantConfig};
use matquant::runtime::{
    speculative_round, DecodeSession, ForwardPlan, ForwardWeights, HostForward, KvConfig,
    PagePool, Sampling,
};
use matquant::serve::{Metrics, PlanKey, PrecisionReq, Request, Server, ServerConfig, WeightStore};

fn toy_dims() -> ModelDims {
    ModelDims {
        vocab: 48,
        d_model: 24,
        n_layers: 2,
        n_heads: 3,
        d_ff: 48,
        seq_len: 10,
        quantize_attn: false,
    }
}

fn toy_model(seed: u64) -> (PresetInfo, QuantizedModel) {
    toy_transformer(toy_dims(), seed)
}

fn host_cfg(warm: Vec<u32>) -> ServerConfig {
    ServerConfig {
        preset: "toy".into(),
        max_wait_ms: 0.5,
        warm_bits: warm,
        ..ServerConfig::default()
    }
}

/// Drive `session` to the position capacity, asserting after the prefill
/// and after every step that its logits are bit-identical to the last
/// position of `reference_last_row(stream)`.
fn assert_decode_matches_reforward<F>(
    session: &mut DecodeSession,
    prompt: &[i32],
    reference_last_row: F,
    label: &str,
) where
    F: Fn(&[i32]) -> Vec<f32>,
{
    let mut stream: Vec<i32> = prompt.to_vec();
    let mut step = 0usize;
    loop {
        let want = reference_last_row(&stream);
        let got = session.logits();
        assert_eq!(got.len(), want.len(), "{label} step {step}: logit arity");
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "{label} step {step} logit {j}: {g} vs {w}"
            );
        }
        let (tok, _) = session.sample();
        stream.push(tok);
        if !session.can_advance() {
            break;
        }
        session.advance(tok).unwrap();
        step += 1;
    }
    assert!(step > 0, "{label}: no decode step was actually exercised");
}

// ---------------------------------------------------------------------------
// KV-cache equivalence (the acceptance property)
// ---------------------------------------------------------------------------

#[test]
fn cached_decode_bit_identical_to_full_reforward_dense() {
    // f32 path: N cached steps == N full re-forwards, bit for bit, for
    // every supported r and with extra-precision overlays.
    let (preset, model) = toy_model(11);
    let v = preset.model.vocab;
    let prompt: Vec<i32> = vec![3, 17, 2, 40];
    for bits in [1u32, 2, 3, 4, 6, 8] {
        for ep in [false, true] {
            let assign = PrecisionAssignment::Uniform {
                bits,
                extra_precision: ep,
            };
            let (weights, biases) = model.materialize(&assign).unwrap();
            let reference = HostForward::new(
                &preset.model,
                &model,
                ForwardWeights::Dense {
                    weights: &weights,
                    biases: &biases,
                },
            )
            .unwrap();
            let plan = Arc::new(
                ForwardPlan::from_dense(
                    &preset.model,
                    &model,
                    weights.clone(),
                    biases.clone(),
                )
                .unwrap(),
            );
            let mut session =
                DecodeSession::new(plan, &prompt, Sampling::Greedy).unwrap();
            assert_decode_matches_reforward(
                &mut session,
                &prompt,
                |stream| {
                    let t = stream.len();
                    let full = reference.forward(stream, 1, t).unwrap();
                    full.data[(t - 1) * v..t * v].to_vec()
                },
                &format!("dense bits={bits} ep={ep}"),
            );
        }
    }
}

#[test]
fn cached_decode_bit_identical_on_the_packed_path() {
    // Packed path: the fused GEMM processes rows independently (proven in
    // kernel tests), so cached steps match a full packed re-forward
    // exactly too — at every r, with and without overlays.
    let (preset, model) = toy_model(13);
    let v = preset.model.vocab;
    let prompt: Vec<i32> = vec![5, 9, 33];
    for bits in [1u32, 2, 3, 4, 6, 8] {
        for ep in [false, true] {
            let plan =
                ForwardPlan::packed_uniform(&preset.model, &model, bits, ep, None, None)
                    .unwrap();
            let full_plan = plan.clone();
            let mut session =
                DecodeSession::new(plan, &prompt, Sampling::Greedy).unwrap();
            assert_decode_matches_reforward(
                &mut session,
                &prompt,
                |stream| {
                    let t = stream.len();
                    let full = full_plan.forward(stream, 1, t).unwrap();
                    full.data[(t - 1) * v..t * v].to_vec()
                },
                &format!("packed bits={bits} ep={ep}"),
            );
        }
    }
}

#[test]
fn cached_decode_bit_identical_with_int8_activations() {
    // Per-token-row activation quantization keeps rows independent, so
    // even the integer-domain path decodes bit-identically to its own
    // full re-forward.
    let (preset, model) = toy_model(17);
    let v = preset.model.vocab;
    let prompt: Vec<i32> = vec![7, 21, 14, 2];
    for bits in [4u32, 8] {
        let plan = ForwardPlan::packed_uniform(
            &preset.model,
            &model,
            bits,
            false,
            Some(ActQuantConfig::absmax()),
            None,
        )
        .unwrap();
        let full_plan = plan.clone();
        let mut session = DecodeSession::new(plan, &prompt, Sampling::Greedy).unwrap();
        assert_decode_matches_reforward(
            &mut session,
            &prompt,
            |stream| {
                let t = stream.len();
                let full = full_plan.forward(stream, 1, t).unwrap();
                full.data[(t - 1) * v..t * v].to_vec()
            },
            &format!("i8 bits={bits}"),
        );
    }
}

#[test]
fn cached_decode_equivalence_property_sweep() {
    // Seeded property harness: random model seeds, prompt lengths,
    // contents, and precisions — the equivalence must hold everywhere, not
    // just on the hand-picked cases above.
    let mut rng = Rng::new(0xDEC0DE);
    let widths = [1u32, 2, 3, 4, 6, 8];
    for case in 0..6 {
        let (preset, model) = toy_model(100 + case);
        let v = preset.model.vocab;
        let bits = *rng.choose(&widths);
        let ep = rng.below(2) == 1;
        let plen = 1 + rng.below(preset.model.seq_len - 2);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(v) as i32).collect();
        let plan =
            ForwardPlan::packed_uniform(&preset.model, &model, bits, ep, None, None).unwrap();
        let full_plan = plan.clone();
        let mut session = DecodeSession::new(plan, &prompt, Sampling::Greedy).unwrap();
        assert_decode_matches_reforward(
            &mut session,
            &prompt,
            |stream| {
                let t = stream.len();
                let full = full_plan.forward(stream, 1, t).unwrap();
                full.data[(t - 1) * v..t * v].to_vec()
            },
            &format!("case {case} bits={bits} ep={ep} plen={plen}"),
        );
    }
}

// ---------------------------------------------------------------------------
// Session edges
// ---------------------------------------------------------------------------

#[test]
fn session_truncates_pads_and_stops_at_capacity() {
    let (preset, model) = toy_model(19);
    let seq = preset.model.seq_len;
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    // over-long prompt truncates to the position capacity and cannot step
    let long: Vec<i32> = (0..seq + 5).map(|i| (i % 7) as i32).collect();
    let mut s = DecodeSession::new(plan.clone(), &long, Sampling::Greedy).unwrap();
    assert_eq!(s.prompt_len(), seq);
    assert!(!s.can_advance());
    let (tok, _) = s.sample();
    assert!(s.advance(tok).is_err(), "capacity-full session must refuse to step");
    // empty prompt pads to one position, like the batch path
    let mut e = DecodeSession::new(plan.clone(), &[], Sampling::Greedy).unwrap();
    assert_eq!(e.prompt_len(), 1);
    assert!(e.can_advance());
    let (tok, _) = e.sample();
    e.advance(tok).unwrap();
    assert_eq!(e.positions(), 2);
    assert!(e.kv_bytes() > 0);
    // bad sampling params never build a session
    assert!(DecodeSession::new(
        plan,
        &[1, 2],
        Sampling::Temperature {
            temp: f32::NAN,
            seed: 1
        }
    )
    .is_err());
}

// ---------------------------------------------------------------------------
// Mix'n'Match per-layer plans (satellite: servable, not just rankable)
// ---------------------------------------------------------------------------

#[test]
fn per_layer_plan_matches_per_layer_dense_reference() {
    let (preset, model) = toy_model(41);
    let t = preset.model.seq_len;
    let tokens: Vec<i32> = (0..t).map(|i| ((i * 11 + 3) % preset.model.vocab) as i32).collect();
    let assign = vec![8u32, 2];
    // dense reference at the same per-layer assignment
    let (weights, biases) = model
        .materialize(&PrecisionAssignment::PerLayer {
            bits: assign.clone(),
            extra_precision: false,
        })
        .unwrap();
    let reference = HostForward::new(
        &preset.model,
        &model,
        ForwardWeights::Dense {
            weights: &weights,
            biases: &biases,
        },
    )
    .unwrap();
    let want = reference.forward(&tokens, 1, t).unwrap();
    // HostForward accepts the per-layer packed map directly
    let handles = model.packed_weights_per_layer(&assign, false).unwrap();
    let hf = HostForward::new(
        &preset.model,
        &model,
        ForwardWeights::Packed {
            packed: &handles,
            int8: None,
        },
    )
    .unwrap();
    let got_hf = hf.forward(&tokens, 1, t).unwrap();
    // the plan carries the same assignment
    let plan =
        ForwardPlan::packed_per_layer(&preset.model, &model, &assign, false, None, None).unwrap();
    assert_eq!(plan.per_layer.as_deref(), Some(&assign[..]));
    let got = plan.forward(&tokens, 1, t).unwrap();
    // plan ≡ HostForward on the packed path (same kernels, bit for bit)
    for (i, (g, h)) in got.data.iter().zip(&got_hf.data).enumerate() {
        assert_eq!(g.to_bits(), h.to_bits(), "plan vs HostForward logit {i}");
    }
    // and both match the dense per-layer reference within the usual
    // accumulation-order tolerance (cf. tests/forward.rs)
    for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
        let tol = 2e-3f32 * (1.0 + w.abs());
        assert!((g - w).abs() <= tol, "logit {i}: {g} vs {w}");
    }
    // the assignment must be live: all-int8 disagrees with [8, 2]
    let uni = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let u = uni.forward(&tokens, 1, t).unwrap();
    let max_diff = u
        .data
        .iter()
        .zip(&got.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff > 1e-3, "per-layer assignment was inert ({max_diff})");
}

// ---------------------------------------------------------------------------
// WeightStore plan caching + payload sharing
// ---------------------------------------------------------------------------

#[test]
fn weight_store_caches_plans_and_reuses_paged_payloads() {
    let (preset, model) = toy_model(43);
    let mut store = WeightStore::new();
    let mut metrics = Metrics::default();
    let p1 = store
        .plan_packed(&model, &preset.model, 4, None, &mut metrics)
        .unwrap();
    let p2 = store
        .plan_packed(&model, &preset.model, 4, None, &mut metrics)
        .unwrap();
    assert!(Arc::ptr_eq(&p1, &p2), "same spec must hit the cache");
    assert_eq!(store.plan_count(), 1);
    let paged_after_first = metrics.page_in_bytes(4);
    assert!(paged_after_first > 0, "packed plan must record its page-in");
    // int8 sibling at the same bits: a new plan, but zero new payload
    let p3 = store
        .plan_packed(
            &model,
            &preset.model,
            4,
            Some(ActQuantConfig::absmax()),
            &mut metrics,
        )
        .unwrap();
    assert!(!Arc::ptr_eq(&p1, &p3));
    assert_eq!(
        metrics.page_in_bytes(4),
        paged_after_first,
        "int8 sibling must reuse the paged payloads"
    );
    assert!(store.has_plan(&PlanKey::Packed { bits: 4, int8: true }));
    // a Mix'n'Match plan composes from the same handle sets
    let pl = store
        .plan_per_layer(&model, &preset.model, &[8, 4], None, &mut metrics)
        .unwrap();
    assert_eq!(pl.per_layer.as_deref(), Some(&[8u32, 4][..]));
    assert_eq!(
        metrics.page_in_bytes(4),
        paged_after_first,
        "per-layer plan must reuse the int4 handles"
    );
    assert_eq!(metrics.page_in_count(8), 1, "int8 handles paged on demand");
    // nested store: the masters became resident with the first precision,
    // so the int8 handles arrive as views — zero new bytes, savings counted
    assert_eq!(metrics.page_in_bytes(8), 0, "int8 views must not re-page");
    assert!(metrics.page_in_saved_bytes(8) > 0);
    // warm dense plan is f32-resident and heavier
    let w = store
        .plan_warm(&model, &preset.model, 8, &mut metrics)
        .unwrap();
    assert!(w.weight_bytes() > p1.weight_bytes());
    // Arc-backed registry params: the handles a plan resolves against ARE
    // the registry's tensors — sibling plans add zero parameter bytes, not
    // a deep copy of embed/pos per plan.
    let params = matquant::runtime::plan_params(&model);
    assert!(!params.is_empty());
    for (name, t) in &params {
        assert!(
            Arc::ptr_eq(t, &model.params[name]),
            "{name}: plan param deep-copied instead of sharing the registry Arc"
        );
    }
}

// ---------------------------------------------------------------------------
// Calibration: compute → persist → load → serve
// ---------------------------------------------------------------------------

#[test]
fn calibration_persists_and_serves_fixed_clips() {
    let (preset, model) = toy_model(31);
    let t = preset.model.seq_len;
    let v = preset.model.vocab;
    let tokens: Vec<i32> = (0..2 * t).map(|i| ((i * 7 + 1) % v) as i32).collect();
    let f32_plan =
        ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let cal = f32_plan
        .calibrate(&tokens, 2, t, &ActQuantConfig::clipped(0.999))
        .unwrap();
    for qn in &model.quantized_order {
        assert!(cal.clip_for(qn).unwrap_or(0.0) > 0.0, "{qn} uncalibrated");
    }
    // persist beside a (hypothetical) checkpoint and load back
    let dir = std::env::temp_dir().join("mq_decode_cal_test");
    let path = ActCalibration::beside(dir.join("model.mqck"));
    cal.save(&path).unwrap();
    let loaded = ActCalibration::load(&path).unwrap();
    assert_eq!(loaded, cal);
    // fixed-clip int8 forward stays within the usual i8 error of f32
    let i8_plan = ForwardPlan::packed_uniform(
        &preset.model,
        &model,
        8,
        false,
        Some(ActQuantConfig::absmax()),
        Some(&loaded),
    )
    .unwrap();
    let want = f32_plan.forward(&tokens[..t], 1, t).unwrap();
    let got = i8_plan.forward(&tokens[..t], 1, t).unwrap();
    let num: f32 = got
        .data
        .iter()
        .zip(&want.data)
        .map(|(g, w)| (g - w) * (g - w))
        .sum();
    let den: f32 = want.data.iter().map(|w| w * w).sum::<f32>().max(1e-12);
    let rel = (num / den).sqrt();
    assert!(rel > 0.0, "calibrated i8 path identical to f32 — inert?");
    assert!(rel < 0.15, "calibrated i8 rel err {rel}");
    // served end-to-end: the worker loads the sidecar at boot
    let cfg = ServerConfig {
        calibration: Some(path.clone()),
        ..host_cfg(vec![])
    };
    let server = Server::start_host(preset.clone(), model, cfg).unwrap();
    let req = Request {
        int8_acts: true,
        ..Request::generate(1, vec![1, 2, 3], PrecisionReq::Bits(8), 3, Sampling::Greedy)
    };
    let r = server.infer(req).unwrap();
    assert!(r.done);
    assert!(r.int8_acts);
    assert_eq!(r.tokens.len(), 3);
    assert!(r.tokens.iter().all(|&t| (0..v as i32).contains(&t)));
    server.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Host server: multi-token streaming end-to-end
// ---------------------------------------------------------------------------

#[test]
fn host_server_streams_greedy_generation() {
    let (preset, model) = toy_model(23);
    // expected stream: a direct session on the same (packed int4) plan
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let prompt = vec![1i32, 2, 3];
    let n = 4usize;
    let mut s = DecodeSession::new(plan, &prompt, Sampling::Greedy).unwrap();
    let mut expect = Vec::new();
    for k in 0..n {
        let (tok, _) = s.sample();
        expect.push(tok);
        if k + 1 < n {
            s.advance(tok).unwrap();
        }
    }

    let server = Server::start_host(preset.clone(), model, host_cfg(vec![])).unwrap();
    let rx = server
        .submit(Request::generate(
            7,
            prompt,
            PrecisionReq::Bits(4),
            n,
            Sampling::Greedy,
        ))
        .unwrap();
    let mut events = Vec::new();
    loop {
        let r = rx.recv().expect("stream must not close early");
        let done = r.done;
        events.push(r);
        if done {
            break;
        }
    }
    assert_eq!(events.len(), n, "one event per generated token");
    for (k, e) in events.iter().enumerate() {
        assert_eq!(e.id, 7);
        assert_eq!(e.bits, 4);
        assert_eq!(e.next_token, expect[k], "event {k}");
        assert_eq!(e.done, k + 1 == n);
        if !e.done {
            // intermediate events carry only next_token — the complete
            // stream rides on the final event
            assert!(e.tokens.is_empty(), "event {k} should not carry the stream");
        }
    }
    let last = events.last().unwrap();
    assert_eq!(last.tokens, expect, "final event carries the whole stream");
    assert!(last.prefill_ms >= 0.0 && last.decode_ms >= 0.0);
    let report = server.metrics_report().unwrap();
    assert!(report.contains("prefill=[int4:1x"), "{report}");
    assert!(report.contains("decode=[int4:3x"), "{report}");
    server.shutdown().unwrap();
}

#[test]
fn generation_truncates_at_capacity_with_done() {
    // prompt fills most of the window; the stream ends early, marked done,
    // instead of hanging on tokens that can never come.
    let (preset, model) = toy_model(29);
    let seq = preset.model.seq_len;
    let prompt: Vec<i32> = (0..seq - 2).map(|i| (i % 5) as i32).collect();
    let server = Server::start_host(preset.clone(), model, host_cfg(vec![])).unwrap();
    let r = server
        .infer(Request::generate(
            1,
            prompt,
            PrecisionReq::Bits(4),
            seq, // wants far more than capacity allows
            Sampling::Greedy,
        ))
        .unwrap();
    assert!(r.done);
    // prompt consumed seq-2 positions → 2 advances fit → 3 tokens total
    assert_eq!(r.tokens.len(), 3, "{:?}", r.tokens);
    server.shutdown().unwrap();
}

#[test]
fn temperature_sampling_is_deterministic_per_seed_through_the_server() {
    let (preset, model) = toy_model(37);
    let v = preset.model.vocab;
    let server = Server::start_host(preset.clone(), model, host_cfg(vec![])).unwrap();
    let sampling = Sampling::Temperature {
        temp: 0.9,
        seed: 1234,
    };
    let run = |id: u64| {
        server
            .infer(Request::generate(
                id,
                vec![4, 8, 15],
                PrecisionReq::Bits(4),
                5,
                sampling,
            ))
            .unwrap()
    };
    let a = run(1);
    let b = run(2);
    assert_eq!(a.tokens.len(), 5);
    assert_eq!(a.tokens, b.tokens, "same seed must reproduce the stream");
    assert!(a.tokens.iter().all(|&t| (0..v as i32).contains(&t)));
    server.shutdown().unwrap();
}

#[test]
fn malformed_generation_params_rejected_without_stalling_batchmates() {
    let (preset, model) = toy_model(47);
    let seq = preset.model.seq_len;
    let server = Server::start_host(preset.clone(), model, host_cfg(vec![])).unwrap();
    // max_new_tokens = 0: nothing to produce
    let zero = server
        .submit(Request::generate(1, vec![1], PrecisionReq::Bits(4), 0, Sampling::Greedy))
        .unwrap();
    // absurd max_new_tokens: past the position capacity
    let absurd = server
        .submit(Request::generate(
            2,
            vec![1],
            PrecisionReq::Bits(4),
            seq + 1,
            Sampling::Greedy,
        ))
        .unwrap();
    // malformed temperatures
    let nan_temp = server
        .submit(Request::generate(
            3,
            vec![1],
            PrecisionReq::Bits(4),
            2,
            Sampling::Temperature {
                temp: f32::NAN,
                seed: 1,
            },
        ))
        .unwrap();
    let zero_temp = server
        .submit(Request::generate(
            4,
            vec![1],
            PrecisionReq::Bits(4),
            2,
            Sampling::Temperature { temp: 0.0, seed: 1 },
        ))
        .unwrap();
    // a valid batchmate at the same precision still gets served
    let good = server
        .submit(Request::generate(5, vec![1, 2], PrecisionReq::Bits(4), 2, Sampling::Greedy))
        .unwrap();
    assert!(zero.recv().is_err(), "max_new_tokens=0 must reject");
    assert!(absurd.recv().is_err(), "absurd max_new_tokens must reject");
    assert!(nan_temp.recv().is_err(), "NaN temperature must reject");
    assert!(zero_temp.recv().is_err(), "zero temperature must reject");
    let r = good.recv().expect("valid batchmate must still be answered");
    assert_eq!(r.id, 5);
    server.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// Paged KV: page-boundary conformance, CoW sharing, pool residency
// ---------------------------------------------------------------------------

#[test]
fn paged_f32_decode_bit_identical_across_page_sizes_and_boundary_prompts() {
    // The tentpole acceptance property: the block-table walk over f32
    // pages performs the exact float ops of the contiguous kernel, so ANY
    // page size — including prompts landing exactly on, one short of, and
    // one past a page boundary — reproduces the full re-forward bit for
    // bit.
    let (preset, model) = toy_model(59);
    let v = preset.model.vocab;
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    for ps in [3usize, 4, 5] {
        for plen in [ps - 1, ps, ps + 1] {
            let prompt: Vec<i32> = (0..plen).map(|i| ((i * 13 + 5) % v) as i32).collect();
            let pool = PagePool::unbounded(KvConfig::f32_paged(ps));
            let mut session = DecodeSession::with_budget_pooled(
                plan.clone(),
                &prompt,
                Sampling::Greedy,
                usize::MAX,
                Some(&pool),
            )
            .unwrap();
            let full_plan = plan.clone();
            assert_decode_matches_reforward(
                &mut session,
                &prompt,
                |stream| {
                    let t = stream.len();
                    let full = full_plan.forward(stream, 1, t).unwrap();
                    full.data[(t - 1) * v..t * v].to_vec()
                },
                &format!("paged ps={ps} plen={plen}"),
            );
        }
    }
}

#[test]
fn speculative_windows_cross_page_boundaries_losslessly() {
    let (preset, model) = toy_model(61);
    let target = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let draft = ForwardPlan::packed_uniform(&preset.model, &model, 2, false, None, None).unwrap();
    let prompt = vec![3i32, 1, 4];
    // Plain reference stream on the wide default pages (one page holds the
    // whole toy window — the contiguous baseline).
    let mut plain = DecodeSession::new(target.clone(), &prompt, Sampling::Greedy).unwrap();
    let mut expect = Vec::new();
    loop {
        let (tok, _) = plain.sample();
        expect.push(tok);
        if !plain.can_advance() {
            break;
        }
        plain.advance(tok).unwrap();
    }
    // ps=3: the prompt fills page 0 exactly, so every 3-wide verify window
    // spans a page boundary, and every rejection rolls K/V back mid-page.
    let pool = PagePool::unbounded(KvConfig::f32_paged(3));
    let mut s = DecodeSession::with_budget_pooled(
        target.clone(),
        &prompt,
        Sampling::Greedy,
        usize::MAX,
        Some(&pool),
    )
    .unwrap();
    let (mut last, _) = s.sample();
    while s.generated().len() < expect.len() {
        let w = s.spec_window().min(3);
        if w >= 2 {
            let r = speculative_round(&mut [&mut s], &draft, &[last], w).unwrap();
            last = r[0].emitted.last().unwrap().0;
        } else if s.can_advance() {
            s.advance(last).unwrap();
            let (tok, _) = s.sample();
            last = tok;
        } else {
            break;
        }
    }
    assert_eq!(s.generated(), &expect[..], "speculative paged stream diverged");
    drop(s);
    assert_eq!(pool.resident_pages(), 0, "dropped session must release its pages");
}

#[test]
fn elastic_switch_plan_on_a_paged_session_stays_bit_identical() {
    let (preset, model) = toy_model(67);
    let p8 = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let p2 = ForwardPlan::packed_uniform(&preset.model, &model, 2, false, None, None).unwrap();
    let prompt = vec![2i32, 7, 1, 8, 2];
    // The same down-then-up shift schedule on the wide default pages and
    // on 2-row pages must produce identical logits at every step: cached
    // K/V rows carry across both the plan swap and the page cuts.
    let run = |pool: Option<&PagePool>| -> Vec<Vec<f32>> {
        let mut s = DecodeSession::with_budget_pooled(
            p8.clone(),
            &prompt,
            Sampling::Greedy,
            usize::MAX,
            pool,
        )
        .unwrap();
        let mut rows = vec![s.logits().to_vec()];
        let mut step = 0usize;
        loop {
            let (tok, _) = s.sample();
            if !s.can_advance() {
                break;
            }
            if step == 2 {
                s.switch_plan(p2.clone()).unwrap();
            }
            if step == 4 {
                s.switch_plan(p8.clone()).unwrap();
            }
            s.advance(tok).unwrap();
            rows.push(s.logits().to_vec());
            step += 1;
        }
        rows
    };
    let want = run(None);
    let pool = PagePool::unbounded(KvConfig::f32_paged(2));
    let got = run(Some(&pool));
    assert_eq!(want.len(), got.len(), "shifted runs diverged in length");
    for (i, (w, g)) in want.iter().zip(&got).enumerate() {
        for (j, (a, b)) in w.iter().zip(g).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "step {i} logit {j}: {a} vs {b}");
        }
    }
    assert_eq!(pool.resident_pages(), 0);
}

#[test]
fn cow_prefix_sharing_matches_solo_prefill_bit_for_bit() {
    let (preset, model) = toy_model(71);
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let pool = PagePool::unbounded(KvConfig::f32_paged(2));
    let donor_prompt = vec![5i32, 9, 33, 2, 7, 1];
    let donor = DecodeSession::with_budget_pooled(
        plan.clone(),
        &donor_prompt,
        Sampling::Greedy,
        4,
        Some(&pool),
    )
    .unwrap();
    // First 4 tokens (2 whole pages) shared, then the prompts diverge.
    let sharer_prompt = vec![5i32, 9, 33, 2, 40, 3];
    let shared = 4usize;
    let via_share = DecodeSession::prefill_shared(
        &plan,
        &sharer_prompt,
        Sampling::Greedy,
        4,
        &pool,
        &donor,
        shared,
    )
    .unwrap();
    assert!(pool.shared_bytes() > 0, "no pages were actually shared");
    let solo =
        DecodeSession::with_budget_pooled(plan.clone(), &sharer_prompt, Sampling::Greedy, 4, None)
            .unwrap();
    for (j, (a, b)) in via_share.logits().iter().zip(solo.logits()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "shared-prefill logit {j}: {a} vs {b}"
        );
    }
    // Both sharer variants — and the donor — decode exactly like solo runs.
    let drive = |mut s: DecodeSession| -> Vec<i32> {
        for k in 0..4 {
            let (tok, _) = s.sample();
            if k + 1 < 4 && s.can_advance() {
                s.advance(tok).unwrap();
            }
        }
        s.generated().to_vec()
    };
    assert_eq!(drive(via_share), drive(solo), "sharer stream diverged");
    let donor_solo = DecodeSession::with_budget_pooled(
        plan.clone(),
        &donor_prompt,
        Sampling::Greedy,
        4,
        None,
    )
    .unwrap();
    assert_eq!(
        drive(donor),
        drive(donor_solo),
        "donor stream corrupted by sharing"
    );
    assert_eq!(pool.resident_pages(), 0, "all pages must return to the pool");
}

#[test]
fn session_kv_bytes_track_resident_pages_not_capacity() {
    let (preset, model) = toy_model(73);
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let pool = PagePool::unbounded(KvConfig::f32_paged(4));
    let page = KvConfig::f32_paged(4).page_bytes(preset.model.d_model);
    let prompt = vec![1i32, 2];
    let mut s = DecodeSession::with_budget_pooled(
        plan.clone(),
        &prompt,
        Sampling::Greedy,
        8,
        Some(&pool),
    )
    .unwrap();
    // 2 prompt rows map ONE page per layer — not the 8-position capacity.
    assert_eq!(s.kv_bytes(), preset.model.n_layers * page);
    assert_eq!(pool.resident_bytes() as usize, s.kv_bytes());
    for _ in 0..3 {
        let (tok, _) = s.sample();
        s.advance(tok).unwrap();
    }
    // 5 rows cross the 4-row boundary → a second page per layer appears.
    assert_eq!(s.positions(), 5);
    assert_eq!(s.kv_bytes(), preset.model.n_layers * 2 * page);
    drop(s);
    assert_eq!(pool.resident_bytes(), 0);
}

#[test]
fn kv_gauge_returns_to_zero_after_streams_finish() {
    let (preset, model) = toy_model(53);
    let server = Server::start_host(preset.clone(), model, host_cfg(vec![])).unwrap();
    let r = server
        .infer(Request::generate(
            1,
            vec![2, 4, 6],
            PrecisionReq::Bits(2),
            4,
            Sampling::Greedy,
        ))
        .unwrap();
    assert_eq!(r.tokens.len(), 4);
    let report = server.metrics_report().unwrap();
    assert!(report.contains("kv_bytes=0"), "{report}");
    server.shutdown().unwrap();
}
