//! Continuous-batching scheduler conformance — the step-round counterpart
//! of `tests/decode.rs`.
//!
//! The load-bearing property: **round composition can never change an
//! answer**.  A session stepped inside a batched GEMM round — whatever its
//! roundmates, whenever it was admitted, however the rounds interleave —
//! produces logits and token streams **bit-identical** to the same session
//! stepped alone, across every r ∈ {1, 2, 3, 4, 6, 8}, with and without
//! int8 activations, and under Mix'n'Match per-layer maps.  Batched ragged
//! prefill obeys the same contract against solo prefill.
//!
//! Also here: the acceptance scenario (a 3-session batched round with one
//! mid-stream admission and one KV-capacity truncation, byte-identical to
//! three solo sessions, at int2/int4/int8), KV-pressure admission deferral
//! (defer, never evict), the truncation-mid-round containment bugfix, a
//! seeded property sweep with staggered admissions/completions, and the
//! round metrics contract (payload bytes counted once per ROUND, not once
//! per session).
//!
//! Everything runs unconditionally — no artifacts, no PJRT.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use matquant::data::Rng;
use matquant::model::manifest::ModelDims;
use matquant::model::testing::toy_transformer;
use matquant::model::{PresetInfo, QuantizedModel};
use matquant::quant::ActQuantConfig;
use matquant::runtime::{advance_sessions, DecodeSession, ForwardPlan, Sampling};
use matquant::serve::{
    projected_kv_bytes, KvConfig, Metrics, PlanKey, PrecisionReq, Request, Response, Scheduler,
    SchedulerConfig, Server, ServerConfig, SpeculativeConfig,
};

fn toy_dims() -> ModelDims {
    ModelDims {
        vocab: 48,
        d_model: 24,
        n_layers: 2,
        n_heads: 3,
        d_ff: 48,
        seq_len: 10,
        quantize_attn: false,
    }
}

fn toy_model(seed: u64) -> (PresetInfo, QuantizedModel) {
    toy_transformer(toy_dims(), seed)
}

/// One spec: (prompt, sampling, max_new_tokens).
type Spec = (Vec<i32>, Sampling, usize);

/// Run one session solo to completion, recording the logits bit-pattern at
/// every sampling point and the final token stream — the reference every
/// batched execution must reproduce exactly.
fn solo_trace(plan: &Arc<ForwardPlan>, spec: &Spec) -> (Vec<Vec<u32>>, Vec<i32>) {
    let (prompt, sampling, max_new) = spec;
    let mut s = DecodeSession::with_budget(plan.clone(), prompt, *sampling, *max_new).unwrap();
    let mut traces = Vec::new();
    let mut remaining = *max_new;
    loop {
        traces.push(s.logits().iter().map(|x| x.to_bits()).collect::<Vec<u32>>());
        let (tok, _) = s.sample();
        remaining -= 1;
        if remaining == 0 || !s.can_advance() {
            break;
        }
        s.advance(tok).unwrap();
    }
    (traces, s.generated().to_vec())
}

/// Drive a set of specs through batched prefill + batched step rounds,
/// asserting every member's logits are bit-identical to its solo trace at
/// every step.  Members retire as they finish (staggered completions), so
/// later rounds run narrower — exactly what the scheduler does.
fn assert_batched_matches_solo(plan: &Arc<ForwardPlan>, specs: &[Spec], label: &str) {
    let n = specs.len();
    let solos: Vec<(Vec<Vec<u32>>, Vec<i32>)> =
        specs.iter().map(|sp| solo_trace(plan, sp)).collect();
    let spec_refs: Vec<(&[i32], Sampling, usize)> = specs
        .iter()
        .map(|(p, s, m)| (p.as_slice(), *s, *m))
        .collect();
    let mut sessions = DecodeSession::prefill_many(plan, &spec_refs).unwrap();
    let mut remaining: Vec<usize> = specs.iter().map(|(_, _, m)| *m).collect();
    let mut step_idx = vec![0usize; n];
    let mut streams: Vec<Vec<i32>> = vec![Vec::new(); n];
    let mut active: Vec<usize> = (0..n).collect();
    while !active.is_empty() {
        let mut tokens = Vec::with_capacity(active.len());
        for &i in &active {
            let got: Vec<u32> = sessions[i].logits().iter().map(|x| x.to_bits()).collect();
            assert_eq!(
                got, solos[i].0[step_idx[i]],
                "{label}: member {i} step {} logits diverged from solo",
                step_idx[i]
            );
            let (tok, _) = sessions[i].sample();
            streams[i].push(tok);
            tokens.push(tok);
            remaining[i] -= 1;
            step_idx[i] += 1;
        }
        let mut next_active = Vec::new();
        let mut next_tokens = Vec::new();
        for (k, &i) in active.iter().enumerate() {
            if remaining[i] > 0 && sessions[i].can_advance() {
                next_active.push(i);
                next_tokens.push(tokens[k]);
            }
        }
        if next_active.is_empty() {
            break;
        }
        let mut refs: Vec<&mut DecodeSession> = sessions
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| next_active.contains(i))
            .map(|(_, s)| s)
            .collect();
        advance_sessions(&mut refs, &next_tokens).unwrap();
        active = next_active;
    }
    for i in 0..n {
        assert_eq!(
            streams[i], solos[i].1,
            "{label}: member {i} token stream diverged from solo"
        );
    }
}

// ---------------------------------------------------------------------------
// Bit-identity of batched rounds and ragged prefill (the core contract)
// ---------------------------------------------------------------------------

#[test]
fn batched_rounds_bit_identical_to_solo_across_precisions() {
    let (preset, model) = toy_model(61);
    // Different lengths (ragged prefill), different budgets (staggered
    // completions), mixed samplers.
    let specs: Vec<Spec> = vec![
        (vec![1, 2, 3], Sampling::Greedy, 5),
        (
            vec![4, 5, 6, 7, 8, 9],
            Sampling::Temperature { temp: 0.8, seed: 7 },
            3,
        ),
        (vec![10], Sampling::Greedy, 6),
    ];
    for bits in [1u32, 2, 3, 4, 6, 8] {
        for int8 in [false, true] {
            let cfg = int8.then(ActQuantConfig::absmax);
            let plan =
                ForwardPlan::packed_uniform(&preset.model, &model, bits, false, cfg, None)
                    .unwrap();
            assert_batched_matches_solo(&plan, &specs, &format!("int{bits} i8={int8}"));
        }
    }
}

#[test]
fn batched_rounds_bit_identical_under_per_layer_maps() {
    let (preset, model) = toy_model(67);
    let specs: Vec<Spec> = vec![
        (vec![2, 4, 6, 8], Sampling::Greedy, 4),
        (vec![1, 3], Sampling::Greedy, 5),
        (vec![5, 7, 9, 11, 13], Sampling::Temperature { temp: 1.1, seed: 3 }, 2),
    ];
    for (assign, int8) in [(vec![8u32, 2], false), (vec![2u32, 6], true)] {
        let cfg = int8.then(ActQuantConfig::absmax);
        let plan =
            ForwardPlan::packed_per_layer(&preset.model, &model, &assign, false, cfg, None)
                .unwrap();
        assert_batched_matches_solo(&plan, &specs, &format!("mix{assign:?} i8={int8}"));
    }
}

#[test]
fn empty_and_overlong_prompts_round_trip_through_batched_prefill() {
    let (preset, model) = toy_model(71);
    let seq = preset.model.seq_len;
    let long: Vec<i32> = (0..2 * seq as i32).map(|i| i % 40).collect();
    let specs: Vec<Spec> = vec![
        (vec![], Sampling::Greedy, 3),       // pads to [0], like the server
        (long, Sampling::Greedy, 2),         // truncates to seq tokens
        (vec![17, 23], Sampling::Greedy, 4),
    ];
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    assert_batched_matches_solo(&plan, &specs, "edge prompts");
}

// ---------------------------------------------------------------------------
// Scheduler harness
// ---------------------------------------------------------------------------

struct Ev {
    round: usize,
    resp: Response,
}

type Inject = (usize, PlanKey, Arc<ForwardPlan>, u32, bool, Request);

/// Run the scheduler to drain, injecting each request at its scheduled
/// round (mid-stream admission).  Returns every event per request id.
fn drive(
    sched: &mut Scheduler,
    metrics: &mut Metrics,
    mut inject: Vec<Inject>,
    max_rounds: usize,
) -> BTreeMap<u64, Vec<Ev>> {
    let mut events: BTreeMap<u64, Vec<Ev>> = BTreeMap::new();
    let mut round = 0usize;
    loop {
        while let Some(pos) = inject.iter().position(|(r, ..)| *r <= round) {
            let (_, key, plan, bits, int8, req) = inject.remove(pos);
            sched.submit(key, plan, bits, int8, req, Instant::now());
        }
        if inject.is_empty() && !sched.has_work() {
            break;
        }
        let events_ref = &mut events;
        sched.run_round(metrics, &mut |id, resp| {
            events_ref.entry(id).or_default().push(Ev { round, resp });
            true
        });
        round += 1;
        assert!(
            round < max_rounds,
            "scheduler failed to drain within {max_rounds} rounds"
        );
    }
    events
}

/// Events → (per-event token sequence, final stream); checks the event
/// envelope (exactly one done, final carries the stream, intermediates
/// carry only next_token).
fn stream_of(events: &[Ev], id: u64) -> (Vec<i32>, Vec<i32>) {
    assert!(!events.is_empty(), "request {id} got no events");
    let toks: Vec<i32> = events.iter().map(|e| e.resp.next_token).collect();
    for e in &events[..events.len() - 1] {
        assert!(!e.resp.done, "request {id}: early done event");
        assert!(
            e.resp.tokens.is_empty(),
            "request {id}: intermediate event carries the stream"
        );
    }
    let last = events.last().unwrap();
    assert!(last.resp.done, "request {id}: stream never finished");
    (toks, last.resp.tokens.clone())
}

// ---------------------------------------------------------------------------
// Acceptance: 3-session round, mid-stream admission, KV truncation
// ---------------------------------------------------------------------------

#[test]
fn three_session_rounds_with_admission_and_truncation_match_solo() {
    let (preset, model) = toy_model(73);
    let seq = preset.model.seq_len;
    for bits in [2u32, 4, 8] {
        let plan =
            ForwardPlan::packed_uniform(&preset.model, &model, bits, false, None, None).unwrap();
        let key = PlanKey::Packed { bits, int8: false };
        // A: plain stream.  B: prompt fills most of the window, so the KV
        // position capacity truncates it mid-stream.  C: admitted two
        // rounds in (mid-stream admission into a running group).
        let spec_a: Spec = (vec![1, 2, 3], Sampling::Greedy, 4);
        let spec_b: Spec = (
            (0..seq as i32 - 2).map(|i| i % 5).collect(),
            Sampling::Greedy,
            seq, // wants far more than capacity allows → truncation
        );
        let spec_c: Spec = (vec![4, 5], Sampling::Greedy, 4);
        let mk = |id: u64, sp: &Spec| {
            Request::generate(id, sp.0.clone(), PrecisionReq::Bits(bits), sp.2, sp.1)
        };
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut metrics = Metrics::default();
        let inject: Vec<Inject> = vec![
            (0, key.clone(), plan.clone(), bits, false, mk(1, &spec_a)),
            (0, key.clone(), plan.clone(), bits, false, mk(2, &spec_b)),
            (2, key.clone(), plan.clone(), bits, false, mk(3, &spec_c)),
        ];
        let events = drive(&mut sched, &mut metrics, inject, 64);
        assert_eq!(events.len(), 3, "int{bits}: every request must answer");

        for (id, sp) in [(1u64, &spec_a), (2, &spec_b), (3, &spec_c)] {
            let (toks, fin) = stream_of(&events[&id], id);
            let (_, want) = solo_trace(&plan, sp);
            assert_eq!(toks, want, "int{bits} req {id}: stream != solo session");
            assert_eq!(fin, want, "int{bits} req {id}: final stream != solo");
        }
        // B truncated by capacity: prompt consumed seq-2 positions → 2
        // advances fit → 3 tokens, despite asking for `seq`.
        assert_eq!(events[&2].len(), 3, "int{bits}: truncation event count");
        // C joined mid-stream: its first event is 2+ rounds in, while A
        // was already streaming from round 0.
        assert_eq!(events[&1][0].round, 0);
        assert!(
            events[&3][0].round >= 2,
            "int{bits}: C admitted at round {}",
            events[&3][0].round
        );
        // C's later steps rode shared rounds with A: occupancy above 1.
        assert!(
            metrics.mean_round_occupancy(bits) > 1.0,
            "int{bits}: rounds never batched (occupancy {})",
            metrics.mean_round_occupancy(bits)
        );
        // The round counters prove the payload streamed once per ROUND,
        // not once per member-step.
        let rounds = metrics.rounds(bits);
        assert!(rounds > 0);
        assert!(metrics.round_member_steps(bits) > rounds);
        assert_eq!(
            metrics.round_weight_bytes(bits),
            rounds * plan.weight_bytes() as u64,
            "int{bits}: weight bytes must grow per round, not per session"
        );
    }
}

#[test]
fn truncated_member_retires_without_stalling_roundmates() {
    let (preset, model) = toy_model(79);
    let seq = preset.model.seq_len;
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let key = PlanKey::Packed { bits: 4, int8: false };
    // B hits the position window after 2 advances; A runs the full budget.
    let spec_a: Spec = (vec![1, 2], Sampling::Greedy, 8);
    let spec_b: Spec = ((0..seq as i32 - 2).map(|i| i % 7).collect(), Sampling::Greedy, seq);
    let mut sched = Scheduler::new(SchedulerConfig::default());
    let mut metrics = Metrics::default();
    let inject: Vec<Inject> = vec![
        (
            0,
            key.clone(),
            plan.clone(),
            4,
            false,
            Request::generate(1, spec_a.0.clone(), PrecisionReq::Bits(4), spec_a.2, spec_a.1),
        ),
        (
            0,
            key.clone(),
            plan.clone(),
            4,
            false,
            Request::generate(2, spec_b.0.clone(), PrecisionReq::Bits(4), spec_b.2, spec_b.1),
        ),
    ];
    let events = drive(&mut sched, &mut metrics, inject, 64);
    let (a_toks, _) = stream_of(&events[&1], 1);
    let (b_toks, _) = stream_of(&events[&2], 2);
    assert_eq!(b_toks.len(), 3, "B must truncate at capacity");
    assert_eq!(a_toks.len(), 8, "A must keep stepping after B's truncation");
    let (_, a_want) = solo_trace(&plan, &spec_a);
    let (_, b_want) = solo_trace(&plan, &spec_b);
    assert_eq!(a_toks, a_want);
    assert_eq!(b_toks, b_want);
    // A's final rounds ran solo (occupancy sinks back toward 1), but every
    // stream stayed intact — no cross-session fallout from the truncation.
    assert_eq!(sched.live_sessions(), 0);
    assert_eq!(sched.pending_prefills(), 0);
}

// ---------------------------------------------------------------------------
// KV-pressure admission: defer, never evict
// ---------------------------------------------------------------------------

#[test]
fn kv_pressure_defers_prefills_and_serves_them_later() {
    let (preset, model) = toy_model(83);
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let key = PlanKey::Packed { bits: 4, int8: false };
    // Each session: prompt 3 + (5-1) new = capacity 7 positions, page-
    // rounded under 2-row pages.  The budget fits exactly ONE projection,
    // so the second prefill must wait until the first stream fully drains.
    let kv = KvConfig::f32_paged(2);
    let spec: Spec = (vec![1, 2, 3], Sampling::Greedy, 5);
    let per_session = projected_kv_bytes(&preset.model, 3, 5, 0, &kv);
    let budget = per_session;
    let mut sched = Scheduler::new(SchedulerConfig {
        max_prefills_per_round: 4,
        kv_capacity_bytes: Some(budget),
        kv,
    });
    let mut metrics = Metrics::default();
    let mk = |id: u64| {
        Request::generate(id, spec.0.clone(), PrecisionReq::Bits(4), spec.2, spec.1)
    };
    for id in [1u64, 2] {
        sched.submit(key.clone(), plan.clone(), 4, false, mk(id), Instant::now());
    }
    let mut events: BTreeMap<u64, Vec<Ev>> = BTreeMap::new();
    let mut deferred_seen = false;
    let mut round = 0usize;
    while sched.has_work() {
        let events_ref = &mut events;
        sched.run_round(&mut metrics, &mut |id, resp| {
            events_ref.entry(id).or_default().push(Ev { round, resp });
            true
        });
        assert!(
            sched.resident_kv_bytes() <= budget,
            "round {round}: resident {} exceeds budget {budget}",
            sched.resident_kv_bytes()
        );
        if sched.pending_prefills() > 0 {
            deferred_seen = true;
        }
        round += 1;
        assert!(round < 64, "KV-deferred scheduler failed to drain");
    }
    assert!(deferred_seen, "the second prefill was never deferred");
    let (_, want) = solo_trace(&plan, &spec);
    for id in [1u64, 2] {
        let (toks, fin) = stream_of(&events[&id], id);
        assert_eq!(toks, want, "req {id}: deferred stream diverged");
        assert_eq!(fin, want);
    }
    // The deferred request was admitted only after the first finished.
    assert!(events[&2][0].round > events[&1][0].round);
}

#[test]
fn admission_is_page_granular_against_actual_usage() {
    // Regression for the whole-stream-reservation gauge: admission holds
    // the budget against pages the pool has actually checked out, so a
    // later request fits as soon as `resident + its projection` does —
    // even when the SUM of both projections exceeds the budget (which the
    // old reservation accounting would have serialized).
    let (preset, model) = toy_model(83);
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let key = PlanKey::Packed { bits: 4, int8: false };
    let kv = KvConfig::f32_paged(2);
    let spec: Spec = (vec![1, 2, 3], Sampling::Greedy, 5);
    let per_session = projected_kv_bytes(&preset.model, 3, 5, 0, &kv);
    // One byte short of two full projections: reservation accounting
    // could never run these concurrently.
    let budget = 2 * per_session - 1;
    let mut sched = Scheduler::new(SchedulerConfig {
        max_prefills_per_round: 4,
        kv_capacity_bytes: Some(budget),
        kv,
    });
    let mut metrics = Metrics::default();
    let mk = |id: u64| Request::generate(id, spec.0.clone(), PrecisionReq::Bits(4), spec.2, spec.1);
    // A at round 0; B arrives at round 1, while A is live but still pages
    // short of its full projection.
    let inject: Vec<Inject> = vec![
        (0, key.clone(), plan.clone(), 4, false, mk(1)),
        (1, key.clone(), plan.clone(), 4, false, mk(2)),
    ];
    let events = drive(&mut sched, &mut metrics, inject, 64);
    let (_, want) = solo_trace(&plan, &spec);
    for id in [1u64, 2] {
        let (toks, fin) = stream_of(&events[&id], id);
        assert_eq!(toks, want, "req {id}: stream diverged under page-granular admission");
        assert_eq!(fin, want);
    }
    // B went live while A was still streaming — the streams overlapped.
    assert!(
        events[&2][0].round <= events[&1].last().unwrap().round,
        "B (first event round {}) never overlapped A (last event round {})",
        events[&2][0].round,
        events[&1].last().unwrap().round
    );
}

#[test]
fn cow_prefix_sharing_through_the_scheduler_keeps_streams_solo_identical() {
    let (preset, model) = toy_model(91);
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let key = PlanKey::Packed { bits: 4, int8: false };
    // 2-row pages so a 4-token common prefix spans two whole shareable
    // pages of the toy window.
    let mut sched = Scheduler::new(SchedulerConfig {
        max_prefills_per_round: 4,
        kv_capacity_bytes: None,
        kv: KvConfig::f32_paged(2),
    });
    let mut metrics = Metrics::default();
    let donor_spec: Spec = (vec![7, 7, 1, 2, 9, 4], Sampling::Greedy, 6);
    let sharer_spec: Spec = (vec![7, 7, 1, 2, 30, 5], Sampling::Greedy, 4);
    let mk = |id: u64, sp: &Spec| {
        Request::generate(id, sp.0.clone(), PrecisionReq::Bits(4), sp.2, sp.1)
    };
    // The sharer arrives two rounds in, while the donor is live — its
    // 4-token page-aligned common prefix adopts the donor's pages and only
    // the suffix prefills.
    let inject: Vec<Inject> = vec![
        (0, key.clone(), plan.clone(), 4, false, mk(1, &donor_spec)),
        (2, key.clone(), plan.clone(), 4, false, mk(2, &sharer_spec)),
    ];
    let events = drive(&mut sched, &mut metrics, inject, 64);
    for (id, sp) in [(1u64, &donor_spec), (2, &sharer_spec)] {
        let (toks, fin) = stream_of(&events[&id], id);
        let (_, want) = solo_trace(&plan, sp);
        assert_eq!(toks, want, "req {id}: CoW sharing changed the stream");
        assert_eq!(fin, want);
    }
    // Pages were actually shared, and the savings reached the gauges.
    assert!(
        sched.pool().shared_bytes() > 0,
        "no pages were shared through admission"
    );
    assert!(metrics.kv_shared_bytes() > 0, "shared-page gauge never set");
    assert_eq!(sched.live_sessions(), 0);
    assert_eq!(metrics.kv_pages(), 0, "page gauge must drain to zero");
    assert!(metrics.report().contains("kv=[pages:0 shared:"), "{}", metrics.report());
}

// ---------------------------------------------------------------------------
// Property sweep: staggered admissions/completions across precision groups
// ---------------------------------------------------------------------------

#[test]
fn property_sweep_staggered_admissions_match_solo_streams() {
    let (preset, model) = toy_model(89);
    let seq = preset.model.seq_len;
    // Plan pool: uniform precisions ± int8, plus a per-layer map — every
    // scheduler group shape.
    let pool: Vec<(PlanKey, Arc<ForwardPlan>, u32, bool)> = vec![
        (
            PlanKey::Packed { bits: 2, int8: false },
            ForwardPlan::packed_uniform(&preset.model, &model, 2, false, None, None).unwrap(),
            2,
            false,
        ),
        (
            PlanKey::Packed { bits: 4, int8: false },
            ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap(),
            4,
            false,
        ),
        (
            PlanKey::Packed { bits: 4, int8: true },
            ForwardPlan::packed_uniform(
                &preset.model,
                &model,
                4,
                false,
                Some(ActQuantConfig::absmax()),
                None,
            )
            .unwrap(),
            4,
            true,
        ),
        (
            PlanKey::Packed { bits: 8, int8: false },
            ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap(),
            8,
            false,
        ),
        (
            PlanKey::PerLayer { bits: vec![8, 2], int8: false },
            ForwardPlan::packed_per_layer(&preset.model, &model, &[8, 2], false, None, None)
                .unwrap(),
            8,
            false,
        ),
    ];
    for seed in 0..3u64 {
        let mut rng = Rng::new(1000 + seed);
        let n_req = 6 + rng.below(3); // 6..=8 requests
        let mut inject: Vec<Inject> = Vec::new();
        let mut expected: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
        for id in 0..n_req as u64 {
            let (key, plan, bits, int8) = pool[rng.below(pool.len())].clone();
            let plen = rng.below(seq - 2); // 0..=seq-3 (empty prompts too)
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(40) as i32).collect();
            let max_new = 1 + rng.below(6); // 1..=6
            let sampling = if rng.below(2) == 0 {
                Sampling::Greedy
            } else {
                Sampling::Temperature {
                    temp: 0.5 + rng.f64() as f32,
                    seed: rng.next_u64(),
                }
            };
            let admit_round = rng.below(5);
            let spec: Spec = (prompt.clone(), sampling, max_new);
            let (_, want) = solo_trace(&plan, &spec);
            expected.insert(id, want);
            let req = Request {
                int8_acts: int8,
                ..Request::generate(id, prompt, PrecisionReq::Bits(bits), max_new, sampling)
            };
            inject.push((admit_round, key, plan, bits, int8, req));
        }
        let mut sched = Scheduler::new(SchedulerConfig {
            max_prefills_per_round: 2, // force multi-round admission queues
            ..SchedulerConfig::default()
        });
        let mut metrics = Metrics::default();
        let events = drive(&mut sched, &mut metrics, inject, 256);
        assert_eq!(events.len(), n_req, "seed {seed}: every request answers");
        for (id, want) in &expected {
            let (toks, fin) = stream_of(&events[id], *id);
            assert_eq!(&toks, want, "seed {seed} req {id}: stream != solo");
            assert_eq!(&fin, want, "seed {seed} req {id}: final != solo");
        }
    }
}

// ---------------------------------------------------------------------------
// Elastic precision shifts: mid-stream downshift/upshift, bit-identical to
// a solo session whose plan pointer is swapped at the same step
// ---------------------------------------------------------------------------

/// Solo reference for an elastically shifted stream: same prompt, same KV
/// prefix, with the plan pointer swapped right before computing the token
/// at each scheduled index — exactly what `Scheduler::shift_uniform` /
/// `shift_up_natives` between rounds must reproduce bit for bit.  Each
/// `(i, plan)` entry means: token `i` (0-based) and everything after it is
/// computed under `plan` (until the next entry).
fn solo_shifted_trace(
    plan: &Arc<ForwardPlan>,
    spec: &Spec,
    switches: &[(usize, Arc<ForwardPlan>)],
) -> Vec<i32> {
    let (prompt, sampling, max_new) = spec;
    let mut s = DecodeSession::with_budget(plan.clone(), prompt, *sampling, *max_new).unwrap();
    let mut remaining = *max_new;
    let mut step = 0usize;
    loop {
        let (tok, _) = s.sample();
        remaining -= 1;
        step += 1;
        if remaining == 0 || !s.can_advance() {
            break;
        }
        if let Some((_, p)) = switches.iter().find(|(i, _)| *i == step) {
            s.switch_plan(p.clone()).unwrap();
        }
        s.advance(tok).unwrap();
    }
    s.generated().to_vec()
}

#[test]
fn elastic_downshift_and_upshift_match_switched_solo_streams() {
    let (preset, model) = toy_model(107);
    let plan8 = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let plan4 = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let specs: Vec<Spec> = vec![
        (vec![1, 2, 3], Sampling::Greedy, 8),
        (vec![4, 5], Sampling::Temperature { temp: 0.8, seed: 9 }, 8),
    ];
    let key = PlanKey::Packed { bits: 8, int8: false };
    let mut sched = Scheduler::new(SchedulerConfig::default());
    let mut metrics = Metrics::default();
    for (i, sp) in specs.iter().enumerate() {
        let req =
            Request::generate(i as u64 + 1, sp.0.clone(), PrecisionReq::Bits(8), sp.2, sp.1);
        sched.submit(key.clone(), plan8.clone(), 8, false, req, Instant::now());
    }
    // Round 0 admits both streams (emitting token 0); each later round
    // emits one more token.  Shifting between rounds therefore changes the
    // plan that computes the NEXT token index: down after round 2 → tokens
    // 3.. run at int4; back up after round 5 → tokens 6.. at int8 again.
    let mut events: BTreeMap<u64, Vec<(u32, i32)>> = BTreeMap::new();
    let mut finals: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
    let mut round = 0usize;
    while sched.has_work() {
        let (ev, fi) = (&mut events, &mut finals);
        sched.run_round(&mut metrics, &mut |id, resp| {
            ev.entry(id).or_default().push((resp.bits, resp.next_token));
            if resp.done {
                fi.insert(id, resp.tokens.clone());
            }
            true
        });
        if round == 2 {
            let rep = sched.shift_uniform(8, false, 4, plan4.clone());
            assert_eq!(rep.moved_live, 2, "both live streams must shift down");
            assert_eq!(rep.moved_pending, 0);
            assert!(rep.failed.is_empty());
            // The int8 group dissolved; one displaced int4 group remains.
            let loads = sched.uniform_groups();
            assert_eq!(loads.len(), 1);
            assert_eq!((loads[0].bits, loads[0].live), (4, 2));
        }
        if round == 5 {
            let rep = sched.shift_up_natives(&mut |bits, int8| {
                assert_eq!((bits, int8), (8, false), "only native int8 resolves");
                Some(plan8.clone())
            });
            assert_eq!(rep.moved_live, 2, "both streams must return to int8");
            assert!(rep.failed.is_empty());
        }
        round += 1;
        assert!(round < 64, "elastic scheduler failed to drain");
    }
    for (i, sp) in specs.iter().enumerate() {
        let id = i as u64 + 1;
        let want = solo_shifted_trace(
            &plan8,
            sp,
            &[(3, plan4.clone()), (6, plan8.clone())],
        );
        let toks: Vec<i32> = events[&id].iter().map(|&(_, t)| t).collect();
        assert_eq!(toks, want, "req {id}: shifted stream != switched solo");
        assert_eq!(finals[&id], want, "req {id}: final stream != switched solo");
        // Response.bits reports what actually served each token.
        let bits: Vec<u32> = events[&id].iter().map(|&(b, _)| b).collect();
        assert_eq!(bits, vec![8, 8, 8, 4, 4, 4, 8, 8], "req {id}: served bits");
    }
}

#[test]
fn elastic_shift_moves_pending_and_upshift_restores_natives() {
    let (preset, model) = toy_model(109);
    let plan8 = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let plan4 = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let specs: Vec<Spec> = vec![
        (vec![7, 8, 9], Sampling::Greedy, 6),
        (vec![2, 4], Sampling::Greedy, 5),
    ];
    let key = PlanKey::Packed { bits: 8, int8: false };
    let mut sched = Scheduler::new(SchedulerConfig::default());
    let mut metrics = Metrics::default();
    for (i, sp) in specs.iter().enumerate() {
        let req =
            Request::generate(i as u64 + 1, sp.0.clone(), PrecisionReq::Bits(8), sp.2, sp.1);
        sched.submit(key.clone(), plan8.clone(), 8, false, req, Instant::now());
    }
    // Shifting a group that does not exist is a no-op…
    let rep = sched.shift_uniform(2, false, 1, plan4.clone());
    assert_eq!(rep.moved(), 0);
    // …while shifting before any round moves the QUEUED requests: they
    // prefill under int4, remembering native_bits = 8.
    let rep = sched.shift_uniform(8, false, 4, plan4.clone());
    assert_eq!((rep.moved_live, rep.moved_pending), (0, 2));
    let loads = sched.uniform_groups();
    assert_eq!(loads.len(), 1);
    assert_eq!((loads[0].bits, loads[0].pending), (4, 2));
    let mut events: BTreeMap<u64, Vec<(u32, i32)>> = BTreeMap::new();
    let mut round = 0usize;
    while sched.has_work() {
        let ev = &mut events;
        sched.run_round(&mut metrics, &mut |id, resp| {
            ev.entry(id).or_default().push((resp.bits, resp.next_token));
            true
        });
        if round == 0 {
            // Both admitted at int4 (token 0).  Upshift returns them to
            // their native int8 group; the int4 KV prefix stays valid.
            let rep = sched.shift_up_natives(&mut |_, _| Some(plan8.clone()));
            assert_eq!(rep.moved_live, 2);
            assert!(rep.failed.is_empty());
            let loads = sched.uniform_groups();
            assert_eq!(loads.len(), 1);
            assert_eq!((loads[0].bits, loads[0].live), (8, 2));
        }
        round += 1;
        assert!(round < 64, "elastic scheduler failed to drain");
    }
    for (i, sp) in specs.iter().enumerate() {
        let id = i as u64 + 1;
        // Solo reference: prefill + token 0 under int4, tokens 1.. at int8.
        let want = solo_shifted_trace(&plan4, sp, &[(1, plan8.clone())]);
        let toks: Vec<i32> = events[&id].iter().map(|&(_, t)| t).collect();
        assert_eq!(toks, want, "req {id}: upshifted stream != switched solo");
        let bits: Vec<u32> = events[&id].iter().map(|&(b, _)| b).collect();
        assert_eq!(bits[0], 4, "req {id}: admission served at int4");
        assert!(bits[1..].iter().all(|&b| b == 8), "req {id}: rest at int8");
    }
}

#[test]
fn host_server_elastic_watermarks_downshift_under_pressure() {
    let (preset, model) = toy_model(113);
    let server = Server::start_host(
        preset.clone(),
        model,
        ServerConfig {
            preset: "toy".into(),
            max_wait_ms: 0.5,
            warm_bits: vec![],
            // A 1-byte KV high watermark trips on any live stream, so the
            // worker must downshift int8 → int4 (→ int2) mid-stream; the
            // streams still complete and answer every token.
            elastic: Some(matquant::serve::ElasticConfig {
                kv_high_bytes: 1,
                cooldown_rounds: 1,
                ..matquant::serve::ElasticConfig::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = (1..=2u64)
        .map(|id| {
            server
                .submit(Request::generate(
                    id,
                    vec![1, 2, 3],
                    PrecisionReq::Bits(8),
                    6,
                    Sampling::Greedy,
                ))
                .unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let mut n = 0;
        let mut min_bits = u32::MAX;
        loop {
            let r = rx.recv().unwrap_or_else(|e| panic!("req {}: {e}", i + 1));
            n += 1;
            min_bits = min_bits.min(r.bits);
            if r.done {
                assert_eq!(r.tokens.len(), 6);
                break;
            }
        }
        assert_eq!(n, 6, "req {}: one event per token", i + 1);
        assert!(
            min_bits < 8,
            "req {}: stream never downshifted (min bits {min_bits})",
            i + 1
        );
    }
    let report = server.metrics_report().unwrap();
    assert!(report.contains("shifts=[down:"), "{report}");
    assert!(!report.contains("shifts=[down:0 "), "no shift recorded: {report}");
    server.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// End-to-end: the host server runs on scheduler rounds
// ---------------------------------------------------------------------------

#[test]
fn host_server_batches_concurrent_streams_bit_identically() {
    let (preset, model) = toy_model(97);
    // Reference streams from solo sessions on identical plans.
    let mut plans: BTreeMap<u32, Arc<ForwardPlan>> = BTreeMap::new();
    for bits in [2u32, 4, 8] {
        plans.insert(
            bits,
            ForwardPlan::packed_uniform(&preset.model, &model, bits, false, None, None).unwrap(),
        );
    }
    let specs: Vec<(u64, u32, Spec)> = vec![
        (1, 2, (vec![1, 2, 3], Sampling::Greedy, 4)),
        (2, 2, (vec![9, 8], Sampling::Temperature { temp: 0.9, seed: 11 }, 5)),
        (3, 4, (vec![7], Sampling::Greedy, 6)),
        (4, 4, (vec![3, 1, 4, 1, 5], Sampling::Greedy, 3)),
        (5, 8, (vec![2, 7, 1, 8], Sampling::Greedy, 4)),
        (6, 8, (vec![], Sampling::Greedy, 2)),
    ];
    let server = Server::start_host(
        preset.clone(),
        model,
        ServerConfig {
            preset: "toy".into(),
            max_wait_ms: 0.5,
            warm_bits: vec![],
            ..ServerConfig::default()
        },
    )
    .unwrap();
    // Submit everything up front: streams at three precisions run
    // concurrently, each precision group batching its own rounds.
    let rxs: Vec<_> = specs
        .iter()
        .map(|(id, bits, sp)| {
            let rx = server
                .submit(Request::generate(
                    *id,
                    sp.0.clone(),
                    PrecisionReq::Bits(*bits),
                    sp.2,
                    sp.1,
                ))
                .unwrap();
            (*id, *bits, rx)
        })
        .collect();
    for ((id, bits, rx), (_, _, sp)) in rxs.into_iter().zip(&specs) {
        let mut toks = Vec::new();
        let fin = loop {
            let r = rx.recv().unwrap_or_else(|e| panic!("req {id}: {e}"));
            assert_eq!(r.id, id);
            assert_eq!(r.bits, bits);
            toks.push(r.next_token);
            if r.done {
                break r.tokens;
            }
        };
        let (_, want) = solo_trace(&plans[&bits], sp);
        assert_eq!(toks, want, "req {id}: served stream != solo session");
        assert_eq!(fin, want, "req {id}: final stream != solo session");
    }
    let report = server.metrics_report().unwrap();
    assert!(report.contains("rounds=["), "{report}");
    assert!(report.contains("requests=6"), "{report}");
    server.shutdown().unwrap();
}

#[test]
fn host_server_serves_per_layer_requests() {
    let (preset, model) = toy_model(101);
    let assign = vec![8u32, 2];
    let plan =
        ForwardPlan::packed_per_layer(&preset.model, &model, &assign, false, None, None).unwrap();
    let spec: Spec = (vec![5, 6, 7], Sampling::Greedy, 4);
    let (_, want) = solo_trace(&plan, &spec);
    let server = Server::start_host(
        preset.clone(),
        model,
        ServerConfig {
            preset: "toy".into(),
            max_wait_ms: 0.5,
            warm_bits: vec![],
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let r = server
        .infer(Request {
            per_layer: Some(assign.clone()),
            ..Request::generate(1, spec.0.clone(), PrecisionReq::Bits(8), spec.2, spec.1)
        })
        .unwrap();
    assert_eq!(r.tokens, want, "per-layer served stream != solo session");
    // malformed maps are rejected at submit (channel closes, no stall)
    let bad = server
        .submit(Request {
            per_layer: Some(vec![]),
            ..Request::generate(2, vec![1], PrecisionReq::Bits(8), 2, Sampling::Greedy)
        })
        .unwrap();
    assert!(bad.recv().is_err(), "empty per-layer map must reject");
    let bad_bits = server
        .submit(Request {
            per_layer: Some(vec![9, 2]),
            ..Request::generate(3, vec![1], PrecisionReq::Bits(8), 2, Sampling::Greedy)
        })
        .unwrap();
    assert!(bad_bits.recv().is_err(), "out-of-range per-layer bits must reject");
    server.shutdown().unwrap();
}

#[test]
fn host_server_rejects_duplicate_in_flight_ids() {
    // A generation long enough that request 7 is still streaming when the
    // duplicate arrives (the worker drains its submit queue every round,
    // and the stream needs ~60 rounds to finish).
    let dims = ModelDims {
        vocab: 48,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 64,
        quantize_attn: false,
    };
    let (preset, model) = toy_transformer(dims, 127);
    let server = Server::start_host(
        preset,
        model,
        ServerConfig {
            preset: "toy".into(),
            max_wait_ms: 0.5,
            warm_bits: vec![],
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let rx1 = server
        .submit(Request::generate(
            7,
            vec![1, 2, 3],
            PrecisionReq::Bits(4),
            60,
            Sampling::Greedy,
        ))
        .unwrap();
    let dup = server
        .submit(Request::generate(
            7,
            vec![4, 5],
            PrecisionReq::Bits(4),
            1,
            Sampling::Greedy,
        ))
        .unwrap();
    // The duplicate must be rejected (its channel closes) instead of
    // silently overwriting the first stream's waiter entry — the clobber
    // left the original client hanging forever on a channel nobody held.
    assert!(dup.recv().is_err(), "duplicate in-flight id must reject");
    let mut n = 0;
    loop {
        let r = rx1
            .recv()
            .expect("original stream must survive the duplicate submit");
        assert_eq!(r.id, 7);
        n += 1;
        if r.done {
            assert_eq!(r.tokens.len(), 60);
            break;
        }
    }
    assert_eq!(n, 60, "original stream must answer every token");
    // Once the stream finished, its id is free for reuse.
    let r = server
        .infer(Request::generate(
            7,
            vec![9],
            PrecisionReq::Bits(4),
            2,
            Sampling::Greedy,
        ))
        .unwrap();
    assert!(r.done);
    assert_eq!(r.tokens.len(), 2, "finished ids must be reusable");
    server.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// Self-speculative rounds: low-bit draft / target verify, bit-identical to
// plain decode (the losslessness contract), across draft/target pairs
// ---------------------------------------------------------------------------

#[test]
fn speculative_rounds_bit_identical_across_draft_target_pairs() {
    let (preset, model) = toy_model(131);
    for (draft_bits, target_bits) in [(2u32, 8u32), (2, 4), (4, 8)] {
        for int8 in [false, true] {
            let cfg = int8.then(ActQuantConfig::absmax);
            let target =
                ForwardPlan::packed_uniform(&preset.model, &model, target_bits, false, cfg, None)
                    .unwrap();
            let draft =
                ForwardPlan::packed_uniform(&preset.model, &model, draft_bits, false, cfg, None)
                    .unwrap();
            let key = PlanKey::Packed { bits: target_bits, int8 };
            // Seeded random specs: greedy streams speculate; the
            // temperature stream must ride the plain sub-round untouched.
            let mut rng = Rng::new(3000 + (draft_bits * 10 + target_bits) as u64 + int8 as u64);
            let mut specs: Vec<Spec> = (0..3)
                .map(|_| {
                    let plen = 1 + rng.below(3);
                    let prompt: Vec<i32> = (0..plen).map(|_| rng.below(40) as i32).collect();
                    (prompt, Sampling::Greedy, 3 + rng.below(4))
                })
                .collect();
            specs.push((
                vec![rng.below(40) as i32],
                Sampling::Temperature {
                    temp: 0.7 + rng.f64() as f32,
                    seed: rng.next_u64(),
                },
                4,
            ));
            let mut sched = Scheduler::new(SchedulerConfig::default());
            sched.set_speculation(key.clone(), draft.clone(), draft_bits, 3);
            let mut metrics = Metrics::default();
            let inject: Vec<Inject> = specs
                .iter()
                .enumerate()
                .map(|(i, sp)| {
                    let req = Request {
                        int8_acts: int8,
                        ..Request::generate(
                            i as u64 + 1,
                            sp.0.clone(),
                            PrecisionReq::Bits(target_bits),
                            sp.2,
                            sp.1,
                        )
                    };
                    (0, key.clone(), target.clone(), target_bits, int8, req)
                })
                .collect();
            let events = drive(&mut sched, &mut metrics, inject, 64);
            let label = format!("int{draft_bits}-draft/int{target_bits} i8={int8}");
            for (i, sp) in specs.iter().enumerate() {
                let id = i as u64 + 1;
                let (toks, fin) = stream_of(&events[&id], id);
                let (_, want) = solo_trace(&target, sp);
                assert_eq!(toks, want, "{label} req {id}: speculative stream != plain solo");
                assert_eq!(fin, want, "{label} req {id}: final stream != plain solo");
            }
            // Speculation actually ran (the streams above were not all
            // served by the plain fallback) and its counters landed.
            assert!(metrics.spec_rounds(target_bits) > 0, "{label}: no speculative rounds");
            assert!(metrics.spec_emitted(target_bits) > 0, "{label}: no speculative tokens");
            assert!(
                metrics.spec_tokens_per_round(target_bits) >= 1.0,
                "{label}: a speculative round must emit at least one token"
            );
        }
    }
}

#[test]
fn speculation_survives_mid_stream_elastic_downshift() {
    let (preset, model) = toy_model(137);
    let plan8 = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let plan4 = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let draft = ForwardPlan::packed_uniform(&preset.model, &model, 2, false, None, None).unwrap();
    let key8 = PlanKey::Packed { bits: 8, int8: false };
    let key4 = PlanKey::Packed { bits: 4, int8: false };
    let spec: Spec = (vec![1, 2, 3], Sampling::Greedy, 7);
    let mut sched = Scheduler::new(SchedulerConfig::default());
    // Both rungs speculate, so the downshift lands BETWEEN speculation
    // windows of an actively speculating stream (windows are atomic within
    // a round — the shift can never split one).
    sched.set_speculation(key8.clone(), draft.clone(), 2, 3);
    sched.set_speculation(key4.clone(), draft.clone(), 2, 3);
    let mut metrics = Metrics::default();
    sched.submit(
        key8,
        plan8.clone(),
        8,
        false,
        Request::generate(1, spec.0.clone(), PrecisionReq::Bits(8), spec.2, spec.1),
        Instant::now(),
    );
    let mut events: Vec<(u32, i32)> = Vec::new();
    let mut round = 0usize;
    while sched.has_work() {
        let ev = &mut events;
        sched.run_round(&mut metrics, &mut |_, resp| {
            ev.push((resp.bits, resp.next_token));
            true
        });
        if round == 1 {
            // Round 0 admitted (token 0), round 1 ran a speculative int8
            // window — now shift the stream down mid-flight.
            assert!(metrics.spec_rounds(8) > 0, "no int8 speculation before the shift");
            let rep = sched.shift_uniform(8, false, 4, plan4.clone());
            assert_eq!(rep.moved_live, 1, "the live stream must shift down");
            assert!(rep.failed.is_empty());
        }
        round += 1;
        assert!(round < 64, "speculating elastic scheduler failed to drain");
    }
    assert!(
        metrics.spec_rounds(4) > 0,
        "speculation must resume on the downshifted rung"
    );
    let toks: Vec<i32> = events.iter().map(|&(_, t)| t).collect();
    let bits: Vec<u32> = events.iter().map(|&(b, _)| b).collect();
    assert_eq!(toks.len(), 7, "every requested token answers across the shift");
    // The served-bits trace tells us exactly which token index the shift
    // landed at; the stream must equal a solo session whose plan pointer
    // swaps at that same index.
    let idx = bits.iter().position(|&b| b == 4).expect("stream never downshifted");
    assert!(idx > 0, "admission served at int8");
    assert!(bits[idx..].iter().all(|&b| b == 4), "no spurious upshift");
    let want = solo_shifted_trace(&plan8, &spec, &[(idx, plan4.clone())]);
    assert_eq!(toks, want, "shifted speculative stream != switched solo");
}

#[test]
fn temperature_streams_keep_their_seeded_rng_stream_under_speculation() {
    // Satellite contract: enabling speculation anywhere in the group must
    // not perturb a temperature session's seeded Rng stream — the
    // (seed, prompt, weights) → same-text invariant.  The temperature
    // member decodes next to speculating greedy members and still matches
    // a solo session from a world with no speculation at all.
    let (preset, model) = toy_model(139);
    let target = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let draft = ForwardPlan::packed_uniform(&preset.model, &model, 2, false, None, None).unwrap();
    let key = PlanKey::Packed { bits: 8, int8: false };
    let temp_spec: Spec = (
        vec![6, 7],
        Sampling::Temperature { temp: 0.9, seed: 42 },
        6,
    );
    let (_, want) = solo_trace(&target, &temp_spec);
    let mut sched = Scheduler::new(SchedulerConfig::default());
    sched.set_speculation(key.clone(), draft, 2, 4);
    let mut metrics = Metrics::default();
    let inject: Vec<Inject> = vec![
        (
            0,
            key.clone(),
            target.clone(),
            8,
            false,
            Request::generate(1, vec![1, 2, 3], PrecisionReq::Bits(8), 6, Sampling::Greedy),
        ),
        (
            0,
            key.clone(),
            target.clone(),
            8,
            false,
            Request::generate(2, temp_spec.0.clone(), PrecisionReq::Bits(8), temp_spec.2, temp_spec.1),
        ),
    ];
    let events = drive(&mut sched, &mut metrics, inject, 64);
    assert!(metrics.spec_rounds(8) > 0, "the greedy member must speculate");
    let (toks, fin) = stream_of(&events[&2], 2);
    assert_eq!(toks, want, "temperature stream perturbed by group speculation");
    assert_eq!(fin, want);
}

// ---------------------------------------------------------------------------
// Metric regressions: completion latency is step cost (not stream age), and
// the resident-KV gauge drains to zero
// ---------------------------------------------------------------------------

#[test]
fn completion_latency_records_step_cost_not_stream_age() {
    // Regression: stream completion used to record `enq.elapsed()` — the
    // stream's AGE — into the request-latency histogram, so a long-lived
    // stream pushed p50/p99 up with its lifetime.  The fixed code records
    // the final round's step cost, which is a small slice of the total.
    let dims = ModelDims {
        vocab: 48,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 64,
        quantize_attn: false,
    };
    let (preset, model) = toy_transformer(dims, 149);
    let plan = ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
    let key = PlanKey::Packed { bits: 4, int8: false };
    let mut sched = Scheduler::new(SchedulerConfig::default());
    let mut metrics = Metrics::default();
    let t0 = Instant::now();
    let inject: Vec<Inject> = vec![(
        0,
        key,
        plan,
        4,
        false,
        Request::generate(1, vec![1, 2, 3], PrecisionReq::Bits(4), 40, Sampling::Greedy),
    )];
    let events = drive(&mut sched, &mut metrics, inject, 64);
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(events[&1].len(), 40, "the stream must run long");
    // One completed stream → one request-latency sample.  40 decode rounds
    // ran, so the stream's age is ≈40 step costs; a sample anywhere near
    // the age means the bug is back.
    let p50 = metrics.percentile(50.0);
    assert!(
        p50 < total_ms / 4.0,
        "completion sample {p50:.3}ms looks like stream age (stream lived {total_ms:.3}ms)"
    );
    // Per-step decode percentiles stay flat as the stream ages: every
    // sample is one round's step cost, never a cumulative figure.
    let d99 = metrics.decode_percentile(4, 99.0);
    assert!(
        d99 < total_ms / 4.0,
        "decode p99 {d99:.3}ms looks cumulative (stream lived {total_ms:.3}ms)"
    );
}

#[test]
fn kv_gauge_tracks_residency_and_returns_to_zero_after_drain() {
    // Regression sweep for the resident-KV gauge across every retirement
    // path in one run: normal completion, KV-capacity truncation, a
    // mid-stream client hangup, and speculative rounds (whose rollback
    // returns whole drained pages to the pool — the gauge must track the
    // pool's actual residency through all of it).
    let (preset, model) = toy_model(151);
    let seq = preset.model.seq_len;
    let target = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let draft = ForwardPlan::packed_uniform(&preset.model, &model, 2, false, None, None).unwrap();
    let key = PlanKey::Packed { bits: 8, int8: false };
    let mut sched = Scheduler::new(SchedulerConfig::default());
    sched.set_speculation(key.clone(), draft, 2, 3);
    let mut metrics = Metrics::default();
    let mk = |id, prompt: Vec<i32>, max_new| {
        Request::generate(id, prompt, PrecisionReq::Bits(8), max_new, Sampling::Greedy)
    };
    sched.submit(key.clone(), target.clone(), 8, false, mk(1, vec![1, 2, 3], 6), Instant::now());
    // Truncates at the position window long before its budget.
    sched.submit(
        key.clone(),
        target.clone(),
        8,
        false,
        mk(2, (0..seq as i32 - 2).map(|i| i % 5).collect(), seq),
        Instant::now(),
    );
    sched.submit(key.clone(), target.clone(), 8, false, mk(3, vec![4, 5], 8), Instant::now());
    let mut hangup_events = 0usize;
    let mut round = 0usize;
    while sched.has_work() {
        sched.run_round(&mut metrics, &mut |id, _| {
            if id == 3 {
                hangup_events += 1;
                hangup_events < 2 // client 3 hangs up after its 2nd event
            } else {
                true
            }
        });
        assert_eq!(
            metrics.kv_bytes(),
            sched.resident_kv_bytes(),
            "round {round}: gauge drifted from true residency"
        );
        round += 1;
        assert!(round < 64, "gauge sweep failed to drain");
    }
    assert!(metrics.spec_rounds(8) > 0, "speculation must have run in this sweep");
    assert_eq!(sched.live_sessions(), 0);
    assert_eq!(sched.pending_prefills(), 0);
    assert_eq!(
        metrics.kv_bytes(),
        0,
        "gauge must return to zero once every stream drained"
    );
}

// ---------------------------------------------------------------------------
// End-to-end: the host server serves speculatively when configured
// ---------------------------------------------------------------------------

#[test]
fn host_server_speculative_serving_is_lossless_and_reports_metrics() {
    let (preset, model) = toy_model(157);
    let target = ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
    let greedy_spec: Spec = (vec![1, 2, 3], Sampling::Greedy, 6);
    let temp_spec: Spec = (vec![4, 5], Sampling::Temperature { temp: 0.9, seed: 13 }, 5);
    let (_, greedy_want) = solo_trace(&target, &greedy_spec);
    let (_, temp_want) = solo_trace(&target, &temp_spec);
    let server = Server::start_host(
        preset.clone(),
        model,
        ServerConfig {
            preset: "toy".into(),
            max_wait_ms: 0.5,
            warm_bits: vec![],
            speculative: Some(SpeculativeConfig { draft_bits: 2, k: 4 }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let submits = [
        (1u64, &greedy_spec, &greedy_want),
        (2u64, &temp_spec, &temp_want),
    ];
    let rxs: Vec<_> = submits
        .iter()
        .map(|(id, sp, _)| {
            server
                .submit(Request::generate(
                    *id,
                    sp.0.clone(),
                    PrecisionReq::Bits(8),
                    sp.2,
                    sp.1,
                ))
                .unwrap()
        })
        .collect();
    for ((id, sp, want), rx) in submits.iter().zip(rxs) {
        let mut toks = Vec::new();
        let fin = loop {
            let r = rx.recv().unwrap_or_else(|e| panic!("req {id}: {e}"));
            assert_eq!(r.bits, 8);
            toks.push(r.next_token);
            if r.done {
                break r.tokens;
            }
        };
        assert_eq!(toks.len(), sp.2, "req {id}: one event per token");
        assert_eq!(&toks, *want, "req {id}: speculative serving changed the stream");
        assert_eq!(&fin, *want, "req {id}: final stream diverged");
    }
    let report = server.metrics_report().unwrap();
    assert!(
        report.contains("spec=[int8:"),
        "report must carry speculation counters: {report}"
    );
    server.shutdown().unwrap();
}

#[test]
fn host_server_kv_budget_defers_but_answers_everyone() {
    let (preset, model) = toy_model(103);
    // capacity 7 positions per session (prompt 3 + 5 - 1), page-rounded
    // under 4-row pages; the budget fits exactly ONE such projection at a
    // time.  (4-row pages also make the full-window request below project
    // strictly MORE pages than the budget, so submit-time rejection
    // still has something to reject.)
    let kv = KvConfig::f32_paged(4);
    let per_session = projected_kv_bytes(&preset.model, 3, 5, 0, &kv);
    let server = Server::start_host(
        preset.clone(),
        model,
        ServerConfig {
            preset: "toy".into(),
            max_wait_ms: 0.5,
            warm_bits: vec![],
            kv_capacity_bytes: Some(per_session),
            kv,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    // A request whose KV page ALONE exceeds the budget can never be
    // admitted: it must be rejected at submit (channel closes), not
    // deferred forever — deferral would pin its client and block
    // shutdown.
    let oversized = server
        .submit(Request::generate(
            99,
            vec![1, 2, 3],
            PrecisionReq::Bits(4),
            preset.model.seq_len, // capacity clamps to the full window
            Sampling::Greedy,
        ))
        .unwrap();
    assert!(
        oversized.recv().is_err(),
        "never-admittable request must reject, not defer forever"
    );
    let rxs: Vec<_> = (1..=3u64)
        .map(|id| {
            server
                .submit(Request::generate(
                    id,
                    vec![1, 2, 3],
                    PrecisionReq::Bits(4),
                    5,
                    Sampling::Greedy,
                ))
                .unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let mut n = 0;
        loop {
            let r = rx.recv().unwrap_or_else(|e| panic!("req {}: {e}", i + 1));
            n += 1;
            if r.done {
                assert_eq!(r.tokens.len(), 5);
                break;
            }
        }
        assert_eq!(n, 5, "req {}: one event per token", i + 1);
    }
    server.shutdown().unwrap();
}
