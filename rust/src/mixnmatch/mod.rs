//! Layer-wise Mix'n'Match (paper §4.3, Fig. 2/3): assign a different
//! precision to each layer of one MatQuant model, densely spanning the
//! accuracy-vs-bits trade-off at zero training cost.
//!
//! Sweep evaluation materializes every per-layer assignment through the
//! fused slice+dequant kernel ([`crate::kernels::slice_dequant_into`] via
//! `QuantizedTensor::materialize`), so a full composition grid never
//! allocates intermediate code vectors — the sweep cost is one fused pass
//! per tensor per configuration.  [`sensitivity`] goes one step further
//! down the packed-domain path: it ranks layers by quantization damage
//! with fused r-bit matvec probes (`y_r = x·W_r` straight from the
//! payload, no weight materialization at all) and greedily spends a bit
//! budget where the probe says it hurts most.  When the MatGPTQ solver has
//! run, [`sensitivity::solver_sensitivity`] supplies the same rows from
//! real calibration curvature instead of random probes.

pub mod pareto;
pub mod sensitivity;
pub mod strategy;

pub use pareto::{pareto_frontier, Point};
pub use sensitivity::{
    probe_sensitivity, solver_sensitivity, suggest_assignment, SensitivityRow,
};
pub use strategy::{assignments_for, compositions, Strategy};
