//! Layer-wise Mix'n'Match (paper §4.3, Fig. 2/3): assign a different
//! precision to each layer of one MatQuant model, densely spanning the
//! accuracy-vs-bits trade-off at zero training cost.
//!
//! Sweep evaluation materializes every per-layer assignment through the
//! fused slice+dequant kernel ([`crate::kernels::slice_dequant_into`] via
//! `QuantizedTensor::materialize`), so a full composition grid never
//! allocates intermediate code vectors — the sweep cost is one fused pass
//! per tensor per configuration.

pub mod pareto;
pub mod strategy;

pub use pareto::{pareto_frontier, Point};
pub use strategy::{assignments_for, compositions, Strategy};
