//! Layer-wise Mix'n'Match (paper §4.3, Fig. 2/3): assign a different
//! precision to each layer of one MatQuant model, densely spanning the
//! accuracy-vs-bits trade-off at zero training cost.

pub mod pareto;
pub mod strategy;

pub use pareto::{pareto_frontier, Point};
pub use strategy::{assignments_for, compositions, Strategy};
