//! Mix'n'Match assignment strategies (paper Appendix B):
//!
//! * **Pyramid** — int2/int4 at the ends, int8 in the middle (the paper's
//!   winner: middle layers carry the critical information).
//! * **ReversePyramid** — int8 at the ends, int2 in the middle.
//! * **Increasing / Decreasing** — monotone bit assignment across layers.
//!
//! A config is a composition `(n2, n4, n8)` with `n2 + n4 + n8 = L`; each
//! strategy turns a composition into a per-layer bit vector.

/// Layout strategy for a given (n2, n4, n8) composition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    Pyramid,
    ReversePyramid,
    Increasing,
    Decreasing,
}

pub const STRATEGIES: [Strategy; 4] = [
    Strategy::Pyramid,
    Strategy::ReversePyramid,
    Strategy::Increasing,
    Strategy::Decreasing,
];

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Pyramid => "pyramid",
            Strategy::ReversePyramid => "reverse_pyramid",
            Strategy::Increasing => "increasing",
            Strategy::Decreasing => "decreasing",
        }
    }
}

/// All compositions (n2, n4, n8) of `layers`.
pub fn compositions(layers: usize) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::new();
    for n2 in 0..=layers {
        for n4 in 0..=(layers - n2) {
            out.push((n2, n4, layers - n2 - n4));
        }
    }
    out
}

/// Per-layer bits for one composition under `strategy`.
pub fn assignments_for(
    strategy: Strategy,
    (n2, n4, n8): (usize, usize, usize),
    layers: usize,
) -> Vec<u32> {
    assert_eq!(n2 + n4 + n8, layers, "composition must cover all layers");
    match strategy {
        Strategy::Increasing => {
            // low bits first
            let mut v = vec![2u32; n2];
            v.extend(std::iter::repeat(4).take(n4));
            v.extend(std::iter::repeat(8).take(n8));
            v
        }
        Strategy::Decreasing => {
            let mut v = vec![8u32; n8];
            v.extend(std::iter::repeat(4).take(n4));
            v.extend(std::iter::repeat(2).take(n2));
            v
        }
        Strategy::Pyramid => {
            // int2 split at both ends, then int4, int8 core:
            // [2…, 4…, 8…, 4…, 2…]
            let mut v = vec![0u32; layers];
            let mut lo = 0usize;
            let mut hi = layers;
            let mut place = |bits: u32, count: usize, lo: &mut usize, hi: &mut usize| {
                for i in 0..count {
                    if i % 2 == 0 {
                        v_set(&mut v, *lo, bits);
                        *lo += 1;
                    } else {
                        *hi -= 1;
                        v_set(&mut v, *hi, bits);
                    }
                }
            };
            place(2, n2, &mut lo, &mut hi);
            place(4, n4, &mut lo, &mut hi);
            place(8, n8, &mut lo, &mut hi);
            v
        }
        Strategy::ReversePyramid => {
            let mut v = vec![0u32; layers];
            let mut lo = 0usize;
            let mut hi = layers;
            let mut place = |bits: u32, count: usize, lo: &mut usize, hi: &mut usize| {
                for i in 0..count {
                    if i % 2 == 0 {
                        v_set(&mut v, *lo, bits);
                        *lo += 1;
                    } else {
                        *hi -= 1;
                        v_set(&mut v, *hi, bits);
                    }
                }
            };
            place(8, n8, &mut lo, &mut hi);
            place(4, n4, &mut lo, &mut hi);
            place(2, n2, &mut lo, &mut hi);
            v
        }
    }
}

fn v_set(v: &mut [u32], i: usize, bits: u32) {
    v[i] = bits;
}

/// Nominal average bits of an assignment (uniform layer sizes).
pub fn nominal_bits(assign: &[u32]) -> f64 {
    assign.iter().map(|&b| b as f64).sum::<f64>() / assign.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compositions_cover_and_sum() {
        let cs = compositions(4);
        assert_eq!(cs.len(), 15); // C(4+2,2)
        for (a, b, c) in cs {
            assert_eq!(a + b + c, 4);
        }
    }

    #[test]
    fn all_strategies_are_permutations_of_multiset() {
        for comp in compositions(6) {
            for s in STRATEGIES {
                let v = assignments_for(s, comp, 6);
                assert_eq!(v.len(), 6);
                assert_eq!(v.iter().filter(|&&b| b == 2).count(), comp.0, "{s:?} {comp:?}");
                assert_eq!(v.iter().filter(|&&b| b == 4).count(), comp.1);
                assert_eq!(v.iter().filter(|&&b| b == 8).count(), comp.2);
            }
        }
    }

    #[test]
    fn pyramid_puts_high_bits_in_middle() {
        let v = assignments_for(Strategy::Pyramid, (2, 2, 2), 6);
        // ends must be int2, middle int8
        assert_eq!(v[0], 2);
        assert_eq!(v[5], 2);
        let mid: Vec<u32> = v[2..4].to_vec();
        assert!(mid.iter().all(|&b| b == 8), "{v:?}");
    }

    #[test]
    fn reverse_pyramid_inverts() {
        let v = assignments_for(Strategy::ReversePyramid, (2, 2, 2), 6);
        assert_eq!(v[0], 8);
        assert_eq!(v[5], 8);
        assert!(v[2..4].iter().all(|&b| b == 2), "{v:?}");
    }

    #[test]
    fn monotone_strategies() {
        let inc = assignments_for(Strategy::Increasing, (2, 2, 2), 6);
        assert!(inc.windows(2).all(|w| w[0] <= w[1]));
        let dec = assignments_for(Strategy::Decreasing, (2, 2, 2), 6);
        assert!(dec.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn nominal_bits_example() {
        let v = assignments_for(Strategy::Increasing, (1, 1, 2), 4);
        assert!((nominal_bits(&v) - 5.5).abs() < 1e-12);
    }
}
