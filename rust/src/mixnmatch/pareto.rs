//! Pareto frontier over (bits/param, accuracy) points — Fig. 2 / Fig. 3.

/// One evaluated Mix'n'Match (or uniform) configuration.
#[derive(Debug, Clone)]
pub struct Point {
    pub label: String,
    /// Average bits per quantized FFN parameter (x-axis).
    pub bits_per_param: f64,
    /// Task average accuracy in [0, 1] (y-axis).
    pub accuracy: f64,
    /// C4-substitute log perplexity (lower is better).
    pub log_pplx: f64,
}

/// Points not dominated by any other (≤ bits AND ≥ accuracy with one
/// strict), sorted by bits.
pub fn pareto_frontier(points: &[Point]) -> Vec<Point> {
    let mut keep: Vec<Point> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.bits_per_param < p.bits_per_param && q.accuracy >= p.accuracy)
                || (q.bits_per_param <= p.bits_per_param && q.accuracy > p.accuracy)
        });
        if !dominated {
            keep.push(p.clone());
        }
    }
    keep.sort_by(|a, b| a.bits_per_param.partial_cmp(&b.bits_per_param).unwrap());
    keep.dedup_by(|a, b| a.bits_per_param == b.bits_per_param && a.accuracy == b.accuracy);
    keep
}

/// Terminal scatter rendering of the accuracy-vs-bits curve.
pub fn render_curve(points: &[Point], width: usize, height: usize) -> String {
    if points.is_empty() {
        return String::from("(no points)\n");
    }
    let (min_b, max_b) = points.iter().fold((f64::MAX, f64::MIN), |(lo, hi), p| {
        (lo.min(p.bits_per_param), hi.max(p.bits_per_param))
    });
    let (min_a, max_a) = points.iter().fold((f64::MAX, f64::MIN), |(lo, hi), p| {
        (lo.min(p.accuracy), hi.max(p.accuracy))
    });
    let span_b = (max_b - min_b).max(1e-9);
    let span_a = (max_a - min_a).max(1e-9);
    let mut grid = vec![vec![' '; width]; height];
    for p in points {
        let x = (((p.bits_per_param - min_b) / span_b) * (width - 1) as f64).round() as usize;
        let y = (((p.accuracy - min_a) / span_a) * (height - 1) as f64).round() as usize;
        grid[height - 1 - y][x] = '*';
    }
    let mut out = String::new();
    out.push_str(&format!("accuracy {:.1}%..{:.1}%\n", min_a * 100.0, max_a * 100.0));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("bits/param {min_b:.2}..{max_b:.2}\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(b: f64, a: f64) -> Point {
        Point {
            label: format!("{b}-{a}"),
            bits_per_param: b,
            accuracy: a,
            log_pplx: 0.0,
        }
    }

    #[test]
    fn dominated_points_removed() {
        let pts = vec![p(2.0, 0.5), p(4.0, 0.7), p(4.0, 0.6), p(8.0, 0.72), p(3.0, 0.4)];
        let f = pareto_frontier(&pts);
        let labels: Vec<f64> = f.iter().map(|x| x.bits_per_param).collect();
        assert_eq!(labels, vec![2.0, 4.0, 8.0]);
        // the 4-bit point kept is the better one
        assert!(f[1].accuracy == 0.7);
        // dominated (3.0, 0.4) removed
        assert!(!f.iter().any(|x| x.bits_per_param == 3.0));
    }

    #[test]
    fn frontier_is_monotone() {
        let pts: Vec<Point> = (0..20)
            .map(|i| p(2.0 + i as f64 * 0.3, 0.4 + (i % 7) as f64 * 0.05))
            .collect();
        let f = pareto_frontier(&pts);
        for w in f.windows(2) {
            assert!(w[0].bits_per_param <= w[1].bits_per_param);
            assert!(w[0].accuracy <= w[1].accuracy);
        }
    }

    #[test]
    fn render_smoke() {
        let s = render_curve(&[p(2.0, 0.5), p(8.0, 0.7)], 20, 5);
        assert!(s.contains('*'));
    }
}
