//! Layer-sensitivity probing on the packed-domain matmul path.
//!
//! Mix'n'Match needs to know *which* layers tolerate low bits.  The classic
//! way is a full eval sweep per assignment (expensive, needs artifacts).
//! This module estimates per-layer damage directly: for each quantized
//! tensor, compare the fused r-bit matvec output against the int8-payload
//! output on random probe vectors — `y_r = x·W_r` vs `y_8 = x·W_8`, both
//! computed straight from packed payloads by [`crate::kernels::matmul`],
//! so the probe never materializes a weight tensor and runs offline (no
//! PJRT, no artifacts).
//!
//! [`suggest_assignment`] turns the probe into a per-layer bit vector with
//! a greedy budgeted upgrade (start everything at the cheapest width,
//! repeatedly buy bits for the most-damaged layer), complementing the
//! fixed Appendix B layouts in [`super::strategy`].

use std::collections::BTreeMap;

use crate::data::Rng;
use crate::model::registry::layer_of;
use crate::model::QuantizedModel;
use crate::Result;

/// Probe result for one quantized tensor.
#[derive(Debug, Clone)]
pub struct SensitivityRow {
    pub name: String,
    pub layer: usize,
    /// `(bits, relative L2 output error vs the int8 payload)`, in the
    /// order of the probed bit options.
    pub rel_err: Vec<(u32, f64)>,
}

/// Probe every quantized tensor at each candidate precision with `probes`
/// random activation vectors; returns one row per tensor in registry order.
pub fn probe_sensitivity(
    model: &QuantizedModel,
    bits_options: &[u32],
    probes: usize,
    seed: u64,
) -> Result<Vec<SensitivityRow>> {
    let mut rows = Vec::with_capacity(model.quantized_order.len());
    let mut rng = Rng::new(seed ^ 0x5E5E);
    for qn in &model.quantized_order {
        let qt = &model.quantized[qn];
        let base = qt.packed_weight(8, false)?;
        let handles: Vec<_> = bits_options
            .iter()
            .map(|&b| qt.packed_weight(b, false).map(|h| (b, h)))
            .collect::<Result<Vec<_>>>()?;
        let mut err2 = vec![0.0f64; handles.len()];
        let mut norm2 = 0.0f64;
        for _ in 0..probes.max(1) {
            let x: Vec<f32> = (0..qt.d_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let y8 = base.matvec(&x)?;
            norm2 += y8.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
            for (k, (_, h)) in handles.iter().enumerate() {
                let yr = h.matvec(&x)?;
                err2[k] += yr
                    .iter()
                    .zip(&y8)
                    .map(|(&a, &b)| {
                        let d = (a - b) as f64;
                        d * d
                    })
                    .sum::<f64>();
            }
        }
        let denom = norm2.max(1e-30);
        rows.push(SensitivityRow {
            name: qn.clone(),
            layer: layer_of(qn),
            rel_err: handles
                .iter()
                .zip(&err2)
                .map(|((b, _), &e)| (*b, (e / denom).sqrt()))
                .collect(),
        });
    }
    Ok(rows)
}

/// Sensitivity rows from the MatGPTQ solver's Hessian-weighted residuals
/// ([`crate::model::QuantizedModel::solve_refined`]): one row per solved
/// tensor, `rel_err` the post-solve `sqrt(ΔᵀHΔ / wᵀHw)` per rung.  Unlike
/// [`probe_sensitivity`]'s random-vector damage estimate, these numbers
/// carry the *calibration data's* curvature — feed them to
/// [`suggest_assignment`] unchanged so Mix'n'Match upgrades the layers the
/// real input distribution says are fragile.
pub fn solver_sensitivity(report: &crate::quant::solver::SolverReport) -> Vec<SensitivityRow> {
    report
        .tensors
        .iter()
        .map(|t| SensitivityRow {
            name: t.name.clone(),
            layer: t.layer,
            rel_err: t.solved_rel.clone(),
        })
        .collect()
}

/// Greedy budgeted assignment from probe rows: every layer starts at the
/// cheapest probed width; while the *average* per-layer bits stay within
/// `budget_avg_bits`, upgrade the layer with the largest error at its
/// current width to the next probed width.  Returns per-layer bits
/// (length `n_layers`), usable as
/// [`crate::model::PrecisionAssignment::PerLayer`].
pub fn suggest_assignment(
    rows: &[SensitivityRow],
    n_layers: usize,
    budget_avg_bits: f64,
) -> Vec<u32> {
    // Aggregate: layer → bits → worst error over the layer's tensors.
    let mut per_layer: Vec<BTreeMap<u32, f64>> = vec![BTreeMap::new(); n_layers];
    for row in rows {
        if row.layer >= n_layers {
            continue;
        }
        for &(b, e) in &row.rel_err {
            let slot = per_layer[row.layer].entry(b).or_insert(0.0);
            if e > *slot {
                *slot = e;
            }
        }
    }
    let widths: Vec<u32> = {
        let mut w: Vec<u32> = rows
            .iter()
            .flat_map(|r| r.rel_err.iter().map(|&(b, _)| b))
            .collect();
        w.sort_unstable();
        w.dedup();
        w
    };
    if widths.is_empty() {
        return vec![8; n_layers];
    }
    let mut bits = vec![widths[0]; n_layers];
    let budget_total = budget_avg_bits * n_layers as f64;
    loop {
        let spent: f64 = bits.iter().map(|&b| b as f64).sum();
        // Pick the layer whose current width hurts most and whose upgrade
        // still fits the budget.
        let mut best: Option<(usize, u32, f64)> = None;
        for l in 0..n_layers {
            let cur = bits[l];
            let Some(&next) = widths.iter().find(|&&w| w > cur) else {
                continue;
            };
            if spent - cur as f64 + next as f64 > budget_total + 1e-9 {
                continue;
            }
            let err = per_layer[l].get(&cur).copied().unwrap_or(0.0);
            if best.map_or(true, |(_, _, e)| err > e) {
                best = Some((l, next, err));
            }
        }
        match best {
            Some((l, next, _)) => bits[l] = next,
            None => break,
        }
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::registry::QuantizedTensor;
    use crate::model::Tensor;

    fn toy_model(layers: usize) -> QuantizedModel {
        let mut rng = Rng::new(7);
        let mut params = std::collections::BTreeMap::new();
        let mut quantized = std::collections::BTreeMap::new();
        let mut order = Vec::new();
        for l in 0..layers {
            let name = format!("layer{l}.ffn.w_in");
            // later layers get wilder weights → more quantization damage
            let spread = 0.5 + l as f32;
            let data: Vec<f32> = (0..32 * 16)
                .map(|_| rng.range_f32(-spread, spread))
                .collect();
            let t = Tensor::new(vec![32, 16], data).unwrap();
            params.insert(name.clone(), t.clone());
            quantized.insert(
                name.clone(),
                QuantizedTensor::from_weight(t, None, None, None).unwrap(),
            );
            order.push(name);
        }
        QuantizedModel::from_parts(params, quantized, order.clone(), order)
    }

    #[test]
    fn error_shrinks_with_bits() {
        let model = toy_model(2);
        let rows = probe_sensitivity(&model, &[2, 4, 8], 3, 11).unwrap();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            let e2 = row.rel_err[0].1;
            let e4 = row.rel_err[1].1;
            let e8 = row.rel_err[2].1;
            assert!(e2 > e4 && e4 > e8, "{}: {:?}", row.name, row.rel_err);
            assert!(e8 < 1e-6, "int8 payload must match itself: {e8}");
        }
    }

    #[test]
    fn greedy_assignment_respects_budget_and_spends_it() {
        let model = toy_model(4);
        let rows = probe_sensitivity(&model, &[2, 4, 8], 2, 3).unwrap();
        for budget in [2.0, 3.5, 8.0] {
            let assign = suggest_assignment(&rows, 4, budget);
            let avg = assign.iter().map(|&b| b as f64).sum::<f64>() / 4.0;
            assert!(avg <= budget + 1e-9, "budget {budget}: {assign:?}");
            assert!(assign.iter().all(|&b| [2, 4, 8].contains(&b)));
        }
        // full budget → everything upgraded
        assert_eq!(suggest_assignment(&rows, 4, 8.0), vec![8, 8, 8, 8]);
        // minimal budget → everything cheapest
        assert_eq!(suggest_assignment(&rows, 4, 2.0), vec![2, 2, 2, 2]);
    }

    #[test]
    fn solver_rows_drive_assignment() {
        use crate::quant::solver::{SolverReport, TensorReport};
        // Layer 1's tensor is much more damaged at int2 → a mid budget
        // must upgrade layer 1 before layer 0.
        let report = SolverReport {
            tensors: vec![
                TensorReport {
                    name: "layer0.ffn.w_in".into(),
                    layer: 0,
                    damp: 1e-3,
                    fallback: false,
                    base_rel: vec![(2, 0.06), (4, 0.02), (8, 0.001)],
                    solved_rel: vec![(2, 0.05), (4, 0.01), (8, 0.001)],
                },
                TensorReport {
                    name: "layer1.ffn.w_in".into(),
                    layer: 1,
                    damp: 1e-3,
                    fallback: false,
                    base_rel: vec![(2, 0.9), (4, 0.3), (8, 0.01)],
                    solved_rel: vec![(2, 0.8), (4, 0.2), (8, 0.01)],
                },
            ],
        };
        let rows = solver_sensitivity(&report);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].layer, 1);
        assert_eq!(rows[1].rel_err[0], (2, 0.8));
        let assign = suggest_assignment(&rows, 2, 3.0);
        assert_eq!(assign, vec![2, 4], "budget goes to the fragile layer");
    }
}
