//! A little-endian bitstream cursor over packed code data.
//!
//! Handles the non-power-of-two widths (3/6-bit) where entries straddle
//! byte boundaries.  The cursor keeps a `u64` accumulator and refills it
//! with a single 8-byte little-endian load whenever a full word is
//! available (the word-at-a-time fast path), falling back to byte loads —
//! and implicit zero padding — near the end of the stream.
//!
//! Bit order matches [`crate::quant::PackedTensor`]: entry `i` of width `w`
//! occupies bits `[i*w, (i+1)*w)` of the stream, least-significant first.

/// Streaming reader of fixed-width little-endian bit fields.
pub struct BitCursor<'a> {
    data: &'a [u8],
    /// Next byte of `data` not yet loaded into `acc`.
    byte: usize,
    /// Pending bits, next field in the low bits.
    acc: u64,
    /// Number of valid bits in `acc`.
    nbits: u32,
}

impl<'a> BitCursor<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        BitCursor {
            data,
            byte: 0,
            acc: 0,
            nbits: 0,
        }
    }

    #[inline]
    fn refill(&mut self) {
        if self.byte + 8 <= self.data.len() && self.nbits <= 56 {
            // Word fast path: absorb as many whole bytes of the u64 as fit.
            let word = u64::from_le_bytes(self.data[self.byte..self.byte + 8].try_into().unwrap());
            self.acc |= word << self.nbits;
            let absorbed = (63 - self.nbits) >> 3;
            self.byte += absorbed as usize;
            self.nbits += absorbed * 8;
        } else {
            // Tail: byte loads, zero padding past the end of the stream.
            while self.nbits <= 56 {
                let b = self.data.get(self.byte).copied().unwrap_or(0) as u64;
                self.acc |= b << self.nbits;
                self.byte += 1;
                self.nbits += 8;
            }
        }
    }

    /// Read the next `width`-bit field (`1 <= width <= 8`).
    #[inline]
    pub fn next(&mut self, width: u32) -> u32 {
        debug_assert!(width >= 1 && width <= 8);
        if self.nbits < width {
            self.refill();
        }
        let v = (self.acc & ((1u64 << width) - 1)) as u32;
        self.acc >>= width;
        self.nbits -= width;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::PackedTensor;

    #[test]
    fn cursor_matches_packed_get_all_widths() {
        for bits in [1u32, 2, 3, 4, 6, 8] {
            for n in [1usize, 7, 8, 63, 64, 255, 1000] {
                let ids: Vec<f32> = (0..n)
                    .map(|i| ((i as u64 * 11 + 5) % (1 << bits)) as f32)
                    .collect();
                let p = PackedTensor::pack(&ids, bits);
                let mut cur = BitCursor::new(&p.data);
                for (i, &want) in ids.iter().enumerate() {
                    assert_eq!(cur.next(bits) as f32, want, "bits={bits} n={n} i={i}");
                }
            }
        }
    }

    #[test]
    fn zero_pads_past_stream_end() {
        let mut cur = BitCursor::new(&[0xFF]);
        assert_eq!(cur.next(6), 0x3F);
        assert_eq!(cur.next(6), 0x03); // two real bits + four padding zeros
        assert_eq!(cur.next(6), 0);
    }
}
