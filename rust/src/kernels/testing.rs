//! Conformance-harness substrate: deterministic data synthesis, the scalar
//! reference paths (the seed's two-pass walk, kept verbatim as the oracle),
//! exact-equality assertions, and a small offline property-test driver
//! ([`run_prop`] — a vendored-proptest substitute: seeded random case
//! generation with the failing case reported for replay).
//!
//! Shared by the in-crate kernel unit tests, the exhaustive suite in
//! `tests/kernel_conformance.rs`, and `benches/quant_hot_paths.rs` (which
//! benches fused vs reference on the same inputs it validates).
//!
//! # Matmul conformance semantics
//!
//! Dequantization kernels are checked **bit-for-bit** (the LUTs are built
//! by the scalar oracle).  The fused matmul kernels evaluate the same sum
//! in a different — equally valid — f32 order (the affine is hoisted out of
//! the reduction), so their contract is a *scaled-ulp* bound instead:
//! [`reference_matmul`] returns, alongside the naive product, a per-output
//! accumulation magnitude covering both evaluation orders, and
//! [`assert_accum_close`] admits `(2·d_in + 16)` units of `f32::EPSILON`
//! of that magnitude — one rounding per accumulated term per order, far
//! below any real kernel defect (which shows up at the scale of the
//! weights themselves).

use crate::data::Rng;
use crate::quant::{self, ExtraBitOverlay, PackedTensor, Scales};

/// Deterministic r-bit bucket ids covering the full `[0, 2^bits)` range.
pub fn synth_ids(bits: u32, n: usize, seed: u64) -> Vec<f32> {
    let m = 1u64 << bits;
    let mut rng = Rng::new(seed ^ 0xBEEF);
    (0..n)
        .map(|i| match i % 5 {
            0 => 0.0,
            1 => (m - 1) as f32,
            _ => (rng.next_u64() % m) as f32,
        })
        .collect()
}

/// Deterministic 8-bit master codes biased toward slicing edge cases: the
/// extremes, the paper's errata example 234 (overflows every `r < 8` under
/// Eq. 8), and round-half-up boundaries.
pub fn synth_master_codes(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0xC0DE);
    (0..n)
        .map(|i| match i % 7 {
            0 => 0.0,
            1 => 255.0,
            2 => 234.0,
            3 => (i % 256) as f32,
            _ => (rng.next_u64() % 256) as f32,
        })
        .collect()
}

/// Deterministic per-channel scales; with `degenerate`, every third channel
/// is a constant-column channel pinned at the `EPS` guard (huge zero-point,
/// tiny alpha — the worst-conditioned case `omni_scales` can produce).
pub fn synth_scales(d_out: usize, seed: u64, degenerate: bool) -> Scales {
    let mut rng = Rng::new(seed ^ 0x5CA1E5);
    let mut alpha = Vec::with_capacity(d_out);
    let mut zero = Vec::with_capacity(d_out);
    for k in 0..d_out {
        if degenerate && k % 3 == 0 {
            alpha.push(quant::EPS);
            zero.push(-0.5 / quant::EPS);
        } else {
            alpha.push(rng.range_f32(1e-3, 2.0));
            zero.push(rng.range_f32(-8.0, 260.0));
        }
    }
    Scales {
        bits: 8,
        alpha,
        zero,
    }
}

/// Bucket ids containing Eq. 8 overflow (`2^bits`), split into a dense
/// packed tensor + overlay.
pub fn synth_overlayed(bits: u32, n: usize, seed: u64) -> (PackedTensor, ExtraBitOverlay) {
    let m = 1u64 << bits;
    let mut rng = Rng::new(seed ^ 0x0F10);
    let ids: Vec<f32> = (0..n)
        .map(|i| {
            if i % 9 == 4 || rng.f64() < 0.05 {
                m as f32 // overflow bucket
            } else {
                (rng.next_u64() % m) as f32
            }
        })
        .collect();
    let (overlay, dense) = ExtraBitOverlay::split(&ids, bits);
    (PackedTensor::pack(&dense, bits), overlay)
}

/// Scalar reference for [`crate::kernels::dequant_packed_into`]: unpack →
/// overlay apply → scale ids to master code space → affine dequantize.
pub fn reference_dequant_packed(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
) -> Vec<f32> {
    let mut ids = packed.unpack();
    if let Some(ov) = overlay {
        ov.apply(&mut ids, packed.bits);
    }
    let step = (1u32 << (master_bits - packed.bits)) as f32;
    for v in ids.iter_mut() {
        *v *= step;
    }
    let mut out = vec![0.0f32; ids.len()];
    quant::dequantize_into(&ids, d_out.max(1), scales, &mut out);
    out
}

/// Scalar reference for [`crate::kernels::slice_dequant_into`]: unpack →
/// slice → affine dequantize (the seed's serving path, verbatim).
pub fn reference_slice_dequant(
    codes: &PackedTensor,
    bits: u32,
    extra_precision: bool,
    scales: &Scales,
    d_out: usize,
) -> Vec<f32> {
    let q = codes.unpack();
    let mut sliced = vec![0.0f32; q.len()];
    quant::slice_codes_into(&q, 8, bits, extra_precision, &mut sliced);
    let mut out = vec![0.0f32; sliced.len()];
    quant::dequantize_into(&sliced, d_out.max(1), scales, &mut out);
    out
}

/// Deterministic activation vector mixing exact zeros, sign flips, large
/// magnitudes, and generic small values.
pub fn synth_x(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0xA11CE);
    (0..n)
        .map(|i| match i % 6 {
            0 => 0.0,
            1 => -1.0,
            2 => rng.range_f32(-100.0, 100.0),
            _ => rng.range_f32(-1.5, 1.5),
        })
        .collect()
}

/// Scalar reference for the fused matmul kernels: scalar-path dequantize
/// (via [`reference_dequant_packed`]) followed by a naive row-major f32
/// matmul `y (m, d_out) = xs (m, d_in) · W (+ bias)`.
///
/// Returns `(y, mag)` where `mag[b·d_out + j]` bounds the magnitude flowing
/// through the accumulation in *either* evaluation order — the naive
/// `Σ|x_i·w_ij|` is covered by the hoisted-affine form's
/// `|alpha_j|·(2^master_bits + |zero_j|)·Σ|x_i|`, which is what
/// [`assert_accum_close`] scales its tolerance by.
#[allow(clippy::too_many_arguments)]
pub fn reference_matmul(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    xs: &[f32],
    m: usize,
    bias: Option<&[f32]>,
) -> (Vec<f32>, Vec<f32>) {
    let w = reference_dequant_packed(packed, overlay, scales, master_bits, d_out);
    let d_in = if d_out == 0 { 0 } else { w.len() / d_out };
    let top = (1u64 << master_bits) as f32;
    let mut y = vec![0.0f32; m * d_out];
    let mut mag = vec![0.0f32; m * d_out];
    for b in 0..m {
        let mut abs_x = 0.0f32;
        for i in 0..d_in {
            let xv = xs[b * d_in + i];
            abs_x += xv.abs();
            for j in 0..d_out {
                y[b * d_out + j] += xv * w[i * d_out + j];
            }
        }
        for j in 0..d_out {
            mag[b * d_out + j] =
                scales.alpha[j].abs() * (top + scales.zero[j].abs()) * abs_x;
            if let Some(bs) = bias {
                y[b * d_out + j] += bs[j];
                mag[b * d_out + j] += bs[j].abs();
            }
        }
    }
    (y, mag)
}

/// Assert fused-matmul outputs agree with the naive reference within the
/// accumulation-order tolerance: `(2·d_in + 16)` ulps of the per-output
/// magnitude returned by [`reference_matmul`].
pub fn assert_accum_close(got: &[f32], want: &[f32], mag: &[f32], d_in: usize, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    let ulps = (2 * d_in + 16) as f32;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = f32::EPSILON * ulps * mag[i] + f32::MIN_POSITIVE;
        assert!(
            (g - w).abs() <= tol,
            "{label}: mismatch at {i}: got {g}, want {w} (|Δ|={} > tol={tol}, mag={})",
            (g - w).abs(),
            mag[i]
        );
    }
}

// ---------------------------------------------------------------------------
// Property-test driver
// ---------------------------------------------------------------------------

/// Configuration for [`run_prop`].
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Number of random cases to generate.
    pub cases: usize,
    /// Master seed; every case derives deterministically from it.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 200,
            seed: 0x4D61_7451, // "MatQ"
        }
    }
}

/// Minimal offline property-test runner: generate `cfg.cases` random cases
/// from a seeded [`Rng`] and run `check` on each.  On failure the panic
/// names the property, the case index, the master seed, and the full case
/// value, so any counterexample replays from the seed alone.
pub fn run_prop<T: std::fmt::Debug>(
    name: &str,
    cfg: PropConfig,
    generate: impl Fn(&mut Rng) -> T,
    check: impl Fn(&T),
) {
    let mut rng = Rng::new(cfg.seed);
    for i in 0..cfg.cases {
        let case = generate(&mut rng);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| check(&case)));
        if let Err(payload) = outcome {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&'static str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed on case {i}/{} (seed {:#x}):\n  case: {case:?}\n  {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// One randomly generated fused-matmul conformance case.
#[derive(Debug, Clone)]
pub struct MatmulCase {
    pub bits: u32,
    pub d_in: usize,
    pub d_out: usize,
    /// Batch rows (1 = GEMV).
    pub m: usize,
    /// Generate an Eq. 8 overflow overlay (only meaningful below the
    /// master width).
    pub overlay: bool,
    /// EPS-guarded degenerate channels in the scales.
    pub degenerate: bool,
    /// Attach a bias vector.
    pub bias: bool,
    pub seed: u64,
}

/// Sample a [`MatmulCase`]: every width, odd/word-straddling/empty shapes,
/// overlay and degenerate-scale toggles.
pub fn gen_matmul_case(rng: &mut Rng) -> MatmulCase {
    const WIDTHS: [u32; 6] = [1, 2, 3, 4, 6, 8];
    let bits = WIDTHS[rng.below(WIDTHS.len())];
    let d_in = match rng.below(8) {
        0 => 0,
        1 => 1,
        _ => 1 + rng.below(65),
    };
    let d_out = match rng.below(8) {
        0 => 1,
        1 => 7,
        _ => 1 + rng.below(33),
    };
    MatmulCase {
        bits,
        d_in,
        d_out,
        m: 1 + rng.below(2 * crate::kernels::matmul::GEMM_BLOCK),
        overlay: bits < 8 && rng.below(2) == 0,
        degenerate: rng.below(4) == 0,
        bias: rng.below(2) == 0,
        seed: rng.next_u64(),
    }
}

/// Materialize the payload side of a [`MatmulCase`]: the packed tensor, its
/// overlay (empty unless `case.overlay`), and the per-channel scales.
pub fn build_matmul_payload(case: &MatmulCase) -> (PackedTensor, ExtraBitOverlay, Scales) {
    let n = case.d_in * case.d_out;
    let (packed, overlay) = if case.overlay {
        synth_overlayed(case.bits, n, case.seed)
    } else {
        let ids = synth_ids(case.bits, n, case.seed);
        (PackedTensor::pack(&ids, case.bits), ExtraBitOverlay::default())
    };
    let scales = synth_scales(case.d_out, case.seed ^ 0x5EED, case.degenerate);
    (packed, overlay, scales)
}

/// Assert two f32 buffers are identical *bit patterns* (stronger than `==`:
/// distinguishes `-0.0` from `0.0` and would catch NaN payload drift).
pub fn assert_bits_eq(got: &[f32], want: &[f32], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{label}: mismatch at {i}: got {g} ({:#010x}), want {w} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}
