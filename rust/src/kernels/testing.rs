//! Conformance-harness substrate: deterministic data synthesis, the scalar
//! reference paths (the seed's two-pass walk, kept verbatim as the oracle),
//! and exact-equality assertions.
//!
//! Shared by the in-crate kernel unit tests, the exhaustive suite in
//! `tests/kernel_conformance.rs`, and `benches/quant_hot_paths.rs` (which
//! benches fused vs reference on the same inputs it validates).

use crate::data::Rng;
use crate::quant::{self, ExtraBitOverlay, PackedTensor, Scales};

/// Deterministic r-bit bucket ids covering the full `[0, 2^bits)` range.
pub fn synth_ids(bits: u32, n: usize, seed: u64) -> Vec<f32> {
    let m = 1u64 << bits;
    let mut rng = Rng::new(seed ^ 0xBEEF);
    (0..n)
        .map(|i| match i % 5 {
            0 => 0.0,
            1 => (m - 1) as f32,
            _ => (rng.next_u64() % m) as f32,
        })
        .collect()
}

/// Deterministic 8-bit master codes biased toward slicing edge cases: the
/// extremes, the paper's errata example 234 (overflows every `r < 8` under
/// Eq. 8), and round-half-up boundaries.
pub fn synth_master_codes(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0xC0DE);
    (0..n)
        .map(|i| match i % 7 {
            0 => 0.0,
            1 => 255.0,
            2 => 234.0,
            3 => (i % 256) as f32,
            _ => (rng.next_u64() % 256) as f32,
        })
        .collect()
}

/// Deterministic per-channel scales; with `degenerate`, every third channel
/// is a constant-column channel pinned at the `EPS` guard (huge zero-point,
/// tiny alpha — the worst-conditioned case `omni_scales` can produce).
pub fn synth_scales(d_out: usize, seed: u64, degenerate: bool) -> Scales {
    let mut rng = Rng::new(seed ^ 0x5CA1E5);
    let mut alpha = Vec::with_capacity(d_out);
    let mut zero = Vec::with_capacity(d_out);
    for k in 0..d_out {
        if degenerate && k % 3 == 0 {
            alpha.push(quant::EPS);
            zero.push(-0.5 / quant::EPS);
        } else {
            alpha.push(rng.range_f32(1e-3, 2.0));
            zero.push(rng.range_f32(-8.0, 260.0));
        }
    }
    Scales {
        bits: 8,
        alpha,
        zero,
    }
}

/// Bucket ids containing Eq. 8 overflow (`2^bits`), split into a dense
/// packed tensor + overlay.
pub fn synth_overlayed(bits: u32, n: usize, seed: u64) -> (PackedTensor, ExtraBitOverlay) {
    let m = 1u64 << bits;
    let mut rng = Rng::new(seed ^ 0x0F10);
    let ids: Vec<f32> = (0..n)
        .map(|i| {
            if i % 9 == 4 || rng.f64() < 0.05 {
                m as f32 // overflow bucket
            } else {
                (rng.next_u64() % m) as f32
            }
        })
        .collect();
    let (overlay, dense) = ExtraBitOverlay::split(&ids, bits);
    (PackedTensor::pack(&dense, bits), overlay)
}

/// Scalar reference for [`crate::kernels::dequant_packed_into`]: unpack →
/// overlay apply → scale ids to master code space → affine dequantize.
pub fn reference_dequant_packed(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
) -> Vec<f32> {
    let mut ids = packed.unpack();
    if let Some(ov) = overlay {
        ov.apply(&mut ids, packed.bits);
    }
    let step = (1u32 << (master_bits - packed.bits)) as f32;
    for v in ids.iter_mut() {
        *v *= step;
    }
    let mut out = vec![0.0f32; ids.len()];
    quant::dequantize_into(&ids, d_out.max(1), scales, &mut out);
    out
}

/// Scalar reference for [`crate::kernels::slice_dequant_into`]: unpack →
/// slice → affine dequantize (the seed's serving path, verbatim).
pub fn reference_slice_dequant(
    codes: &PackedTensor,
    bits: u32,
    extra_precision: bool,
    scales: &Scales,
    d_out: usize,
) -> Vec<f32> {
    let q = codes.unpack();
    let mut sliced = vec![0.0f32; q.len()];
    quant::slice_codes_into(&q, 8, bits, extra_precision, &mut sliced);
    let mut out = vec![0.0f32; sliced.len()];
    quant::dequantize_into(&sliced, d_out.max(1), scales, &mut out);
    out
}

/// Assert two f32 buffers are identical *bit patterns* (stronger than `==`:
/// distinguishes `-0.0` from `0.0` and would catch NaN payload drift).
pub fn assert_bits_eq(got: &[f32], want: &[f32], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{label}: mismatch at {i}: got {g} ({:#010x}), want {w} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}
