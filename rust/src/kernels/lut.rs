//! Byte-expansion lookup tables for the fused dequantization kernels.
//!
//! For the power-of-two widths (1/2/4/8 bits) a packed byte expands to a
//! fixed number of bucket ids, so a 256-entry table turns bit extraction
//! into one indexed load per byte.  Tables are built once on first use
//! (`OnceLock`) and shared across threads.
//!
//! [`slice_value_lut`] is the Mix'n'Match variant: a 256-entry table over
//! the *8-bit master code itself*, mapping each byte straight to its sliced
//! value `S(q, r)` (Eq. 6 / Eq. 8), so slice+dequant fuses into a single
//! lookup + affine per weight.

use std::sync::OnceLock;

use crate::quant::slice_code;
use crate::MASTER_BITS;

fn build<const EPB: usize>(bits: u32) -> [[f32; EPB]; 256] {
    let mask = (1u32 << bits) - 1;
    let mut table = [[0.0f32; EPB]; 256];
    for (byte, entry) in table.iter_mut().enumerate() {
        for (k, v) in entry.iter_mut().enumerate() {
            *v = ((byte as u32 >> (bits as usize * k)) & mask) as f32;
        }
    }
    table
}

/// byte → 8 × 1-bit bucket ids.
pub fn lut1() -> &'static [[f32; 8]; 256] {
    static L: OnceLock<[[f32; 8]; 256]> = OnceLock::new();
    L.get_or_init(|| build::<8>(1))
}

/// byte → 4 × 2-bit bucket ids.
pub fn lut2() -> &'static [[f32; 4]; 256] {
    static L: OnceLock<[[f32; 4]; 256]> = OnceLock::new();
    L.get_or_init(|| build::<4>(2))
}

/// byte → 2 × 4-bit bucket ids.
pub fn lut4() -> &'static [[f32; 2]; 256] {
    static L: OnceLock<[[f32; 2]; 256]> = OnceLock::new();
    L.get_or_init(|| build::<2>(4))
}

/// byte → the 8-bit bucket id itself (kept as a table so every power-of-two
/// width shares one kernel shape).
pub fn lut8() -> &'static [[f32; 1]; 256] {
    static L: OnceLock<[[f32; 1]; 256]> = OnceLock::new();
    L.get_or_init(|| build::<1>(8))
}

/// 256-entry master-code → sliced-value table for `S(q^8, r)`.
///
/// `table[q] == slice_code(q, 8, r, extra_precision)` exactly — the table is
/// built *by* the scalar oracle, so fused results are bit-for-bit identical
/// to the reference two-pass path by construction.  All 16 `(r, ep)`
/// variants are cached, so per-tensor materialization never rebuilds one.
pub fn slice_value_lut(r: u32, extra_precision: bool) -> &'static [f32; 256] {
    assert!(r >= 1 && r <= MASTER_BITS);
    // interior-mutable const is intentional: array-repeat seed for statics
    #[allow(clippy::declare_interior_mutable_const)]
    const EMPTY: OnceLock<[f32; 256]> = OnceLock::new();
    static LUTS: [OnceLock<[f32; 256]>; 16] = [EMPTY; 16];
    LUTS[(r as usize - 1) * 2 + extra_precision as usize].get_or_init(|| {
        let mut table = [0.0f32; 256];
        for (q, v) in table.iter_mut().enumerate() {
            *v = slice_code(q as f32, MASTER_BITS, r, extra_precision);
        }
        table
    })
}

/// Integer mirror of [`slice_value_lut`] for the integer-domain bit-slice
/// view kernels: `table[q] == slice_code(q, 8, r, ep) as i32`.  Sliced
/// values are integers in `0..=256` (bucket id times the power-of-two
/// step), so the i32 form is exact and the view GEMM's reduction stays in
/// the integer domain end-to-end.
pub fn slice_value_lut_i32(r: u32, extra_precision: bool) -> &'static [i32; 256] {
    assert!(r >= 1 && r <= MASTER_BITS);
    // interior-mutable const is intentional: array-repeat seed for statics
    #[allow(clippy::declare_interior_mutable_const)]
    const EMPTY: OnceLock<[i32; 256]> = OnceLock::new();
    static LUTS: [OnceLock<[i32; 256]>; 16] = [EMPTY; 16];
    LUTS[(r as usize - 1) * 2 + extra_precision as usize].get_or_init(|| {
        let mut table = [0i32; 256];
        for (q, v) in table.iter_mut().enumerate() {
            *v = slice_code(q as f32, MASTER_BITS, r, extra_precision) as i32;
        }
        table
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_tables_match_bit_extraction() {
        for byte in 0..256usize {
            for (k, &v) in lut1()[byte].iter().enumerate() {
                assert_eq!(v, ((byte >> k) & 1) as f32);
            }
            for (k, &v) in lut2()[byte].iter().enumerate() {
                assert_eq!(v, ((byte >> (2 * k)) & 3) as f32);
            }
            for (k, &v) in lut4()[byte].iter().enumerate() {
                assert_eq!(v, ((byte >> (4 * k)) & 15) as f32);
            }
            assert_eq!(lut8()[byte][0], byte as f32);
        }
    }

    #[test]
    fn slice_lut_matches_scalar_oracle() {
        for r in [1u32, 2, 3, 4, 6, 8] {
            for ep in [false, true] {
                let lut = slice_value_lut(r, ep);
                for q in 0..256usize {
                    assert_eq!(
                        lut[q].to_bits(),
                        slice_code(q as f32, 8, r, ep).to_bits(),
                        "r={r} ep={ep} q={q}"
                    );
                }
            }
        }
    }

    #[test]
    fn i32_slice_lut_mirrors_f32_table_exactly() {
        for r in [1u32, 2, 3, 4, 6, 8] {
            for ep in [false, true] {
                let f = slice_value_lut(r, ep);
                let i = slice_value_lut_i32(r, ep);
                for q in 0..256usize {
                    assert_eq!(i[q] as f32, f[q], "r={r} ep={ep} q={q}");
                    assert!((0..=256).contains(&i[q]), "r={r} ep={ep} q={q}: {}", i[q]);
                }
            }
        }
    }
}
