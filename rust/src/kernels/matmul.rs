//! Fused packed-domain dequant×matmul — `y = x·W_r (+ bias)` straight from
//! the bitstream.
//!
//! PR 1 fused unpack+affine into one pass, but every matmul still began by
//! materializing the full f32 weight tensor.  These kernels keep the packed
//! representation alive all the way into the GEMV/GEMM inner loop: the only
//! f32 weight state that ever exists is one `d_out`-wide row tile, decoded
//! on the fly and immediately consumed.  At r bits the weight bytes read
//! per token drop by `32/r` versus the materialize-then-multiply path.
//!
//! Layout matches the registry: `W` is `(d_in, d_out)` row-major with per
//! *output-channel* (column) scales, and the product is the model's
//! activation flow `y[j] = Σ_i x[i]·W[i,j]` (what [`crate::model::Tensor::vecmat`]
//! computes on dense weights).
//!
//! # The affine hoist
//!
//! With `W[i,j] = (id[i,j]·step − zero[j])·alpha[j]`, the per-channel affine
//! factors completely out of the reduction:
//!
//! ```text
//!   y[j] = alpha[j]·(step·Σ_i x[i]·id[i,j]  −  zero[j]·Σ_i x[i])
//! ```
//!
//! so the inner loop is a raw multiply-accumulate over bucket ids — no
//! subtract, no per-element scale — and the affine runs once per output in
//! the epilogue.  The same factoring enables the integer path
//! ([`matvec_packed_i8_into`]): with int8 activations the reduction is an
//! exact i32 multiply-accumulate, scaled to f32 only at the end.
//!
//! # Kernel shapes
//!
//! * [`matvec_packed_into`] — row-tiled GEMV.  Power-of-two widths
//!   (1/2/4/8) decode through the 256-entry byte-expansion LUTs
//!   ([`super::lut`]); 3/6-bit fall back to the [`BitCursor`].  The
//!   accumulate over each decoded row runs [`LANES`]-wide (8-lane
//!   unrolled, the autovectorizer-friendly shape) in the f32 and i8 paths.
//! * [`matmul_packed_into`] — blocked multi-column GEMM for batched
//!   requests: each block of up to [`GEMM_BLOCK`] batch rows re-streams the
//!   (2–8× smaller) packed weights once, so accumulator tiles stay
//!   cache-resident while the decode cost is amortized over the block.
//! * [`matvec_packed_i8_into`] — accumulate-in-i32-then-scale GEMV over
//!   quantized activations, with periodic i64 spills so the i32 partials
//!   cannot overflow (see [`I32_FLUSH_ROWS`]).
//!
//! Eq. 8 overflow overlays are applied as a sparse correction: overlay
//! entries decode to the bucket id `2^r`, exactly as in
//! [`super::fused::dequant_packed_into`].
//!
//! Conformance: `cargo test --test kernel_conformance` checks every kernel
//! against the scalar `quant::` dequant followed by a naive f32 matmul —
//! bit-for-bit on decode, within an accumulation-magnitude-scaled tolerance
//! on the reductions (the factored sum is a different, equally valid f32
//! evaluation order).  See [`super::testing::reference_matmul`].

use super::cursor::BitCursor;
use super::lut;
use crate::quant::{ExtraBitOverlay, PackedTensor, Scales};
use crate::MASTER_BITS;

/// Batch rows per GEMM block: small enough that the `(GEMM_BLOCK, d_out)`
/// accumulator tile stays cache-hot, large enough to amortize one decode of
/// the packed stream across the block.
pub const GEMM_BLOCK: usize = 8;

/// Rows between i64 spills in the i32-accumulation path.  One term is
/// bounded by `|xq|·id ≤ 128·255 = 32640`, so `32640·4096 ≈ 1.3e8` keeps
/// the i32 partial more than an order of magnitude clear of overflow even
/// in release builds (where wrap-around would be silent).
pub const I32_FLUSH_ROWS: usize = 4096;

/// SIMD-width row tile for the GEMV/GEMM inner loops: 8 f32 lanes (two
/// 128-bit or one 256-bit vector register).  The accumulate over a decoded
/// weight row is unrolled in `LANES`-wide chunks with no cross-lane
/// dependency, which is the shape LLVM reliably vectorizes; per-lane the
/// sequence of adds into each output slot is unchanged, so results stay
/// bit-identical to the rolled loop.
pub const LANES: usize = 8;

/// `acc[j] += xv · ids[j]` over one row tile, unrolled [`LANES`] wide.
#[inline(always)]
fn axpy_row_f32(acc: &mut [f32], ids: &[f32], xv: f32) {
    let mut a = acc.chunks_exact_mut(LANES);
    let mut w = ids.chunks_exact(LANES);
    for (a8, w8) in (&mut a).zip(&mut w) {
        a8[0] += xv * w8[0];
        a8[1] += xv * w8[1];
        a8[2] += xv * w8[2];
        a8[3] += xv * w8[3];
        a8[4] += xv * w8[4];
        a8[5] += xv * w8[5];
        a8[6] += xv * w8[6];
        a8[7] += xv * w8[7];
    }
    for (o, &id) in a.into_remainder().iter_mut().zip(w.remainder()) {
        *o += xv * id;
    }
}

/// `acc[j] += xi · ids[j]` over one i32 row tile, unrolled [`LANES`] wide
/// (exact integer accumulation — order is irrelevant to the result).
#[inline(always)]
fn mac_row_i32(acc: &mut [i32], ids: &[i32], xi: i32) {
    let mut a = acc.chunks_exact_mut(LANES);
    let mut w = ids.chunks_exact(LANES);
    for (a8, w8) in (&mut a).zip(&mut w) {
        a8[0] += xi * w8[0];
        a8[1] += xi * w8[1];
        a8[2] += xi * w8[2];
        a8[3] += xi * w8[3];
        a8[4] += xi * w8[4];
        a8[5] += xi * w8[5];
        a8[6] += xi * w8[6];
        a8[7] += xi * w8[7];
    }
    for (o, &id) in a.into_remainder().iter_mut().zip(w.remainder()) {
        *o += xi * id;
    }
}

/// Streaming state for the LUT row decoder: ids decoded from the current
/// byte but not yet emitted (a byte can straddle a row boundary whenever
/// `d_out` is not a multiple of the entries-per-byte).
#[derive(Default)]
struct LutState {
    byte: usize,
    pending: [f32; 8],
    pos: usize,
    len: usize,
}

/// Decode the next `out.len()` entries of the stream into `out`.
fn fill_row_lut<const EPB: usize>(
    data: &[u8],
    table: &[[f32; EPB]; 256],
    st: &mut LutState,
    out: &mut [f32],
) {
    let n = out.len();
    let mut k = 0usize;
    while k < n && st.pos < st.len {
        out[k] = st.pending[st.pos];
        st.pos += 1;
        k += 1;
    }
    while n - k >= EPB {
        out[k..k + EPB].copy_from_slice(&table[data[st.byte] as usize]);
        st.byte += 1;
        k += EPB;
    }
    if k < n {
        let ids = &table[data[st.byte] as usize];
        st.byte += 1;
        let take = n - k;
        out[k..].copy_from_slice(&ids[..take]);
        st.pending[..EPB - take].copy_from_slice(&ids[take..]);
        st.pos = 0;
        st.len = EPB - take;
    }
}

/// One-pass row decoder over a packed bitstream: LUT byte expansion for the
/// power-of-two widths, bit cursor for 3/6-bit, and the MSB-prefix
/// **sliced view** over an int8 master (one byte per entry, mapped through
/// the 256-entry sliced-value table — no intermediate r-bit payload).
enum RowStream<'a> {
    L1(&'a [u8], LutState),
    L2(&'a [u8], LutState),
    L4(&'a [u8], LutState),
    L8(&'a [u8], LutState),
    Cursor(BitCursor<'a>, u32),
    /// (master bytes, sliced-value table, next entry index).  The stream
    /// emits `S(q, r)` values — bucket id times the power-of-two step — so
    /// the consumer runs with `step = 1.0` and no overlay fix-up (the
    /// Eq. 8 overflow bucket is already in the table).
    Sliced(&'a [u8], &'static [f32; 256], usize),
}

impl<'a> RowStream<'a> {
    fn new(data: &'a [u8], bits: u32) -> Self {
        match bits {
            1 => RowStream::L1(data, LutState::default()),
            2 => RowStream::L2(data, LutState::default()),
            4 => RowStream::L4(data, LutState::default()),
            8 => RowStream::L8(data, LutState::default()),
            _ => RowStream::Cursor(BitCursor::new(data), bits),
        }
    }

    /// A bit-slice view stream over int8 master `data` at `bits`.
    fn sliced(data: &'a [u8], bits: u32, extra_precision: bool) -> Self {
        RowStream::Sliced(data, lut::slice_value_lut(bits, extra_precision), 0)
    }

    /// Decode the next `out.len()` bucket ids (one weight row tile).
    fn fill_row(&mut self, out: &mut [f32]) {
        match self {
            RowStream::L1(d, st) => fill_row_lut::<8>(*d, lut::lut1(), st, out),
            RowStream::L2(d, st) => fill_row_lut::<4>(*d, lut::lut2(), st, out),
            RowStream::L4(d, st) => fill_row_lut::<2>(*d, lut::lut4(), st, out),
            RowStream::L8(d, st) => fill_row_lut::<1>(*d, lut::lut8(), st, out),
            RowStream::Cursor(cur, bits) => {
                for o in out.iter_mut() {
                    *o = cur.next(*bits) as f32;
                }
            }
            RowStream::Sliced(d, table, pos) => {
                let n = out.len();
                for (o, &q) in out.iter_mut().zip(&d[*pos..*pos + n]) {
                    *o = table[q as usize];
                }
                *pos += n;
            }
        }
    }
}

/// Shared argument validation; returns `d_in`.
#[allow(clippy::too_many_arguments)]
fn check_matmul_shapes(
    packed: &PackedTensor,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    xs_len: usize,
    m: usize,
    bias: Option<&[f32]>,
    out_len: usize,
) -> usize {
    assert!(
        packed.bits <= master_bits && master_bits <= MASTER_BITS,
        "widths out of range: {} within {}",
        packed.bits,
        master_bits
    );
    assert_eq!(scales.d_out(), d_out, "scales channel count mismatch");
    assert_eq!(out_len, m * d_out, "output buffer length mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), d_out, "bias length mismatch");
    }
    if packed.len == 0 && d_out == 0 {
        assert_eq!(xs_len, 0, "input must be empty for a 0-channel weight");
        return 0;
    }
    assert!(d_out > 0, "d_out must be positive");
    assert_eq!(packed.len % d_out, 0, "tensor length not a multiple of d_out");
    let d_in = packed.len / d_out;
    assert_eq!(xs_len, m * d_in, "input length mismatch");
    d_in
}

/// Core fused GEMM over one block of `m <= GEMM_BLOCK` batch rows.
///
/// `acc` (the caller's output slice) receives raw id dot products first and
/// is rewritten in place by the affine epilogue, so no extra accumulator
/// allocation exists beyond the `d_out`-wide row tile.  The caller owns the
/// decode stream (rebuilt per block): compact payloads pass their overlay
/// indices + overflow value `top` and the payload's `step`; sliced-view
/// streams pass an empty overlay and `step = 1.0` (the table already emits
/// stepped values — same epilogue, bit-identical results).
#[allow(clippy::too_many_arguments)]
fn gemm_block(
    stream: &mut RowStream,
    ov: &[u32],
    top: f32,
    scales: &Scales,
    step: f32,
    d_in: usize,
    d_out: usize,
    xs: &[f32],
    m: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
    row_ids: &mut [f32],
) {
    let mut ovp = 0usize;
    out.fill(0.0);
    let mut xsum = [0.0f32; GEMM_BLOCK];
    for row in 0..d_in {
        stream.fill_row(row_ids);
        // Sparse Eq. 8 fix-up: overlay indices are sorted, so the entries
        // belonging to this row are a contiguous run.
        let hi = (row + 1) * d_out;
        while ovp < ov.len() && (ov[ovp] as usize) < hi {
            row_ids[ov[ovp] as usize - row * d_out] = top;
            ovp += 1;
        }
        for b in 0..m {
            let xv = xs[b * d_in + row];
            if xv == 0.0 {
                continue;
            }
            xsum[b] += xv;
            axpy_row_f32(&mut out[b * d_out..(b + 1) * d_out], row_ids, xv);
        }
    }
    // Epilogue: the hoisted per-channel affine, once per output element.
    for b in 0..m {
        let sx = xsum[b];
        let orow = &mut out[b * d_out..(b + 1) * d_out];
        match bias {
            Some(bs) => {
                for j in 0..d_out {
                    orow[j] = scales.alpha[j] * (step * orow[j] - scales.zero[j] * sx) + bs[j];
                }
            }
            None => {
                for j in 0..d_out {
                    orow[j] = scales.alpha[j] * (step * orow[j] - scales.zero[j] * sx);
                }
            }
        }
    }
}

/// Fused packed-domain GEMV: `out[j] = Σ_i x[i]·W[i,j] (+ bias[j])` where
/// `W` is decoded on the fly from `packed` (+ optional Eq. 8 `overlay`) and
/// the shared `master_bits`-width per-channel `scales` — the f32 weight
/// tensor is never materialized.
///
/// `packed` holds `r = packed.bits`-bit bucket ids exactly as produced by
/// [`crate::model::registry::QuantizedTensor::pack_sliced`]; `x` has length
/// `d_in = packed.len / d_out` and `out` has length `d_out`.
#[allow(clippy::too_many_arguments)]
pub fn matvec_packed_into(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    x: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    matmul_packed_into(packed, overlay, scales, master_bits, d_out, x, 1, bias, out);
}

/// Allocating convenience wrapper over [`matvec_packed_into`].
pub fn matvec_packed(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    x: &[f32],
    bias: Option<&[f32]>,
) -> Vec<f32> {
    let mut out = vec![0.0f32; d_out];
    matvec_packed_into(packed, overlay, scales, master_bits, d_out, x, bias, &mut out);
    out
}

/// Blocked multi-column fused GEMM for batched requests:
/// `out (m, d_out) = xs (m, d_in) · W_r (+ bias per row)`, both row-major.
///
/// Batch rows are processed in blocks of [`GEMM_BLOCK`]; each block streams
/// the packed weights once, so total weight bytes read are
/// `ceil(m / GEMM_BLOCK) · payload` — still `32·GEMM_BLOCK / r` times fewer
/// than reading a materialized f32 tensor per batch row.
#[allow(clippy::too_many_arguments)]
pub fn matmul_packed_into(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    xs: &[f32],
    m: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let d_in = check_matmul_shapes(
        packed,
        scales,
        master_bits,
        d_out,
        xs.len(),
        m,
        bias,
        out.len(),
    );
    if m == 0 || d_out == 0 {
        return;
    }
    let step = (1u32 << (master_bits - packed.bits)) as f32;
    let top = (1u32 << packed.bits) as f32;
    let ov: &[u32] = overlay.map_or(&[], |o| &o.indices);
    let mut row_ids = vec![0.0f32; d_out];
    let mut b0 = 0usize;
    while b0 < m {
        let mb = GEMM_BLOCK.min(m - b0);
        let mut stream = RowStream::new(&packed.data, packed.bits);
        gemm_block(
            &mut stream,
            ov,
            top,
            scales,
            step,
            d_in,
            d_out,
            &xs[b0 * d_in..(b0 + mb) * d_in],
            mb,
            bias,
            &mut out[b0 * d_out..(b0 + mb) * d_out],
            &mut row_ids,
        );
        b0 += mb;
    }
}

/// Blocked fused GEMM over an MSB-prefix **bit-slice view**:
/// `out (m, d_out) = xs (m, d_in) · W_r (+ bias)` where `W_r` is the
/// `bits`-wide slice of the int8 master `codes` — no r-bit payload exists;
/// each master byte maps through the 256-entry sliced-value LUT
/// ([`super::lut::slice_value_lut`]) on the fly.
///
/// Bit-for-bit identical to [`matmul_packed_into`] over the compact
/// payload from `QuantizedTensor::pack_sliced` at the same `(bits, ep)`:
/// the table emits `S = id·step` with `step` a power of two, so every
/// partial sum is the compact path's partial sum exactly scaled by `step`,
/// and the `step = 1.0` epilogue lands on the same f32 values the compact
/// epilogue computes via `step·acc`.  The Eq. 8 overflow bucket is inside
/// the table, so extra-precision views need no overlay fix-up.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sliced_into(
    codes: &PackedTensor,
    bits: u32,
    extra_precision: bool,
    scales: &Scales,
    d_out: usize,
    xs: &[f32],
    m: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!(codes.bits, MASTER_BITS, "sliced GEMM reads the int8 master");
    assert!(bits >= 1 && bits <= MASTER_BITS, "bits out of range: {bits}");
    let d_in = check_matmul_shapes(
        codes,
        scales,
        MASTER_BITS,
        d_out,
        xs.len(),
        m,
        bias,
        out.len(),
    );
    if m == 0 || d_out == 0 {
        return;
    }
    let mut row_ids = vec![0.0f32; d_out];
    let mut b0 = 0usize;
    while b0 < m {
        let mb = GEMM_BLOCK.min(m - b0);
        let mut stream = RowStream::sliced(&codes.data, bits, extra_precision);
        gemm_block(
            &mut stream,
            &[],
            0.0,
            scales,
            1.0,
            d_in,
            d_out,
            &xs[b0 * d_in..(b0 + mb) * d_in],
            mb,
            bias,
            &mut out[b0 * d_out..(b0 + mb) * d_out],
            &mut row_ids,
        );
        b0 += mb;
    }
}

/// Allocating convenience wrapper over [`matmul_packed_into`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_packed(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    xs: &[f32],
    m: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * d_out];
    matmul_packed_into(packed, overlay, scales, master_bits, d_out, xs, m, bias, &mut out);
    out
}

/// Integer-domain fused GEMV: activations are symmetric int8 codes
/// (`x[i] = xq[i]·x_scale`), so the reduction `Σ xq[i]·id[i,j]` is an exact
/// i32 multiply-accumulate — the per-channel affine *and* both scales move
/// entirely into the f32 epilogue:
///
/// ```text
///   y[j] = alpha[j]·(step·x_scale·acc[j] − zero[j]·x_scale·Σ xq[i]) (+ bias)
/// ```
///
/// i32 partials spill into i64 every [`I32_FLUSH_ROWS`] rows, which keeps
/// the path exact (and overflow-free) at any `d_in` in both debug and
/// release builds.  Decode runs through the [`BitCursor`] for every width
/// so the ids stay integral end-to-end.
#[allow(clippy::too_many_arguments)]
pub fn matvec_packed_i8_into(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    xq: &[i8],
    x_scale: f32,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let d_in = check_matmul_shapes(
        packed,
        scales,
        master_bits,
        d_out,
        xq.len(),
        1,
        bias,
        out.len(),
    );
    if d_out == 0 {
        return;
    }
    let step = (1u32 << (master_bits - packed.bits)) as f32;
    let bits = packed.bits;
    let mut cur = BitCursor::new(&packed.data);
    let mut row_ids = vec![0i32; d_out];
    let mut acc32 = vec![0i32; d_out];
    let mut acc = vec![0i64; d_out];
    let mut xsum: i64 = 0;
    for (row, &xv) in xq.iter().take(d_in).enumerate() {
        // Row-tile decode first (the cursor must advance even for zero
        // activations), then the LANES-unrolled integer accumulate.
        for id in row_ids.iter_mut() {
            *id = cur.next(bits) as i32;
        }
        let xi = xv as i32;
        xsum += xi as i64;
        if xi != 0 {
            mac_row_i32(&mut acc32, &row_ids, xi);
        }
        if (row + 1) % I32_FLUSH_ROWS == 0 {
            for (wide, narrow) in acc.iter_mut().zip(acc32.iter_mut()) {
                *wide += *narrow as i64;
                *narrow = 0;
            }
        }
    }
    for (wide, narrow) in acc.iter_mut().zip(acc32.iter_mut()) {
        *wide += *narrow as i64;
        *narrow = 0;
    }
    if let Some(ov) = overlay {
        // The dense stream stores 2^r − 1 at overlay positions; the true
        // bucket id is 2^r, so correct by the exact integer difference.
        let top = 1i64 << bits;
        for &idx in &ov.indices {
            let i = idx as usize;
            acc[i % d_out] += (xq[i / d_out] as i64) * (top - packed.get(i) as i64);
        }
    }
    let sx = x_scale * xsum as f32;
    match bias {
        Some(bs) => {
            for j in 0..d_out {
                out[j] = scales.alpha[j] * (step * x_scale * acc[j] as f32 - scales.zero[j] * sx)
                    + bs[j];
            }
        }
        None => {
            for j in 0..d_out {
                out[j] = scales.alpha[j] * (step * x_scale * acc[j] as f32 - scales.zero[j] * sx);
            }
        }
    }
}

/// Blocked integer-domain GEMM over per-row-quantized activations:
/// `out (m, d_out) = dequant(xq·W_r)` where row `b` of `xq` carries its own
/// activation scale `x_scales[b]` (per-token quantization — rows stay
/// independent).  Like [`matmul_packed_into`], each block of up to
/// [`GEMM_BLOCK`] batch rows streams the packed payload **once**, so the
/// weight bytes read are `ceil(m / GEMM_BLOCK) · payload` instead of
/// `m · payload` for per-row [`matvec_packed_i8_into`] calls.  A
/// single-row block is bit-identical to `matvec_packed_i8_into` (integer
/// accumulation is exact; the f32 epilogue is the same expression).
#[allow(clippy::too_many_arguments)]
pub fn matmul_packed_i8_into(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    xq: &[i8],
    m: usize,
    x_scales: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let d_in = check_matmul_shapes(
        packed,
        scales,
        master_bits,
        d_out,
        xq.len(),
        m,
        bias,
        out.len(),
    );
    assert_eq!(x_scales.len(), m, "one activation scale per batch row");
    if m == 0 || d_out == 0 {
        return;
    }
    let bits = packed.bits;
    let step = (1u32 << (master_bits - bits)) as f32;
    let mut row_ids = vec![0i32; d_out];
    // Accumulator tiles are allocated once and zero-filled per block — no
    // allocator traffic inside the hot loop.
    let tile = GEMM_BLOCK.min(m) * d_out;
    let mut acc32_buf = vec![0i32; tile];
    let mut acc_buf = vec![0i64; tile];
    let mut b0 = 0usize;
    while b0 < m {
        let mb = GEMM_BLOCK.min(m - b0);
        let mut cur = BitCursor::new(&packed.data);
        let acc32 = &mut acc32_buf[..mb * d_out];
        let acc = &mut acc_buf[..mb * d_out];
        acc32.fill(0);
        acc.fill(0);
        let mut xsum = [0i64; GEMM_BLOCK];
        for row in 0..d_in {
            for id in row_ids.iter_mut() {
                *id = cur.next(bits) as i32;
            }
            for b in 0..mb {
                let xi = xq[(b0 + b) * d_in + row] as i32;
                xsum[b] += xi as i64;
                if xi != 0 {
                    mac_row_i32(&mut acc32[b * d_out..(b + 1) * d_out], &row_ids, xi);
                }
            }
            if (row + 1) % I32_FLUSH_ROWS == 0 {
                for (wide, narrow) in acc.iter_mut().zip(acc32.iter_mut()) {
                    *wide += *narrow as i64;
                    *narrow = 0;
                }
            }
        }
        for (wide, narrow) in acc.iter_mut().zip(acc32.iter_mut()) {
            *wide += *narrow as i64;
            *narrow = 0;
        }
        if let Some(ov) = overlay {
            // Same exact-integer overlay correction as the GEMV path.
            let top = 1i64 << bits;
            for &idx in &ov.indices {
                let i = idx as usize;
                let (r, c) = (i / d_out, i % d_out);
                let diff = top - packed.get(i) as i64;
                for b in 0..mb {
                    acc[b * d_out + c] += (xq[(b0 + b) * d_in + r] as i64) * diff;
                }
            }
        }
        for b in 0..mb {
            let x_scale = x_scales[b0 + b];
            let sx = x_scale * xsum[b] as f32;
            let arow = &acc[b * d_out..(b + 1) * d_out];
            let orow = &mut out[(b0 + b) * d_out..(b0 + b + 1) * d_out];
            match bias {
                Some(bs) => {
                    for j in 0..d_out {
                        orow[j] = scales.alpha[j]
                            * (step * x_scale * arow[j] as f32 - scales.zero[j] * sx)
                            + bs[j];
                    }
                }
                None => {
                    for j in 0..d_out {
                        orow[j] = scales.alpha[j]
                            * (step * x_scale * arow[j] as f32 - scales.zero[j] * sx);
                    }
                }
            }
        }
        b0 += mb;
    }
}

/// Blocked integer-domain GEMM over an MSB-prefix **bit-slice view** with
/// per-row-quantized activations — the i8 twin of [`matmul_sliced_into`].
/// Each master byte maps through the i32 sliced-value LUT
/// ([`super::lut::slice_value_lut_i32`]); the reduction is an exact
/// i32/i64 multiply-accumulate over `S = id·step` values (`S ≤ 256`, so
/// one term is bounded by `128·256` and the [`I32_FLUSH_ROWS`] spill keeps
/// the same overflow margin as the compact path), and the epilogue omits
/// `step` — the accumulator already carries it.  Bit-for-bit identical to
/// [`matmul_packed_i8_into`] over the compact payload at the same
/// `(bits, ep)`: the integer accumulators relate by the exact power-of-two
/// factor `step`, which commutes with the i64→f32 rounding and with the
/// f32 epilogue products.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sliced_i8_into(
    codes: &PackedTensor,
    bits: u32,
    extra_precision: bool,
    scales: &Scales,
    d_out: usize,
    xq: &[i8],
    m: usize,
    x_scales: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!(codes.bits, MASTER_BITS, "sliced GEMM reads the int8 master");
    assert!(bits >= 1 && bits <= MASTER_BITS, "bits out of range: {bits}");
    let d_in = check_matmul_shapes(
        codes,
        scales,
        MASTER_BITS,
        d_out,
        xq.len(),
        m,
        bias,
        out.len(),
    );
    assert_eq!(x_scales.len(), m, "one activation scale per batch row");
    if m == 0 || d_out == 0 {
        return;
    }
    let table = lut::slice_value_lut_i32(bits, extra_precision);
    let mut row_ids = vec![0i32; d_out];
    let tile = GEMM_BLOCK.min(m) * d_out;
    let mut acc32_buf = vec![0i32; tile];
    let mut acc_buf = vec![0i64; tile];
    let mut b0 = 0usize;
    while b0 < m {
        let mb = GEMM_BLOCK.min(m - b0);
        let acc32 = &mut acc32_buf[..mb * d_out];
        let acc = &mut acc_buf[..mb * d_out];
        acc32.fill(0);
        acc.fill(0);
        let mut xsum = [0i64; GEMM_BLOCK];
        let mut pos = 0usize;
        for row in 0..d_in {
            for (id, &q) in row_ids.iter_mut().zip(&codes.data[pos..pos + d_out]) {
                *id = table[q as usize];
            }
            pos += d_out;
            for b in 0..mb {
                let xi = xq[(b0 + b) * d_in + row] as i32;
                xsum[b] += xi as i64;
                if xi != 0 {
                    mac_row_i32(&mut acc32[b * d_out..(b + 1) * d_out], &row_ids, xi);
                }
            }
            if (row + 1) % I32_FLUSH_ROWS == 0 {
                for (wide, narrow) in acc.iter_mut().zip(acc32.iter_mut()) {
                    *wide += *narrow as i64;
                    *narrow = 0;
                }
            }
        }
        for (wide, narrow) in acc.iter_mut().zip(acc32.iter_mut()) {
            *wide += *narrow as i64;
            *narrow = 0;
        }
        for b in 0..mb {
            let x_scale = x_scales[b0 + b];
            let sx = x_scale * xsum[b] as f32;
            let arow = &acc[b * d_out..(b + 1) * d_out];
            let orow = &mut out[(b0 + b) * d_out..(b0 + b + 1) * d_out];
            match bias {
                Some(bs) => {
                    for j in 0..d_out {
                        orow[j] = scales.alpha[j]
                            * (x_scale * arow[j] as f32 - scales.zero[j] * sx)
                            + bs[j];
                    }
                }
                None => {
                    for j in 0..d_out {
                        orow[j] =
                            scales.alpha[j] * (x_scale * arow[j] as f32 - scales.zero[j] * sx);
                    }
                }
            }
        }
        b0 += mb;
    }
}

/// Allocating convenience wrapper over [`matvec_packed_i8_into`].
#[allow(clippy::too_many_arguments)]
pub fn matvec_packed_i8(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    xq: &[i8],
    x_scale: f32,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    let mut out = vec![0.0f32; d_out];
    matvec_packed_i8_into(
        packed,
        overlay,
        scales,
        master_bits,
        d_out,
        xq,
        x_scale,
        bias,
        &mut out,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testing;

    #[test]
    fn row_stream_matches_unpack_all_widths() {
        for bits in [1u32, 2, 3, 4, 6, 8] {
            for d_out in [1usize, 3, 7, 8, 16] {
                let n = d_out * 9;
                let ids = testing::synth_ids(bits, n, 5);
                let packed = PackedTensor::pack(&ids, bits);
                let mut stream = RowStream::new(&packed.data, bits);
                let mut row = vec![0.0f32; d_out];
                for r in 0..9 {
                    stream.fill_row(&mut row);
                    assert_eq!(
                        &row[..],
                        &ids[r * d_out..(r + 1) * d_out],
                        "bits={bits} d_out={d_out} row={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn matvec_matches_naive_smoke() {
        for bits in [1u32, 2, 3, 4, 6, 8] {
            let (d_in, d_out) = (24, 7);
            let ids = testing::synth_ids(bits, d_in * d_out, 3);
            let packed = PackedTensor::pack(&ids, bits);
            let scales = testing::synth_scales(d_out, 9, false);
            let x = testing::synth_x(d_in, 4);
            let got = matvec_packed(&packed, None, &scales, 8, d_out, &x, None);
            let (want, mag) =
                testing::reference_matmul(&packed, None, &scales, 8, d_out, &x, 1, None);
            testing::assert_accum_close(&got, &want, &mag, d_in, &format!("smoke bits={bits}"));
        }
    }

    #[test]
    fn empty_weight_yields_bias() {
        let packed = PackedTensor::pack(&[], 2);
        let scales = testing::synth_scales(3, 1, false);
        let bias = [1.0f32, -2.0, 3.0];
        let got = matvec_packed(&packed, None, &scales, 8, 3, &[], Some(&bias));
        assert_eq!(got, bias.to_vec());
        let gemm = matmul_packed(&packed, None, &scales, 8, 3, &[], 4, Some(&bias));
        assert_eq!(gemm, bias.repeat(4));
    }

    #[test]
    fn gemm_blocks_agree_with_per_row_matvec() {
        let (d_in, d_out, m) = (13, 5, GEMM_BLOCK * 2 + 3);
        let ids = testing::synth_ids(4, d_in * d_out, 11);
        let packed = PackedTensor::pack(&ids, 4);
        let scales = testing::synth_scales(d_out, 2, false);
        let xs = testing::synth_x(m * d_in, 8);
        let gemm = matmul_packed(&packed, None, &scales, 8, d_out, &xs, m, None);
        for b in 0..m {
            let row = matvec_packed(
                &packed,
                None,
                &scales,
                8,
                d_out,
                &xs[b * d_in..(b + 1) * d_in],
                None,
            );
            assert_eq!(
                &gemm[b * d_out..(b + 1) * d_out],
                &row[..],
                "batch row {b} diverged from its own matvec"
            );
        }
    }

    #[test]
    fn i8_gemm_blocks_agree_with_per_row_matvec() {
        // The blocked kernel must be bit-identical to per-row matvec calls
        // (exact integer accumulation, same epilogue expression), across a
        // block boundary and with an overlay + per-row scales.
        let (d_in, d_out, m) = (11, 9, GEMM_BLOCK + 3);
        for bits in [2u32, 3, 8] {
            let (packed, overlay) = testing::synth_overlayed(bits.min(7), d_in * d_out, 31);
            let packed = if bits == 8 {
                PackedTensor::pack(&testing::synth_ids(8, d_in * d_out, 31), 8)
            } else {
                packed
            };
            let ov = if bits == 8 { None } else { Some(&overlay) };
            let scales = testing::synth_scales(d_out, 13, false);
            let xq: Vec<i8> = (0..m * d_in).map(|i| ((i * 29) % 251) as i64 as i8).collect();
            let x_scales: Vec<f32> = (0..m).map(|b| 0.01 + 0.003 * b as f32).collect();
            let bias: Vec<f32> = (0..d_out).map(|j| j as f32 * 0.1 - 0.3).collect();
            let mut gemm = vec![0.0f32; m * d_out];
            matmul_packed_i8_into(
                &packed,
                ov,
                &scales,
                8,
                d_out,
                &xq,
                m,
                &x_scales,
                Some(&bias),
                &mut gemm,
            );
            for b in 0..m {
                let row = matvec_packed_i8(
                    &packed,
                    ov,
                    &scales,
                    8,
                    d_out,
                    &xq[b * d_in..(b + 1) * d_in],
                    x_scales[b],
                    Some(&bias),
                );
                for j in 0..d_out {
                    assert_eq!(
                        gemm[b * d_out + j].to_bits(),
                        row[j].to_bits(),
                        "bits={bits} b={b} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn i8_row_tiling_matches_scalar_reference_with_remainder() {
        // d_out straddles the 8-lane tile (2 full tiles + 3 remainder) so
        // both the unrolled body and the tail are exercised.
        let (d_in, d_out) = (29, LANES * 2 + 3);
        for bits in [1u32, 2, 3, 4, 6, 8] {
            let ids = testing::synth_ids(bits, d_in * d_out, 17);
            let packed = PackedTensor::pack(&ids, bits);
            let scales = testing::synth_scales(d_out, 23, false);
            let xq: Vec<i8> = (0..d_in).map(|i| ((i * 41) % 255) as i64 as i8).collect();
            let got = matvec_packed_i8(&packed, None, &scales, 8, d_out, &xq, 0.25, None);
            let step = (1u32 << (8 - bits)) as f32;
            let mut xsum = 0i64;
            let mut acc = vec![0i64; d_out];
            for i in 0..d_in {
                xsum += xq[i] as i64;
                for j in 0..d_out {
                    acc[j] += (xq[i] as i64) * (ids[i * d_out + j] as i64);
                }
            }
            for j in 0..d_out {
                let want = scales.alpha[j]
                    * (step * 0.25 * acc[j] as f32 - scales.zero[j] * (0.25 * xsum as f32));
                assert_eq!(got[j].to_bits(), want.to_bits(), "bits={bits} j={j}");
            }
        }
    }

    /// Compact payload (pack_sliced semantics) for the view-vs-compact
    /// bit-identity tests, straight from the scalar slicing oracle.
    fn compact_payload(
        q: &[f32],
        bits: u32,
        ep: bool,
    ) -> (PackedTensor, ExtraBitOverlay) {
        let step = (1u32 << (8 - bits)) as f32;
        let ids: Vec<f32> = q
            .iter()
            .map(|&x| crate::quant::slice_code(x, 8, bits, ep) / step)
            .collect();
        if ep {
            let (ov, dense) = ExtraBitOverlay::split(&ids, bits);
            (PackedTensor::pack(&dense, bits), ov)
        } else {
            (PackedTensor::pack(&ids, bits), ExtraBitOverlay::default())
        }
    }

    #[test]
    fn sliced_view_gemm_bit_identical_to_compact_payload() {
        let (d_in, d_out, m) = (23, 9, GEMM_BLOCK + 3);
        let q = testing::synth_ids(8, d_in * d_out, 77);
        let master = PackedTensor::pack(&q, 8);
        let scales = testing::synth_scales(d_out, 3, false);
        let xs = testing::synth_x(m * d_in, 21);
        let bias: Vec<f32> = (0..d_out).map(|j| 0.2 * j as f32 - 0.5).collect();
        for bits in [1u32, 2, 3, 4, 6, 8] {
            for ep in [false, true] {
                let (packed, ov) = compact_payload(&q, bits, ep);
                let ovo = if ov.is_empty() { None } else { Some(&ov) };
                let mut want = vec![0.0f32; m * d_out];
                matmul_packed_into(
                    &packed, ovo, &scales, 8, d_out, &xs, m, Some(&bias), &mut want,
                );
                let mut got = vec![0.0f32; m * d_out];
                matmul_sliced_into(
                    &master, bits, ep, &scales, d_out, &xs, m, Some(&bias), &mut got,
                );
                for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} ep={ep} i={i}");
                }
            }
        }
    }

    #[test]
    fn sliced_view_i8_gemm_bit_identical_to_compact_payload() {
        let (d_in, d_out, m) = (19, 11, GEMM_BLOCK + 2);
        let q = testing::synth_ids(8, d_in * d_out, 123);
        let master = PackedTensor::pack(&q, 8);
        let scales = testing::synth_scales(d_out, 7, false);
        let xq: Vec<i8> = (0..m * d_in)
            .map(|i| (((i * 37 + 5) % 255) as i64 - 127) as i8)
            .collect();
        let x_scales: Vec<f32> = (0..m).map(|b| 0.01 + 0.002 * b as f32).collect();
        let bias: Vec<f32> = (0..d_out).map(|j| j as f32 * 0.1 - 0.4).collect();
        for bits in [1u32, 2, 3, 4, 6, 8] {
            for ep in [false, true] {
                let (packed, ov) = compact_payload(&q, bits, ep);
                let ovo = if ov.is_empty() { None } else { Some(&ov) };
                let mut want = vec![0.0f32; m * d_out];
                matmul_packed_i8_into(
                    &packed,
                    ovo,
                    &scales,
                    8,
                    d_out,
                    &xq,
                    m,
                    &x_scales,
                    Some(&bias),
                    &mut want,
                );
                let mut got = vec![0.0f32; m * d_out];
                matmul_sliced_i8_into(
                    &master,
                    bits,
                    ep,
                    &scales,
                    d_out,
                    &xq,
                    m,
                    &x_scales,
                    Some(&bias),
                    &mut got,
                );
                for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} ep={ep} i={i}");
                }
            }
        }
    }

    #[test]
    fn i32_flush_path_is_exact() {
        // Enough rows to cross the I32_FLUSH_ROWS boundary with worst-case
        // magnitude terms; compare against an i64 scalar reference.
        let d_in = I32_FLUSH_ROWS + 37;
        let d_out = 2;
        let ids: Vec<f32> = (0..d_in * d_out)
            .map(|i| if i % 2 == 0 { 255.0 } else { 3.0 })
            .collect();
        let packed = PackedTensor::pack(&ids, 8);
        let scales = testing::synth_scales(d_out, 6, false);
        let xq: Vec<i8> = (0..d_in)
            .map(|i| if i % 3 == 0 { -128 } else { 127 })
            .collect();
        let got = matvec_packed_i8(&packed, None, &scales, 8, d_out, &xq, 0.5, None);
        let mut acc = [0i64; 2];
        let mut xsum = 0i64;
        for i in 0..d_in {
            xsum += xq[i] as i64;
            for j in 0..d_out {
                acc[j] += (xq[i] as i64) * (ids[i * d_out + j] as i64);
            }
        }
        for j in 0..d_out {
            let want =
                scales.alpha[j] * (0.5 * acc[j] as f32 - scales.zero[j] * (0.5 * xsum as f32));
            assert_eq!(got[j].to_bits(), want.to_bits(), "j={j}");
        }
    }
}
