//! Single-pass fused dequantization kernels.
//!
//! The seed served weights through a two-pass walk — `PackedTensor::unpack`
//! into an intermediate f32 code vector, then an affine `dequantize` pass.
//! These kernels go straight from the packed bitstream (+ the Eq. 8
//! overflow overlay) and per-channel scales to f32 weights in one pass:
//!
//! * [`dequant_packed_into`] — packed r-bit bucket ids → weights.  Power-of-
//!   two widths expand bytes through 256-entry LUTs fed by u64 word loads;
//!   3/6-bit use the generic [`super::cursor::BitCursor`].  Overlay entries
//!   (the "single extra bit" outlier bucket) are fixed up in a sparse
//!   post-pass.
//! * [`slice_dequant_into`] — the Mix'n'Match path: 8-bit master codes →
//!   sliced-and-dequantized weights at any precision `r` through one
//!   256-entry value LUT, never materializing intermediate code vectors.
//!
//! Both are bit-for-bit identical to the scalar reference path (the LUTs
//! are built by the scalar oracles themselves); the conformance suite
//! (`tests/kernel_conformance.rs`) enforces this across every width, odd
//! lengths, overflow overlays, and degenerate channels.

use super::cursor::BitCursor;
use super::lut;
use crate::quant::{ExtraBitOverlay, PackedTensor, Scales};
use crate::MASTER_BITS;

/// Shared shape checks for both kernels.
fn check_shapes(n: usize, d_out: usize, scales: &Scales, out: &[f32]) {
    assert_eq!(out.len(), n, "output buffer length mismatch");
    assert_eq!(scales.d_out(), d_out, "scales channel count mismatch");
    if n > 0 {
        assert!(d_out > 0, "d_out must be positive");
        assert_eq!(n % d_out, 0, "tensor length not a multiple of d_out");
    }
}

/// LUT-expansion inner loop for the power-of-two widths: the stream is read
/// as u64 words while a full word of entries remains, then byte-by-byte.
fn dequant_lut<const EPB: usize>(
    data: &[u8],
    table: &[[f32; EPB]; 256],
    step: f32,
    scales: &Scales,
    d_out: usize,
    out: &mut [f32],
) {
    let n = out.len();
    let alpha = &scales.alpha[..];
    let zero = &scales.zero[..];
    let mut i = 0usize;
    let mut j = 0usize;
    let mut b = 0usize;
    while i + 8 * EPB <= n && b + 8 <= data.len() {
        let word = u64::from_le_bytes(data[b..b + 8].try_into().unwrap());
        for k in 0..8 {
            let ids = &table[((word >> (8 * k)) & 0xFF) as usize];
            for &id in ids.iter() {
                out[i] = (id * step - zero[j]) * alpha[j];
                i += 1;
                j += 1;
                if j == d_out {
                    j = 0;
                }
            }
        }
        b += 8;
    }
    while i < n {
        let ids = &table[data[b] as usize];
        let take = EPB.min(n - i);
        for &id in &ids[..take] {
            out[i] = (id * step - zero[j]) * alpha[j];
            i += 1;
            j += 1;
            if j == d_out {
                j = 0;
            }
        }
        b += 1;
    }
}

/// Fused packed-domain dequantization (deployment hot path, paper §5.4).
///
/// `packed` holds `r = packed.bits`-bit bucket ids of a tensor whose master
/// width is `master_bits` (ids are multiples-of-`2^(master_bits - r)` in
/// master code space, divided down — exactly what
/// [`crate::model::registry::QuantizedTensor::pack_sliced`] stores).
/// `overlay` marks Eq. 8 overflow entries, which decode to the bucket id
/// `2^r`.  `scales` are the shared master-width per-channel scales; weights
/// land in `out` row-major with `d_out` channels.
pub fn dequant_packed_into(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
    out: &mut [f32],
) {
    assert!(
        packed.bits <= master_bits && master_bits <= MASTER_BITS,
        "widths out of range: {} within {}",
        packed.bits,
        master_bits
    );
    check_shapes(packed.len, d_out, scales, out);
    if packed.len == 0 {
        return;
    }
    let step = (1u32 << (master_bits - packed.bits)) as f32;
    match packed.bits {
        1 => dequant_lut(&packed.data, lut::lut1(), step, scales, d_out, out),
        2 => dequant_lut(&packed.data, lut::lut2(), step, scales, d_out, out),
        4 => dequant_lut(&packed.data, lut::lut4(), step, scales, d_out, out),
        8 => dequant_lut(&packed.data, lut::lut8(), step, scales, d_out, out),
        bits => {
            let mut cur = BitCursor::new(&packed.data);
            let mut j = 0usize;
            for o in out.iter_mut() {
                let id = cur.next(bits) as f32;
                *o = (id * step - scales.zero[j]) * scales.alpha[j];
                j += 1;
                if j == d_out {
                    j = 0;
                }
            }
        }
    }
    if let Some(ov) = overlay {
        // Sparse outlier fix-up: overflow entries decode to bucket id 2^r.
        let top = (1u32 << packed.bits) as f32 * step;
        for &idx in &ov.indices {
            let i = idx as usize;
            let j = i % d_out;
            out[i] = (top - scales.zero[j]) * scales.alpha[j];
        }
    }
}

/// Allocating convenience wrapper over [`dequant_packed_into`].
pub fn dequant_packed(
    packed: &PackedTensor,
    overlay: Option<&ExtraBitOverlay>,
    scales: &Scales,
    master_bits: u32,
    d_out: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; packed.len];
    dequant_packed_into(packed, overlay, scales, master_bits, d_out, &mut out);
    out
}

/// Fused slice+dequantize (the Mix'n'Match serving path).
///
/// `codes` is the stored 8-bit master; the sliced value `S(q, bits)` and the
/// affine map collapse into one 256-entry lookup plus one fused
/// multiply-subtract per weight — no intermediate code vector exists.
pub fn slice_dequant_into(
    codes: &PackedTensor,
    bits: u32,
    extra_precision: bool,
    scales: &Scales,
    d_out: usize,
    out: &mut [f32],
) {
    assert_eq!(codes.bits, MASTER_BITS, "slice source must be the int8 master");
    assert!(bits >= 1 && bits <= MASTER_BITS, "bits out of range: {bits}");
    check_shapes(codes.len, d_out, scales, out);
    if codes.len == 0 {
        return;
    }
    let table = lut::slice_value_lut(bits, extra_precision);
    for (orow, qrow) in out
        .chunks_exact_mut(d_out)
        .zip(codes.data.chunks_exact(d_out))
    {
        for (k, (o, &q)) in orow.iter_mut().zip(qrow).enumerate() {
            *o = (table[q as usize] - scales.zero[k]) * scales.alpha[k];
        }
    }
}

/// Allocating convenience wrapper over [`slice_dequant_into`].
pub fn slice_dequant(
    codes: &PackedTensor,
    bits: u32,
    extra_precision: bool,
    scales: &Scales,
    d_out: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; codes.len];
    slice_dequant_into(codes, bits, extra_precision, scales, d_out, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testing;

    #[test]
    fn fused_matches_reference_smoke() {
        for bits in [1u32, 2, 3, 4, 6, 8] {
            let n = 96;
            let d_out = 8;
            let ids = testing::synth_ids(bits, n, 7);
            let packed = PackedTensor::pack(&ids, bits);
            let scales = testing::synth_scales(d_out, 11, false);
            let want = testing::reference_dequant_packed(&packed, None, &scales, 8, d_out);
            let got = dequant_packed(&packed, None, &scales, 8, d_out);
            testing::assert_bits_eq(&got, &want, &format!("bits={bits}"));
        }
    }

    #[test]
    fn fused_slice_matches_reference_smoke() {
        let codes = testing::synth_master_codes(128, 3);
        let packed = PackedTensor::pack(&codes, 8);
        let scales = testing::synth_scales(16, 5, false);
        for bits in [2u32, 4, 8] {
            for ep in [false, true] {
                let want = testing::reference_slice_dequant(&packed, bits, ep, &scales, 16);
                let got = slice_dequant(&packed, bits, ep, &scales, 16);
                testing::assert_bits_eq(&got, &want, &format!("bits={bits} ep={ep}"));
            }
        }
    }

    #[test]
    fn empty_tensor_is_a_noop() {
        let packed = PackedTensor::pack(&[], 2);
        let scales = testing::synth_scales(4, 1, false);
        assert!(dequant_packed(&packed, None, &scales, 8, 4).is_empty());
        let master = PackedTensor::pack(&[], 8);
        assert!(slice_dequant(&master, 2, false, &scales, 4).is_empty());
    }
}
