//! Single-query causal attention — the decode-path kernel and the one
//! attention implementation in the crate.
//!
//! The full host forward pass ([`crate::runtime::forward`] /
//! [`crate::runtime::plan`]) computes causal attention as *t* independent
//! single-query problems (query *i* attends over keys `0..=i`), and the
//! incremental decode engine ([`crate::runtime::decode`]) computes exactly
//! one such problem per generated token — query = the current position,
//! keys/values = the KV cache.  Both call [`attend_single_query`], so the
//! KV-cached step is **bit-identical** to the corresponding query of a full
//! re-forward by construction: same dot-product order, same max-subtracted
//! softmax, same `p·v` accumulation order, same NaN propagation (a NaN
//! score yields NaN outputs instead of a panic).
//!
//! Layout matches the forward pass buffers: keys/values are row-major
//! position rows of `stride` floats (the full `d_model` row), the head
//! lives at `hoff..hoff + dh` inside each row.  That makes the same kernel
//! consume both the forward's `(t, d)` K/V scratch and the decode engine's
//! `(len, d)` cache pages without reshaping.
//!
//! Since the KV cache went paged (PR 8), the decode path instead calls
//! [`attend_single_query_paged`]: the same query attends over a *sequence of
//! segments* ([`KvSegment`]) — one per physical page the session's block
//! table maps — in logical row order.  For f32 segments the walk performs
//! the exact floating-point operations of [`attend_single_query`] in the
//! exact order (segmentation only changes which slice a row is read from),
//! so paged-f32 attention is bit-identical to the contiguous kernel.  Int8
//! segments dequantize inline: each row carries one symmetric scale, folded
//! into the score (`q·codes · k_scale · inv_sqrt_dh`) and the value
//! accumulation (`p · v_scale · codes[c]`) without materializing f32 rows.

/// One causal-attention query over `n` cached key/value rows:
///
/// ```text
///   scores[j] = (q · keys[j]) / sqrt(dh)      j in 0..n
///   p = softmax(scores)                        (max-subtracted)
///   out[c]   += Σ_j p[j] · vals[j][c]
/// ```
///
/// `q` is one head slice (`dh` floats); `keys`/`vals` hold `n` rows of
/// `stride` floats with the head at offset `hoff`; `scores` is caller
/// scratch of length `n`; `out` (`dh` floats) is **accumulated into** — the
/// caller zeroes it (the forward pass accumulates all heads of a position
/// into one `d_model` row).
///
/// Degenerate softmax mass (`sum <= 0`, e.g. all scores `-inf`) contributes
/// nothing; NaN scores propagate NaN into `out` — never a panic, matching
/// the serve loop's poison-survival contract.
#[allow(clippy::too_many_arguments)]
pub fn attend_single_query(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    n: usize,
    stride: usize,
    hoff: usize,
    inv_sqrt_dh: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let dh = q.len();
    debug_assert!(scores.len() >= n, "scores scratch too short");
    debug_assert!(out.len() == dh, "output/head width mismatch");
    for j in 0..n {
        let ko = j * stride + hoff;
        let krow = &keys[ko..ko + dh];
        let mut s = 0.0f32;
        for c in 0..dh {
            s += q[c] * krow[c];
        }
        scores[j] = s * inv_sqrt_dh;
    }
    // Max-subtracted softmax over scores[0..n]. NaN scores propagate as
    // NaN outputs — never panic.
    let mut mx = f32::NEG_INFINITY;
    for &s in &scores[..n] {
        if s > mx {
            mx = s;
        }
    }
    let mut sum = 0.0f32;
    for s in scores[..n].iter_mut() {
        *s = (*s - mx).exp();
        sum += *s;
    }
    let inv_sum = if sum > 0.0 { 1.0 / sum } else { 0.0 };
    for j in 0..n {
        let pj = scores[j] * inv_sum;
        if pj == 0.0 {
            continue;
        }
        let vo = j * stride + hoff;
        let vrow = &vals[vo..vo + dh];
        for c in 0..dh {
            out[c] += pj * vrow[c];
        }
    }
}

/// A run of consecutive K/V rows from one physical page, as borrowed by
/// the paged attend walk.  Slices start at the segment's first row (offset
/// 0 = segment row 0) and hold `rows` rows of `stride` floats / codes.
#[derive(Debug, Clone, Copy)]
pub enum KvSegment<'a> {
    /// Raw f32 rows — identical layout to the contiguous cache.
    F32 {
        rows: usize,
        k: &'a [f32],
        v: &'a [f32],
    },
    /// Int8 code rows with one symmetric dequant scale per row
    /// (`value = code * scale`), stored beside the page.
    Int8 {
        rows: usize,
        k: &'a [i8],
        v: &'a [i8],
        k_scales: &'a [f32],
        v_scales: &'a [f32],
    },
}

impl KvSegment<'_> {
    /// Rows this segment contributes to the logical K/V sequence.
    pub fn rows(&self) -> usize {
        match self {
            KvSegment::F32 { rows, .. } | KvSegment::Int8 { rows, .. } => *rows,
        }
    }
}

/// [`attend_single_query`] over a paged K/V sequence: `segs` concatenated
/// in order form the `n` logical rows the query attends over.  F32
/// segments reproduce the contiguous kernel's operations bit-for-bit;
/// int8 segments dequantize inline through their per-row scales (see the
/// module docs).  `scores` is caller scratch of length >= `n`; `out` is
/// accumulated into, exactly like the contiguous kernel.
#[allow(clippy::too_many_arguments)]
pub fn attend_single_query_paged(
    q: &[f32],
    segs: &[KvSegment<'_>],
    n: usize,
    stride: usize,
    hoff: usize,
    inv_sqrt_dh: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let dh = q.len();
    debug_assert!(scores.len() >= n, "scores scratch too short");
    debug_assert!(out.len() == dh, "output/head width mismatch");
    debug_assert_eq!(
        segs.iter().map(|s| s.rows()).sum::<usize>(),
        n,
        "segments must cover exactly n rows"
    );
    // Pass 1: scores in logical row order, walking segments.
    let mut j = 0usize;
    for seg in segs {
        match seg {
            KvSegment::F32 { rows, k, .. } => {
                for r in 0..*rows {
                    let ko = r * stride + hoff;
                    let krow = &k[ko..ko + dh];
                    let mut s = 0.0f32;
                    for c in 0..dh {
                        s += q[c] * krow[c];
                    }
                    scores[j] = s * inv_sqrt_dh;
                    j += 1;
                }
            }
            KvSegment::Int8 { rows, k, k_scales, .. } => {
                for r in 0..*rows {
                    let ko = r * stride + hoff;
                    let krow = &k[ko..ko + dh];
                    let mut s = 0.0f32;
                    for c in 0..dh {
                        s += q[c] * krow[c] as f32;
                    }
                    scores[j] = s * k_scales[r] * inv_sqrt_dh;
                    j += 1;
                }
            }
        }
    }
    debug_assert_eq!(j, n);
    // Max-subtracted softmax over scores[0..n] — verbatim the contiguous
    // kernel's block, so f32 paging stays bit-identical.
    let mut mx = f32::NEG_INFINITY;
    for &s in &scores[..n] {
        if s > mx {
            mx = s;
        }
    }
    let mut sum = 0.0f32;
    for s in scores[..n].iter_mut() {
        *s = (*s - mx).exp();
        sum += *s;
    }
    let inv_sum = if sum > 0.0 { 1.0 / sum } else { 0.0 };
    // Pass 2: p·v accumulation in the same logical order.
    let mut j = 0usize;
    for seg in segs {
        match seg {
            KvSegment::F32 { rows, v, .. } => {
                for r in 0..*rows {
                    let pj = scores[j] * inv_sum;
                    j += 1;
                    if pj == 0.0 {
                        continue;
                    }
                    let vo = r * stride + hoff;
                    let vrow = &v[vo..vo + dh];
                    for c in 0..dh {
                        out[c] += pj * vrow[c];
                    }
                }
            }
            KvSegment::Int8 { rows, v, v_scales, .. } => {
                for r in 0..*rows {
                    let pj = scores[j] * inv_sum;
                    j += 1;
                    if pj == 0.0 {
                        continue;
                    }
                    let pv = pj * v_scales[r];
                    let vo = r * stride + hoff;
                    let vrow = &v[vo..vo + dh];
                    for c in 0..dh {
                        out[c] += pv * vrow[c] as f32;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_scores_average_values() {
        // q ⟂ every key → all scores 0 → softmax uniform → out = mean(v).
        let q = [0.0f32, 1.0];
        let keys = [1.0f32, 0.0, 1.0, 0.0, 1.0, 0.0];
        let vals = [1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0];
        let mut scores = [0.0f32; 3];
        let mut out = [0.0f32; 2];
        attend_single_query(&q, &keys, &vals, 3, 2, 0, 1.0, &mut scores, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6, "{out:?}");
        assert!((out[1] - 20.0).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn single_key_copies_value() {
        let q = [0.3f32, -0.7];
        let keys = [0.9f32, 0.1];
        let vals = [5.0f32, -6.0];
        let mut scores = [0.0f32; 1];
        let mut out = [0.0f32; 2];
        attend_single_query(&q, &keys, &vals, 1, 2, 0, 0.5, &mut scores, &mut out);
        assert_eq!(out, [5.0, -6.0]);
    }

    #[test]
    fn head_offset_and_stride_select_the_right_lanes() {
        // Two heads of width 1 in stride-2 rows; attend head 1 only.
        let q = [1.0f32];
        let keys = [9.0f32, 0.0, 9.0, 0.0]; // head-1 lanes are both 0 → uniform
        let vals = [0.0f32, 4.0, 0.0, 8.0];
        let mut scores = [0.0f32; 2];
        let mut out = [0.0f32; 1];
        attend_single_query(&q, &keys, &vals, 2, 2, 1, 1.0, &mut scores, &mut out);
        assert!((out[0] - 6.0).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn accumulates_into_out() {
        let q = [1.0f32];
        let keys = [1.0f32];
        let vals = [2.0f32];
        let mut scores = [0.0f32; 1];
        let mut out = [10.0f32];
        attend_single_query(&q, &keys, &vals, 1, 1, 0, 1.0, &mut scores, &mut out);
        assert_eq!(out[0], 12.0);
    }

    #[test]
    fn nan_scores_propagate_without_panicking() {
        let q = [f32::NAN];
        let keys = [1.0f32, 2.0];
        let vals = [1.0f32, 1.0];
        let mut scores = [0.0f32; 2];
        let mut out = [0.0f32];
        attend_single_query(&q, &keys, &vals, 2, 1, 0, 1.0, &mut scores, &mut out);
        assert!(out[0].is_nan());
    }

    #[test]
    fn degenerate_mass_contributes_nothing() {
        let q = [1.0f32];
        let keys = [f32::NEG_INFINITY];
        let vals = [7.0f32];
        let mut scores = [0.0f32; 1];
        let mut out = [0.0f32];
        attend_single_query(&q, &keys, &vals, 1, 1, 0, 1.0, &mut scores, &mut out);
        // score -inf → exp 0 → sum 0 → inv_sum 0 → out untouched
        assert_eq!(out[0], 0.0);
    }

    /// Deterministic pseudo-random floats (no external rng in kernels).
    fn lcg_rows(seed: u64, n: usize) -> Vec<f32> {
        let mut state = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
            })
            .collect()
    }

    #[test]
    fn paged_f32_is_bit_identical_to_contiguous_for_every_segmentation() {
        let stride = 6;
        let dh = 3;
        let n = 7;
        let keys = lcg_rows(11, n * stride);
        let vals = lcg_rows(22, n * stride);
        let q = lcg_rows(33, dh);
        for hoff in [0usize, 3] {
            let mut scores = vec![0.0f32; n];
            let mut want = vec![0.0f32; dh];
            attend_single_query(&q, &keys, &vals, n, stride, hoff, 0.7, &mut scores, &mut want);
            // Sweep every two-cut segmentation of the 7 rows (incl. empty-free
            // single segment and page-boundary-like splits).
            for cut1 in 0..=n {
                for cut2 in cut1..=n {
                    let segs = [
                        KvSegment::F32 {
                            rows: cut1,
                            k: &keys[..cut1 * stride],
                            v: &vals[..cut1 * stride],
                        },
                        KvSegment::F32 {
                            rows: cut2 - cut1,
                            k: &keys[cut1 * stride..cut2 * stride],
                            v: &vals[cut1 * stride..cut2 * stride],
                        },
                        KvSegment::F32 {
                            rows: n - cut2,
                            k: &keys[cut2 * stride..],
                            v: &vals[cut2 * stride..],
                        },
                    ];
                    let mut got = vec![0.0f32; dh];
                    let mut s2 = vec![0.0f32; n];
                    attend_single_query_paged(
                        &q, &segs, n, stride, hoff, 0.7, &mut s2, &mut got,
                    );
                    assert_eq!(
                        got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        "segmentation ({cut1},{cut2}) hoff {hoff} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_segments_dequantize_through_per_row_scales() {
        // Codes chosen so code*scale reproduces exact f32 values; the paged
        // int8 walk must then match the f32 kernel exactly.
        let stride = 2;
        let n = 3;
        let k_codes: Vec<i8> = vec![10, -20, 40, 5, -8, 16];
        let v_codes: Vec<i8> = vec![100, 50, -25, 10, 64, -32];
        let k_scales = [0.5f32, 0.25, 0.125];
        let v_scales = [0.1f32, 0.2, 0.05];
        let keys: Vec<f32> = k_codes
            .iter()
            .enumerate()
            .map(|(i, &c)| c as f32 * k_scales[i / stride])
            .collect();
        let vals: Vec<f32> = v_codes
            .iter()
            .enumerate()
            .map(|(i, &c)| c as f32 * v_scales[i / stride])
            .collect();
        let q = [0.3f32, -0.9];
        let mut scores = [0.0f32; 3];
        let mut want = [0.0f32; 2];
        attend_single_query(&q, &keys, &vals, n, stride, 0, 1.0, &mut scores, &mut want);
        let segs = [
            KvSegment::Int8 {
                rows: 2,
                k: &k_codes[..4],
                v: &v_codes[..4],
                k_scales: &k_scales[..2],
                v_scales: &v_scales[..2],
            },
            KvSegment::Int8 {
                rows: 1,
                k: &k_codes[4..],
                v: &v_codes[4..],
                k_scales: &k_scales[2..],
                v_scales: &v_scales[2..],
            },
        ];
        let mut got = [0.0f32; 2];
        let mut s2 = [0.0f32; 3];
        attend_single_query_paged(&q, &segs, n, stride, 0, 1.0, &mut s2, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn paged_walk_accumulates_into_out_like_the_contiguous_kernel() {
        let segs = [KvSegment::F32 {
            rows: 1,
            k: &[1.0f32],
            v: &[2.0f32],
        }];
        let mut scores = [0.0f32; 1];
        let mut out = [10.0f32];
        attend_single_query_paged(&[1.0f32], &segs, 1, 1, 0, 1.0, &mut scores, &mut out);
        assert_eq!(out[0], 12.0);
    }
}
