//! Single-query causal attention — the decode-path kernel and the one
//! attention implementation in the crate.
//!
//! The full host forward pass ([`crate::runtime::forward`] /
//! [`crate::runtime::plan`]) computes causal attention as *t* independent
//! single-query problems (query *i* attends over keys `0..=i`), and the
//! incremental decode engine ([`crate::runtime::decode`]) computes exactly
//! one such problem per generated token — query = the current position,
//! keys/values = the KV cache.  Both call [`attend_single_query`], so the
//! KV-cached step is **bit-identical** to the corresponding query of a full
//! re-forward by construction: same dot-product order, same max-subtracted
//! softmax, same `p·v` accumulation order, same NaN propagation (a NaN
//! score yields NaN outputs instead of a panic).
//!
//! Layout matches the forward pass buffers: keys/values are row-major
//! position rows of `stride` floats (the full `d_model` row), the head
//! lives at `hoff..hoff + dh` inside each row.  That makes the same kernel
//! consume both the forward's `(t, d)` K/V scratch and the decode engine's
//! `(len, d)` cache pages without reshaping.

/// One causal-attention query over `n` cached key/value rows:
///
/// ```text
///   scores[j] = (q · keys[j]) / sqrt(dh)      j in 0..n
///   p = softmax(scores)                        (max-subtracted)
///   out[c]   += Σ_j p[j] · vals[j][c]
/// ```
///
/// `q` is one head slice (`dh` floats); `keys`/`vals` hold `n` rows of
/// `stride` floats with the head at offset `hoff`; `scores` is caller
/// scratch of length `n`; `out` (`dh` floats) is **accumulated into** — the
/// caller zeroes it (the forward pass accumulates all heads of a position
/// into one `d_model` row).
///
/// Degenerate softmax mass (`sum <= 0`, e.g. all scores `-inf`) contributes
/// nothing; NaN scores propagate NaN into `out` — never a panic, matching
/// the serve loop's poison-survival contract.
#[allow(clippy::too_many_arguments)]
pub fn attend_single_query(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    n: usize,
    stride: usize,
    hoff: usize,
    inv_sqrt_dh: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let dh = q.len();
    debug_assert!(scores.len() >= n, "scores scratch too short");
    debug_assert!(out.len() == dh, "output/head width mismatch");
    for j in 0..n {
        let ko = j * stride + hoff;
        let krow = &keys[ko..ko + dh];
        let mut s = 0.0f32;
        for c in 0..dh {
            s += q[c] * krow[c];
        }
        scores[j] = s * inv_sqrt_dh;
    }
    // Max-subtracted softmax over scores[0..n]. NaN scores propagate as
    // NaN outputs — never panic.
    let mut mx = f32::NEG_INFINITY;
    for &s in &scores[..n] {
        if s > mx {
            mx = s;
        }
    }
    let mut sum = 0.0f32;
    for s in scores[..n].iter_mut() {
        *s = (*s - mx).exp();
        sum += *s;
    }
    let inv_sum = if sum > 0.0 { 1.0 / sum } else { 0.0 };
    for j in 0..n {
        let pj = scores[j] * inv_sum;
        if pj == 0.0 {
            continue;
        }
        let vo = j * stride + hoff;
        let vrow = &vals[vo..vo + dh];
        for c in 0..dh {
            out[c] += pj * vrow[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_scores_average_values() {
        // q ⟂ every key → all scores 0 → softmax uniform → out = mean(v).
        let q = [0.0f32, 1.0];
        let keys = [1.0f32, 0.0, 1.0, 0.0, 1.0, 0.0];
        let vals = [1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0];
        let mut scores = [0.0f32; 3];
        let mut out = [0.0f32; 2];
        attend_single_query(&q, &keys, &vals, 3, 2, 0, 1.0, &mut scores, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6, "{out:?}");
        assert!((out[1] - 20.0).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn single_key_copies_value() {
        let q = [0.3f32, -0.7];
        let keys = [0.9f32, 0.1];
        let vals = [5.0f32, -6.0];
        let mut scores = [0.0f32; 1];
        let mut out = [0.0f32; 2];
        attend_single_query(&q, &keys, &vals, 1, 2, 0, 0.5, &mut scores, &mut out);
        assert_eq!(out, [5.0, -6.0]);
    }

    #[test]
    fn head_offset_and_stride_select_the_right_lanes() {
        // Two heads of width 1 in stride-2 rows; attend head 1 only.
        let q = [1.0f32];
        let keys = [9.0f32, 0.0, 9.0, 0.0]; // head-1 lanes are both 0 → uniform
        let vals = [0.0f32, 4.0, 0.0, 8.0];
        let mut scores = [0.0f32; 2];
        let mut out = [0.0f32; 1];
        attend_single_query(&q, &keys, &vals, 2, 2, 1, 1.0, &mut scores, &mut out);
        assert!((out[0] - 6.0).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn accumulates_into_out() {
        let q = [1.0f32];
        let keys = [1.0f32];
        let vals = [2.0f32];
        let mut scores = [0.0f32; 1];
        let mut out = [10.0f32];
        attend_single_query(&q, &keys, &vals, 1, 1, 0, 1.0, &mut scores, &mut out);
        assert_eq!(out[0], 12.0);
    }

    #[test]
    fn nan_scores_propagate_without_panicking() {
        let q = [f32::NAN];
        let keys = [1.0f32, 2.0];
        let vals = [1.0f32, 1.0];
        let mut scores = [0.0f32; 2];
        let mut out = [0.0f32];
        attend_single_query(&q, &keys, &vals, 2, 1, 0, 1.0, &mut scores, &mut out);
        assert!(out[0].is_nan());
    }

    #[test]
    fn degenerate_mass_contributes_nothing() {
        let q = [1.0f32];
        let keys = [f32::NEG_INFINITY];
        let vals = [7.0f32];
        let mut scores = [0.0f32; 1];
        let mut out = [0.0f32];
        attend_single_query(&q, &keys, &vals, 1, 1, 0, 1.0, &mut scores, &mut out);
        // score -inf → exp 0 → sum 0 → inv_sum 0 → out untouched
        assert_eq!(out[0], 0.0);
    }
}
