//! Fused packed-domain dequantization kernels — the serving hot path.
//!
//! # Layering
//!
//! ```text
//!   quant::packed   PackedTensor / ExtraBitOverlay   (storage model)
//!   quant::minmax   Scales, scalar quant/dequant     (semantics oracle)
//!   quant::slicing  S(q^c, r) scalar ops             (semantics oracle)
//!        │
//!   kernels::lut    256-entry byte→ids & code→sliced-value tables
//!   kernels::cursor u64 bitstream reader for 3/6-bit widths
//!   kernels::fused  dequant_packed_into / slice_dequant_into
//!        │
//!   model::registry QuantizedTensor::materialize / pack_sliced
//!   serve::server   warm + lazy weight-set builds
//!   mixnmatch       per-layer sweeps (via registry materialization)
//! ```
//!
//! The scalar functions in [`crate::quant`] remain the reference semantics;
//! the kernels here are *implementations* of the same math that read the
//! packed bitstream directly (u64 word loads + byte-expansion LUTs, a
//! generic bit cursor for 3/6-bit) and fuse slicing with the per-channel
//! affine map so no intermediate code vector is ever materialized.
//!
//! # Conformance and benchmarks
//!
//! * `cargo test --test kernel_conformance` — exhaustive fused-vs-reference
//!   bit-for-bit checks over bits ∈ {1, 2, 3, 4, 6, 8}, odd lengths,
//!   Eq. 8 overflow overlays, and degenerate (EPS-guarded) channels.
//! * `cargo bench --bench quant_hot_paths` — fused vs two-pass throughput,
//!   including the `fused ≥ 2×` serving-path comparison.
//!
//! [`testing`] holds the data synthesis + scalar reference paths shared by
//! both, so new kernels get a conformance harness for free.

pub mod cursor;
pub mod fused;
pub mod lut;
pub mod testing;

pub use cursor::BitCursor;
pub use fused::{dequant_packed, dequant_packed_into, slice_dequant, slice_dequant_into};
