//! Fused packed-domain kernels — the serving hot path, from bitstream to
//! activations.
//!
//! # Layering
//!
//! ```text
//!   quant::packed   PackedTensor / ExtraBitOverlay     (storage model)
//!   quant::minmax   Scales, scalar quant/dequant       (semantics oracle)
//!   quant::slicing  S(q^c, r) scalar ops               (semantics oracle)
//!        │
//!   kernels::lut    256-entry byte→ids & code→sliced-value tables
//!   kernels::cursor u64 bitstream reader for 3/6-bit widths
//!   kernels::fused  dequant_packed_into / slice_dequant_into
//!   kernels::matmul matvec/matmul_packed_into, i8→i32 GEMV
//!   kernels::attention  single-query causal attention (shared by the
//!                   full forward and the KV-cached decode step), plus
//!                   the paged-segment walk over KV pages (f32 or int8
//!                   with inline per-row dequant)
//!        │
//!   model::registry QuantizedTensor::materialize / pack_sliced,
//!                   PackedWeight payload handles (+ byte accounting)
//!   runtime::engine run_packed — host packed-linear path beside PJRT
//!   serve::weights  WeightStore: warm dense sets + lazily *paged* r-bit
//!                   payloads (no f32 weight set for lazy precisions)
//!   mixnmatch       per-layer sweeps + matvec-probe layer sensitivity
//! ```
//!
//! # Packed-domain data flow
//!
//! The scalar functions in [`crate::quant`] remain the reference semantics;
//! the kernels here are *implementations* of the same math that read the
//! packed bitstream directly (u64 word loads + byte-expansion LUTs, a
//! generic bit cursor for 3/6-bit) and fuse slicing with the per-channel
//! affine map so no intermediate code vector is ever materialized.
//!
//! [`matmul`] extends the fusion through the matmul itself: `y = x·W_r`
//! is computed straight from the r-bit payload with the affine hoisted out
//! of the reduction, so the full f32 weight tensor never exists either —
//! the weight bytes read per token shrink by `32/r` (2–8× fewer packed
//! bytes than the int8 master at low bits, 4–32× vs f32).  The serving
//! stack pages exactly these payloads for lazily-built precisions
//! ([`crate::serve::weights`]).
//!
//! # Conformance and benchmarks
//!
//! * `cargo test --test kernel_conformance` — exhaustive fused-vs-reference
//!   checks over bits ∈ {1, 2, 3, 4, 6, 8}, odd lengths, Eq. 8 overflow
//!   overlays, and degenerate (EPS-guarded) channels: bit-for-bit for the
//!   dequant kernels, accumulation-magnitude-scaled ulp tolerance for the
//!   matmul kernels, plus seeded property-based sweeps
//!   ([`testing::run_prop`]) over random (bits, shape, overlay, scale)
//!   cases.
//! * `cargo bench --bench quant_hot_paths` — fused vs two-pass dequant and
//!   fused matmul vs materialize-then-matmul throughput.
//!
//! [`testing`] holds the data synthesis, scalar reference paths, and the
//! property-test driver shared by both, so new kernels get a conformance
//! harness for free.

pub mod attention;
pub mod cursor;
pub mod fused;
pub mod lut;
pub mod matmul;
pub mod testing;

pub use attention::{attend_single_query, attend_single_query_paged, KvSegment};
pub use cursor::BitCursor;
pub use fused::{dequant_packed, dequant_packed_into, slice_dequant, slice_dequant_into};
pub use matmul::{
    matmul_packed, matmul_packed_i8_into, matmul_packed_into, matmul_sliced_i8_into,
    matmul_sliced_into, matvec_packed, matvec_packed_i8, matvec_packed_i8_into,
    matvec_packed_into,
};
