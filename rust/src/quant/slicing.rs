//! The Matryoshka slicing operator `S(q^c, r)` — paper Eq. 6 and Eq. 8.
//!
//! Slicing keeps the `r` most-significant bits of a `c`-bit code and
//! returns the result in c-bit scale space (multiples of `2^(c-r)`), so a
//! single stored int8 tensor + one `(alpha, zero)` pair serves *every*
//! precision.  Eq. 6 clamps the rounded value to `2^r − 1`; Eq. 8 (the
//! errata's Extra-Precision variant) does not, admitting `2^r + 1` buckets
//! whose overflow entries cost one extra stored bit (→ 2.05-avg-bit int2).

use super::round_half_up;

/// Slice one code. `q` must be an integer-valued f32 in `[0, 2^c)`.
#[inline(always)]
pub fn slice_code(q: f32, c: u32, r: u32, extra_precision: bool) -> f32 {
    debug_assert!(r <= c);
    if r == c {
        return q;
    }
    let step = (1u32 << (c - r)) as f32;
    let mut s = round_half_up(q / step);
    if !extra_precision {
        s = s.clamp(0.0, (1u32 << r) as f32 - 1.0);
    }
    s * step
}

/// Slice a whole code tensor.
pub fn slice_codes(q: &[f32], c: u32, r: u32, extra_precision: bool) -> Vec<f32> {
    q.iter()
        .map(|&x| slice_code(x, c, r, extra_precision))
        .collect()
}

/// Slice into a caller-provided buffer (hot path).
pub fn slice_codes_into(q: &[f32], c: u32, r: u32, extra_precision: bool, out: &mut [f32]) {
    assert_eq!(q.len(), out.len());
    if r == c {
        out.copy_from_slice(q);
        return;
    }
    let step = (1u32 << (c - r)) as f32;
    let inv = 1.0 / step;
    let hi = (1u32 << r) as f32 - 1.0;
    if extra_precision {
        for (o, &x) in out.iter_mut().zip(q) {
            *o = round_half_up(x * inv) * step;
        }
    } else {
        for (o, &x) in out.iter_mut().zip(q) {
            *o = round_half_up(x * inv).clamp(0.0, hi) * step;
        }
    }
}

/// Average stored bits/param at precision `r` for Extra-Precision slicing:
/// `r + fraction_of_overflow_codes` (paper Table 7's "Avg. Bits" column).
pub fn effective_bits(q: &[f32], c: u32, r: u32) -> f64 {
    if q.is_empty() || r == c {
        return r as f64;
    }
    let step = (1u32 << (c - r)) as f32;
    let top = (1u32 << r) as f32;
    let overflow = q
        .iter()
        .filter(|&&x| round_half_up(x / step) >= top)
        .count();
    r as f64 + overflow as f64 / q.len() as f64
}

/// Fraction of codes that land in the Eq. 8 overflow bucket.
pub fn overflow_fraction(q: &[f32], c: u32, r: u32) -> f64 {
    effective_bits(q, c, r) - r as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_errata_example_234() {
        // 234 → round(234/64)=4 → clamp 3 → 192; EP keeps 4 → 256.
        assert_eq!(slice_code(234.0, 8, 2, false), 192.0);
        assert_eq!(slice_code(234.0, 8, 2, true), 256.0);
    }

    #[test]
    fn paper_appendix_example_53() {
        // 53 = 0b00110101: bit just below the slice boundary is set → round
        // up into bucket 1 (64), not down to 0.
        assert_eq!(slice_code(53.0, 8, 2, false), 64.0);
    }

    #[test]
    fn paper_appendix_example_240() {
        assert_eq!(slice_code(240.0, 8, 2, false), 192.0);
    }

    #[test]
    fn full_width_is_identity() {
        for q in 0..256 {
            assert_eq!(slice_code(q as f32, 8, 8, false), q as f32);
        }
    }

    #[test]
    fn matches_shift_arithmetic_all_codes() {
        for r in [2u32, 3, 4, 6] {
            let shift = 8 - r;
            for q in 0..256u32 {
                let rounded = ((q + (1 << (shift - 1))) >> shift).min((1 << r) - 1);
                let expect = (rounded << shift) as f32;
                assert_eq!(slice_code(q as f32, 8, r, false), expect, "q={q} r={r}");
            }
        }
    }

    #[test]
    fn extra_precision_has_one_more_bucket() {
        for r in [2u32, 3, 4, 6] {
            let codes: Vec<f32> = (0..256).map(|x| x as f32).collect();
            let sliced = slice_codes(&codes, 8, r, true);
            let step = (1u32 << (8 - r)) as f32;
            let mut buckets: Vec<i64> = sliced.iter().map(|&s| (s / step) as i64).collect();
            buckets.sort_unstable();
            buckets.dedup();
            assert_eq!(buckets.len(), (1usize << r) + 1, "r={r}");
            assert_eq!(*buckets.last().unwrap(), 1i64 << r);
        }
    }

    #[test]
    fn into_matches_alloc_version() {
        let codes: Vec<f32> = (0..256).map(|x| x as f32).collect();
        for r in [2u32, 3, 4, 6, 8] {
            for ep in [false, true] {
                let a = slice_codes(&codes, 8, r, ep);
                let mut b = vec![0.0; 256];
                slice_codes_into(&codes, 8, r, ep, &mut b);
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn effective_bits_bounds() {
        let codes: Vec<f32> = (0..256).map(|x| x as f32).collect();
        for r in [2u32, 3, 4, 6] {
            let eb = effective_bits(&codes, 8, r);
            // uniform codes: overflow bucket holds step/2 of 256 codes
            let expect = r as f64 + (1u32 << (8 - r - 1)) as f64 / 256.0;
            assert!((eb - expect).abs() < 1e-9, "r={r} eb={eb} expect={expect}");
        }
    }
}
