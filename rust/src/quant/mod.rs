//! The nested-integer quantization algebra (paper §3), in Rust.
//!
//! Semantics are bit-for-bit identical to the L1 oracles in
//! `python/compile/kernels/ref.py` — enforced by the golden-vector test
//! (`tests/goldens.rs`) against `artifacts/goldens.json`:
//!
//! * round-half-up `floor(x + 0.5)` (the paper's Appendix A rounding),
//! * per-output-channel MinMax / OmniQuant affine scales (Eq. 1 / Eq. 3),
//! * MSB slicing `S(q^c, r)` with clamp (Eq. 6) and the Extra-Precision
//!   variant without clamp (Eq. 8, `2^r + 1` buckets),
//! * bit-packed storage for 2/3/4/6/8-bit codes plus the sparse
//!   extra-bit overlay that realizes the paper's 2.05-avg-bits models,
//! * per-tensor symmetric int8 *activation* quantization (absmax or
//!   histogram-percentile clip) — the producer for the integer-domain GEMV,
//! * persisted per-layer activation-clip calibration ([`calibration`]):
//!   thresholds computed once offline, stored as JSON beside the
//!   checkpoint, and baked into serving plans as fixed-clip quantizers,
//! * the MatGPTQ post-training solver ([`solver`]): calibration Grams →
//!   dampened Cholesky → nested-MSB GPTQ rounding → Eq. 8 outlier-budget
//!   sweep, refining the int8 masters the nested serving path slices.

pub mod activations;
pub mod calibration;
pub mod histogram;
pub mod minmax;
pub mod packed;
pub mod slicing;
pub mod solver;

pub use activations::{act_clip, quantize_acts, quantize_acts_into, ActQuantConfig, QuantizedActs};
pub use calibration::ActCalibration;
pub use histogram::{code_histogram, mean_code, render_histogram, upper_half_mass};
pub use minmax::{
    col_min_max, dequantize, dequantize_into, minmax_scales, omni_scales, quantize, Scales,
};
pub use packed::{BitSliceView, ExtraBitOverlay, PackedTensor};
pub use slicing::{
    effective_bits, overflow_fraction, slice_code, slice_codes, slice_codes_into,
};

/// `floor(x + 0.5)` — the paper's round-half-up for non-negative operands.
#[inline(always)]
pub fn round_half_up(x: f32) -> f32 {
    (x + 0.5).floor()
}

/// Epsilon guarding degenerate (constant) channels; matches ref.py.
pub const EPS: f32 = 1e-8;
