//! Quantized-code histograms (paper Fig. 1c / Fig. 4).
//!
//! The paper's key mechanistic observation is that MatQuant training
//! *right-shifts* the quantized weight distribution — more mass in the
//! higher-valued buckets — which is what rescues int2.  These helpers
//! compute and compare the histograms used by `experiment --fig 1c`.

/// Histogram of integer-valued codes over `[0, 2^bits)`.
pub fn code_histogram(codes: &[f32], bits: u32) -> Vec<u64> {
    let n = 1usize << bits;
    let mut h = vec![0u64; n];
    for &c in codes {
        let i = (c as i64).clamp(0, n as i64 - 1) as usize;
        h[i] += 1;
    }
    h
}

/// Mean bucket id — a single-number summary of the right-shift effect.
pub fn mean_code(codes: &[f32]) -> f64 {
    if codes.is_empty() {
        return 0.0;
    }
    codes.iter().map(|&c| c as f64).sum::<f64>() / codes.len() as f64
}

/// Fraction of codes at or above the midpoint bucket.
pub fn upper_half_mass(codes: &[f32], bits: u32) -> f64 {
    if codes.is_empty() {
        return 0.0;
    }
    let mid = (1u32 << (bits - 1)) as f32;
    codes.iter().filter(|&&c| c >= mid).count() as f64 / codes.len() as f64
}

/// Render a terminal bar chart (used by the fig-1c experiment output).
pub fn render_histogram(h: &[u64], width: usize) -> String {
    let max = h.iter().copied().max().unwrap_or(1).max(1);
    let mut out = String::new();
    for (i, &v) in h.iter().enumerate() {
        let bar = "#".repeat(((v as f64 / max as f64) * width as f64).round() as usize);
        out.push_str(&format!("{i:>4} | {bar} {v}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts() {
        let codes = vec![0.0, 1.0, 1.0, 3.0, 3.0, 3.0];
        assert_eq!(code_histogram(&codes, 2), vec![1, 2, 0, 3]);
    }

    #[test]
    fn clamps_out_of_range() {
        let codes = vec![-1.0, 4.0, 2.0];
        assert_eq!(code_histogram(&codes, 2), vec![1, 0, 1, 1]);
    }

    #[test]
    fn upper_half() {
        let codes = vec![0.0, 1.0, 2.0, 3.0];
        assert_eq!(upper_half_mass(&codes, 2), 0.5);
    }

    #[test]
    fn mean_shift_detects_right_shift() {
        let baseline = vec![0.0, 1.0, 1.0, 2.0];
        let shifted = vec![1.0, 2.0, 2.0, 3.0];
        assert!(mean_code(&shifted) > mean_code(&baseline));
    }

    #[test]
    fn render_smoke() {
        let h = code_histogram(&[0.0, 1.0, 1.0], 1);
        let s = render_histogram(&h, 10);
        assert!(s.contains('#'));
    }
}
