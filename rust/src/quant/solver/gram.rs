//! Input Gram matrices and their dampened Cholesky machinery — the
//! curvature side of the MatGPTQ solver.
//!
//! `H = Σ XᵀX` over calibration batches is the layerwise proxy Hessian of
//! the output-MSE objective `‖XW − XŴ‖²` (GPTQ, Frantar et al.).  The
//! accumulator runs in f64 (calibration sums thousands of token rows;
//! f32 accumulation loses the small-eigenvalue tail that the solver's
//! error feedback depends on) and is captured through the forward plan
//! ([`crate::runtime::ForwardPlan::accumulate_grams`]) **after** the
//! OmniQuant `1/s` smoothing fold — exactly the values the fused matmuls
//! multiply against the quantized payload.
//!
//! [`GptqFactor`] turns a Gram into the upper-triangular `U` with
//! `(H + λI)⁻¹ = UᵀU` that GPTQ's error-feedback sweep consumes.  Rank
//! deficiency is the *normal* case (calibration batches shorter than
//! `d_in`, dead ReLU-style rows), so factorization always dampens by
//! `λ = damp_frac · mean(diag H)`, escalates λ ×10 on a failed Cholesky
//! pivot, and degenerates to the identity factor (zero error propagation —
//! plain nearest-code rounding) when no finite factorization exists.

use crate::Result;
use anyhow::ensure;

/// How many ×10 damping escalations to attempt before falling back to the
/// identity factor.
const DAMP_RETRIES: usize = 8;

/// A per-tensor input Gram accumulator: `h[i][k] = Σ_rows x_i·x_k` in f64.
#[derive(Debug, Clone)]
pub struct Gram {
    d: usize,
    h: Vec<f64>,
    /// Token rows accumulated so far.
    pub rows: usize,
}

impl Gram {
    pub fn new(d: usize) -> Self {
        Gram {
            d,
            h: vec![0.0; d * d],
            rows: 0,
        }
    }

    /// Input dimension (`d_in` of the linear this Gram belongs to).
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Row-major `d × d` Gram entries.
    pub fn entries(&self) -> &[f64] {
        &self.h
    }

    /// Accumulate `m` activation rows (`xs` is row-major `(m, d)`):
    /// `H += XᵀX`.  Non-finite rows are skipped whole — a poisoned
    /// calibration batch must not poison the factorization.
    pub fn accumulate(&mut self, xs: &[f32], m: usize) -> Result<()> {
        ensure!(
            xs.len() == m * self.d,
            "gram accumulate: {} values for {} rows of dim {}",
            xs.len(),
            m,
            self.d
        );
        let d = self.d;
        for row in xs.chunks_exact(d.max(1)) {
            if !row.iter().all(|v| v.is_finite()) {
                continue;
            }
            for i in 0..d {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let hrow = &mut self.h[i * d..(i + 1) * d];
                for (hk, &xk) in hrow.iter_mut().zip(row) {
                    *hk += xi * xk as f64;
                }
            }
            self.rows += 1;
        }
        Ok(())
    }

    /// `mean(diag H)` — the damping reference scale.
    pub fn mean_diag(&self) -> f64 {
        if self.d == 0 {
            return 0.0;
        }
        (0..self.d).map(|i| self.h[i * self.d + i]).sum::<f64>() / self.d as f64
    }
}

/// The factored curvature a GPTQ sweep consumes: upper-triangular `U` with
/// `(H + λI)⁻¹ = UᵀU`, plus the damping that was actually needed.
#[derive(Debug, Clone)]
pub struct GptqFactor {
    d: usize,
    /// Row-major upper-triangular `U` (entries below the diagonal zero).
    u: Vec<f64>,
    /// The λ that produced a successful factorization (0 for identity).
    pub damp: f64,
    /// True when no dampened Cholesky succeeded (or no Gram existed) and
    /// the factor degenerated to the identity — error propagation off.
    pub fallback: bool,
}

impl GptqFactor {
    /// The identity factor: `U = I`, zero error propagation.  This is the
    /// correct degenerate solver — each row rounds independently to its
    /// nearest nested code, exactly minmax-with-LUT behavior.
    pub fn identity(d: usize) -> Self {
        let mut u = vec![0.0; d * d];
        for i in 0..d {
            u[i * d + i] = 1.0;
        }
        GptqFactor {
            d,
            u,
            damp: 0.0,
            fallback: true,
        }
    }

    /// Factor a Gram with dampened Cholesky: `λ = damp_frac·mean(diag H)`,
    /// escalated ×10 up to [`DAMP_RETRIES`] times on pivot failure, then
    /// the identity fallback.  A Gram with no accumulated rows (or an
    /// all-zero diagonal) goes straight to the fallback.
    pub fn from_gram(gram: &Gram, damp_frac: f64) -> Self {
        let d = gram.dim();
        let scale = gram.mean_diag();
        if d == 0 || gram.rows == 0 || !(scale > 0.0) || !scale.is_finite() {
            return Self::identity(d);
        }
        let mut damp = damp_frac.max(1e-12) * scale;
        for _ in 0..DAMP_RETRIES {
            if let Some(u) = factor_damped(gram.entries(), d, damp) {
                return GptqFactor {
                    d,
                    u,
                    damp,
                    fallback: false,
                };
            }
            damp *= 10.0;
        }
        Self::identity(d)
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// `U[i][k]` (zero below the diagonal).
    #[inline(always)]
    pub fn u(&self, i: usize, k: usize) -> f64 {
        self.u[i * self.d + k]
    }

    /// The error-feedback row for pivot `i`: `U[i][k]/U[i][i]` for
    /// `k > i` (empty under the identity fallback's zero propagation).
    pub fn propagation_row(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let dii = self.u(i, i);
        let d = self.d;
        ((i + 1)..d).map(move |k| (k, self.u(i, k) / dii))
    }
}

/// Lower Cholesky of `A = H + λI`; `None` on a non-positive pivot.
fn cholesky_lower(h: &[f64], d: usize, damp: f64) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..=i {
            let mut s = h[i * d + j];
            if i == j {
                s += damp;
            }
            for k in 0..j {
                s -= l[i * d + k] * l[j * d + k];
            }
            if i == j {
                if !(s > 0.0) || !s.is_finite() {
                    return None;
                }
                l[i * d + i] = s.sqrt();
            } else {
                l[i * d + j] = s / l[j * d + j];
            }
        }
    }
    Some(l)
}

/// `U` upper-triangular with `(H + λI)⁻¹ = UᵀU`:
/// Cholesky `A = L·Lᵀ` → `A⁻¹ = L⁻ᵀL⁻¹` by triangular solves → Cholesky of
/// the inverse (`A⁻¹ = Lh·Lhᵀ`) → `U = Lhᵀ`.  Any non-finite intermediate
/// fails the whole attempt (the caller escalates damping).
fn factor_damped(h: &[f64], d: usize, damp: f64) -> Option<Vec<f64>> {
    let l = cholesky_lower(h, d, damp)?;
    // L⁻¹ by forward substitution, column by column.
    let mut linv = vec![0.0f64; d * d];
    for j in 0..d {
        linv[j * d + j] = 1.0 / l[j * d + j];
        for i in (j + 1)..d {
            let mut s = 0.0;
            for k in j..i {
                s -= l[i * d + k] * linv[k * d + j];
            }
            linv[i * d + j] = s / l[i * d + i];
        }
    }
    // A⁻¹ = L⁻ᵀ·L⁻¹ (symmetric).
    let mut ainv = vec![0.0f64; d * d];
    for i in 0..d {
        for j in i..d {
            let mut s = 0.0;
            // (L⁻ᵀL⁻¹)[i][j] = Σ_k L⁻¹[k][i]·L⁻¹[k][j]; L⁻¹ lower.
            for k in j..d {
                s += linv[k * d + i] * linv[k * d + j];
            }
            if !s.is_finite() {
                return None;
            }
            ainv[i * d + j] = s;
            ainv[j * d + i] = s;
        }
    }
    let lh = cholesky_lower(&ainv, d, 0.0)?;
    // U = Lhᵀ.
    let mut u = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..=i {
            u[j * d + i] = lh[i * d + j];
        }
    }
    Some(u)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matmul(a: &[f64], b: &[f64], d: usize) -> Vec<f64> {
        let mut c = vec![0.0; d * d];
        for i in 0..d {
            for k in 0..d {
                let aik = a[i * d + k];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..d {
                    c[i * d + j] += aik * b[k * d + j];
                }
            }
        }
        c
    }

    fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn gram_accumulates_xtx() {
        let mut g = Gram::new(3);
        let xs = [1.0f32, 2.0, 3.0, -1.0, 0.5, 0.0];
        g.accumulate(&xs, 2).unwrap();
        assert_eq!(g.rows, 2);
        // H[0][1] = 1·2 + (−1)·0.5
        assert!((g.entries()[1] - 1.5).abs() < 1e-12);
        assert!((g.entries()[0] - 2.0).abs() < 1e-12);
        // symmetric
        assert_eq!(g.entries()[1], g.entries()[3]);
    }

    #[test]
    fn gram_skips_poisoned_rows() {
        let mut g = Gram::new(2);
        let xs = [1.0f32, 1.0, f32::NAN, 1.0, 2.0, 2.0];
        g.accumulate(&xs, 3).unwrap();
        assert_eq!(g.rows, 2);
        assert!((g.entries()[0] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn factor_inverts_full_rank_gram() {
        // Well-conditioned H from more rows than dims.
        let d = 4;
        let mut g = Gram::new(d);
        let mut rng = crate::data::Rng::new(7);
        let rows = 32;
        let xs: Vec<f32> = (0..rows * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        g.accumulate(&xs, rows).unwrap();
        let f = GptqFactor::from_gram(&g, 0.01);
        assert!(!f.fallback);
        // UᵀU must be (H + λI)⁻¹: check (H+λI)·UᵀU ≈ I.
        let mut utu = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += f.u(k, i) * f.u(k, j);
                }
                utu[i * d + j] = s;
            }
        }
        let mut a: Vec<f64> = g.entries().to_vec();
        for i in 0..d {
            a[i * d + i] += f.damp;
        }
        let prod = matmul(&a, &utu, d);
        let mut eye = vec![0.0; d * d];
        for i in 0..d {
            eye[i * d + i] = 1.0;
        }
        assert_close(&prod, &eye, 1e-6, "A·UᵀU");
    }

    #[test]
    fn rank_deficient_gram_dampens_not_fails() {
        // One calibration row for a 3-dim layer: rank-1 Gram.  The damped
        // factorization must succeed without the identity fallback.
        let mut g = Gram::new(3);
        g.accumulate(&[1.0, 2.0, -1.0], 1).unwrap();
        let f = GptqFactor::from_gram(&g, 0.01);
        assert!(!f.fallback, "damping should rescue a rank-1 gram");
        assert!(f.damp > 0.0);
        for i in 0..3 {
            assert!(f.u(i, i).is_finite() && f.u(i, i) > 0.0);
        }
    }

    #[test]
    fn empty_gram_falls_back_to_identity() {
        let g = Gram::new(4);
        let f = GptqFactor::from_gram(&g, 0.01);
        assert!(f.fallback);
        for i in 0..4 {
            assert_eq!(f.u(i, i), 1.0);
            assert_eq!(f.propagation_row(i).count(), 4 - i - 1);
            assert!(f.propagation_row(i).all(|(_, v)| v == 0.0));
        }
    }

    #[test]
    fn single_dim_gram_factors() {
        let mut g = Gram::new(1);
        g.accumulate(&[2.0, 3.0], 2).unwrap();
        let f = GptqFactor::from_gram(&g, 0.01);
        assert!(!f.fallback);
        // H = 13, λ = 0.13 → U = 1/sqrt(13.13)
        assert!((f.u(0, 0) - 1.0 / (13.13f64).sqrt()).abs() < 1e-9);
    }
}
