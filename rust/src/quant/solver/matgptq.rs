//! Nested-MSB GPTQ — Hessian-weighted column-wise rounding where every
//! chosen int8 master code simultaneously minimizes error at **every
//! serving rung**.
//!
//! Classic GPTQ rounds to the nearest code of ONE bit-width.  A MatQuant
//! master is served at r ∈ {2, 4, 8} through MSB slicing, so the solver
//! scores each candidate code `c ∈ [0, 256)` by its *sliced* values:
//!
//! ```text
//!   cost(c | t) = Σ_r λ_r · (t − S(c, r))²,   S = slice_code (Eq. 6/8)
//! ```
//!
//! with `t` the real-valued target in 8-bit code space (`w/α + z`).  The
//! per-code sums are precomputed once into a 256-entry LUT ([`CodeLut`]):
//! `cost(c|t) = Σλ_r S_r(c)² − 2t·Σλ_r S_r(c) + t²Σλ_r`, so the argmin
//! needs only `c2[c] − 2t·b[c]` per candidate.  Error feedback uses the
//! exact decomposition `Σλ_r(t−S_r)² = Λ(t−s̄(c))² + spread(c)` — the
//! propagated error is `t − s̄(c)` against the λ-weighted mean sliced
//! value, and the code-independent spread term cannot be fed back.
//!
//! The sweep itself is standard GPTQ over input-dim rows (our weights are
//! row-major `(d_in, d_out)` with per-output-channel scales, so "GPTQ
//! columns" are rows here, and all `d_out` output channels round one row
//! in lockstep): quantize row `i`, then fold `err·U[i][k]/U[i][i]` into
//! every later row `k` ([`GptqFactor::propagation_row`]).  Propagation is
//! performed directly in code space — the per-column affine `t = w/α + z`
//! shares `α_j` across rows, so the weight-space update divides through.

use super::gram::{Gram, GptqFactor};
use crate::quant::{slice_code, Scales};
use crate::MASTER_BITS;

/// Per-rung loss weights for the nested objective, mirroring the training
/// loss lambdas (`λ_2 = 1.0, λ_4 = λ_8 = 0.1` — the paper's int2-focused
/// default, where the int2 rung is the hardest to serve).
#[derive(Debug, Clone, PartialEq)]
pub struct RungWeights {
    /// `(rung bits, λ)` pairs; rungs must be in `[1, MASTER_BITS]`.
    pub weights: Vec<(u32, f64)>,
    /// Score sliced values under Eq. 8 (overflow bucket admitted) instead
    /// of Eq. 6 clamping.
    pub extra_precision: bool,
}

impl Default for RungWeights {
    fn default() -> Self {
        RungWeights {
            weights: vec![(2, 1.0), (4, 0.1), (8, 0.1)],
            extra_precision: false,
        }
    }
}

impl RungWeights {
    /// A single-rung objective — degenerate nested scoring; at rung 8 the
    /// solver reduces to plain GPTQ on the int8 master.
    pub fn single(bits: u32) -> Self {
        RungWeights {
            weights: vec![(bits, 1.0)],
            extra_precision: false,
        }
    }

    /// The rungs this objective scores, in listed order.
    pub fn rungs(&self) -> Vec<u32> {
        self.weights.iter().map(|&(r, _)| r).collect()
    }
}

const N_CODES: usize = 1 << MASTER_BITS;

/// The 256-entry scoring tables for one [`RungWeights`] objective.
#[derive(Debug, Clone)]
pub struct CodeLut {
    /// `b[c] = Σ_r λ_r·S_r(c)`.
    b: Vec<f64>,
    /// `c2[c] = Σ_r λ_r·S_r(c)²`.
    c2: Vec<f64>,
    /// `Λ = Σ_r λ_r`.
    lam: f64,
}

impl CodeLut {
    pub fn new(rw: &RungWeights) -> Self {
        assert!(!rw.weights.is_empty(), "empty rung objective");
        let mut b = vec![0.0f64; N_CODES];
        let mut c2 = vec![0.0f64; N_CODES];
        let mut lam = 0.0f64;
        for &(r, l) in &rw.weights {
            assert!(
                r >= 1 && r <= MASTER_BITS && l >= 0.0,
                "bad rung weight ({r}, {l})"
            );
            lam += l;
            for c in 0..N_CODES {
                let s = slice_code(c as f32, MASTER_BITS, r, rw.extra_precision) as f64;
                b[c] += l * s;
                c2[c] += l * s * s;
            }
        }
        assert!(lam > 0.0, "rung weights sum to zero");
        CodeLut { b, c2, lam }
    }

    /// The code minimizing `Σ_r λ_r (t − S_r(c))²`; ties round up (larger
    /// code), matching `round_half_up`.
    #[inline]
    pub fn best(&self, t: f64) -> usize {
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for c in 0..N_CODES {
            let score = self.c2[c] - 2.0 * t * self.b[c];
            if score <= best_score {
                best_score = score;
                best = c;
            }
        }
        best
    }

    /// The λ-weighted mean sliced value `s̄(c)` — what error feedback
    /// measures the target against.
    #[inline]
    pub fn sbar(&self, c: usize) -> f64 {
        self.b[c] / self.lam
    }
}

/// Solve refined int8 master codes for one tensor.
///
/// `w_eff` is the row-major `(d_in, d_out)` **smoothing-folded** weight
/// (`W⊙s` — the exact tensor `Q(·)` quantized at build time), `scales` its
/// per-output-channel master scales, `factor` the dampened curvature from
/// this tensor's calibration Gram, and `lut` the nested objective.
/// Returns int8 codes as f32 (integers in `[0, 255]`, the
/// [`crate::quant::PackedTensor::pack`] input format).
pub fn solve_codes(
    w_eff: &[f32],
    d_in: usize,
    d_out: usize,
    scales: &Scales,
    factor: &GptqFactor,
    lut: &CodeLut,
) -> Vec<f32> {
    assert_eq!(w_eff.len(), d_in * d_out, "weight shape mismatch");
    assert_eq!(scales.d_out(), d_out, "scales arity mismatch");
    assert_eq!(factor.dim(), d_in, "factor dim mismatch");
    // Targets in code space, f32 op order matching `quantize_one` so the
    // degenerate solver (identity factor, single rung 8) is bit-identical
    // to minmax rounding.
    let mut t: Vec<f64> = w_eff
        .chunks_exact(d_out.max(1))
        .flat_map(|row| {
            row.iter()
                .enumerate()
                .map(|(j, &w)| (w / scales.alpha[j] + scales.zero[j]) as f64)
        })
        .collect();
    let mut codes = vec![0.0f32; d_in * d_out];
    let mut err = vec![0.0f64; d_out];
    for i in 0..d_in {
        let row = &mut t[i * d_out..(i + 1) * d_out];
        for j in 0..d_out {
            let c = lut.best(row[j]);
            codes[i * d_out + j] = c as f32;
            err[j] = row[j] - lut.sbar(c);
        }
        for (k, p) in factor.propagation_row(i) {
            if p == 0.0 {
                continue;
            }
            let krow = &mut t[k * d_out..(k + 1) * d_out];
            for (tk, &e) in krow.iter_mut().zip(&err) {
                *tk -= e * p;
            }
        }
    }
    codes
}

/// Hessian-weighted squared reconstruction error of `codes` served at one
/// rung: returns `(err, norm)` with
///
/// ```text
///   err  = Σ_j α_j² · Δ_jᵀ H Δ_j,    Δ_ij = S_r(c_ij) − t⁰_ij
///   norm = Σ_j ‖X·w_j‖² = Σ_j w_jᵀ H w_j
/// ```
///
/// — i.e. the output-MSE `‖XŴ − XW‖²` the GPTQ objective bounds, and the
/// matching signal energy (take `sqrt(err/norm)` via [`relative`] for the
/// dimensionless per-tensor number).  `gram: None` scores against the
/// identity Hessian (plain weight-space MSE).
pub fn weighted_residual(
    codes: &[f32],
    w_eff: &[f32],
    d_in: usize,
    d_out: usize,
    scales: &Scales,
    gram: Option<&Gram>,
    rung: u32,
    extra_precision: bool,
) -> (f64, f64) {
    assert_eq!(codes.len(), d_in * d_out, "codes shape mismatch");
    assert_eq!(w_eff.len(), d_in * d_out, "weight shape mismatch");
    if let Some(g) = gram {
        assert_eq!(g.dim(), d_in, "gram dim mismatch");
    }
    let mut err = 0.0f64;
    let mut norm = 0.0f64;
    let mut delta = vec![0.0f64; d_in];
    let mut wcol = vec![0.0f64; d_in];
    for j in 0..d_out {
        let a = scales.alpha[j] as f64;
        let z = scales.zero[j] as f64;
        for i in 0..d_in {
            let idx = i * d_out + j;
            let s = slice_code(codes[idx], MASTER_BITS, rung, extra_precision) as f64;
            delta[i] = a * (s - z) - w_eff[idx] as f64;
            wcol[i] = w_eff[idx] as f64;
        }
        match gram {
            None => {
                err += delta.iter().map(|d| d * d).sum::<f64>();
                norm += wcol.iter().map(|w| w * w).sum::<f64>();
            }
            Some(g) => {
                let h = g.entries();
                for i in 0..d_in {
                    let hrow = &h[i * d_in..(i + 1) * d_in];
                    let mut hd = 0.0;
                    let mut hw = 0.0;
                    for k in 0..d_in {
                        hd += hrow[k] * delta[k];
                        hw += hrow[k] * wcol[k];
                    }
                    err += delta[i] * hd;
                    norm += wcol[i] * hw;
                }
            }
        }
    }
    // Quadratic forms in PSD H are non-negative up to rounding noise.
    (err.max(0.0), norm.max(0.0))
}

/// `sqrt(err / norm)` guarded against a zero-signal tensor.
pub fn relative(err: f64, norm: f64) -> f64 {
    (err / norm.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::quant::{minmax_scales, quantize};

    fn toy(seed: u64, d_in: usize, d_out: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d_in * d_out)
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect()
    }

    #[test]
    fn single_rung8_identity_reproduces_minmax_codes() {
        let (d_in, d_out) = (24, 10);
        let w = toy(3, d_in, d_out);
        let scales = minmax_scales(&w, d_in, d_out, MASTER_BITS);
        let want = quantize(&w, d_out, &scales);
        let lut = CodeLut::new(&RungWeights::single(8));
        let got = solve_codes(
            &w,
            d_in,
            d_out,
            &scales,
            &GptqFactor::identity(d_in),
            &lut,
        );
        assert_eq!(got, want, "degenerate solver must equal minmax rounding");
    }

    #[test]
    fn best_code_is_brute_force_argmin_at_every_rung_mix() {
        for rw in [
            RungWeights::default(),
            RungWeights {
                weights: vec![(2, 1.0), (4, 0.5), (8, 0.25)],
                extra_precision: true,
            },
        ] {
            let lut = CodeLut::new(&rw);
            let mut rng = Rng::new(11);
            for _ in 0..200 {
                let t = rng.range_f32(-20.0, 276.0) as f64;
                let got = lut.best(t);
                let mut best = 0usize;
                let mut best_cost = f64::INFINITY;
                for c in 0..N_CODES {
                    let mut cost = 0.0;
                    for &(r, l) in &rw.weights {
                        let s =
                            slice_code(c as f32, MASTER_BITS, r, rw.extra_precision) as f64;
                        cost += l * (t - s) * (t - s);
                    }
                    if cost <= best_cost {
                        best_cost = cost;
                        best = c;
                    }
                }
                assert_eq!(got, best, "t={t}");
            }
        }
    }

    #[test]
    fn nested_objective_never_loses_to_minmax_at_int2() {
        // Minmax-then-slice is per-element near-optimal at rung 2 (the
        // double rounding only loses on boundary-sliver targets), so the
        // identity-factor nested objective must tie or win — the large
        // int2 gains come from Gram feedback, tested separately.
        let (d_in, d_out) = (48, 16);
        let w = toy(7, d_in, d_out);
        let scales = minmax_scales(&w, d_in, d_out, MASTER_BITS);
        let minmax_codes = quantize(&w, d_out, &scales);
        let lut = CodeLut::new(&RungWeights::default());
        let solved = solve_codes(
            &w,
            d_in,
            d_out,
            &scales,
            &GptqFactor::identity(d_in),
            &lut,
        );
        let (e_minmax, n) =
            weighted_residual(&minmax_codes, &w, d_in, d_out, &scales, None, 2, false);
        let (e_solved, _) =
            weighted_residual(&solved, &w, d_in, d_out, &scales, None, 2, false);
        assert!(
            e_solved <= e_minmax + 1e-9,
            "int2 err: solved {e_solved} vs minmax {e_minmax} (norm {n})"
        );
    }

    #[test]
    fn nested_objective_fixes_double_rounding_slivers_at_int2() {
        // Targets in (95.5, 96): minmax rounds to code 96 whose rung-2
        // slice is 128 (error ≈ 32.3), but code 95 slices to 64 (error
        // ≈ 31.7) at negligible rung-4/8 cost.  The λ2-dominant LUT must
        // take the win that double rounding forfeits.
        let d_out = 1;
        let scales = Scales {
            bits: MASTER_BITS,
            alpha: vec![1.0; d_out],
            zero: vec![0.0; d_out],
        };
        let w: Vec<f32> = vec![95.6, 95.7, 95.9, 159.6, 223.8];
        let d_in = w.len();
        let minmax_codes = quantize(&w, d_out, &scales);
        let lut = CodeLut::new(&RungWeights::default());
        let solved = solve_codes(
            &w,
            d_in,
            d_out,
            &scales,
            &GptqFactor::identity(d_in),
            &lut,
        );
        let (e_minmax, _) =
            weighted_residual(&minmax_codes, &w, d_in, d_out, &scales, None, 2, false);
        let (e_solved, _) =
            weighted_residual(&solved, &w, d_in, d_out, &scales, None, 2, false);
        assert!(
            e_solved < e_minmax,
            "sliver targets must improve strictly: solved {e_solved} vs minmax {e_minmax}"
        );
    }

    #[test]
    fn error_feedback_reduces_hessian_weighted_error() {
        // Correlated inputs → off-diagonal Gram mass → propagation helps.
        let (d_in, d_out) = (16, 8);
        let w = toy(13, d_in, d_out);
        let scales = minmax_scales(&w, d_in, d_out, MASTER_BITS);
        let mut g = Gram::new(d_in);
        let mut rng = Rng::new(29);
        let rows = 64;
        let mut xs = vec![0.0f32; rows * d_in];
        for r in 0..rows {
            let base = rng.range_f32(-1.0, 1.0);
            for i in 0..d_in {
                // shared component + private noise → correlated columns
                xs[r * d_in + i] = base + 0.3 * rng.range_f32(-1.0, 1.0);
            }
        }
        g.accumulate(&xs, rows).unwrap();
        let factor = GptqFactor::from_gram(&g, 0.01);
        assert!(!factor.fallback);
        let lut = CodeLut::new(&RungWeights::default());
        let with_fb = solve_codes(&w, d_in, d_out, &scales, &factor, &lut);
        let without_fb = solve_codes(
            &w,
            d_in,
            d_out,
            &scales,
            &GptqFactor::identity(d_in),
            &lut,
        );
        let score = |codes: &[f32]| {
            RungWeights::default()
                .weights
                .iter()
                .map(|&(r, l)| {
                    let (e, _) =
                        weighted_residual(codes, &w, d_in, d_out, &scales, Some(&g), r, false);
                    l * e
                })
                .sum::<f64>()
        };
        let a = score(&with_fb);
        let b = score(&without_fb);
        assert!(a < b, "feedback {a} must beat independent rounding {b}");
    }

    #[test]
    fn single_column_tensor_solves() {
        // d_out = 1 and d_in = 1 corner shapes must round-trip.
        let lut = CodeLut::new(&RungWeights::default());
        for (d_in, d_out) in [(1usize, 1usize), (1, 5), (6, 1)] {
            let w = toy(17, d_in, d_out);
            let scales = minmax_scales(&w, d_in, d_out, MASTER_BITS);
            let codes = solve_codes(
                &w,
                d_in,
                d_out,
                &scales,
                &GptqFactor::identity(d_in),
                &lut,
            );
            assert_eq!(codes.len(), d_in * d_out);
            assert!(codes
                .iter()
                .all(|&c| c >= 0.0 && c <= 255.0 && c.fract() == 0.0));
        }
    }

    #[test]
    fn residual_zero_for_exact_codes_at_rung8() {
        // Weights already on the int8 grid: rung-8 residual must be ~0.
        let d_out = 4;
        let scales = Scales {
            bits: MASTER_BITS,
            alpha: vec![1.0; d_out],
            zero: vec![0.0; d_out],
        };
        let w: Vec<f32> = (0..8).map(|i| (i * 31 % 256) as f32).collect();
        let codes = w.clone();
        let (e, n) = weighted_residual(&codes, &w, 2, d_out, &scales, None, 8, false);
        assert!(e < 1e-12, "err {e}");
        assert!(n > 0.0);
    }
}
