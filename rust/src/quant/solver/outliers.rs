//! Eq. 8 outlier-budget sweep — spend extra-bit overlay storage where the
//! solver residuals say it pays.
//!
//! Extra-Precision slicing admits the `2^r` overflow bucket; each overflow
//! code costs one extra stored bit (the sparse overlay of
//! [`crate::quant::ExtraBitOverlay`]), so enabling EP on a tensor raises
//! its average bits/param from `r` to `r + overflow_fraction`.  The sweep
//! scores every quantized tensor's Hessian-weighted residual at the rung
//! with EP off vs on, then greedily enables tensors by error-reduction per
//! extra bit until an average-extra-bits budget is exhausted — landing the
//! paper's 2.05-bit effective-precision point when the budget covers the
//! natural overflow mass of an int2 model.
//!
//! A sweep point is *servable*, not just a score: [`packed_views_with_outliers`]
//! builds the per-tensor-EP `BitSliceView` handle map that drops into
//! [`crate::runtime::ForwardPlan::from_packed`] unchanged.

use std::collections::{BTreeMap, BTreeSet};

use super::gram::Gram;
use super::matgptq::{relative, weighted_residual};
use crate::model::{PackedWeight, QuantizedModel};
use crate::quant::overflow_fraction;
use crate::{Result, MASTER_BITS};

/// One point of the outlier-budget sweep.
#[derive(Debug, Clone)]
pub struct OutlierSweepPoint {
    /// The average-extra-bits budget this point was solved under.
    pub budget: f64,
    /// Tensors whose Eq. 8 overlay the budget admits.
    pub enabled: BTreeSet<String>,
    /// Achieved model-wide average bits/param (`rung` + spent overlay bits).
    pub effective_bits: f64,
    /// Aggregate Hessian-weighted relative error at the rung under this
    /// enablement (`sqrt(Σerr/Σnorm)` across quantized tensors).
    pub rel_err: f64,
}

/// Per-tensor sweep inputs: the residual with EP off/on and the overlay
/// cost in average bits contributed model-wide.
struct TensorGain {
    name: String,
    err_off: f64,
    err_on: f64,
    /// Model-wide average-bits cost of enabling this tensor's overlay
    /// (`overflow_fraction · n_tensor / n_total`).
    cost: f64,
}

/// Sweep Eq. 8 outlier budgets at serving rung `rung` against the solver
/// residuals.  `budgets` are average extra bits/param over the whole
/// quantized weight set (e.g. `[0.0, 0.02, 0.05, 0.1, 0.25]`); each point
/// reports the greedy-optimal tensor enablement, the achieved effective
/// bits, and the aggregate weighted relative error.  Tensors missing from
/// `grams` (or dimension-mismatched) score against the identity Hessian.
pub fn sweep_outlier_budgets(
    model: &QuantizedModel,
    grams: &BTreeMap<String, Gram>,
    rung: u32,
    budgets: &[f64],
) -> Result<Vec<OutlierSweepPoint>> {
    let n_total: usize = model
        .quantized
        .values()
        .map(|qt| qt.d_in * qt.d_out)
        .sum();
    let mut gains = Vec::new();
    let mut norm_total = 0.0f64;
    for qn in &model.quantized_order {
        let qt = &model.quantized[qn];
        let codes = qt.codes.unpack();
        let w_eff = qt.smoothed_weight();
        let gram = grams.get(qn).filter(|g| g.dim() == qt.d_in);
        let (err_off, norm) = weighted_residual(
            &codes, &w_eff, qt.d_in, qt.d_out, &qt.scales, gram, rung, false,
        );
        let (err_on, _) = weighted_residual(
            &codes, &w_eff, qt.d_in, qt.d_out, &qt.scales, gram, rung, true,
        );
        let of = overflow_fraction(&codes, MASTER_BITS, rung);
        norm_total += norm;
        gains.push(TensorGain {
            name: qn.clone(),
            err_off,
            err_on,
            cost: of * (qt.d_in * qt.d_out) as f64 / n_total.max(1) as f64,
        });
    }
    // Greedy order: error reduction per extra bit, descending.  Zero-cost
    // tensors (no overflow codes at this rung) change nothing either way
    // and sort last.
    let mut order: Vec<usize> = (0..gains.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = ratio(&gains[a]);
        let rb = ratio(&gains[b]);
        rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut out = Vec::with_capacity(budgets.len());
    for &budget in budgets {
        let mut enabled = BTreeSet::new();
        let mut spent = 0.0f64;
        for &i in &order {
            let g = &gains[i];
            if g.cost == 0.0 || g.err_on >= g.err_off {
                continue;
            }
            if spent + g.cost <= budget + 1e-12 {
                spent += g.cost;
                enabled.insert(g.name.clone());
            }
        }
        let err: f64 = gains
            .iter()
            .map(|g| {
                if enabled.contains(&g.name) {
                    g.err_on
                } else {
                    g.err_off
                }
            })
            .sum();
        out.push(OutlierSweepPoint {
            budget,
            effective_bits: rung as f64 + spent,
            rel_err: relative(err, norm_total),
            enabled,
        });
    }
    Ok(out)
}

fn ratio(g: &TensorGain) -> f64 {
    if g.cost <= 0.0 {
        return f64::NEG_INFINITY;
    }
    (g.err_off - g.err_on) / g.cost
}

/// Build the servable handle map for a sweep point: every quantized tensor
/// as a nested `BitSliceView` at `bits`, with Eq. 8 extra precision on
/// exactly the `enabled` tensors.  Drops into
/// [`crate::runtime::ForwardPlan::from_packed`] like any uniform map.
pub fn packed_views_with_outliers(
    model: &QuantizedModel,
    bits: u32,
    enabled: &BTreeSet<String>,
) -> Result<BTreeMap<String, PackedWeight>> {
    let mut out = BTreeMap::new();
    for qn in &model.quantized_order {
        let qt = &model.quantized[qn];
        out.insert(qn.clone(), qt.packed_view(bits, enabled.contains(qn))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::model::{QuantizedTensor, Tensor};

    fn toy_model(seed: u64, tensors: &[(&str, usize, usize)]) -> QuantizedModel {
        let mut rng = Rng::new(seed);
        let mut quantized = BTreeMap::new();
        let mut order = Vec::new();
        for &(name, d_in, d_out) in tensors {
            let data: Vec<f32> = (0..d_in * d_out)
                .map(|_| rng.range_f32(-1.0, 1.0))
                .collect();
            let fp = Tensor::new(vec![d_in, d_out], data).unwrap();
            quantized.insert(
                name.to_string(),
                QuantizedTensor::from_weight(fp, None, None, None).unwrap(),
            );
            order.push(name.to_string());
        }
        QuantizedModel::from_parts(BTreeMap::new(), quantized, vec![], order)
    }

    #[test]
    fn sweep_is_monotone_in_budget() {
        let model = toy_model(5, &[("layer0.a", 32, 16), ("layer1.b", 32, 16)]);
        let grams = BTreeMap::new();
        let pts =
            sweep_outlier_budgets(&model, &grams, 2, &[0.0, 0.01, 0.05, 0.2, 1.0]).unwrap();
        assert_eq!(pts.len(), 5);
        for w in pts.windows(2) {
            assert!(
                w[1].rel_err <= w[0].rel_err + 1e-12,
                "more budget must not hurt: {} → {}",
                w[0].rel_err,
                w[1].rel_err
            );
            assert!(w[1].effective_bits >= w[0].effective_bits - 1e-12);
        }
        // Zero budget enables nothing and serves exactly `rung` bits.
        assert!(pts[0].enabled.is_empty());
        assert!((pts[0].effective_bits - 2.0).abs() < 1e-12);
        // A generous budget enables the overlay everywhere there is gain,
        // landing the paper's "2 + overflow mass" effective precision.
        let last = pts.last().unwrap();
        assert!(!last.enabled.is_empty());
        assert!(last.effective_bits > 2.0 && last.effective_bits < 2.3);
        assert!(last.rel_err < pts[0].rel_err);
    }

    #[test]
    fn sweep_points_are_servable() {
        let model = toy_model(9, &[("layer0.a", 16, 8)]);
        let pts = sweep_outlier_budgets(&model, &BTreeMap::new(), 2, &[1.0]).unwrap();
        let views = packed_views_with_outliers(&model, 2, &pts[0].enabled).unwrap();
        let qt = &model.quantized["layer0.a"];
        let ep = pts[0].enabled.contains("layer0.a");
        let (want, _) = qt.materialize(2, ep).unwrap();
        let (got, _) = views["layer0.a"].decode().unwrap();
        assert_eq!(got.data, want.data);
    }
}
