//! MatGPTQ — the post-training accuracy frontier (MatQuant without
//! co-training).
//!
//! Data flow, end to end:
//!
//! ```text
//!   calibration tokens
//!     → ForwardPlan::accumulate_grams      (runtime/plan.rs: per-linear
//!        H = ΣXᵀX, captured AFTER the OmniQuant 1/s fold)
//!     → GptqFactor::from_gram              (gram.rs: dampened Cholesky,
//!        (H+λI)⁻¹ = UᵀU, ×10 λ escalation, identity fallback)
//!     → solve_codes                        (matgptq.rs: GPTQ row sweep,
//!        each code argmin of Σ_r λ_r(t − S_r(c))² via the 256-entry LUT,
//!        error feedback through U)
//!     → QuantizedModel::solve_refined      (model/registry.rs: repack the
//!        refined int8 masters; scales/smoothing/serving path unchanged)
//!     → sweep_outlier_budgets              (outliers.rs: Eq. 8 extra-bit
//!        budgets per tensor → the 2.05-bit effective-precision point)
//! ```
//!
//! The output is only a better int8 master: every downstream consumer —
//! `BitSliceView` nested serving, compact payload export, Mix'n'Match
//! per-layer maps, speculative decode — works on the refined model with
//! **zero serving-side changes**.  Per-tensor residuals double as real
//! curvature input for [`crate::mixnmatch::sensitivity`].

pub mod gram;
pub mod matgptq;
pub mod outliers;

pub use gram::{GptqFactor, Gram};
pub use matgptq::{relative, solve_codes, weighted_residual, CodeLut, RungWeights};
pub use outliers::{packed_views_with_outliers, sweep_outlier_budgets, OutlierSweepPoint};

/// Configuration for [`crate::model::QuantizedModel::solve_refined`].
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// The nested per-rung objective (default mirrors the training loss:
    /// `λ_2 = 1.0, λ_4 = λ_8 = 0.1`).
    pub rung_weights: RungWeights,
    /// Cholesky damping as a fraction of `mean(diag H)` (GPTQ's 1%).
    pub damp_frac: f64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            rung_weights: RungWeights::default(),
            damp_frac: 0.01,
        }
    }
}

/// Per-tensor solver outcome: the damping that factorized its Gram and the
/// Hessian-weighted relative residual (`sqrt(err/norm)`) per rung, for the
/// pre-solve (minmax) and post-solve codes.
#[derive(Debug, Clone)]
pub struct TensorReport {
    pub name: String,
    pub layer: usize,
    /// λ actually used (0 when the identity fallback fired).
    pub damp: f64,
    /// True when no Gram existed or no dampened Cholesky succeeded.
    pub fallback: bool,
    /// `(rung, rel_err)` of the original minmax master codes.
    pub base_rel: Vec<(u32, f64)>,
    /// `(rung, rel_err)` of the solver-refined codes.
    pub solved_rel: Vec<(u32, f64)>,
}

/// The full [`crate::model::QuantizedModel::solve_refined`] outcome.
#[derive(Debug, Clone, Default)]
pub struct SolverReport {
    pub tensors: Vec<TensorReport>,
}

impl SolverReport {
    /// Mean relative residual across tensors at `rung` (solved codes).
    pub fn mean_solved_rel(&self, rung: u32) -> f64 {
        mean_rel(&self.tensors, rung, |t| &t.solved_rel)
    }

    /// Mean relative residual across tensors at `rung` (minmax codes).
    pub fn mean_base_rel(&self, rung: u32) -> f64 {
        mean_rel(&self.tensors, rung, |t| &t.base_rel)
    }

    /// Human-readable per-tensor table.
    pub fn render(&self) -> String {
        let mut s = String::from(
            "tensor                         damp        rung  minmax    solved\n",
        );
        for t in &self.tensors {
            for (i, &(r, solved)) in t.solved_rel.iter().enumerate() {
                let base = t.base_rel.get(i).map_or(f64::NAN, |&(_, b)| b);
                let head = if i == 0 {
                    format!(
                        "{:<28}  {:<10}",
                        t.name,
                        if t.fallback {
                            "identity".to_string()
                        } else {
                            format!("{:.2e}", t.damp)
                        }
                    )
                } else {
                    format!("{:<28}  {:<10}", "", "")
                };
                s.push_str(&format!(
                    "{head}  int{r:<2}  {base:<8.5}  {solved:<8.5}\n"
                ));
            }
        }
        s
    }
}

fn mean_rel<'a, F>(tensors: &'a [TensorReport], rung: u32, pick: F) -> f64
where
    F: Fn(&'a TensorReport) -> &'a Vec<(u32, f64)>,
{
    let vals: Vec<f64> = tensors
        .iter()
        .filter_map(|t| {
            pick(t)
                .iter()
                .find(|&&(r, _)| r == rung)
                .map(|&(_, v)| v)
        })
        .collect();
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}
