//! Persisted per-layer int8 activation calibration.
//!
//! The histogram-percentile clip ([`super::activations`]) originally ran
//! **per token row per quantized layer per request** — a 256-bucket |x|
//! histogram pass on the serving hot path, every time.  Calibration runs
//! that pass once, offline, over representative prompts
//! ([`crate::runtime::ForwardPlan::calibrate`]), keeps the worst-case
//! (max-over-rows) clip per quantized tensor, and persists the thresholds
//! as JSON **beside the checkpoint** ([`ActCalibration::beside`]).  The
//! serving worker loads the file into
//! [`crate::serve::WeightStore::set_calibration`]; forward plans then bake
//! each layer's threshold into an [`super::ActQuantConfig::fixed`] quantizer
//! — zero range scans at request time, stable codes across batches.
//!
//! File format (self-describing, hand-editable):
//!
//! ```json
//! {"clip_fraction": 0.999, "clips": {"layer0.ffn.w_in": 1.25, ...}}
//! ```
//!
//! `clip_fraction` records how the thresholds were derived (`null` =
//! absmax) so a report can say what policy produced them; the serving path
//! only consumes `clips`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context};

use crate::util::Json;
use crate::Result;

/// Per-quantized-tensor activation clip thresholds (post smoothing fold —
/// exactly the values the fused i8 matmul quantizes against).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ActCalibration {
    /// The histogram fraction the thresholds were calibrated with
    /// (`None` = absmax).
    pub clip_fraction: Option<f32>,
    /// `quantized tensor name → clip threshold` (strictly positive).
    pub clips: BTreeMap<String, f32>,
}

impl ActCalibration {
    /// The clip for one quantized tensor, if calibrated.
    pub fn clip_for(&self, name: &str) -> Option<f32> {
        self.clips.get(name).copied()
    }

    /// Conventional sidecar path next to a checkpoint:
    /// `model.mqck` → `model.act_clips.json`.
    pub fn beside(checkpoint: impl AsRef<Path>) -> PathBuf {
        checkpoint.as_ref().with_extension("act_clips.json")
    }

    pub fn to_json(&self) -> String {
        let clips = Json::Obj(
            self.clips
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                .collect(),
        );
        let frac = match self.clip_fraction {
            Some(f) => Json::Num(f as f64),
            None => Json::Null,
        };
        Json::obj(vec![("clip_fraction", frac), ("clips", clips)]).to_string()
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing activation calibration")?;
        let clip_fraction = match j.get("clip_fraction")? {
            Json::Null => None,
            v => Some(v.as_f64()? as f32),
        };
        let mut clips = BTreeMap::new();
        for (name, v) in j.get("clips")?.as_obj()? {
            let c = v.as_f64()? as f32;
            ensure!(
                c.is_finite() && c > 0.0,
                "calibration clip for {name:?} must be finite and positive, got {c}"
            );
            clips.insert(name.clone(), c);
        }
        Ok(ActCalibration {
            clip_fraction,
            clips,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, self.to_json()).with_context(|| format!("writing {path:?}"))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_json(&text).with_context(|| format!("loading calibration {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut cal = ActCalibration {
            clip_fraction: Some(0.999),
            clips: BTreeMap::new(),
        };
        cal.clips.insert("layer0.ffn.w_in".into(), 1.25);
        cal.clips.insert("layer1.ffn.w_out".into(), 0.5);
        let back = ActCalibration::from_json(&cal.to_json()).unwrap();
        assert_eq!(back, cal);
        assert_eq!(back.clip_for("layer0.ffn.w_in"), Some(1.25));
        assert_eq!(back.clip_for("missing"), None);
    }

    #[test]
    fn absmax_policy_serializes_as_null() {
        let cal = ActCalibration::default();
        let text = cal.to_json();
        assert!(text.contains("\"clip_fraction\":null"), "{text}");
        assert_eq!(ActCalibration::from_json(&text).unwrap(), cal);
    }

    #[test]
    fn rejects_degenerate_clips() {
        for bad in ["0", "-1.5"] {
            let text = format!(r#"{{"clip_fraction": null, "clips": {{"w": {bad}}}}}"#);
            assert!(ActCalibration::from_json(&text).is_err(), "{bad}");
        }
    }

    #[test]
    fn file_roundtrip_and_sidecar_path() {
        let dir = std::env::temp_dir().join("mq_act_cal_test");
        let ckpt = dir.join("model.mqck");
        let side = ActCalibration::beside(&ckpt);
        assert_eq!(side, dir.join("model.act_clips.json"));
        let mut cal = ActCalibration::default();
        cal.clips.insert("layer0.ffn.w_in".into(), 2.0);
        cal.save(&side).unwrap();
        let back = ActCalibration::load(&side).unwrap();
        assert_eq!(back, cal);
        std::fs::remove_dir_all(&dir).ok();
    }
}
