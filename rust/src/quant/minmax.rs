//! MinMax (Eq. 1) and OmniQuant (Eq. 3) affine quantization.
//!
//! Weight matrices are row-major `(d_in, d_out)`; scales are per *output
//! channel* (one `(alpha, zero)` per column), matching the L2 model and
//! the L1 kernels.

use super::{round_half_up, EPS};

/// Per-channel affine quantization parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Scales {
    /// Bit-width the scales were computed for.
    pub bits: u32,
    /// `alpha[j] = (γ·max_j − β·min_j) / (2^bits − 1)` per column `j`.
    pub alpha: Vec<f32>,
    /// `zero[j] = −β·min_j / alpha[j]`.
    pub zero: Vec<f32>,
}

impl Scales {
    pub fn d_out(&self) -> usize {
        self.alpha.len()
    }
}

/// Column-wise min/max of a row-major `(d_in, d_out)` matrix.
pub fn col_min_max(w: &[f32], d_in: usize, d_out: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(w.len(), d_in * d_out, "shape mismatch");
    let mut mins = vec![f32::INFINITY; d_out];
    let mut maxs = vec![f32::NEG_INFINITY; d_out];
    for row in w.chunks_exact(d_out) {
        for (j, &x) in row.iter().enumerate() {
            if x < mins[j] {
                mins[j] = x;
            }
            if x > maxs[j] {
                maxs[j] = x;
            }
        }
    }
    (mins, maxs)
}

/// MinMax scales (Eq. 1): `γ = β = 1`.
pub fn minmax_scales(w: &[f32], d_in: usize, d_out: usize, bits: u32) -> Scales {
    omni_scales(w, d_in, d_out, bits, None, None)
}

/// OmniQuant scales (Eq. 3) with optional per-column clipping factors.
pub fn omni_scales(
    w: &[f32],
    d_in: usize,
    d_out: usize,
    bits: u32,
    gamma: Option<&[f32]>,
    beta: Option<&[f32]>,
) -> Scales {
    let (mins, maxs) = col_min_max(w, d_in, d_out);
    let levels = (1u32 << bits) as f32 - 1.0;
    let mut alpha = Vec::with_capacity(d_out);
    let mut zero = Vec::with_capacity(d_out);
    for j in 0..d_out {
        let g = gamma.map_or(1.0, |g| g[j]);
        let b = beta.map_or(1.0, |b| b[j]);
        let mut a = (g * maxs[j] - b * mins[j]) / levels;
        if a.abs() < EPS {
            a = EPS;
        }
        alpha.push(a);
        zero.push(-(b * mins[j]) / a);
    }
    Scales { bits, alpha, zero }
}

/// Quantize one value for column `j`: `clamp(⌊w/α + z⌉, 0, 2^bits − 1)`.
#[inline(always)]
pub fn quantize_one(w: f32, alpha: f32, zero: f32, bits: u32) -> f32 {
    let levels = (1u32 << bits) as f32 - 1.0;
    round_half_up(w / alpha + zero).clamp(0.0, levels)
}

/// Quantize a `(d_in, d_out)` matrix to unsigned codes (f32 storage, like
/// the L1 kernels — integers up to 255 are exact in f32).
pub fn quantize(w: &[f32], d_out: usize, scales: &Scales) -> Vec<f32> {
    assert_eq!(scales.d_out(), d_out);
    w.chunks_exact(d_out)
        .flat_map(|row| {
            row.iter()
                .enumerate()
                .map(|(j, &x)| quantize_one(x, scales.alpha[j], scales.zero[j], scales.bits))
        })
        .collect()
}

/// Dequantize codes back to weights: `(q − z)·α`.
pub fn dequantize(q: &[f32], d_out: usize, scales: &Scales) -> Vec<f32> {
    q.chunks_exact(d_out)
        .flat_map(|row| {
            row.iter()
                .enumerate()
                .map(|(j, &c)| (c - scales.zero[j]) * scales.alpha[j])
        })
        .collect()
}

/// Dequantize into a caller-provided buffer (hot path, no allocation).
pub fn dequantize_into(q: &[f32], d_out: usize, scales: &Scales, out: &mut [f32]) {
    assert_eq!(q.len(), out.len());
    for (qrow, orow) in q.chunks_exact(d_out).zip(out.chunks_exact_mut(d_out)) {
        for j in 0..d_out {
            orow[j] = (qrow[j] - scales.zero[j]) * scales.alpha[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (Vec<f32>, usize, usize) {
        // 3x2: column 0 spans [-1, 1], column 1 spans [0, 4]
        (vec![-1.0, 0.0, 0.0, 2.0, 1.0, 4.0], 3, 2)
    }

    #[test]
    fn scales_basic() {
        let (w, di, dd) = toy();
        let s = minmax_scales(&w, di, dd, 2);
        // col0: (1 - -1)/3, col1: (4-0)/3
        assert!((s.alpha[0] - 2.0 / 3.0).abs() < 1e-6);
        assert!((s.alpha[1] - 4.0 / 3.0).abs() < 1e-6);
        assert!((s.zero[0] - 1.5).abs() < 1e-6);
        assert_eq!(s.zero[1], 0.0);
    }

    #[test]
    fn quantize_round_trip_error_bound() {
        let (w, di, dd) = toy();
        for bits in [2, 3, 4, 6, 8] {
            let s = minmax_scales(&w, di, dd, bits);
            let q = quantize(&w, dd, &s);
            let wq = dequantize(&q, dd, &s);
            for (i, (&a, &b)) in w.iter().zip(wq.iter()).enumerate() {
                let j = i % dd;
                assert!(
                    (a - b).abs() <= s.alpha[j] / 2.0 + 1e-5,
                    "bits={bits} i={i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn codes_hit_extremes() {
        let (w, di, dd) = toy();
        let s = minmax_scales(&w, di, dd, 4);
        let q = quantize(&w, dd, &s);
        // min maps to 0, max to 15 in each column
        assert_eq!(q[0], 0.0); // -1 in col 0
        assert_eq!(q[4], 15.0); // 1 in col 0
        assert_eq!(q[1], 0.0); // 0 in col 1
        assert_eq!(q[5], 15.0); // 4 in col 1
    }

    #[test]
    fn constant_column_is_finite() {
        let w = vec![0.5; 8];
        let s = minmax_scales(&w, 4, 2, 8);
        let q = quantize(&w, 2, &s);
        let wq = dequantize(&q, 2, &s);
        assert!(wq.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn omni_clipping_halves_range() {
        let w: Vec<f32> = (0..64).map(|i| (i as f32 / 63.0) * 2.0 - 1.0).collect();
        let g = vec![0.5f32];
        let s = omni_scales(&w, 64, 1, 8, Some(&g), Some(&g));
        let q = quantize(&w, 1, &s);
        let wq = dequantize(&q, 1, &s);
        let m = wq.iter().cloned().fold(f32::MIN, f32::max);
        assert!(m <= 0.5 + 1e-4, "max {m}");
    }

    #[test]
    fn round_half_up_matches_paper() {
        assert_eq!(round_half_up(0.5), 1.0);
        assert_eq!(round_half_up(1.5), 2.0);
        assert_eq!(round_half_up(2.5), 3.0); // round-half-even would give 2
        assert_eq!(round_half_up(0.49), 0.0);
    }

    use super::super::round_half_up;
}
