//! Per-tensor int8 activation quantization — the *producer* for the
//! integer-domain GEMV path ([`crate::kernels::matvec_packed_i8_into`]).
//!
//! The i8 kernel has existed since the fused-matmul PR but nothing fed it:
//! layer activations were always f32.  This module closes the loop so a
//! forward pass can keep matrix products in the quantized domain end-to-end
//! (NestQuant / integer-inference style): symmetric per-tensor codes
//! `x ≈ q·scale` with `q ∈ [−127, 127]`, where the clip range is either the
//! tensor's absmax or a histogram-derived percentile (the bucketing of
//! [`crate::quant::histogram::code_histogram`], accumulated allocation-free)
//! that sheds outlier tails — activation distributions are heavy-tailed,
//! and one outlier otherwise wastes most of the 8-bit range.
//!
//! Non-finite inputs never panic: NaN activations quantize to 0 and a
//! NaN/zero clip range degenerates to the all-zero code vector, so a
//! poisoned batch still completes (the serve loop must survive it).

/// Largest symmetric code magnitude (`q ∈ [−ACT_QMAX, ACT_QMAX]`; −128 is
/// left unused so the range is sign-symmetric).
pub const ACT_QMAX: i32 = 127;

/// Histogram resolution used by the percentile clip (256 |x| buckets).
pub const ACT_HIST_BITS: u32 = 8;

/// How the clip range of the symmetric quantizer is chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActQuantConfig {
    /// `None` → clip at absmax (exact range, outlier-sensitive).
    /// `Some(f)` → clip at the smallest |x|-histogram bucket edge covering
    /// fraction `f` of the entries (outliers beyond it saturate).
    pub clip_fraction: Option<f32>,
    /// Pre-calibrated clip threshold: when set, the quantizer uses this
    /// range directly and never scans the tensor — the serving fast path
    /// for persisted per-layer calibration
    /// ([`crate::quant::calibration::ActCalibration`]).  Takes precedence
    /// over `clip_fraction`.
    pub fixed_clip: Option<f32>,
}

impl Default for ActQuantConfig {
    fn default() -> Self {
        ActQuantConfig::absmax()
    }
}

impl ActQuantConfig {
    /// Absmax clip — every value representable, resolution pays for tails.
    pub fn absmax() -> Self {
        ActQuantConfig {
            clip_fraction: None,
            fixed_clip: None,
        }
    }

    /// Histogram clip keeping `fraction` of the |x| mass in range
    /// (e.g. `0.999`); values beyond the clip saturate at ±[`ACT_QMAX`].
    pub fn clipped(fraction: f32) -> Self {
        ActQuantConfig {
            clip_fraction: Some(fraction),
            fixed_clip: None,
        }
    }

    /// Pre-calibrated clip: quantize against the fixed threshold `clip`
    /// (values beyond it saturate at ±[`ACT_QMAX`]) with **no per-tensor
    /// range scan** — what a loaded [`crate::quant::calibration`] file
    /// turns the per-request histogram pass into.  A non-finite or
    /// non-positive `clip` degenerates to the all-zero code vector, like
    /// an all-NaN tensor would.
    pub fn fixed(clip: f32) -> Self {
        ActQuantConfig {
            clip_fraction: None,
            fixed_clip: Some(clip),
        }
    }
}

/// A quantized activation tensor: `x[i] ≈ q[i] as f32 * scale`.
#[derive(Debug, Clone)]
pub struct QuantizedActs {
    pub q: Vec<i8>,
    pub scale: f32,
}

/// Absmax over finite entries (NaN/±inf contribute nothing — a poisoned
/// tensor must not poison the clip range).
fn finite_absmax(x: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &v in x {
        let a = v.abs();
        if a.is_finite() && a > m {
            m = a;
        }
    }
    m
}

/// Choose the clip threshold for `x` under `cfg`.
///
/// The histogram clip buckets `|x|` into `2^ACT_HIST_BITS` bins over
/// `[0, absmax]` — the same truncate-and-clamp bucketing as
/// [`crate::quant::code_histogram`], but accumulated directly into a stack
/// array (this
/// runs once per token row on the serving hot path, so no `O(n)` id buffer
/// is materialized) — and returns the upper edge of the first bin whose
/// cumulative count reaches `clip_fraction` of the entries.  Degenerate
/// inputs (empty, all-zero, all-NaN) return 0.
pub fn act_clip(x: &[f32], cfg: &ActQuantConfig) -> f32 {
    if let Some(c) = cfg.fixed_clip {
        // Calibrated threshold: no scan at all; a degenerate value yields
        // the same graceful zero-codes path as an all-NaN tensor.
        return if c.is_finite() && c > 0.0 { c } else { 0.0 };
    }
    let absmax = finite_absmax(x);
    if absmax <= 0.0 {
        return 0.0;
    }
    let frac = match cfg.clip_fraction {
        None => return absmax,
        Some(f) => f.clamp(0.0, 1.0) as f64,
    };
    const BUCKETS: usize = 1 << ACT_HIST_BITS;
    let to_bucket = (BUCKETS - 1) as f32 / absmax;
    if !to_bucket.is_finite() {
        // Subnormal-magnitude rows (absmax ≲ 7.5e-37): 255/absmax
        // overflows to +inf, every entry saturates into the top bucket,
        // and the returned edge `(i+1)/inf` degenerates to a zero-width
        // clip — an all-zero code vector for a perfectly valid constant
        // row.  The histogram can't resolve anything at this scale, so
        // the exact range is the right clip.
        return absmax;
    }
    let mut hist = [0u64; BUCKETS];
    for &v in x {
        let a = v.abs();
        // non-finite: counted in the bottom bin, never widens the clip
        let b = if a.is_finite() {
            ((a * to_bucket) as usize).min(BUCKETS - 1)
        } else {
            0
        };
        hist[b] += 1;
    }
    let total: u64 = hist.iter().sum();
    let keep = frac * total as f64;
    let mut cum = 0u64;
    for (i, &c) in hist.iter().enumerate() {
        cum += c;
        if cum as f64 >= keep {
            // True upper edge of bin i under `to_bucket` (id = ⌊|x|·to_bucket⌋),
            // so every value counted into the kept mass stays inside the clip;
            // the top bin's edge caps at absmax.
            return ((i + 1) as f32 / to_bucket).min(absmax);
        }
    }
    absmax
}

/// Quantize `x` into the caller's i8 buffer; returns the dequantization
/// `scale` (`x[i] ≈ out[i] as f32 * scale`).
///
/// Symmetric round-to-nearest with saturation at ±[`ACT_QMAX`]; NaN inputs
/// quantize to 0 (the `NaN as i8` cast saturates to 0 by Rust semantics,
/// which is exactly the graceful behavior the serve loop needs).  A
/// degenerate clip (all-zero / all-NaN tensor) yields the all-zero code
/// vector with scale 1.
pub fn quantize_acts_into(x: &[f32], cfg: &ActQuantConfig, out: &mut [i8]) -> f32 {
    assert_eq!(x.len(), out.len(), "activation buffer length mismatch");
    let clip = act_clip(x, cfg);
    if clip <= 0.0 || !clip.is_finite() {
        out.fill(0);
        return 1.0;
    }
    let scale = clip / ACT_QMAX as f32;
    let inv = 1.0 / scale;
    let lim = ACT_QMAX as f32;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = (v * inv).round().clamp(-lim, lim) as i8;
    }
    scale
}

/// Allocating convenience over [`quantize_acts_into`].
pub fn quantize_acts(x: &[f32], cfg: &ActQuantConfig) -> QuantizedActs {
    let mut q = vec![0i8; x.len()];
    let scale = quantize_acts_into(x, cfg, &mut q);
    QuantizedActs { q, scale }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absmax_roundtrip_error_bounded() {
        let x: Vec<f32> = (0..257).map(|i| (i as f32 - 128.0) / 37.0).collect();
        let qa = quantize_acts(&x, &ActQuantConfig::absmax());
        for (i, &v) in x.iter().enumerate() {
            let back = qa.q[i] as f32 * qa.scale;
            assert!(
                (v - back).abs() <= qa.scale * 0.5 + 1e-6,
                "x[{i}]={v} back={back} scale={}",
                qa.scale
            );
        }
    }

    #[test]
    fn clip_shrinks_scale_with_outlier() {
        let mut x = vec![0.1f32; 1000];
        x[500] = 100.0; // one outlier
        let full = quantize_acts(&x, &ActQuantConfig::absmax());
        let clipped = quantize_acts(&x, &ActQuantConfig::clipped(0.999));
        assert!(clipped.scale < full.scale / 10.0, "{} vs {}", clipped.scale, full.scale);
        // the outlier saturates, everything else gets real resolution
        assert_eq!(clipped.q[500], ACT_QMAX as i8);
        assert!(clipped.q[0] != 0, "inliers must not collapse to zero");
    }

    #[test]
    fn clip_fraction_one_is_absmax() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32) * 0.25 - 8.0).collect();
        let a = act_clip(&x, &ActQuantConfig::absmax());
        let b = act_clip(&x, &ActQuantConfig::clipped(1.0));
        // fraction 1.0 lands in the top bucket; its upper edge is absmax
        assert!((a - b).abs() <= a * (1.0 / 256.0) + 1e-6, "{a} vs {b}");
    }

    #[test]
    fn nan_and_inf_quantize_to_zero_without_panicking() {
        let x = vec![f32::NAN, 1.0, -1.0, f32::INFINITY, f32::NEG_INFINITY];
        let qa = quantize_acts(&x, &ActQuantConfig::absmax());
        assert_eq!(qa.q[0], 0);
        assert_eq!(qa.q[1], ACT_QMAX as i8);
        assert_eq!(qa.q[2], -(ACT_QMAX as i8));
        // infinities saturate through the clamp, never widen the clip
        assert_eq!(qa.q[3], ACT_QMAX as i8);
        assert_eq!(qa.q[4], -(ACT_QMAX as i8));
        assert!((qa.scale - 1.0 / ACT_QMAX as f32).abs() < 1e-9);
    }

    #[test]
    fn fixed_clip_skips_the_scan_and_saturates() {
        let x = vec![0.5f32, -0.25, 3.0];
        let qa = quantize_acts(&x, &ActQuantConfig::fixed(1.0));
        // scale = 1/127; 3.0 saturates at the calibrated range
        assert!((qa.scale - 1.0 / ACT_QMAX as f32).abs() < 1e-9);
        assert_eq!(qa.q[2], ACT_QMAX as i8);
        assert_eq!(qa.q[0], 64); // round(0.5·127)
        // degenerate calibrated clips degrade to zero codes, never panic
        for bad in [0.0f32, -1.0, f32::NAN, f32::INFINITY] {
            let qa = quantize_acts(&x, &ActQuantConfig::fixed(bad));
            assert!(qa.q.iter().all(|&q| q == 0), "clip {bad}");
            assert_eq!(qa.scale, 1.0);
        }
    }

    #[test]
    fn tiny_constant_rows_survive_percentile_clip() {
        // Regression: absmax small enough that 255/absmax overflows to
        // +inf used to collapse the histogram clip to a zero-width range
        // (bucket edge (i+1)/inf = 0) — valid constant rows quantized to
        // all-zero codes.  The clip must fall back to the exact range.
        for tiny in [1e-38f32, 7e-37, f32::MIN_POSITIVE] {
            let x = vec![tiny; 64];
            let cfg = ActQuantConfig::clipped(0.999);
            let clip = act_clip(&x, &cfg);
            assert_eq!(clip, tiny, "clip must be the row's absmax");
            let qa = quantize_acts(&x, &cfg);
            assert!(
                qa.q.iter().all(|&q| q == ACT_QMAX as i8),
                "constant row must hit the top code, got {:?}",
                &qa.q[..4]
            );
            // round-trip stays at the right magnitude
            let back = qa.q[0] as f32 * qa.scale;
            assert!((back - tiny).abs() <= tiny * 0.01);
        }
        // ... and ordinary magnitudes still use the histogram path.
        let mut x = vec![0.1f32; 1000];
        x[0] = 100.0;
        let clip = act_clip(&x, &ActQuantConfig::clipped(0.99));
        assert!(clip < 1.0, "outlier must still be shed: clip {clip}");
    }

    #[test]
    fn degenerate_tensors_yield_zero_codes() {
        for x in [vec![], vec![0.0f32; 8], vec![f32::NAN; 8]] {
            let qa = quantize_acts(&x, &ActQuantConfig::clipped(0.99));
            assert!(qa.q.iter().all(|&q| q == 0));
            assert_eq!(qa.scale, 1.0);
        }
    }
}
