//! Bit-packed code storage — the deployment memory model (paper §5.4).
//!
//! `PackedTensor` stores unsigned codes at 2/3/4/6/8 bits/entry in a dense
//! little-endian bitstream; `ExtraBitOverlay` stores the Eq. 8 overflow
//! bucket as a sparse index list (the paper's "single extra bit is enough
//! to capture outliers" — realized as CSR-style sparse additions, which is
//! exactly what its custom-CUDA-kernel discussion proposes).
//!
//! These types make the paper's storage accounting *real*: an int2 model
//! with 2.05 effective bits is a `PackedTensor { bits: 2 }` plus an overlay
//! holding ~0.05·n entries, and `bytes()` reports the true footprint used
//! by the serving planner.

/// Dense bit-packed unsigned integer tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedTensor {
    /// Bits per entry (1..=8).
    pub bits: u32,
    /// Number of entries.
    pub len: usize,
    /// Little-endian bitstream.
    pub data: Vec<u8>,
}

impl PackedTensor {
    /// Pack integer-valued f32 codes (as produced by [`crate::quant::quantize`]
    /// or [`crate::quant::slice_codes`] *divided down to r-bit bucket ids*).
    ///
    /// Values must lie in `[0, 2^bits)`.
    pub fn pack(codes: &[f32], bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 8, "bits out of range: {bits}");
        let max = (1u32 << bits) as f32;
        let nbits = codes.len() * bits as usize;
        let mut data = vec![0u8; nbits.div_ceil(8)];
        for (i, &c) in codes.iter().enumerate() {
            debug_assert!(
                c >= 0.0 && c < max && c.fract() == 0.0,
                "code {c} not a {bits}-bit integer"
            );
            let v = c as u32;
            let bit0 = i * bits as usize;
            for b in 0..bits as usize {
                if (v >> b) & 1 == 1 {
                    data[(bit0 + b) / 8] |= 1 << ((bit0 + b) % 8);
                }
            }
        }
        PackedTensor {
            bits,
            len: codes.len(),
            data,
        }
    }

    /// Unpack entry `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        let bits = self.bits as usize;
        let bit0 = i * bits;
        let mut v = 0u32;
        for b in 0..bits {
            let bit = bit0 + b;
            if (self.data[bit / 8] >> (bit % 8)) & 1 == 1 {
                v |= 1 << b;
            }
        }
        v
    }

    /// Unpack all entries to f32 bucket ids.
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.unpack_into(&mut out);
        out
    }

    /// Unpack into a caller buffer (hot path; specialized fast paths for
    /// the power-of-two widths dominate serving-time dequantization).
    pub fn unpack_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        match self.bits {
            8 => {
                for (o, &b) in out.iter_mut().zip(&self.data) {
                    *o = b as f32;
                }
            }
            4 => {
                for i in 0..self.len {
                    let byte = self.data[i / 2];
                    out[i] = ((byte >> ((i % 2) * 4)) & 0xF) as f32;
                }
            }
            2 => {
                for i in 0..self.len {
                    let byte = self.data[i / 4];
                    out[i] = ((byte >> ((i % 4) * 2)) & 0x3) as f32;
                }
            }
            _ => {
                for i in 0..self.len {
                    out[i] = self.get(i) as f32;
                }
            }
        }
    }

    /// True storage footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Stored bits per entry (exact, including padding waste).  Empty
    /// tensors report 0 rather than dividing by zero.
    pub fn bits_per_entry(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.bytes() as f64 * 8.0 / self.len as f64
    }
}

/// Sparse overflow overlay for Extra-Precision (Eq. 8) models: entries
/// whose sliced bucket id is `2^r` (one past the dense range).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExtraBitOverlay {
    /// Indices (into the flat tensor) of overflow entries, sorted.
    pub indices: Vec<u32>,
}

impl ExtraBitOverlay {
    /// Build from r-bit bucket ids (f32, possibly containing `2^r`).
    /// Returns the overlay and the clamped dense ids to pack.
    pub fn split(bucket_ids: &[f32], r: u32) -> (Self, Vec<f32>) {
        let top = (1u32 << r) as f32;
        let mut indices = Vec::new();
        let dense: Vec<f32> = bucket_ids
            .iter()
            .enumerate()
            .map(|(i, &b)| {
                if b >= top {
                    indices.push(i as u32);
                    top - 1.0
                } else {
                    b
                }
            })
            .collect();
        (ExtraBitOverlay { indices }, dense)
    }

    /// Re-apply overflow onto unpacked dense bucket ids.
    pub fn apply(&self, dense: &mut [f32], r: u32) {
        let top = (1u32 << r) as f32;
        for &i in &self.indices {
            dense[i as usize] = top;
        }
    }

    /// Overlay storage cost: one index per overflow entry.  The paper
    /// argues one extra *bit* per param suffices; a bitmap costs n/8 bytes,
    /// a sparse list 4·k bytes — we report whichever is smaller, as a real
    /// kernel would choose.
    pub fn bytes(&self, n: usize) -> usize {
        (self.indices.len() * 4).min(n.div_ceil(8))
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(bits: u32, n: usize) -> Vec<f32> {
        let m = 1u32 << bits;
        (0..n).map(|i| ((i as u32 * 7 + 3) % m) as f32).collect()
    }

    #[test]
    fn pack_unpack_roundtrip_all_widths() {
        for bits in [1, 2, 3, 4, 6, 8] {
            for n in [1usize, 7, 8, 63, 256] {
                let c = codes(bits, n);
                let p = PackedTensor::pack(&c, bits);
                assert_eq!(p.unpack(), c, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn storage_is_tight() {
        let p = PackedTensor::pack(&codes(2, 1024), 2);
        assert_eq!(p.bytes(), 256); // 2 bits × 1024 = 256 bytes
        let p3 = PackedTensor::pack(&codes(3, 1024), 3);
        assert_eq!(p3.bytes(), 384);
    }

    #[test]
    fn get_matches_unpack() {
        let c = codes(6, 100);
        let p = PackedTensor::pack(&c, 6);
        for i in 0..100 {
            assert_eq!(p.get(i) as f32, c[i]);
        }
    }

    #[test]
    fn overlay_split_apply_roundtrip() {
        // bucket ids for r=2 including some overflow (4)
        let ids = vec![0.0, 3.0, 4.0, 1.0, 4.0, 2.0];
        let (ov, dense) = ExtraBitOverlay::split(&ids, 2);
        assert_eq!(ov.indices, vec![2, 4]);
        assert_eq!(dense, vec![0.0, 3.0, 3.0, 1.0, 3.0, 2.0]);
        let p = PackedTensor::pack(&dense, 2);
        let mut back = p.unpack();
        ov.apply(&mut back, 2);
        assert_eq!(back, ids);
    }

    #[test]
    fn overlay_bytes_caps_at_bitmap() {
        let ids: Vec<f32> = (0..1000).map(|_| 4.0).collect(); // all overflow
        let (ov, _) = ExtraBitOverlay::split(&ids, 2);
        assert_eq!(ov.bytes(1000), 125); // bitmap wins: 1000/8
        let (ov2, _) = ExtraBitOverlay::split(&[0.0; 1000].to_vec(), 2);
        assert_eq!(ov2.bytes(1000), 0);
    }

    #[test]
    fn effective_bits_accounting() {
        // 5% overflow at r=2 → ~2.05 avg bits with the sparse-bitmap bound
        let n = 10_000;
        let ids: Vec<f32> = (0..n)
            .map(|i| if i % 20 == 0 { 4.0 } else { (i % 4) as f32 })
            .collect();
        let (ov, dense) = ExtraBitOverlay::split(&ids, 2);
        let p = PackedTensor::pack(&dense, 2);
        let total_bits = (p.bytes() + ov.bytes(n)) as f64 * 8.0 / n as f64;
        assert!(total_bits > 2.0 && total_bits < 3.3, "{total_bits}");
    }
}
