//! Bit-packed code storage — the deployment memory model (paper §5.4).
//!
//! `PackedTensor` stores unsigned codes at 2/3/4/6/8 bits/entry in a dense
//! little-endian bitstream; `ExtraBitOverlay` stores the Eq. 8 overflow
//! bucket as a sparse index list (the paper's "single extra bit is enough
//! to capture outliers" — realized as CSR-style sparse additions, which is
//! exactly what its custom-CUDA-kernel discussion proposes).
//!
//! These types make the paper's storage accounting *real*: an int2 model
//! with 2.05 effective bits is a `PackedTensor { bits: 2 }` plus an overlay
//! holding ~0.05·n entries, and `bytes()` reports the true footprint used
//! by the serving planner.
//!
//! [`BitSliceView`] is the serving-side realization of the paper's nesting:
//! int4/int2 live in the MSBs of the int8 codes, so a precision below the
//! master does not need its own payload — a view is the shared
//! (`Arc`-held) master plus `(r, extra_precision)` slice semantics, decoded
//! through the 256-entry sliced-value LUTs at consume time.  One nested
//! payload per tensor serves every r ≤ 8; [`BitSliceView::materialize`]
//! derives the standalone compact form (bit-identical to
//! `QuantizedTensor::pack_sliced`) when a consumer genuinely needs r-bit
//! storage, and [`BitSliceView::compact_bytes`] reports what that form
//! would cost — the bytes the shared view *saves*.

use std::sync::Arc;

use super::slicing::slice_code;
use crate::MASTER_BITS;

/// Dense bit-packed unsigned integer tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedTensor {
    /// Bits per entry (1..=8).
    pub bits: u32,
    /// Number of entries.
    pub len: usize,
    /// Little-endian bitstream.
    pub data: Vec<u8>,
}

impl PackedTensor {
    /// Pack integer-valued f32 codes (as produced by [`crate::quant::quantize`]
    /// or [`crate::quant::slice_codes`] *divided down to r-bit bucket ids*).
    ///
    /// Values must lie in `[0, 2^bits)`.
    pub fn pack(codes: &[f32], bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 8, "bits out of range: {bits}");
        let max = (1u32 << bits) as f32;
        let nbits = codes.len() * bits as usize;
        let mut data = vec![0u8; nbits.div_ceil(8)];
        for (i, &c) in codes.iter().enumerate() {
            debug_assert!(
                c >= 0.0 && c < max && c.fract() == 0.0,
                "code {c} not a {bits}-bit integer"
            );
            let v = c as u32;
            let bit0 = i * bits as usize;
            for b in 0..bits as usize {
                if (v >> b) & 1 == 1 {
                    data[(bit0 + b) / 8] |= 1 << ((bit0 + b) % 8);
                }
            }
        }
        PackedTensor {
            bits,
            len: codes.len(),
            data,
        }
    }

    /// Unpack entry `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        let bits = self.bits as usize;
        let bit0 = i * bits;
        let mut v = 0u32;
        for b in 0..bits {
            let bit = bit0 + b;
            if (self.data[bit / 8] >> (bit % 8)) & 1 == 1 {
                v |= 1 << b;
            }
        }
        v
    }

    /// Unpack all entries to f32 bucket ids.
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.unpack_into(&mut out);
        out
    }

    /// Unpack into a caller buffer (hot path; specialized fast paths for
    /// the power-of-two widths dominate serving-time dequantization).
    pub fn unpack_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        match self.bits {
            8 => {
                for (o, &b) in out.iter_mut().zip(&self.data) {
                    *o = b as f32;
                }
            }
            4 => {
                for i in 0..self.len {
                    let byte = self.data[i / 2];
                    out[i] = ((byte >> ((i % 2) * 4)) & 0xF) as f32;
                }
            }
            2 => {
                for i in 0..self.len {
                    let byte = self.data[i / 4];
                    out[i] = ((byte >> ((i % 4) * 2)) & 0x3) as f32;
                }
            }
            _ => {
                for i in 0..self.len {
                    out[i] = self.get(i) as f32;
                }
            }
        }
    }

    /// True storage footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Stored bits per entry (exact, including padding waste).  Empty
    /// tensors report 0 rather than dividing by zero.
    pub fn bits_per_entry(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.bytes() as f64 * 8.0 / self.len as f64
    }
}

/// Sparse overflow overlay for Extra-Precision (Eq. 8) models: entries
/// whose sliced bucket id is `2^r` (one past the dense range).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExtraBitOverlay {
    /// Indices (into the flat tensor) of overflow entries, sorted.
    pub indices: Vec<u32>,
}

impl ExtraBitOverlay {
    /// Build from r-bit bucket ids (f32, possibly containing `2^r`).
    /// Returns the overlay and the clamped dense ids to pack.
    pub fn split(bucket_ids: &[f32], r: u32) -> (Self, Vec<f32>) {
        let top = (1u32 << r) as f32;
        let mut indices = Vec::new();
        let dense: Vec<f32> = bucket_ids
            .iter()
            .enumerate()
            .map(|(i, &b)| {
                if b >= top {
                    indices.push(i as u32);
                    top - 1.0
                } else {
                    b
                }
            })
            .collect();
        (ExtraBitOverlay { indices }, dense)
    }

    /// Re-apply overflow onto unpacked dense bucket ids.
    pub fn apply(&self, dense: &mut [f32], r: u32) {
        let top = (1u32 << r) as f32;
        for &i in &self.indices {
            dense[i as usize] = top;
        }
    }

    /// Overlay storage cost: one index per overflow entry.  The paper
    /// argues one extra *bit* per param suffices; a bitmap costs n/8 bytes,
    /// a sparse list 4·k bytes — we report whichever is smaller, as a real
    /// kernel would choose.
    pub fn bytes(&self, n: usize) -> usize {
        (self.indices.len() * 4).min(n.div_ceil(8))
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
}

/// An MSB-prefix bit-slice **view** of a shared int8 master bitstream: the
/// nested payload stored once, consumed at any `bits ≤ 8`.
///
/// The view owns no code storage — `master` is the `Arc`-shared
/// [`PackedTensor`] of int8 codes (one per model tensor, shared across
/// every precision's handles) — and slicing is deferred to consume time:
/// the fused kernels map each master byte through the 256-entry
/// sliced-value LUT (`kernels::lut::slice_value_lut`), whose entries equal
/// `slice_code(q, 8, r, ep)` exactly.  Because the table *is* the Eq. 6 /
/// Eq. 8 oracle, results are bit-for-bit identical to first deriving the
/// compact r-bit payload and decoding that — including the Eq. 8 overflow
/// bucket, which the LUT subsumes (no sparse overlay needed at all).
#[derive(Debug, Clone)]
pub struct BitSliceView {
    /// The shared int8 master codes (`bits == 8`).
    pub master: Arc<PackedTensor>,
    /// View precision r (1..=8); at 8 the view is the identity.
    pub bits: u32,
    /// Eq. 8 semantics: no clamp, overflow bucket `2^r` included.
    pub extra_precision: bool,
}

impl BitSliceView {
    pub fn new(master: Arc<PackedTensor>, bits: u32, extra_precision: bool) -> Self {
        assert_eq!(
            master.bits, MASTER_BITS,
            "bit-slice views slice the int8 master, got a {}-bit source",
            master.bits
        );
        assert!(bits >= 1 && bits <= MASTER_BITS, "bits out of range: {bits}");
        BitSliceView {
            master,
            bits,
            extra_precision,
        }
    }

    /// Entries in the viewed tensor.
    pub fn len(&self) -> usize {
        self.master.len
    }

    pub fn is_empty(&self) -> bool {
        self.master.len == 0
    }

    /// Derive the standalone compact payload this view represents: r-bit
    /// sliced bucket ids plus (under Eq. 8) the sparse overflow overlay —
    /// bit-identical to `QuantizedTensor::pack_sliced` on the same master.
    /// One pass over the master; the view itself stays untouched.
    pub fn materialize(&self) -> (PackedTensor, ExtraBitOverlay) {
        let step = (1u32 << (MASTER_BITS - self.bits)) as f32;
        let ids: Vec<f32> = self
            .master
            .unpack()
            .iter()
            .map(|&q| slice_code(q, MASTER_BITS, self.bits, self.extra_precision) / step)
            .collect();
        if self.extra_precision {
            let (overlay, dense) = ExtraBitOverlay::split(&ids, self.bits);
            (PackedTensor::pack(&dense, self.bits), overlay)
        } else {
            (PackedTensor::pack(&ids, self.bits), ExtraBitOverlay::default())
        }
    }

    /// Bytes a standalone compact r-bit payload of this tensor would
    /// occupy (codes + Eq. 8 overlay) — what per-precision paging would
    /// page in, i.e. the bytes the shared nested payload saves.  Counting
    /// pass only; nothing is packed.
    pub fn compact_bytes(&self) -> usize {
        let code_bytes = (self.master.len * self.bits as usize).div_ceil(8);
        if !self.extra_precision || self.bits == MASTER_BITS {
            return code_bytes;
        }
        // Overflow census through the same scalar oracle the LUT is built
        // from: a master code q overflows iff its sliced bucket id is 2^r.
        let step = (1u32 << (MASTER_BITS - self.bits)) as f32;
        let top = (1u32 << self.bits) as f32;
        let mut overflows = [false; 256];
        for (q, o) in overflows.iter_mut().enumerate() {
            *o = slice_code(q as f32, MASTER_BITS, self.bits, true) / step >= top;
        }
        // master is 8-bit: one byte per entry, so data IS the code stream
        let k = self
            .master
            .data
            .iter()
            .filter(|&&b| overflows[b as usize])
            .count();
        code_bytes + (k * 4).min(self.master.len.div_ceil(8))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(bits: u32, n: usize) -> Vec<f32> {
        let m = 1u32 << bits;
        (0..n).map(|i| ((i as u32 * 7 + 3) % m) as f32).collect()
    }

    #[test]
    fn pack_unpack_roundtrip_all_widths() {
        for bits in [1, 2, 3, 4, 6, 8] {
            for n in [1usize, 7, 8, 63, 256] {
                let c = codes(bits, n);
                let p = PackedTensor::pack(&c, bits);
                assert_eq!(p.unpack(), c, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn storage_is_tight() {
        let p = PackedTensor::pack(&codes(2, 1024), 2);
        assert_eq!(p.bytes(), 256); // 2 bits × 1024 = 256 bytes
        let p3 = PackedTensor::pack(&codes(3, 1024), 3);
        assert_eq!(p3.bytes(), 384);
    }

    #[test]
    fn get_matches_unpack() {
        let c = codes(6, 100);
        let p = PackedTensor::pack(&c, 6);
        for i in 0..100 {
            assert_eq!(p.get(i) as f32, c[i]);
        }
    }

    #[test]
    fn overlay_split_apply_roundtrip() {
        // bucket ids for r=2 including some overflow (4)
        let ids = vec![0.0, 3.0, 4.0, 1.0, 4.0, 2.0];
        let (ov, dense) = ExtraBitOverlay::split(&ids, 2);
        assert_eq!(ov.indices, vec![2, 4]);
        assert_eq!(dense, vec![0.0, 3.0, 3.0, 1.0, 3.0, 2.0]);
        let p = PackedTensor::pack(&dense, 2);
        let mut back = p.unpack();
        ov.apply(&mut back, 2);
        assert_eq!(back, ids);
    }

    #[test]
    fn overlay_bytes_caps_at_bitmap() {
        let ids: Vec<f32> = (0..1000).map(|_| 4.0).collect(); // all overflow
        let (ov, _) = ExtraBitOverlay::split(&ids, 2);
        assert_eq!(ov.bytes(1000), 125); // bitmap wins: 1000/8
        let (ov2, _) = ExtraBitOverlay::split(&[0.0; 1000].to_vec(), 2);
        assert_eq!(ov2.bytes(1000), 0);
    }

    #[test]
    fn view_materialize_matches_direct_slicing() {
        let q: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let master = Arc::new(PackedTensor::pack(&q, 8));
        for bits in [1u32, 2, 3, 4, 6, 8] {
            for ep in [false, true] {
                let view = BitSliceView::new(master.clone(), bits, ep);
                let (packed, overlay) = view.materialize();
                let step = (1u32 << (8 - bits)) as f32;
                let ids: Vec<f32> = q
                    .iter()
                    .map(|&x| slice_code(x, 8, bits, ep) / step)
                    .collect();
                let (want_ov, want_dense) = if ep {
                    ExtraBitOverlay::split(&ids, bits)
                } else {
                    (ExtraBitOverlay::default(), ids)
                };
                assert_eq!(packed, PackedTensor::pack(&want_dense, bits), "bits={bits} ep={ep}");
                assert_eq!(overlay, want_ov, "bits={bits} ep={ep}");
            }
        }
    }

    #[test]
    fn view_compact_bytes_match_materialized_payload() {
        let q: Vec<f32> = (0..1000).map(|i| ((i * 13 + 7) % 256) as f32).collect();
        let master = Arc::new(PackedTensor::pack(&q, 8));
        for bits in [1u32, 2, 3, 4, 6, 8] {
            for ep in [false, true] {
                let view = BitSliceView::new(master.clone(), bits, ep);
                let (packed, overlay) = view.materialize();
                assert_eq!(
                    view.compact_bytes(),
                    packed.bytes() + overlay.bytes(view.len()),
                    "bits={bits} ep={ep}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "int8 master")]
    fn view_rejects_non_master_source() {
        let p = Arc::new(PackedTensor::pack(&[0.0, 1.0, 2.0, 3.0], 2));
        let _ = BitSliceView::new(p, 2, false);
    }

    #[test]
    fn effective_bits_accounting() {
        // 5% overflow at r=2 → ~2.05 avg bits with the sparse-bitmap bound
        let n = 10_000;
        let ids: Vec<f32> = (0..n)
            .map(|i| if i % 20 == 0 { 4.0 } else { (i % 4) as f32 })
            .collect();
        let (ov, dense) = ExtraBitOverlay::split(&ids, 2);
        let p = PackedTensor::pack(&dense, 2);
        let total_bits = (p.bytes() + ov.bytes(n)) as f64 * 8.0 / n as f64;
        assert!(total_bits > 2.0 && total_bits < 3.3, "{total_bits}");
    }
}
