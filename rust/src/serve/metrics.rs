//! Serving telemetry: latency percentiles, throughput, per-precision mix,
//! weight-build latencies, and the packed-paging counters — per-precision
//! matmul/compute timings and weight **bytes touched**, the number the
//! packed data flow exists to shrink (2–8× fewer bytes at low bits).

use std::collections::BTreeMap;
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    /// When the first scheduler round ran — the denominator epoch for
    /// [`Metrics::rounds_per_sec`].  Measuring from boot instead diluted
    /// the rate with however long the server sat idle before traffic.
    first_round: Option<Instant>,
    latencies_ms: Vec<f64>,
    per_bits: BTreeMap<u32, u64>,
    batch_sizes: Vec<usize>,
    /// Dense (warm) weight-set builds: precision → (count, total ms).
    /// Warm builds happen at boot; a dense lazy build would show up as a
    /// one-off latency cliff, so the report breaks them out per precision.
    materialize_ms: BTreeMap<u32, (u64, f64)>,
    /// Paged (lazy) payload builds: precision → (count, payload bytes,
    /// total ms).  These replace dense lazy builds: the bytes recorded are
    /// r-bit payload bytes, not int8 masters or f32 weight sets.
    page_ins: BTreeMap<u32, (u64, u64, f64)>,
    /// Page-in bytes **avoided** by the nested handle store: precision →
    /// compact per-r payload bytes a non-nested build would have paged for
    /// a precision that instead arrived as a zero-copy view of the already
    /// resident int8 masters ([`crate::serve::weights::WeightStore`]).
    page_in_saved: BTreeMap<u32, u64>,
    /// Per-precision matmul/decode work: precision → (ops, total ms,
    /// weight bytes touched).  Fed by batch execution (compute time +
    /// whatever weight bytes the batch had to read: payload bytes on the
    /// packed path, 4·n on a dense f32 path).
    matmul_ms: BTreeMap<u32, (u64, f64, u64)>,
    /// Prefill passes on the decode path: precision → (count, total ms,
    /// prompt tokens).  The O(t²) cost a sequence pays exactly once.
    prefill_ms: BTreeMap<u32, (u64, f64, u64)>,
    /// KV-cached decode steps: precision → (steps, total ms).  The O(n)
    /// per-token cost the decode engine exists to reach — the report pairs
    /// it with prefill so the prefill-vs-step gap is visible per precision.
    /// Under the scheduler each entry is a per-member share of its round,
    /// so this line is directly comparable with solo per-session stepping
    /// (the batched-vs-solo step latency the rounds exist to shrink).
    decode_step_ms: BTreeMap<u32, (u64, f64)>,
    /// Raw per-step decode latency samples, per precision — the
    /// distribution behind [`Metrics::decode_percentile`].  Each sample is
    /// the cost of ONE step (a member's share of its round), never a
    /// stream-age figure: recording `enq.elapsed()` here once made decode
    /// percentiles climb with stream lifetime instead of step cost.
    decode_lat: BTreeMap<u32, Vec<f64>>,
    /// Raw time-to-first-token samples, per precision: submit → first
    /// sampled token, recorded once per stream at prefill
    /// ([`crate::serve::Scheduler`]'s stream start).  First-class because
    /// the SLO report needs TTFT percentiles split from per-step decode
    /// latency — folding first-token cost into the prefill/decode lines
    /// hid the number a newly arrived request actually waits.
    ttft: BTreeMap<u32, Vec<f64>>,
    /// Self-speculative rounds: target precision → (rounds, drafted,
    /// accepted, emitted).  `accepted / drafted` is the draft accept rate
    /// (how often the low-bit MSB-prefix view agrees with its own int8
    /// payload); `emitted / rounds` is tokens per round, the speculation
    /// speedup over plain decode's fixed 1 token/round.
    spec: BTreeMap<u32, (u64, u64, u64, u64)>,
    /// Scheduler **step rounds**: precision → (rounds, member-steps, total
    /// ms, weight bytes streamed).  One round = one blocked fused GEMM
    /// sweep per layer across every live session of the precision group —
    /// the weight bytes here grow once per ROUND, not once per session,
    /// which is the continuous-batching win the counters exist to prove
    /// (`member-steps / rounds` is the mean round occupancy).
    round_ms: BTreeMap<u32, (u64, u64, f64, u64)>,
    /// Resident KV-cache bytes across live decode sessions (gauge, set by
    /// the worker after every step round).  With the paged pool this is
    /// the pool's checked-out bytes — shared CoW pages count once.
    kv_bytes: u64,
    /// KV page-pool gauges: resident pages, bytes deduplicated by
    /// copy-on-write prefix sharing (each shared page's size counted once
    /// per *extra* mapping), and cumulative CoW breaks (writes into a
    /// shared page that forced a private copy).
    kv_pool: (u64, u64, u64),
    /// Elastic precision shifts applied (downshifts, upshifts).
    shifts: (u64, u64),
    /// Sessions + queued requests moved by shifts.
    shift_moved: u64,
    /// Weight bytes a shift did NOT have to page because the destination
    /// precision is an MSB-prefix view of resident masters (the compact
    /// per-r payload a non-nested store would stream before serving the
    /// shifted group).
    shift_saved_bytes: u64,
    /// Destination-group live occupancy observed right after each shift:
    /// (shifts observed, summed occupancy) → mean post-shift occupancy.
    shift_occupancy: (u64, u64),
    pub requests: u64,
    pub batches: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            start: Instant::now(),
            first_round: None,
            latencies_ms: Vec::new(),
            per_bits: BTreeMap::new(),
            batch_sizes: Vec::new(),
            materialize_ms: BTreeMap::new(),
            page_ins: BTreeMap::new(),
            page_in_saved: BTreeMap::new(),
            matmul_ms: BTreeMap::new(),
            prefill_ms: BTreeMap::new(),
            decode_step_ms: BTreeMap::new(),
            decode_lat: BTreeMap::new(),
            ttft: BTreeMap::new(),
            spec: BTreeMap::new(),
            round_ms: BTreeMap::new(),
            kv_bytes: 0,
            kv_pool: (0, 0, 0),
            shifts: (0, 0),
            shift_moved: 0,
            shift_saved_bytes: 0,
            shift_occupancy: (0, 0),
            requests: 0,
            batches: 0,
        }
    }
}

impl Metrics {
    pub fn record(&mut self, latency_ms: f64, bits: u32, batch_size: usize) {
        self.latencies_ms.push(latency_ms);
        *self.per_bits.entry(bits).or_default() += 1;
        self.requests += 1;
        if batch_size > 0 {
            self.batch_sizes.push(batch_size);
        }
    }

    /// One batch executed at `bits`: compute time plus the weight bytes the
    /// execution touched (per-precision matmul timing + bytes counter).
    pub fn record_batch(&mut self, bits: u32, compute_ms: f64, weight_bytes: u64) {
        self.batches += 1;
        let e = self.matmul_ms.entry(bits).or_insert((0, 0.0, 0));
        e.0 += 1;
        e.1 += compute_ms;
        e.2 += weight_bytes;
    }

    /// One dense (warm) weight-set materialization completed.
    pub fn record_materialize(&mut self, bits: u32, ms: f64) {
        let e = self.materialize_ms.entry(bits).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += ms;
    }

    /// One lazy build paged in `payload_bytes` of r-bit weights.
    pub fn record_page_in(&mut self, bits: u32, payload_bytes: u64, ms: f64) {
        let e = self.page_ins.entry(bits).or_insert((0, 0, 0.0));
        e.0 += 1;
        e.1 += payload_bytes;
        e.2 += ms;
    }

    /// A precision arrived as a nested view of already-resident masters:
    /// `bytes` is the compact per-r payload a non-nested build would have
    /// paged in instead.
    pub fn record_page_in_saved(&mut self, bits: u32, bytes: u64) {
        *self.page_in_saved.entry(bits).or_default() += bytes;
    }

    /// One decode-path prefill completed: `tokens` prompt positions ran
    /// through the batched forward in `ms`.
    pub fn record_prefill(&mut self, bits: u32, ms: f64, tokens: u64) {
        let e = self.prefill_ms.entry(bits).or_insert((0, 0.0, 0));
        e.0 += 1;
        e.1 += ms;
        e.2 += tokens;
    }

    /// One KV-cached decode step completed.
    pub fn record_decode_step(&mut self, bits: u32, ms: f64) {
        let e = self.decode_step_ms.entry(bits).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += ms;
        self.decode_lat.entry(bits).or_default().push(ms);
    }

    /// Percentile of per-step decode latency at `bits` (0 if no steps ran).
    /// Step samples, not stream ages: a long-lived stream contributes many
    /// small samples, so its p50 stays flat as it ages.
    pub fn decode_percentile(&self, bits: u32, p: f64) -> f64 {
        Self::sample_percentile(self.decode_lat.get(&bits), p)
    }

    /// One stream's time-to-first-token at `bits`: submit → first sampled
    /// token, in milliseconds.  Recorded exactly once per stream.
    pub fn record_ttft(&mut self, bits: u32, ms: f64) {
        self.ttft.entry(bits).or_default().push(ms);
    }

    /// Percentile of time-to-first-token at `bits` (0 if no stream started).
    pub fn ttft_percentile(&self, bits: u32, p: f64) -> f64 {
        Self::sample_percentile(self.ttft.get(&bits), p)
    }

    /// Streams that reached their first token at `bits`.
    pub fn ttft_count(&self, bits: u32) -> u64 {
        self.ttft.get(&bits).map_or(0, |v| v.len() as u64)
    }

    fn sample_percentile(samples: Option<&Vec<f64>>, p: f64) -> f64 {
        let Some(samples) = samples else {
            return 0.0;
        };
        if samples.is_empty() {
            return 0.0;
        }
        let mut v = samples.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((v.len() as f64 - 1.0) * p / 100.0).round() as usize;
        v[idx]
    }

    /// Fold another worker's counters into this one — fleet aggregation
    /// for the multi-worker front end ([`crate::serve::frontend`]), where
    /// every worker owns a private `Metrics` (no lock on the hot path) and
    /// the fleet report is the merge.  Cumulative counters and raw sample
    /// vectors add; gauges (`kv_bytes`, `kv_pool`) take the elementwise
    /// max — with a shared page pool every worker gauges the same figure,
    /// so max = latest-observed, never a double count; epochs take the
    /// earliest so rates stay denominated over real wall time.
    pub fn merge(&mut self, other: &Metrics) {
        self.start = self.start.min(other.start);
        self.first_round = match (self.first_round, other.first_round) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.latencies_ms.extend_from_slice(&other.latencies_ms);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        for (b, n) in &other.per_bits {
            *self.per_bits.entry(*b).or_default() += n;
        }
        for (b, (n, ms)) in &other.materialize_ms {
            let e = self.materialize_ms.entry(*b).or_insert((0, 0.0));
            e.0 += n;
            e.1 += ms;
        }
        for (b, (n, bytes, ms)) in &other.page_ins {
            let e = self.page_ins.entry(*b).or_insert((0, 0, 0.0));
            e.0 += n;
            e.1 += bytes;
            e.2 += ms;
        }
        for (b, bytes) in &other.page_in_saved {
            *self.page_in_saved.entry(*b).or_default() += bytes;
        }
        for (b, (n, ms, bytes)) in &other.matmul_ms {
            let e = self.matmul_ms.entry(*b).or_insert((0, 0.0, 0));
            e.0 += n;
            e.1 += ms;
            e.2 += bytes;
        }
        for (b, (n, ms, toks)) in &other.prefill_ms {
            let e = self.prefill_ms.entry(*b).or_insert((0, 0.0, 0));
            e.0 += n;
            e.1 += ms;
            e.2 += toks;
        }
        for (b, (n, ms)) in &other.decode_step_ms {
            let e = self.decode_step_ms.entry(*b).or_insert((0, 0.0));
            e.0 += n;
            e.1 += ms;
        }
        for (b, v) in &other.decode_lat {
            self.decode_lat.entry(*b).or_default().extend_from_slice(v);
        }
        for (b, v) in &other.ttft {
            self.ttft.entry(*b).or_default().extend_from_slice(v);
        }
        for (b, (r, d, a, e0)) in &other.spec {
            let e = self.spec.entry(*b).or_insert((0, 0, 0, 0));
            e.0 += r;
            e.1 += d;
            e.2 += a;
            e.3 += e0;
        }
        for (b, (r, m, ms, bytes)) in &other.round_ms {
            let e = self.round_ms.entry(*b).or_insert((0, 0, 0.0, 0));
            e.0 += r;
            e.1 += m;
            e.2 += ms;
            e.3 += bytes;
        }
        self.kv_bytes = self.kv_bytes.max(other.kv_bytes);
        self.kv_pool = (
            self.kv_pool.0.max(other.kv_pool.0),
            self.kv_pool.1.max(other.kv_pool.1),
            self.kv_pool.2.max(other.kv_pool.2),
        );
        self.shifts.0 += other.shifts.0;
        self.shifts.1 += other.shifts.1;
        self.shift_moved += other.shift_moved;
        self.shift_saved_bytes += other.shift_saved_bytes;
        self.shift_occupancy.0 += other.shift_occupancy.0;
        self.shift_occupancy.1 += other.shift_occupancy.1;
        self.requests += other.requests;
        self.batches += other.batches;
    }

    /// One self-speculative round at target precision `bits`: the draft
    /// rung proposed `drafted` tokens (k−1 per member, summed), the target
    /// accepted `accepted` of them, and `emitted` tokens reached streams
    /// (accepted + one target pick per member).
    pub fn record_spec_round(&mut self, bits: u32, drafted: u64, accepted: u64, emitted: u64) {
        let e = self.spec.entry(bits).or_insert((0, 0, 0, 0));
        e.0 += 1;
        e.1 += drafted;
        e.2 += accepted;
        e.3 += emitted;
    }

    /// Speculative rounds run at target precision `bits`.
    pub fn spec_rounds(&self, bits: u32) -> u64 {
        self.spec.get(&bits).map_or(0, |e| e.0)
    }

    /// Draft accept rate at target precision `bits` (0 if nothing drafted):
    /// the fraction of low-bit draft proposals the full payload agreed with.
    pub fn spec_accept_rate(&self, bits: u32) -> f64 {
        match self.spec.get(&bits) {
            Some((_, d, a, _)) if *d > 0 => *a as f64 / *d as f64,
            _ => 0.0,
        }
    }

    /// Tokens emitted per speculative round at `bits` (plain decode = 1.0;
    /// anything above is the speculation win).  0 if no rounds ran.
    pub fn spec_tokens_per_round(&self, bits: u32) -> f64 {
        match self.spec.get(&bits) {
            Some((r, _, _, e)) if *r > 0 => *e as f64 / *r as f64,
            _ => 0.0,
        }
    }

    /// Tokens emitted by speculative rounds at `bits`.
    pub fn spec_emitted(&self, bits: u32) -> u64 {
        self.spec.get(&bits).map_or(0, |e| e.3)
    }

    /// One scheduler step round completed at `bits`: `members` sessions
    /// advanced one token through a single blocked-GEMM sweep that
    /// streamed `weight_bytes` of payload (once for the whole round).
    pub fn record_round(&mut self, bits: u32, members: usize, ms: f64, weight_bytes: u64) {
        self.first_round.get_or_insert_with(Instant::now);
        let e = self.round_ms.entry(bits).or_insert((0, 0, 0.0, 0));
        e.0 += 1;
        e.1 += members as u64;
        e.2 += ms;
        e.3 += weight_bytes;
    }

    /// Step rounds executed at `bits` (0 if none).
    pub fn rounds(&self, bits: u32) -> u64 {
        self.round_ms.get(&bits).map_or(0, |e| e.0)
    }

    /// Member-steps executed inside step rounds at `bits`.
    pub fn round_member_steps(&self, bits: u32) -> u64 {
        self.round_ms.get(&bits).map_or(0, |e| e.1)
    }

    /// Weight bytes streamed by step rounds at `bits` — grows once per
    /// round, NOT once per member (the continuous-batching contract).
    pub fn round_weight_bytes(&self, bits: u32) -> u64 {
        self.round_ms.get(&bits).map_or(0, |e| e.3)
    }

    /// Mean sessions per step round at `bits` (0 if no rounds ran).
    pub fn mean_round_occupancy(&self, bits: u32) -> f64 {
        match self.round_ms.get(&bits) {
            Some((r, m, _, _)) if *r > 0 => *m as f64 / *r as f64,
            _ => 0.0,
        }
    }

    /// Step rounds per second across all precisions, measured from the
    /// FIRST round — not from boot, which would dilute the rate with idle
    /// time before any traffic arrived.  0 until a round runs.
    pub fn rounds_per_sec(&self) -> f64 {
        let Some(first) = self.first_round else {
            return 0.0;
        };
        let total: u64 = self.round_ms.values().map(|e| e.0).sum();
        total as f64 / first.elapsed().as_secs_f64().max(1e-9)
    }

    /// One elastic precision shift applied: `moved` sessions + queued
    /// requests changed groups, `saved_bytes` of per-r payload did NOT page
    /// thanks to the nested views, and the destination group holds
    /// `post_occupancy` live members after the move.
    pub fn record_shift(&mut self, down: bool, moved: u64, saved_bytes: u64, post_occupancy: u64) {
        if down {
            self.shifts.0 += 1;
        } else {
            self.shifts.1 += 1;
        }
        self.shift_moved += moved;
        self.shift_saved_bytes += saved_bytes;
        self.shift_occupancy.0 += 1;
        self.shift_occupancy.1 += post_occupancy;
    }

    /// Elastic downshifts applied.
    pub fn shifts_down(&self) -> u64 {
        self.shifts.0
    }

    /// Elastic upshifts applied.
    pub fn shifts_up(&self) -> u64 {
        self.shifts.1
    }

    /// Sessions + queued requests moved across all shifts.
    pub fn shift_moved(&self) -> u64 {
        self.shift_moved
    }

    /// Weight bytes shifts avoided paging (nested views vs per-r payloads).
    pub fn shift_saved_bytes(&self) -> u64 {
        self.shift_saved_bytes
    }

    /// Mean destination-group live occupancy right after a shift.
    pub fn mean_post_shift_occupancy(&self) -> f64 {
        match self.shift_occupancy {
            (0, _) => 0.0,
            (n, sum) => sum as f64 / n as f64,
        }
    }

    /// Update the resident KV-cache gauge (bytes across live sessions).
    pub fn set_kv_bytes(&mut self, bytes: u64) {
        self.kv_bytes = bytes;
    }

    pub fn kv_bytes(&self) -> u64 {
        self.kv_bytes
    }

    /// Update the page-pool gauges (resident pages, bytes saved by CoW
    /// prefix sharing, cumulative CoW breaks).
    pub fn set_kv_pool(&mut self, pages: u64, shared_bytes: u64, cow_breaks: u64) {
        self.kv_pool = (pages, shared_bytes, cow_breaks);
    }

    /// Resident KV pages in the pool.
    pub fn kv_pages(&self) -> u64 {
        self.kv_pool.0
    }

    /// Bytes deduplicated by copy-on-write prefix sharing.
    pub fn kv_shared_bytes(&self) -> u64 {
        self.kv_pool.1
    }

    /// Cumulative copy-on-write breaks (private copies of shared pages).
    pub fn kv_cow_breaks(&self) -> u64 {
        self.kv_pool.2
    }

    /// Decode steps executed at `bits` (0 if none).
    pub fn decode_steps(&self, bits: u32) -> u64 {
        self.decode_step_ms.get(&bits).map_or(0, |e| e.0)
    }

    /// Prefill passes executed at `bits` (0 if none).
    pub fn prefills(&self, bits: u32) -> u64 {
        self.prefill_ms.get(&bits).map_or(0, |e| e.0)
    }

    /// Total payload bytes paged in at `bits` (0 if never paged).
    pub fn page_in_bytes(&self, bits: u32) -> u64 {
        self.page_ins.get(&bits).map_or(0, |e| e.1)
    }

    /// Page-in events recorded at `bits` (0 if never paged).  A precision
    /// serving both the PJRT and host paths must still count exactly one.
    pub fn page_in_count(&self, bits: u32) -> u64 {
        self.page_ins.get(&bits).map_or(0, |e| e.0)
    }

    /// Page-in bytes avoided at `bits` by the nested handle store (0 if the
    /// precision was the first paged in, or was never paged).
    pub fn page_in_saved_bytes(&self, bits: u32) -> u64 {
        self.page_in_saved.get(&bits).copied().unwrap_or(0)
    }

    /// Total weight bytes touched by batch executions at `bits`.
    pub fn weight_bytes_touched(&self, bits: u32) -> u64 {
        self.matmul_ms.get(&bits).map_or(0, |e| e.2)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        // total_cmp: a NaN latency (clock skew, poisoned batch) sorts to the
        // end instead of panicking the worker mid-report.
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((v.len() as f64 - 1.0) * p / 100.0).round() as usize;
        v[idx]
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64().max(1e-9);
        self.requests as f64 / secs
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn report(&self) -> String {
        let mix: Vec<String> = self
            .per_bits
            .iter()
            .map(|(b, n)| format!("int{b}:{n}"))
            .collect();
        let builds: Vec<String> = self
            .materialize_ms
            .iter()
            .map(|(b, (n, ms))| format!("int{b}:{n}x{:.1}ms", ms / (*n).max(1) as f64))
            .collect();
        // Every `{n}x...` segment reports PER-EVENT means — mixing a mean
        // ms with a cumulative bytes/tokens figure in the same slot read as
        // if both were per-event and overstated the tail entries.
        let paged: Vec<String> = self
            .page_ins
            .iter()
            .map(|(b, (n, bytes, ms))| {
                format!(
                    "int{b}:{n}x{}B/{:.1}ms",
                    bytes / (*n).max(1),
                    ms / (*n).max(1) as f64
                )
            })
            .collect();
        let matmul: Vec<String> = self
            .matmul_ms
            .iter()
            .map(|(b, (n, ms, bytes))| {
                format!(
                    "int{b}:{n}x{:.2}ms/{}B",
                    ms / (*n).max(1) as f64,
                    bytes / (*n).max(1)
                )
            })
            .collect();
        let prefill: Vec<String> = self
            .prefill_ms
            .iter()
            .map(|(b, (n, ms, toks))| {
                format!(
                    "int{b}:{n}x{:.2}ms/{}tok",
                    ms / (*n).max(1) as f64,
                    toks / (*n).max(1)
                )
            })
            .collect();
        let decode: Vec<String> = self
            .decode_step_ms
            .iter()
            .map(|(b, (n, ms))| format!("int{b}:{n}x{:.3}ms", ms / (*n).max(1) as f64))
            .collect();
        let ttft: Vec<String> = self
            .ttft
            .iter()
            .map(|(b, v)| {
                format!(
                    "int{b}:{}x p50:{:.2}ms p99:{:.2}ms",
                    v.len(),
                    Self::sample_percentile(Some(v), 50.0),
                    Self::sample_percentile(Some(v), 99.0)
                )
            })
            .collect();
        let rounds: Vec<String> = self
            .round_ms
            .iter()
            .map(|(b, (r, m, ms, bytes))| {
                format!(
                    "int{b}:{r}x{:.1}occ/{:.3}ms/{}B",
                    *m as f64 / (*r).max(1) as f64,
                    ms / (*r).max(1) as f64,
                    bytes / (*r).max(1)
                )
            })
            .collect();
        let spec: Vec<String> = self
            .spec
            .iter()
            .map(|(b, (r, d, a, e))| {
                format!(
                    "int{b}:{r}x acc:{:.2} tok/rnd:{:.2}",
                    if *d > 0 { *a as f64 / *d as f64 } else { 0.0 },
                    *e as f64 / (*r).max(1) as f64
                )
            })
            .collect();
        format!(
            "requests={} batches={} p50={:.2}ms p99={:.2}ms throughput={:.1} req/s mean_batch={:.1} mix=[{}] weight_builds=[{}] paged=[{}] matmul=[{}] prefill=[{}] decode=[{}] ttft=[{}] rounds=[{}] rounds_per_s={:.1} kv_bytes={} shifts=[down:{} up:{} moved:{} saved:{}B occ:{:.1}] spec=[{}] kv=[pages:{} shared:{}B cow:{}]",
            self.requests,
            self.batches,
            self.percentile(50.0),
            self.percentile(99.0),
            self.throughput_rps(),
            self.mean_batch_size(),
            mix.join(" "),
            builds.join(" "),
            paged.join(" "),
            matmul.join(" "),
            prefill.join(" "),
            decode.join(" "),
            ttft.join(" "),
            rounds.join(" "),
            self.rounds_per_sec(),
            self.kv_bytes,
            self.shifts.0,
            self.shifts.1,
            self.shift_moved,
            self.shift_saved_bytes,
            self.mean_post_shift_occupancy(),
            spec.join(" "),
            self.kv_pool.0,
            self.kv_pool.1,
            self.kv_pool.2
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_survives_nan_latency() {
        let mut m = Metrics::default();
        m.record(1.0, 4, 1);
        m.record(f64::NAN, 4, 1);
        m.record(3.0, 4, 1);
        // NaN sorts last under total order — p50 is finite, nothing panics
        assert_eq!(m.percentile(50.0), 3.0);
        assert!(m.percentile(100.0).is_nan());
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for i in 0..100 {
            m.record(i as f64, 4, 1);
        }
        assert!(m.percentile(50.0) <= m.percentile(99.0));
        assert_eq!(m.requests, 100);
    }

    #[test]
    fn report_breaks_out_weight_builds() {
        let mut m = Metrics::default();
        m.record_materialize(2, 4.0);
        m.record_materialize(2, 2.0);
        m.record_materialize(8, 1.0);
        let r = m.report();
        assert!(r.contains("int2:2x3.0ms"), "{r}");
        assert!(r.contains("int8:1x1.0ms"), "{r}");
    }

    #[test]
    fn report_contains_mix() {
        let mut m = Metrics::default();
        m.record(1.0, 2, 4);
        m.record(2.0, 8, 4);
        let r = m.report();
        assert!(r.contains("int2:1") && r.contains("int8:1"));
    }

    #[test]
    fn prefill_decode_and_kv_counters() {
        let mut m = Metrics::default();
        m.record_prefill(4, 2.0, 16);
        m.record_prefill(4, 4.0, 16);
        m.record_decode_step(4, 0.25);
        m.record_decode_step(4, 0.75);
        m.record_decode_step(2, 0.1);
        m.set_kv_bytes(4096);
        assert_eq!(m.prefills(4), 2);
        assert_eq!(m.prefills(8), 0);
        assert_eq!(m.decode_steps(4), 2);
        assert_eq!(m.decode_steps(2), 1);
        assert_eq!(m.kv_bytes(), 4096);
        let r = m.report();
        // per-event mean tokens (32 total / 2 prefills), not the cumulative
        assert!(r.contains("prefill=[int4:2x3.00ms/16tok]"), "{r}");
        assert!(r.contains("int4:2x0.500ms"), "{r}");
        assert!(r.contains("kv_bytes=4096"), "{r}");
    }

    #[test]
    fn kv_pool_gauges_surface_in_the_report() {
        let mut m = Metrics::default();
        assert_eq!((m.kv_pages(), m.kv_shared_bytes(), m.kv_cow_breaks()), (0, 0, 0));
        m.set_kv_pool(7, 6144, 2);
        assert_eq!(m.kv_pages(), 7);
        assert_eq!(m.kv_shared_bytes(), 6144);
        assert_eq!(m.kv_cow_breaks(), 2);
        let r = m.report();
        assert!(r.contains("kv=[pages:7 shared:6144B cow:2]"), "{r}");
    }

    #[test]
    fn round_counters_track_occupancy_and_bytes_per_round() {
        let mut m = Metrics::default();
        // 2 rounds at int4: 3 + 1 members, 100B of payload each round
        m.record_round(4, 3, 0.6, 100);
        m.record_round(4, 1, 0.2, 100);
        m.record_round(2, 2, 0.5, 40);
        assert_eq!(m.rounds(4), 2);
        assert_eq!(m.rounds(8), 0);
        assert_eq!(m.round_member_steps(4), 4);
        assert_eq!(m.mean_round_occupancy(4), 2.0);
        assert_eq!(m.mean_round_occupancy(8), 0.0);
        // bytes grow once per ROUND, not once per member
        assert_eq!(m.round_weight_bytes(4), 200);
        assert_eq!(m.round_weight_bytes(2), 40);
        assert!(m.rounds_per_sec() > 0.0);
        let r = m.report();
        assert!(r.contains("rounds=[int2:1x2.0occ"), "{r}");
        // per-ROUND mean bytes (200 total / 2 rounds)
        assert!(r.contains("int4:2x2.0occ/0.400ms/100B"), "{r}");
        assert!(r.contains("rounds_per_s="), "{r}");
    }

    #[test]
    fn rounds_per_sec_measures_from_first_round_not_boot() {
        let mut m = Metrics::default();
        // No rounds yet: the rate is 0, not "0 rounds over idle time".
        assert_eq!(m.rounds_per_sec(), 0.0);
        // Idle before traffic must not dilute the rate: even after sitting
        // a while post-boot, one round over ~no elapsed time since the
        // FIRST round reads as a high rate, not rounds/idle-time.
        std::thread::sleep(std::time::Duration::from_millis(30));
        m.record_round(4, 1, 0.1, 100);
        let rate = m.rounds_per_sec();
        let from_boot = 1.0 / 0.030; // ≈33/s if measured from boot
        assert!(
            rate > 2.0 * from_boot,
            "rate {rate:.1}/s looks measured from boot (~{from_boot:.1}/s)"
        );
    }

    #[test]
    fn shift_counters_and_report_segment() {
        let mut m = Metrics::default();
        assert_eq!(m.mean_post_shift_occupancy(), 0.0);
        m.record_shift(true, 3, 1536, 4);
        m.record_shift(true, 1, 0, 2);
        m.record_shift(false, 4, 0, 3);
        assert_eq!(m.shifts_down(), 2);
        assert_eq!(m.shifts_up(), 1);
        assert_eq!(m.shift_moved(), 8);
        assert_eq!(m.shift_saved_bytes(), 1536);
        assert_eq!(m.mean_post_shift_occupancy(), 3.0);
        let r = m.report();
        assert!(
            r.contains("shifts=[down:2 up:1 moved:8 saved:1536B occ:3.0]"),
            "{r}"
        );
    }

    #[test]
    fn spec_counters_and_report_segment() {
        let mut m = Metrics::default();
        assert_eq!(m.spec_rounds(8), 0);
        assert_eq!(m.spec_accept_rate(8), 0.0);
        assert_eq!(m.spec_tokens_per_round(8), 0.0);
        // Round 1: one member, k=4 → 3 drafted, 3 accepted, 4 emitted.
        m.record_spec_round(8, 3, 3, 4);
        // Round 2: first draft rejected → 3 drafted, 0 accepted, 1 emitted.
        m.record_spec_round(8, 3, 0, 1);
        assert_eq!(m.spec_rounds(8), 2);
        assert_eq!(m.spec_emitted(8), 5);
        assert_eq!(m.spec_accept_rate(8), 0.5);
        assert_eq!(m.spec_tokens_per_round(8), 2.5);
        let r = m.report();
        assert!(r.contains("spec=[int8:2x acc:0.50 tok/rnd:2.50]"), "{r}");
    }

    #[test]
    fn decode_percentile_tracks_step_cost_not_stream_age() {
        let mut m = Metrics::default();
        assert_eq!(m.decode_percentile(4, 50.0), 0.0);
        // A long-lived stream: 100 cheap steps.  Were the metric fed
        // stream age (enq.elapsed), the samples would climb 1,2,3,…,100
        // and p50 would read ~50; per-step cost keeps it flat.
        for _ in 0..100 {
            m.record_decode_step(4, 0.5);
        }
        assert_eq!(m.decode_percentile(4, 50.0), 0.5);
        assert_eq!(m.decode_percentile(4, 99.0), 0.5);
        m.record_decode_step(4, 2.0);
        assert!(m.decode_percentile(4, 50.0) < 1.0);
    }

    #[test]
    fn ttft_percentiles_split_from_decode_latency() {
        let mut m = Metrics::default();
        assert_eq!(m.ttft_percentile(8, 50.0), 0.0);
        assert_eq!(m.ttft_count(8), 0);
        // TTFT samples are one-per-stream; decode steps must not feed them.
        for i in 0..10 {
            m.record_ttft(8, 10.0 + i as f64);
        }
        m.record_decode_step(8, 0.5);
        assert_eq!(m.ttft_count(8), 10);
        assert!(m.ttft_percentile(8, 50.0) >= 10.0);
        assert!(m.ttft_percentile(8, 99.0) <= 19.0);
        // decode percentiles stay on step cost, unmoved by TTFT samples
        assert_eq!(m.decode_percentile(8, 99.0), 0.5);
        let r = m.report();
        assert!(r.contains("ttft=[int8:10x p50:"), "{r}");
    }

    #[test]
    fn merge_aggregates_workers_into_a_fleet_view() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        a.record(1.0, 8, 1);
        b.record(3.0, 4, 2);
        b.record(5.0, 8, 2);
        a.record_ttft(8, 12.0);
        b.record_ttft(8, 20.0);
        b.record_ttft(4, 7.0);
        a.record_decode_step(8, 0.5);
        b.record_decode_step(8, 1.5);
        a.record_round(8, 2, 0.4, 100);
        b.record_round(8, 3, 0.6, 100);
        a.record_shift(true, 2, 64, 3);
        b.record_shift(false, 1, 0, 1);
        a.set_kv_pool(5, 0, 1);
        b.set_kv_pool(7, 128, 0); // same shared pool, later observation
        a.merge(&b);
        assert_eq!(a.requests, 3);
        assert_eq!(a.ttft_count(8), 2);
        assert_eq!(a.ttft_count(4), 1);
        assert_eq!(a.decode_steps(8), 2);
        assert_eq!(a.rounds(8), 2);
        assert_eq!(a.round_member_steps(8), 5);
        assert_eq!(a.shifts_down(), 1);
        assert_eq!(a.shifts_up(), 1);
        // gauges: elementwise max, never summed (shared pool, one figure)
        assert_eq!(a.kv_pages(), 7);
        assert_eq!(a.kv_shared_bytes(), 128);
        assert_eq!(a.kv_cow_breaks(), 1);
        let r = a.report();
        assert!(r.contains("int4:1") && r.contains("int8:2"), "{r}");
    }

    #[test]
    fn page_in_and_matmul_counters() {
        let mut m = Metrics::default();
        m.record_page_in(2, 1536, 0.5);
        m.record_batch(2, 1.25, 1536);
        m.record_batch(2, 0.75, 1536);
        m.record_batch(8, 2.0, 4096);
        assert_eq!(m.page_in_bytes(2), 1536);
        assert_eq!(m.page_in_bytes(4), 0);
        assert_eq!(m.weight_bytes_touched(2), 3072);
        assert_eq!(m.weight_bytes_touched(8), 4096);
        assert_eq!(m.batches, 3);
        let r = m.report();
        assert!(r.contains("paged=[int2:1x1536B/0.5ms]"), "{r}");
        // per-event mean bytes (3072 total / 2 batches)
        assert!(r.contains("int2:2x1.00ms/1536B"), "{r}");
    }
}
