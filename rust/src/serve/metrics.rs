//! Serving telemetry: latency percentiles, throughput, per-precision mix.

use std::collections::BTreeMap;
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    latencies_ms: Vec<f64>,
    per_bits: BTreeMap<u32, u64>,
    batch_sizes: Vec<usize>,
    /// Fused weight-set builds: precision → (count, total ms).  Warm builds
    /// happen at boot; lazy builds show up as a one-off latency cliff, so
    /// the report breaks them out per precision.
    materialize_ms: BTreeMap<u32, (u64, f64)>,
    pub requests: u64,
    pub batches: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            start: Instant::now(),
            latencies_ms: Vec::new(),
            per_bits: BTreeMap::new(),
            batch_sizes: Vec::new(),
            materialize_ms: BTreeMap::new(),
            requests: 0,
            batches: 0,
        }
    }
}

impl Metrics {
    pub fn record(&mut self, latency_ms: f64, bits: u32, batch_size: usize) {
        self.latencies_ms.push(latency_ms);
        *self.per_bits.entry(bits).or_default() += 1;
        self.requests += 1;
        if batch_size > 0 {
            self.batch_sizes.push(batch_size);
        }
    }

    pub fn record_batch(&mut self) {
        self.batches += 1;
    }

    /// One fused weight-set materialization (warm or lazy) completed.
    pub fn record_materialize(&mut self, bits: u32, ms: f64) {
        let e = self.materialize_ms.entry(bits).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += ms;
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() as f64 - 1.0) * p / 100.0).round() as usize;
        v[idx]
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64().max(1e-9);
        self.requests as f64 / secs
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn report(&self) -> String {
        let mix: Vec<String> = self
            .per_bits
            .iter()
            .map(|(b, n)| format!("int{b}:{n}"))
            .collect();
        let builds: Vec<String> = self
            .materialize_ms
            .iter()
            .map(|(b, (n, ms))| format!("int{b}:{n}x{:.1}ms", ms / (*n).max(1) as f64))
            .collect();
        format!(
            "requests={} batches={} p50={:.2}ms p99={:.2}ms throughput={:.1} req/s mean_batch={:.1} mix=[{}] weight_builds=[{}]",
            self.requests,
            self.batches,
            self.percentile(50.0),
            self.percentile(99.0),
            self.throughput_rps(),
            self.mean_batch_size(),
            mix.join(" "),
            builds.join(" ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for i in 0..100 {
            m.record(i as f64, 4, 1);
        }
        assert!(m.percentile(50.0) <= m.percentile(99.0));
        assert_eq!(m.requests, 100);
    }

    #[test]
    fn report_breaks_out_weight_builds() {
        let mut m = Metrics::default();
        m.record_materialize(2, 4.0);
        m.record_materialize(2, 2.0);
        m.record_materialize(8, 1.0);
        let r = m.report();
        assert!(r.contains("int2:2x3.0ms"), "{r}");
        assert!(r.contains("int8:1x1.0ms"), "{r}");
    }

    #[test]
    fn report_contains_mix() {
        let mut m = Metrics::default();
        m.record(1.0, 2, 4);
        m.record(2.0, 8, 4);
        let r = m.report();
        assert!(r.contains("int2:1") && r.contains("int8:1"));
    }
}
