//! The serving worker: a dedicated thread owns the backend — either the
//! (non-Send) PJRT engine or the **host decode engine** — plus the
//! per-precision weight state; clients submit requests through an mpsc
//! channel and receive responses on per-request channels.
//!
//! Two backends, two worker loops:
//!
//! * [`Server::start`] — PJRT: batches run the `fwd_b{B}` HLO artifacts;
//!   weight sets convert to literals per batch (warm dense or paged).
//!   Single-token greedy only (no KV cache in the artifacts), batched by
//!   the [`DynamicBatcher`].
//! * [`Server::start_host`] — host: the worker owns a
//!   [`crate::serve::Scheduler`] and serves from **cached forward plans**
//!   ([`crate::serve::WeightStore`] → [`crate::runtime::ForwardPlan`]).
//!   The loop validates and resolves each request at submit, hands it to
//!   its precision group, then just runs **scheduling rounds**: every live
//!   group advances all of its streams with one blocked fused GEMM per
//!   layer (the payload streams once per GEMM block per round, not once
//!   per session), admitted requests prefill as one ragged fused batch and
//!   join their group's next round — continuous batching with mid-stream
//!   admission, a round-robin fairness cap, and KV-pressure-aware
//!   deferral ([`ServerConfig::kv_capacity_bytes`]).  Responses
//!   **stream**: one [`Response`] event per token on the request's
//!   channel, the last with `done`.  With
//!   [`ServerConfig::speculative`] set, greedy packed-group streams run
//!   self-speculative rounds (low-bit MSB-prefix drafts, one batched
//!   target verify, KV rollback) — several events may arrive per round,
//!   bit-identical to plain decode, paused by the elastic planner under
//!   watermark pressure.
//!
//! The prefill/decode interleave policy lives in the scheduler, not here:
//! this loop only moves messages, resolves plans, and forwards events.

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use super::batcher::{DynamicBatcher, ReadyBatch};
use super::metrics::Metrics;
use super::planner::{ElasticConfig, ElasticPlanner, ShiftDirection};
use super::request::{Request, Response};
use super::scheduler::{projected_kv_bytes, Scheduler, SchedulerConfig};
use super::weights::{PlanKey, WeightStore};
use crate::model::{PresetInfo, QuantizedModel};
use crate::quant::{ActCalibration, ActQuantConfig};
use crate::runtime::{argmax_logit, lit_i32, Engine, Sampling};
use crate::Result;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub preset: String,
    /// Micro-batch window in ms (PJRT batching; on the host backend this
    /// is only the idle-poll granularity — round composition is the
    /// scheduler's job).
    pub max_wait_ms: f64,
    /// Precisions to pre-build as dense f32 state (others are built lazily
    /// as paged r-bit payloads).  On the **host** backend a warm precision
    /// serves through a dense-f32 forward plan — exact f32 numerics at
    /// full f32 residency; pass `warm_bits: vec![]` to serve every
    /// precision through fused packed plans instead (`32/r`× fewer
    /// resident weight bytes).
    pub warm_bits: Vec<u32>,
    /// Clip policy for the int8-activation host path (absmax by default;
    /// histogram clip sheds outlier tails).  Superseded per layer by a
    /// loaded `calibration` file.
    pub act_quant: ActQuantConfig,
    /// Optional persisted activation-clip calibration
    /// ([`crate::quant::calibration`], the JSON sidecar beside the
    /// checkpoint).  Loaded once at boot into the [`WeightStore`]; int8
    /// plans then quantize against fixed per-layer thresholds instead of
    /// re-scanning every token row of every request.
    pub calibration: Option<PathBuf>,
    /// Host backend: prefills admitted per scheduling round, distributed
    /// round-robin across precision groups
    /// ([`SchedulerConfig::max_prefills_per_round`]).
    pub max_prefills_per_round: usize,
    /// Host backend: KV admission budget in bytes against the shared page
    /// pool's resident pages ([`SchedulerConfig::kv_capacity_bytes`]).
    /// Prefills whose page-rounded projection would exceed it are deferred
    /// to a later round; live streams are never evicted.  `None` =
    /// unbounded.
    pub kv_capacity_bytes: Option<u64>,
    /// Host backend: KV page-pool geometry
    /// ([`crate::runtime::KvConfig`]) — page size in token rows and the
    /// row dtype.  The default is 16-row f32 pages, bit-identical to a
    /// contiguous cache; [`crate::runtime::KvConfig::int8`] stores K/V
    /// rows as int8 codes + per-row scales for ~4× more live streams per
    /// byte of budget at a bounded quality cost.
    pub kv: crate::runtime::KvConfig,
    /// Host backend: **elastic precision under load**.  When set, the
    /// worker consults an [`ElasticPlanner`] after every scheduling round:
    /// above the high watermarks the highest uniform *packed* group's live
    /// streams and queued requests shift one rung down the ladder
    /// mid-stream (a plan-pointer swap — KV stays, and under the nested
    /// payload the lower-bit plan pages zero new weight bytes); below the
    /// low watermarks displaced streams return to their native precision.
    /// Warm (dense f32) and per-layer groups never shift — a warm group
    /// serves f32-exact reference numerics by contract, so elastic serving
    /// wants `warm_bits: vec![]`.  `None` disables shifting.
    pub elastic: Option<ElasticConfig>,
    /// Host backend: **self-speculative decoding** (opt-in; `None`
    /// disables it).  Greedy streams in uniform packed groups above
    /// `draft_bits` draft `k − 1` tokens per round with the `draft_bits`
    /// MSB-prefix view of their own nested payload and verify the window
    /// in one batched target pass — emitted tokens are bit-identical to
    /// plain decode, only tokens/round changes.  Costs `k` provisional KV
    /// slots per stream (projected at admission) and draft compute, so
    /// the elastic planner suspends it while a high watermark is breached.
    /// Temperature requests always decode plain.
    pub speculative: Option<SpeculativeConfig>,
}

/// Self-speculative decode knobs ([`ServerConfig::speculative`]).
#[derive(Debug, Clone, Copy)]
pub struct SpeculativeConfig {
    /// The MSB-prefix rung that drafts (2 = int2 drafts).  Groups at or
    /// below this width never speculate — there is no cheaper rung to
    /// draft with.
    pub draft_bits: u32,
    /// Verify-window width `k`: each speculative round feeds 1 committed
    /// token plus `k − 1` drafts through one batched target pass, emitting
    /// between 1 and `k` tokens.  Values below 2 disable speculation (a
    /// 1-wide window IS plain decode).
    pub k: usize,
}

impl Default for SpeculativeConfig {
    fn default() -> Self {
        SpeculativeConfig {
            draft_bits: 2,
            k: 4,
        }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            preset: "tiny".into(),
            max_wait_ms: 2.0,
            warm_bits: vec![8, 4, 2],
            act_quant: ActQuantConfig::absmax(),
            calibration: None,
            max_prefills_per_round: 4,
            kv_capacity_bytes: None,
            kv: crate::runtime::KvConfig::default(),
            elastic: None,
            speculative: None,
        }
    }
}

enum Msg {
    Submit(Request, Sender<Response>),
    Report(Sender<String>),
    Shutdown,
}

/// Client handle; the worker thread dies when this is dropped (after a
/// `shutdown()` or implicitly via channel close + queue drain).
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Server {
    /// Boot a PJRT-backed worker.  The PJRT engine is *not* `Send` (Rc +
    /// raw pointers), so the worker thread constructs its own from
    /// `artifacts_dir`; the quantized model registry is plain data and
    /// moves in.
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        model: QuantizedModel,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (boot_tx, boot_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("mq-serve-worker".into())
            .spawn(move || {
                // The boot ack is sent only after BOTH the engine and the
                // preset lookup succeed, so a bad preset name surfaces as
                // an error from `start()` instead of a dead worker behind
                // an opaque closed-channel error.
                let engine = match Engine::new(&artifacts_dir) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let preset = match engine.manifest().preset(&cfg.preset) {
                    Ok(p) => p.clone(),
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let _ = boot_tx.send(Ok(()));
                pjrt_worker_loop(engine, preset, model, cfg, rx)
            })
            .context("spawning serve worker")?;
        boot_rx.recv().context("worker boot")??;
        Ok(Server {
            tx,
            worker: Some(worker),
        })
    }

    /// Boot a **host-backed** worker: whole requests — including
    /// multi-token generations — are answered by the continuous-batching
    /// scheduler over the incremental decode engine, with no artifacts
    /// directory, no PJRT, and no f32 weight set for lazily-built
    /// precisions.  `preset` supplies the model dimensions that the
    /// manifest would otherwise provide.
    pub fn start_host(
        preset: PresetInfo,
        model: QuantizedModel,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::Builder::new()
            .name("mq-serve-worker".into())
            .spawn(move || host_worker_loop(preset, model, cfg, rx))
            .context("spawning host serve worker")?;
        Ok(Server {
            tx,
            worker: Some(worker),
        })
    }

    /// Submit a request; returns the channel its response events arrive
    /// on — one [`Response`] per generated token, the last with `done`
    /// set (single-token requests get exactly one, `done` event).
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("server worker is gone"))?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait for the **final** event (the
    /// complete token stream rides in [`Response::tokens`]).
    pub fn infer(&self, req: Request) -> Result<Response> {
        let rx = self.submit(req)?;
        loop {
            let r = rx.recv().context("waiting for response")?;
            if r.done {
                return Ok(r);
            }
        }
    }

    pub fn metrics_report(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Report(tx))
            .map_err(|_| anyhow::anyhow!("server worker is gone"))?;
        rx.recv().context("waiting for metrics")
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Host backend: scheduler-driven continuous batching
// ---------------------------------------------------------------------------

/// The host worker loop: drain submissions (validating + resolving each
/// request's plan), then run one scheduling round — every iteration.  With
/// live or pending work the submit poll is non-blocking, so decode rounds
/// never wait on the channel; idle, the loop parks on the batch window.
/// Shutdown keeps running rounds until every stream and queued prefill has
/// drained — every accepted request is answered.
fn host_worker_loop(
    preset: PresetInfo,
    model: QuantizedModel,
    cfg: ServerConfig,
    rx: Receiver<Msg>,
) {
    let seq = preset.model.seq_len;
    let vocab = preset.model.vocab;
    let mut store = WeightStore::new();
    let mut waiters: BTreeMap<u64, Sender<Response>> = BTreeMap::new();
    let mut metrics = Metrics::default();
    let mut sched = Scheduler::new(SchedulerConfig {
        max_prefills_per_round: cfg.max_prefills_per_round,
        kv_capacity_bytes: cfg.kv_capacity_bytes,
        kv: cfg.kv,
    });
    let mut elastic = cfg.elastic.clone().map(ElasticPlanner::new);

    // Warm state at boot (build latency is free there): dense f32 forward
    // plans for the warm precisions, and the persisted activation-clip
    // calibration — loaded before any plan exists, so int8 plans bake the
    // fixed thresholds in from the first request.
    if let Some(path) = &cfg.calibration {
        match ActCalibration::load(path) {
            Ok(c) => store.set_calibration(Some(Arc::new(c))),
            Err(e) => eprintln!("serve worker: calibration {path:?}: {e:#}"),
        }
    }
    for &b in &cfg.warm_bits {
        if let Err(e) = store.plan_warm(&model, &preset.model, b, &mut metrics) {
            eprintln!("serve worker: warm plan int{b}: {e:#}");
        }
    }

    let mut running = true;
    while running || sched.has_work() {
        // Drain every queued message; block (bounded by the batch window)
        // only when there is nothing to step or prefill.
        let mut may_block = running && !sched.has_work();
        loop {
            let msg = if may_block {
                may_block = false;
                match rx.recv_timeout(Duration::from_micros(
                    (cfg.max_wait_ms * 1000.0) as u64 + 100,
                )) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        running = false;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        running = false;
                        None
                    }
                }
            };
            let Some(msg) = msg else { break };
            match msg {
                Msg::Submit(req, tx) => host_submit(
                    req,
                    tx,
                    seq,
                    vocab,
                    &cfg,
                    &model,
                    &preset,
                    &mut store,
                    &mut sched,
                    &mut waiters,
                    &mut metrics,
                ),
                Msg::Report(tx) => {
                    let _ = tx.send(metrics.report());
                }
                Msg::Shutdown => running = false,
            }
        }
        // Clients that hung up free their streams (and KV pages) now.
        sched.prune(&|id| waiters.contains_key(&id));
        // Refresh the resident-KV gauge after the prune: a hangup can be
        // the loop's last event, and the stale pre-prune figure would
        // otherwise survive until (or past) shutdown.
        metrics.set_kv_bytes(sched.resident_kv_bytes());
        // Speculation runs only while the elastic watermarks have
        // headroom: a speculative round holds k provisional KV rows per
        // member and spends draft compute — exactly the resources a
        // breached watermark says are gone.  Without an elastic config
        // speculation is unconditional.
        if let Some(planner) = elastic.as_ref() {
            sched.suspend_speculation(
                !planner.speculation_allowed(sched.resident_kv_bytes(), sched.pending_prefills()),
            );
        }
        let outcome = sched.run_round(&mut metrics, &mut |id, resp| {
            if resp.done {
                if let Some(tx) = waiters.remove(&id) {
                    let _ = tx.send(resp);
                }
                false
            } else {
                let alive = waiters.get(&id).is_some_and(|tx| tx.send(resp).is_ok());
                if !alive {
                    // A failed mid-stream send means the client hung up:
                    // drop the dead sender now, or `waiters` grows without
                    // bound (and prune() keeps treating the id as live).
                    waiters.remove(&id);
                }
                alive
            }
        });
        // Mid-round failures close their channels: clients get a recv
        // error instead of hanging on a stream that cannot continue.
        for id in outcome.failed {
            waiters.remove(&id);
        }
        if let Some(planner) = elastic.as_mut() {
            for id in apply_elastic(
                planner,
                &mut sched,
                &mut store,
                &model,
                &preset,
                &cfg,
                &mut metrics,
            ) {
                waiters.remove(&id);
            }
            // A shift can retire streams (failed plan swaps) after the
            // round already set the gauge — recompute so the gauge never
            // carries bytes of sessions that no longer exist.
            metrics.set_kv_bytes(sched.resident_kv_bytes());
        }
    }
}

/// Consult the elastic planner against the load the round just left behind
/// and apply at most one shift.  Returns the ids of streams the shift
/// failed (a stream that cannot switch plans) — the caller closes their
/// response channels exactly like mid-round failures.  A decision with
/// nothing to move starts no cooldown, so the planner keeps watching.
/// Shared by the single-worker host loop and the `serve::frontend` pool
/// workers (each worker runs its own planner over its own scheduler).
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_elastic(
    planner: &mut ElasticPlanner,
    sched: &mut Scheduler,
    store: &mut WeightStore,
    model: &QuantizedModel,
    preset: &PresetInfo,
    cfg: &ServerConfig,
    metrics: &mut Metrics,
) -> Vec<u64> {
    let round = sched.round();
    let Some(dir) = planner.decide(round, sched.resident_kv_bytes(), sched.pending_prefills())
    else {
        return Vec::new();
    };
    let failed = match dir {
        ShiftDirection::Down => {
            // The highest uniform packed group that has members and a
            // rung left below it.
            let Some(cand) = sched
                .uniform_groups()
                .into_iter()
                .filter(|g| g.live > 0 || g.pending > 0)
                .filter(|g| planner.cfg.next_down(g.bits).is_some())
                .max_by_key(|g| g.bits)
            else {
                return Vec::new();
            };
            let to_bits = planner.cfg.next_down(cand.bits).expect("filtered above");
            let int8 = if cand.int8 { Some(cfg.act_quant) } else { None };
            // Page-in savings attributable to this shift: bytes the nested
            // store avoids streaming to make the destination resident.
            let saved0 = metrics.page_in_saved_bytes(to_bits);
            let plan = match store.plan_packed(model, &preset.model, to_bits, int8, metrics) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("serve worker: elastic downshift plan int{to_bits}: {e:#}");
                    return Vec::new();
                }
            };
            let saved = metrics.page_in_saved_bytes(to_bits).saturating_sub(saved0);
            let report = sched.shift_uniform(cand.bits, cand.int8, to_bits, plan);
            if report.moved() > 0 {
                let occ = sched
                    .uniform_groups()
                    .iter()
                    .find(|g| g.bits == to_bits && g.int8 == cand.int8)
                    .map_or(0, |g| g.live as u64);
                metrics.record_shift(true, report.moved() as u64, saved, occ);
                planner.note_shift(round);
                eprintln!(
                    "serve worker: elastic downshift int{}→int{to_bits}: {} live + {} queued moved",
                    cand.bits, report.moved_live, report.moved_pending
                );
            }
            report.failed
        }
        ShiftDirection::Up => {
            let mut saved = 0u64;
            let report = {
                let saved = &mut saved;
                sched.shift_up_natives(&mut |bits, int8| {
                    let act = if int8 { Some(cfg.act_quant) } else { None };
                    let s0 = metrics.page_in_saved_bytes(bits);
                    let plan = store.plan_packed(model, &preset.model, bits, act, metrics).ok();
                    *saved += metrics.page_in_saved_bytes(bits).saturating_sub(s0);
                    plan
                })
            };
            if report.moved() > 0 {
                metrics.record_shift(
                    false,
                    report.moved() as u64,
                    saved,
                    sched.live_sessions() as u64,
                );
                planner.note_shift(round);
                eprintln!(
                    "serve worker: elastic upshift: {} live + {} queued restored to native precision",
                    report.moved_live, report.moved_pending
                );
            }
            report.failed
        }
    };
    failed
}

/// Verify-window KV slots this request would reserve if admitted into a
/// speculating group (0 when the config or request shape is ineligible).
/// Shared by [`prepare_submit`] and the frontend pool's budget-aware
/// queue gate so the two projections cannot disagree.
pub(crate) fn spec_slots_for(cfg: &ServerConfig, req: &Request, bits: u32) -> usize {
    cfg.speculative
        .as_ref()
        .filter(|s| {
            s.k >= 2
                && req.per_layer.is_none()
                && matches!(req.sampling, Sampling::Greedy)
                && bits > s.draft_bits
                && (req.int8_acts || !cfg.warm_bits.contains(&bits))
        })
        .map_or(0, |s| s.k)
}

/// A validated request with its resolved plan — everything
/// [`Scheduler::submit`] needs.  Produced by [`prepare_submit`].
pub(crate) struct PreparedSubmit {
    pub key: PlanKey,
    pub plan: Arc<crate::runtime::ForwardPlan>,
    /// The uniform bit-width the request resolved to (a per-layer map's
    /// maximum — the group/reporting width).
    pub bits: u32,
}

/// Validate one host request against the model/window limits and resolve
/// its forward plan, arming its group's speculative draft when eligible.
/// Shared by the single-worker host loop and every
/// [`crate::serve::frontend`] pool worker, so the two front doors cannot
/// drift: a request the in-process path rejects is rejected with the same
/// reason over TCP (where the message becomes the 400 body instead of a
/// log line).  Rejecting at submit keeps a malformed request out of every
/// round, so it cannot fail innocent round members or stall a stream.
#[allow(clippy::too_many_arguments)]
pub(crate) fn prepare_submit(
    req: &Request,
    seq: usize,
    vocab: usize,
    cfg: &ServerConfig,
    model: &QuantizedModel,
    preset: &PresetInfo,
    store: &mut WeightStore,
    sched: &mut Scheduler,
    metrics: &mut Metrics,
) -> std::result::Result<PreparedSubmit, String> {
    // Only the first `seq` tokens reach the forward pass (prompts
    // truncate), so tokens in the clipped tail must not fail a request
    // they cannot affect.
    let bad_token = req
        .prompt
        .iter()
        .take(seq)
        .find(|&&t| t < 0 || t as usize >= vocab)
        .copied();
    if let Some(bad) = bad_token {
        return Err(format!("token {bad} outside vocab [0, {vocab})"));
    }
    if req.max_new_tokens == 0 || req.max_new_tokens > seq {
        // 0 would produce an empty stream; anything past the position
        // capacity can never be served and would pin a round slot for
        // nothing.
        return Err(format!(
            "max_new_tokens {} outside [1, {seq}]",
            req.max_new_tokens
        ));
    }
    if let Err(e) = req.sampling.validate() {
        return Err(format!("{e:#}"));
    }
    if let Some(map) = &req.per_layer {
        if map.is_empty() || map.iter().any(|b| !(1..=8).contains(b)) {
            return Err(format!(
                "per-layer map {map:?} invalid (bits must be in [1, 8])"
            ));
        }
    }
    // Per-layer traffic is grouped and reported under the map's maximum
    // bit-width (deterministic and group-consistent — the uniform
    // `precision` field does not describe what actually ran).
    let bits = match &req.per_layer {
        Some(map) => *map.iter().max().expect("validated non-empty"),
        None => req.precision.bits(),
    };
    // Would this request land in a speculating group?  Then its session
    // reserves k provisional verify-window slots, and the projection must
    // say so — admission and the submit-time budget check otherwise
    // under-count the stream by k positions of K/V.
    let spec_slots = spec_slots_for(cfg, req, bits);
    if let Some(cap) = cfg.kv_capacity_bytes {
        // A request whose KV page alone exceeds the budget could never be
        // admitted — deferring it would park it (and its client) forever.
        let projected = projected_kv_bytes(
            &preset.model,
            req.prompt.len(),
            req.max_new_tokens,
            spec_slots,
            &cfg.kv,
        );
        if projected > cap {
            return Err(format!(
                "projected KV {projected}B exceeds the {cap}B budget"
            ));
        }
    }
    let int8 = if req.int8_acts {
        Some(cfg.act_quant)
    } else {
        None
    };
    // Warm f32 traffic rides the dense plan; everything else (including
    // int8 at a warm precision, and every per-layer map) needs packed
    // handles.  Plans cache per PlanKey, so this resolve is a lookup for
    // all but a precision's first request.
    let resolved = if let Some(map) = &req.per_layer {
        store
            .plan_per_layer(model, &preset.model, map, int8, metrics)
            .map(|p| {
                (
                    PlanKey::PerLayer {
                        bits: map.clone(),
                        int8: req.int8_acts,
                    },
                    p,
                )
            })
    } else if req.int8_acts || !cfg.warm_bits.contains(&bits) {
        store
            .plan_packed(model, &preset.model, bits, int8, metrics)
            .map(|p| {
                (
                    PlanKey::Packed {
                        bits,
                        int8: req.int8_acts,
                    },
                    p,
                )
            })
    } else {
        store
            .plan_warm(model, &preset.model, bits, metrics)
            .map(|p| (PlanKey::Warm(bits), p))
    };
    let (key, plan) = resolved.map_err(|e| format!("plan build failed: {e:#}"))?;
    // First greedy request of a speculation-eligible packed group:
    // resolve the draft rung (an MSB-prefix view of the SAME nested
    // payload — a store cache hit after the first time, and zero new
    // weight bytes under the nested store) and arm the group.
    // Registration is idempotent; a failed draft build just means the
    // group serves plain.
    if spec_slots >= 2 {
        if let Some(s) = &cfg.speculative {
            match store.plan_packed(model, &preset.model, s.draft_bits, int8, metrics) {
                Ok(draft) => sched.set_speculation(key.clone(), draft, s.draft_bits, s.k),
                Err(e) => eprintln!(
                    "serve worker: request {}: int{} draft plan failed ({e:#}); serving plain",
                    req.id, s.draft_bits
                ),
            }
        }
    }
    Ok(PreparedSubmit { key, plan, bits })
}

/// Validate one host request and enqueue it with its resolved plan.
/// Rejecting here (the dropped sender surfaces as a recv error on the
/// client) keeps a malformed request out of every round.
#[allow(clippy::too_many_arguments)]
fn host_submit(
    req: Request,
    tx: Sender<Response>,
    seq: usize,
    vocab: usize,
    cfg: &ServerConfig,
    model: &QuantizedModel,
    preset: &PresetInfo,
    store: &mut WeightStore,
    sched: &mut Scheduler,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) {
    // A duplicate in-flight id would silently overwrite the first
    // request's waiter entry: its response events would go nowhere, the
    // client would hang, and the scheduler would step BOTH streams while
    // only one channel existed.  Ids are only reusable once the previous
    // stream finished (its waiter entry is gone).
    if waiters.contains_key(&req.id) {
        eprintln!(
            "serve worker: request {}: id already in flight — rejected",
            req.id
        );
        return;
    }
    match prepare_submit(
        &req, seq, vocab, cfg, model, preset, store, sched, metrics,
    ) {
        Ok(p) => {
            let id = req.id;
            waiters.insert(id, tx);
            sched.submit(p.key, p.plan, p.bits, req.int8_acts, req, Instant::now());
        }
        Err(msg) => {
            eprintln!("serve worker: request {}: {msg} — rejected", req.id);
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT backend: dynamic batching over the `fwd_b{B}` artifacts
// ---------------------------------------------------------------------------

fn pjrt_worker_loop(
    engine: Engine,
    preset: PresetInfo,
    model: QuantizedModel,
    cfg: ServerConfig,
    rx: Receiver<Msg>,
) {
    let seq = preset.model.seq_len;
    let vocab = preset.model.vocab;
    let mut batcher = DynamicBatcher::new(preset.fwd_batch_sizes.clone(), cfg.max_wait_ms);
    let mut store = WeightStore::new();
    let mut waiters: BTreeMap<u64, Sender<Response>> = BTreeMap::new();
    let mut metrics = Metrics::default();

    // Warm dense f32 weight sets at boot; every other precision pages in
    // r-bit payloads lazily.
    for &b in &cfg.warm_bits {
        if let Err(e) = store.build_warm(&model, b, &mut metrics) {
            eprintln!("serve worker: materialize int{b}: {e:#}");
        }
    }

    let mut running = true;
    // Shutdown flush: `drain_all` empties every queue at once, so the
    // batches it returns must all be executed — parking them here (instead
    // of taking the first and dropping the rest, which silently lost the
    // other precisions' requests) keeps every waiter answered.
    let mut drained: VecDeque<ReadyBatch> = VecDeque::new();
    while running || batcher.pending() > 0 || !drained.is_empty() {
        if running {
            let timeout = Duration::from_micros((cfg.max_wait_ms * 500.0) as u64 + 100);
            match rx.recv_timeout(timeout) {
                Ok(Msg::Submit(req, tx)) => {
                    // PJRT serves exactly one greedy f32 token per request
                    // from fixed executables — everything else needs the
                    // host backend, and rejecting is honest where silently
                    // downgrading is not.
                    let bad_token = req
                        .prompt
                        .iter()
                        .take(seq)
                        .find(|&&t| t < 0 || t as usize >= vocab)
                        .copied();
                    if waiters.contains_key(&req.id) {
                        // Same waiter-clobber hazard as the host path: an
                        // in-flight id's channel must not be overwritten.
                        eprintln!(
                            "serve worker: request {}: id already in flight — rejected",
                            req.id
                        );
                        drop(tx);
                    } else if let Some(bad) = bad_token {
                        eprintln!(
                            "serve worker: request {}: token {bad} outside vocab [0, {vocab}) — rejected",
                            req.id
                        );
                        drop(tx);
                    } else if req.max_new_tokens == 0 || req.max_new_tokens > seq {
                        eprintln!(
                            "serve worker: request {}: max_new_tokens {} outside [1, {seq}] — rejected",
                            req.id, req.max_new_tokens
                        );
                        drop(tx);
                    } else if let Err(e) = req.sampling.validate() {
                        eprintln!("serve worker: request {}: {e:#} — rejected", req.id);
                        drop(tx);
                    } else if req.int8_acts {
                        eprintln!(
                            "serve worker: request {}: int8 activations need the host backend — rejected",
                            req.id
                        );
                        drop(tx);
                    } else if !matches!(req.sampling, Sampling::Greedy) {
                        eprintln!(
                            "serve worker: request {}: temperature sampling needs the host backend — rejected",
                            req.id
                        );
                        drop(tx);
                    } else if req.max_new_tokens > 1 {
                        eprintln!(
                            "serve worker: request {}: multi-token generation needs the host backend (PJRT has no KV cache) — rejected",
                            req.id
                        );
                        drop(tx);
                    } else if req.per_layer.is_some() {
                        eprintln!(
                            "serve worker: request {}: per-layer serving needs the host backend — rejected",
                            req.id
                        );
                        drop(tx);
                    } else {
                        waiters.insert(req.id, tx);
                        batcher.push(req);
                    }
                }
                Ok(Msg::Report(tx)) => {
                    let _ = tx.send(metrics.report());
                }
                Ok(Msg::Shutdown) => running = false,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => running = false,
            }
        }
        // Prefetch: page in payloads for precisions that already have
        // queued work, so the build is off the batch critical path.
        for b in batcher.queued_precisions() {
            if !store.contains(b) {
                if let Err(e) = store.build_paged(&model, b, &mut metrics) {
                    eprintln!("serve worker: page-in int{b}: {e:#}");
                }
            }
        }
        let ready = if running {
            batcher.pop_ready(Instant::now())
        } else {
            if drained.is_empty() {
                drained.extend(batcher.drain_all());
            }
            drained.pop_front()
        };
        if let Some(batch) = ready {
            let member_ids: Vec<u64> = batch.requests.iter().map(|(r, _)| r.id).collect();
            if !store.contains(batch.bits) {
                if let Err(e) = store.build_paged(&model, batch.bits, &mut metrics) {
                    eprintln!("serve worker: page-in int{}: {e:#}", batch.bits);
                }
            }
            let result = execute_batch_pjrt(
                &engine,
                &cfg.preset,
                seq,
                vocab,
                &store,
                &model,
                batch,
                &mut waiters,
                &mut metrics,
            );
            if let Err(e) = result {
                eprintln!("serve worker: batch failed: {e:#}");
                // Close the batch members' response channels: clients get a
                // recv error instead of hanging forever on a batch a single
                // malformed request poisoned.
                for id in member_ids {
                    waiters.remove(&id);
                }
            }
        }
    }
}

/// Greedy-decode each request's next token from the batch logits and send
/// the responses.  `enq.elapsed()` is read **once** per request so the
/// reported `queue_ms` and the latency metric cannot drift apart; the
/// argmax is total-order ([`argmax_logit`]) so a NaN logit yields a
/// response instead of killing the worker thread.
#[allow(clippy::too_many_arguments)]
fn respond_greedy(
    logits: &[f32],
    t: usize, // positions per logits row (seq_len for PJRT)
    vocab: usize,
    batch_bits: u32,
    batch_int8: bool,
    requests: Vec<(Request, Instant)>,
    last_pos: &[usize],
    compute_ms: f64,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) {
    let n_req = requests.len();
    for (i, (req, enq)) in requests.into_iter().enumerate() {
        let row_start = (i * t + last_pos[i]) * vocab;
        let row = &logits[row_start..row_start + vocab];
        let (next_token, logit) = argmax_logit(row);
        let total_ms = enq.elapsed().as_secs_f64() * 1e3;
        let queue_ms = total_ms - compute_ms;
        metrics.record(total_ms, batch_bits, n_req);
        if let Some(tx) = waiters.remove(&req.id) {
            let _ = tx.send(Response {
                id: req.id,
                next_token,
                logit,
                tokens: vec![next_token],
                done: true,
                bits: batch_bits,
                int8_acts: batch_int8,
                queue_ms: queue_ms.max(0.0),
                compute_ms: compute_ms / n_req as f64,
                prefill_ms: compute_ms / n_req as f64,
                decode_ms: 0.0,
                batch_size: n_req,
            });
        }
    }
}

/// Pad-and-pack a batch's prompts into a `(rows, t)` token buffer; returns
/// the buffer and each request's last prompt position (an empty prompt
/// reads position 0 of the all-pad row — it round-trips instead of
/// erroring).  PJRT passes the fixed executable shape `(bucket, seq_len)`.
fn fill_tokens(batch: &ReadyBatch, rows: usize, t: usize) -> (Vec<i32>, Vec<usize>) {
    let mut tokens = vec![0i32; rows * t];
    let mut last_pos = vec![0usize; rows];
    for (i, (req, _)) in batch.requests.iter().enumerate() {
        let n = req.prompt.len().min(t);
        tokens[i * t..i * t + n].copy_from_slice(&req.prompt[..n]);
        last_pos[i] = n.saturating_sub(1);
    }
    (tokens, last_pos)
}

/// PJRT path: weight args as literals (dense sets convert resident
/// tensors; paged sets decode one tensor at a time from the r-bit payload)
/// into the `fwd_b{B}` executable.
#[allow(clippy::too_many_arguments)]
fn execute_batch_pjrt(
    engine: &Engine,
    preset: &str,
    seq: usize,
    vocab: usize,
    store: &WeightStore,
    model: &QuantizedModel,
    batch: ReadyBatch,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) -> Result<()> {
    let bucket = batch.bucket;
    let (tokens, last_pos) = fill_tokens(&batch, bucket, seq);
    let mut args = store.batch_args(model, batch.bits)?;
    args.push(lit_i32(&[bucket, seq], &tokens)?);
    let t0 = Instant::now();
    let out = engine.run(preset, &format!("fwd_b{bucket}"), &args)?;
    let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
    metrics.record_batch(
        batch.bits,
        compute_ms,
        store.batch_weight_bytes(batch.bits) as u64,
    );
    let logits = &out[0]; // (bucket, seq, vocab)
    respond_greedy(
        &logits.data,
        seq,
        vocab,
        batch.bits,
        false,
        batch.requests,
        &last_pos,
        compute_ms,
        waiters,
        metrics,
    );
    Ok(())
}
