//! The serving worker: a dedicated thread owns the (non-Send) PJRT engine
//! and materialized weight sets; clients submit requests through an mpsc
//! channel and receive responses on per-request channels.

use std::collections::BTreeMap;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use super::batcher::{DynamicBatcher, ReadyBatch};
use super::metrics::Metrics;
use super::request::{Request, Response};
use super::weights::WeightStore;
use crate::model::QuantizedModel;
use crate::runtime::{lit_i32, Engine};
use crate::Result;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub preset: String,
    /// Micro-batch window in ms.
    pub max_wait_ms: f64,
    /// Precisions to pre-materialize (others are built lazily).
    pub warm_bits: Vec<u32>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            preset: "tiny".into(),
            max_wait_ms: 2.0,
            warm_bits: vec![8, 4, 2],
        }
    }
}

enum Msg {
    Submit(Request, Sender<Response>),
    Report(Sender<String>),
    Shutdown,
}

/// Client handle; the worker thread dies when this is dropped (after a
/// `shutdown()` or implicitly via channel close + queue drain).
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Server {
    /// Boot the worker.  The PJRT engine is *not* `Send` (Rc + raw
    /// pointers), so the worker thread constructs its own from
    /// `artifacts_dir`; the quantized model registry is plain data and
    /// moves in.
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        model: QuantizedModel,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (boot_tx, boot_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("mq-serve-worker".into())
            .spawn(move || {
                let engine = match Engine::new(&artifacts_dir) {
                    Ok(e) => {
                        let _ = boot_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                worker_loop(engine, model, cfg, rx)
            })
            .context("spawning serve worker")?;
        boot_rx.recv().context("worker boot")??;
        Ok(Server {
            tx,
            worker: Some(worker),
        })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("server worker is gone"))?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, req: Request) -> Result<Response> {
        let rx = self.submit(req)?;
        rx.recv().context("waiting for response")
    }

    pub fn metrics_report(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Report(tx))
            .map_err(|_| anyhow::anyhow!("server worker is gone"))?;
        rx.recv().context("waiting for metrics")
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(engine: Engine, model: QuantizedModel, cfg: ServerConfig, rx: Receiver<Msg>) {
    let preset = match engine.manifest().preset(&cfg.preset) {
        Ok(p) => p.clone(),
        Err(e) => {
            eprintln!("serve worker: {e:#}");
            return;
        }
    };
    let seq = preset.model.seq_len;
    let vocab = preset.model.vocab;
    let mut batcher = DynamicBatcher::new(preset.fwd_batch_sizes.clone(), cfg.max_wait_ms);
    let mut store = WeightStore::new();
    let mut waiters: BTreeMap<u64, Sender<Response>> = BTreeMap::new();
    let mut metrics = Metrics::default();

    // Warm precisions decode a dense f32 set at boot (build latency is
    // free there).  Every other precision is built lazily by *paging in*
    // the r-bit `pack_sliced` payloads — `32/r`× fewer resident weight
    // bytes than a dense set, no f32 weight buffers allocated — and is
    // decoded tensor-by-tensor only while batch arguments are built.
    for &b in &cfg.warm_bits {
        if let Err(e) = store.build_warm(&model, b, &mut metrics) {
            eprintln!("serve worker: materialize int{b}: {e:#}");
        }
    }

    let mut running = true;
    while running || batcher.pending() > 0 {
        let timeout = Duration::from_micros((cfg.max_wait_ms * 500.0) as u64 + 100);
        if running {
            match rx.recv_timeout(timeout) {
                Ok(Msg::Submit(req, tx)) => {
                    waiters.insert(req.id, tx);
                    batcher.push(req);
                }
                Ok(Msg::Report(tx)) => {
                    let _ = tx.send(metrics.report());
                }
                Ok(Msg::Shutdown) => running = false,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => running = false,
            }
        }
        // Prefetch: page in payloads for precisions that already have
        // queued work, so the (cheap) build is off the batch critical path.
        for b in batcher.queued_precisions() {
            if !store.contains(b) {
                if let Err(e) = store.build_paged(&model, b, &mut metrics) {
                    eprintln!("serve worker: page-in int{b}: {e:#}");
                }
            }
        }
        let ready = if running {
            batcher.pop_ready(Instant::now())
        } else {
            batcher.drain_all().into_iter().next()
        };
        if let Some(batch) = ready {
            if !store.contains(batch.bits) {
                if let Err(e) = store.build_paged(&model, batch.bits, &mut metrics) {
                    eprintln!("serve worker: page-in int{}: {e:#}", batch.bits);
                }
            }
            if let Err(e) = execute_batch(
                &engine,
                &cfg.preset,
                seq,
                vocab,
                &store,
                &model,
                batch,
                &mut waiters,
                &mut metrics,
            ) {
                eprintln!("serve worker: batch failed: {e:#}");
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn execute_batch(
    engine: &Engine,
    preset: &str,
    seq: usize,
    vocab: usize,
    store: &WeightStore,
    model: &QuantizedModel,
    batch: ReadyBatch,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) -> Result<()> {
    let bucket = batch.bucket;
    let mut tokens = vec![0i32; bucket * seq];
    let mut last_pos = vec![0usize; bucket];
    for (i, (req, _)) in batch.requests.iter().enumerate() {
        let n = req.prompt.len().min(seq);
        tokens[i * seq..i * seq + n].copy_from_slice(&req.prompt[..n]);
        last_pos[i] = n.saturating_sub(1);
    }
    // Weight args: dense sets convert resident tensors; paged sets decode
    // one tensor at a time from the r-bit payload (fused kernel) — the
    // weight bytes the batch touches are recorded per precision.
    let mut args = store.batch_args(model, batch.bits)?;
    args.push(lit_i32(&[bucket, seq], &tokens)?);
    let t0 = Instant::now();
    let out = engine.run(preset, &format!("fwd_b{bucket}"), &args)?;
    let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
    metrics.record_batch(
        batch.bits,
        compute_ms,
        store.batch_weight_bytes(batch.bits) as u64,
    );
    let logits = &out[0]; // (bucket, seq, vocab)
    let n_req = batch.requests.len();
    for (i, (req, enq)) in batch.requests.into_iter().enumerate() {
        let row = &logits.data[(i * seq + last_pos[i]) * vocab..(i * seq + last_pos[i] + 1) * vocab];
        let (next_token, &logit) = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let queue_ms = enq.elapsed().as_secs_f64() * 1e3 - compute_ms;
        metrics.record(enq.elapsed().as_secs_f64() * 1e3, batch.bits, n_req);
        if let Some(tx) = waiters.remove(&req.id) {
            let _ = tx.send(Response {
                id: req.id,
                next_token: next_token as i32,
                logit,
                bits: batch.bits,
                queue_ms: queue_ms.max(0.0),
                compute_ms: compute_ms / n_req as f64,
                batch_size: n_req,
            });
        }
    }
    Ok(())
}
