//! The serving worker: a dedicated thread owns the backend — either the
//! (non-Send) PJRT engine or the **host packed forward pass** — plus the
//! per-precision weight sets; clients submit requests through an mpsc
//! channel and receive responses on per-request channels.
//!
//! Two backends, one worker loop:
//!
//! * [`Server::start`] — PJRT: batches run the `fwd_b{B}` HLO artifacts;
//!   weight sets convert to literals per batch (warm dense or paged).
//! * [`Server::start_host`] — host: batches run
//!   [`crate::runtime::HostForward`] straight from the [`WeightStore`] —
//!   paged precisions execute fused packed-domain matmuls with **no f32
//!   weight tensor and no artifacts at all**, at any r ∈ {1..8}; requests
//!   flagged [`Request::int8_acts`] additionally run quantized activations
//!   through the integer-domain GEMV.

use std::collections::BTreeMap;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use super::batcher::{DynamicBatcher, ReadyBatch};
use super::metrics::Metrics;
use super::request::{Request, Response};
use super::weights::WeightStore;
use crate::model::{PresetInfo, QuantizedModel};
use crate::quant::ActQuantConfig;
use crate::runtime::{argmax_logit, lit_i32, Engine, HostForward};
use crate::Result;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub preset: String,
    /// Micro-batch window in ms.
    pub max_wait_ms: f64,
    /// Precisions to pre-materialize as dense f32 sets (others are built
    /// lazily as paged r-bit payloads).  On the **host** backend a warm
    /// precision serves through the dense f32 reference matmul — exact
    /// f32 numerics at full f32 residency; pass `warm_bits: vec![]` to
    /// serve every precision through the fused packed kernels instead
    /// (`32/r`× fewer resident weight bytes).
    pub warm_bits: Vec<u32>,
    /// Clip policy for the int8-activation host path (absmax by default;
    /// histogram clip sheds outlier tails).
    pub act_quant: ActQuantConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            preset: "tiny".into(),
            max_wait_ms: 2.0,
            warm_bits: vec![8, 4, 2],
            act_quant: ActQuantConfig::absmax(),
        }
    }
}

/// What executes a ready batch.
enum Backend {
    /// Compiled `fwd_b{B}` artifacts through the PJRT engine.
    Pjrt(Engine),
    /// The host packed forward pass — no artifacts, no PJRT.
    Host,
}

enum Msg {
    Submit(Request, Sender<Response>),
    Report(Sender<String>),
    Shutdown,
}

/// Client handle; the worker thread dies when this is dropped (after a
/// `shutdown()` or implicitly via channel close + queue drain).
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Server {
    /// Boot a PJRT-backed worker.  The PJRT engine is *not* `Send` (Rc +
    /// raw pointers), so the worker thread constructs its own from
    /// `artifacts_dir`; the quantized model registry is plain data and
    /// moves in.
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        model: QuantizedModel,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (boot_tx, boot_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("mq-serve-worker".into())
            .spawn(move || {
                // The boot ack is sent only after BOTH the engine and the
                // preset lookup succeed, so a bad preset name surfaces as
                // an error from `start()` instead of a dead worker behind
                // an opaque closed-channel error.
                let engine = match Engine::new(&artifacts_dir) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let preset = match engine.manifest().preset(&cfg.preset) {
                    Ok(p) => p.clone(),
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let _ = boot_tx.send(Ok(()));
                worker_loop(Backend::Pjrt(engine), preset, model, cfg, rx)
            })
            .context("spawning serve worker")?;
        boot_rx.recv().context("worker boot")??;
        Ok(Server {
            tx,
            worker: Some(worker),
        })
    }

    /// Boot a **host-backed** worker: whole requests are answered by the
    /// host packed forward pass from the paged `WeightStore` — no
    /// artifacts directory, no PJRT, no f32 weight set for lazily-built
    /// precisions.  `preset` supplies the model dimensions and batch
    /// buckets that the manifest would otherwise provide.
    pub fn start_host(
        preset: PresetInfo,
        model: QuantizedModel,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::Builder::new()
            .name("mq-serve-worker".into())
            .spawn(move || worker_loop(Backend::Host, preset, model, cfg, rx))
            .context("spawning host serve worker")?;
        Ok(Server {
            tx,
            worker: Some(worker),
        })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("server worker is gone"))?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, req: Request) -> Result<Response> {
        let rx = self.submit(req)?;
        rx.recv().context("waiting for response")
    }

    pub fn metrics_report(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Report(tx))
            .map_err(|_| anyhow::anyhow!("server worker is gone"))?;
        rx.recv().context("waiting for metrics")
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    backend: Backend,
    preset: PresetInfo,
    model: QuantizedModel,
    cfg: ServerConfig,
    rx: Receiver<Msg>,
) {
    let seq = preset.model.seq_len;
    let vocab = preset.model.vocab;
    let mut batcher = DynamicBatcher::new(preset.fwd_batch_sizes.clone(), cfg.max_wait_ms);
    let mut store = WeightStore::new();
    let mut waiters: BTreeMap<u64, Sender<Response>> = BTreeMap::new();
    let mut metrics = Metrics::default();

    // Warm precisions decode a dense f32 set at boot (build latency is
    // free there).  Every other precision is built lazily by *paging in*
    // the r-bit `pack_sliced` payloads — `32/r`× fewer resident weight
    // bytes than a dense set, no f32 weight buffers allocated.  The PJRT
    // backend decodes paged sets tensor-by-tensor at batch-arg build; the
    // host backend streams them through the fused matmul kernels with no
    // decode at all.
    for &b in &cfg.warm_bits {
        if let Err(e) = store.build_warm(&model, b, &mut metrics) {
            eprintln!("serve worker: materialize int{b}: {e:#}");
        }
    }

    let mut running = true;
    // Shutdown flush: `drain_all` empties every queue at once, so the
    // batches it returns must all be executed — parking them here (instead
    // of taking the first and dropping the rest, which silently lost the
    // other precisions' requests) keeps every waiter answered.
    let mut drained: std::collections::VecDeque<ReadyBatch> = std::collections::VecDeque::new();
    while running || batcher.pending() > 0 || !drained.is_empty() {
        let timeout = Duration::from_micros((cfg.max_wait_ms * 500.0) as u64 + 100);
        if running {
            match rx.recv_timeout(timeout) {
                Ok(Msg::Submit(req, tx)) => {
                    // Validate up front: rejecting a bad request here (the
                    // dropped sender surfaces as a recv error on the
                    // client) keeps it out of a batch, so it cannot fail
                    // innocent batchmates downstream.  int8 activations
                    // are a host-path feature — the PJRT backend rejects
                    // the flag instead of silently serving f32 from a
                    // needlessly fragmented (bits, int8) queue.
                    // Only the first `seq` tokens reach the forward pass
                    // (`fill_tokens` truncates), so tokens in the clipped
                    // tail must not fail a request they cannot affect.
                    let bad_token = req
                        .prompt
                        .iter()
                        .take(seq)
                        .find(|&&t| t < 0 || t as usize >= vocab)
                        .copied();
                    if let Some(bad) = bad_token {
                        eprintln!(
                            "serve worker: request {}: token {bad} outside vocab [0, {vocab}) — rejected",
                            req.id
                        );
                        drop(tx);
                    } else if req.int8_acts && !matches!(backend, Backend::Host) {
                        eprintln!(
                            "serve worker: request {}: int8 activations need the host backend — rejected",
                            req.id
                        );
                        drop(tx);
                    } else {
                        waiters.insert(req.id, tx);
                        batcher.push(req);
                    }
                }
                Ok(Msg::Report(tx)) => {
                    let _ = tx.send(metrics.report());
                }
                Ok(Msg::Shutdown) => running = false,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => running = false,
            }
        }
        // Prefetch: page in payloads for precisions that already have
        // queued work, so the (cheap) build is off the batch critical path.
        for b in batcher.queued_precisions() {
            if !store.contains(b) {
                if let Err(e) = store.build_paged(&model, b, &mut metrics) {
                    eprintln!("serve worker: page-in int{b}: {e:#}");
                }
            }
        }
        // int8 requests need packed handles even at warm (dense) precisions.
        if matches!(backend, Backend::Host) {
            for b in batcher.queued_int8_precisions() {
                if let Err(e) = store.ensure_packed(&model, b, &mut metrics) {
                    eprintln!("serve worker: packed build int{b}: {e:#}");
                }
            }
        }
        let ready = if running {
            batcher.pop_ready(Instant::now())
        } else {
            if drained.is_empty() {
                drained.extend(batcher.drain_all());
            }
            drained.pop_front()
        };
        if let Some(batch) = ready {
            if !store.contains(batch.bits) {
                if let Err(e) = store.build_paged(&model, batch.bits, &mut metrics) {
                    eprintln!("serve worker: page-in int{}: {e:#}", batch.bits);
                }
            }
            // (int8 packed handles were provisioned by the prefetch loop
            // above while this batch's requests were still queued.)
            let member_ids: Vec<u64> = batch.requests.iter().map(|(r, _)| r.id).collect();
            let result = match &backend {
                Backend::Pjrt(engine) => execute_batch_pjrt(
                    engine,
                    &cfg.preset,
                    seq,
                    vocab,
                    &store,
                    &model,
                    batch,
                    &mut waiters,
                    &mut metrics,
                ),
                Backend::Host => execute_batch_host(
                    &preset,
                    &cfg,
                    &store,
                    &model,
                    batch,
                    &mut waiters,
                    &mut metrics,
                ),
            };
            if let Err(e) = result {
                eprintln!("serve worker: batch failed: {e:#}");
                // Close the batch members' response channels: clients get a
                // recv error instead of hanging forever on a batch a single
                // malformed request (e.g. an out-of-vocab token) poisoned.
                for id in member_ids {
                    waiters.remove(&id);
                }
            }
        }
    }
}

/// Pad-and-pack a batch's prompts into a `(rows, t)` token buffer; returns
/// the buffer and each request's last prompt position (an empty prompt
/// reads position 0 of the all-pad row — it round-trips instead of
/// erroring).  PJRT passes the fixed executable shape `(bucket, seq_len)`;
/// the host path passes the tight `(n_requests, longest prompt)`.
fn fill_tokens(batch: &ReadyBatch, rows: usize, t: usize) -> (Vec<i32>, Vec<usize>) {
    let mut tokens = vec![0i32; rows * t];
    let mut last_pos = vec![0usize; rows];
    for (i, (req, _)) in batch.requests.iter().enumerate() {
        let n = req.prompt.len().min(t);
        tokens[i * t..i * t + n].copy_from_slice(&req.prompt[..n]);
        last_pos[i] = n.saturating_sub(1);
    }
    (tokens, last_pos)
}

/// Greedy-decode each request's next token from the batch logits and send
/// the responses.  `enq.elapsed()` is read **once** per request so the
/// reported `queue_ms` and the latency metric cannot drift apart; the
/// argmax is total-order ([`argmax_logit`]) so a NaN logit yields a
/// response instead of killing the worker thread.
#[allow(clippy::too_many_arguments)]
fn respond_greedy(
    logits: &[f32],
    t: usize, // positions per logits row (seq_len for PJRT, tight t for host)
    vocab: usize,
    batch_bits: u32,
    batch_int8: bool,
    requests: Vec<(Request, Instant)>,
    last_pos: &[usize],
    compute_ms: f64,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) {
    let n_req = requests.len();
    for (i, (req, enq)) in requests.into_iter().enumerate() {
        let row_start = (i * t + last_pos[i]) * vocab;
        let row = &logits[row_start..row_start + vocab];
        let (next_token, logit) = argmax_logit(row);
        let total_ms = enq.elapsed().as_secs_f64() * 1e3;
        let queue_ms = total_ms - compute_ms;
        metrics.record(total_ms, batch_bits, n_req);
        if let Some(tx) = waiters.remove(&req.id) {
            let _ = tx.send(Response {
                id: req.id,
                next_token,
                logit,
                bits: batch_bits,
                int8_acts: batch_int8,
                queue_ms: queue_ms.max(0.0),
                compute_ms: compute_ms / n_req as f64,
                batch_size: n_req,
            });
        }
    }
}

/// PJRT path: weight args as literals (dense sets convert resident
/// tensors; paged sets decode one tensor at a time from the r-bit payload)
/// into the `fwd_b{B}` executable.
#[allow(clippy::too_many_arguments)]
fn execute_batch_pjrt(
    engine: &Engine,
    preset: &str,
    seq: usize,
    vocab: usize,
    store: &WeightStore,
    model: &QuantizedModel,
    batch: ReadyBatch,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) -> Result<()> {
    let bucket = batch.bucket;
    let (tokens, last_pos) = fill_tokens(&batch, bucket, seq);
    let mut args = store.batch_args(model, batch.bits)?;
    args.push(lit_i32(&[bucket, seq], &tokens)?);
    let t0 = Instant::now();
    let out = engine.run(preset, &format!("fwd_b{bucket}"), &args)?;
    let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
    metrics.record_batch(
        batch.bits,
        compute_ms,
        store.batch_weight_bytes(batch.bits) as u64,
    );
    let logits = &out[0]; // (bucket, seq, vocab)
    respond_greedy(
        &logits.data,
        seq,
        vocab,
        batch.bits,
        false,
        batch.requests,
        &last_pos,
        compute_ms,
        waiters,
        metrics,
    );
    Ok(())
}

/// Host path: the full forward pass from the weight store — fused
/// packed-domain matmuls for paged precisions (payload bytes are the only
/// resident weight state), dense f32 for warm ones, integer-domain GEMV
/// when the batch asked for int8 activations.
fn execute_batch_host(
    preset: &PresetInfo,
    cfg: &ServerConfig,
    store: &WeightStore,
    model: &QuantizedModel,
    batch: ReadyBatch,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) -> Result<()> {
    let seq = preset.model.seq_len;
    let vocab = preset.model.vocab;
    // Unlike PJRT the host forward has no fixed executable shape, so skip
    // the batch bucket's padding rows and run only to the longest prompt —
    // causal attention makes the last-position logits identical to the
    // full-`seq_len` forward, at a fraction of the (t²) attention work.
    let n_req = batch.requests.len();
    let t = batch
        .requests
        .iter()
        .map(|(r, _)| r.prompt.len().min(seq))
        .max()
        .unwrap_or(1)
        .max(1);
    let (tokens, last_pos) = fill_tokens(&batch, n_req, t);
    let int8 = if batch.int8 {
        Some(cfg.act_quant)
    } else {
        None
    };
    let view = store.forward_weights(batch.bits, int8)?;
    let fw = HostForward::new(&preset.model, model, view)?;
    let t0 = Instant::now();
    let logits = fw.forward(&tokens, n_req, t)?;
    let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
    metrics.record_batch(
        batch.bits,
        compute_ms,
        store.host_batch_weight_bytes(batch.bits, batch.int8) as u64,
    );
    respond_greedy(
        &logits.data,
        t,
        vocab,
        batch.bits,
        batch.int8,
        batch.requests,
        &last_pos,
        compute_ms,
        waiters,
        metrics,
    );
    Ok(())
}
