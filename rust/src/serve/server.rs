//! The serving worker: a dedicated thread owns the backend — either the
//! (non-Send) PJRT engine or the **host decode engine** — plus the
//! per-precision weight state; clients submit requests through an mpsc
//! channel and receive responses on per-request channels.
//!
//! Two backends, one worker loop:
//!
//! * [`Server::start`] — PJRT: batches run the `fwd_b{B}` HLO artifacts;
//!   weight sets convert to literals per batch (warm dense or paged).
//!   Single-token only (no KV cache in the artifacts).
//! * [`Server::start_host`] — host: the worker serves from **cached
//!   forward plans** ([`crate::serve::WeightStore`] →
//!   [`crate::runtime::ForwardPlan`]): each request prefills a
//!   [`DecodeSession`] once through the fused packed kernels, then
//!   generates up to `max_new_tokens` tokens with KV-cached O(n) decode
//!   steps — no artifacts, no PJRT, and on paged precisions no f32 weight
//!   tensor, at any r ∈ {1..8}.  Responses **stream**: one [`Response`]
//!   event per token on the request's channel, the last with `done`.
//!
//! Scheduling: every worker iteration first advances each live decode
//! session by one token (decode priority — inter-token latency stays flat
//! while prefills queue behind), then admits new work from the batcher.
//! With live sessions the queue poll is non-blocking, so decode throughput
//! never waits on the batch window.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use super::batcher::{DynamicBatcher, ReadyBatch};
use super::metrics::Metrics;
use super::request::{Request, Response};
use super::weights::WeightStore;
use crate::data::Rng;
use crate::model::{PresetInfo, QuantizedModel};
use crate::quant::{ActCalibration, ActQuantConfig};
use crate::runtime::{argmax_logit, lit_i32, sample_logits, DecodeSession, Engine, Sampling};
use crate::Result;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub preset: String,
    /// Micro-batch window in ms.
    pub max_wait_ms: f64,
    /// Precisions to pre-build as dense f32 state (others are built lazily
    /// as paged r-bit payloads).  On the **host** backend a warm precision
    /// serves through a dense-f32 forward plan — exact f32 numerics at
    /// full f32 residency; pass `warm_bits: vec![]` to serve every
    /// precision through fused packed plans instead (`32/r`× fewer
    /// resident weight bytes).
    pub warm_bits: Vec<u32>,
    /// Clip policy for the int8-activation host path (absmax by default;
    /// histogram clip sheds outlier tails).  Superseded per layer by a
    /// loaded `calibration` file.
    pub act_quant: ActQuantConfig,
    /// Optional persisted activation-clip calibration
    /// ([`crate::quant::calibration`], the JSON sidecar beside the
    /// checkpoint).  Loaded once at boot into the [`WeightStore`]; int8
    /// plans then quantize against fixed per-layer thresholds instead of
    /// re-scanning every token row of every request.
    pub calibration: Option<PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            preset: "tiny".into(),
            max_wait_ms: 2.0,
            warm_bits: vec![8, 4, 2],
            act_quant: ActQuantConfig::absmax(),
            calibration: None,
        }
    }
}

/// What executes a ready batch.
enum Backend {
    /// Compiled `fwd_b{B}` artifacts through the PJRT engine.
    Pjrt(Engine),
    /// The host decode engine — no artifacts, no PJRT.
    Host,
}

enum Msg {
    Submit(Request, Sender<Response>),
    Report(Sender<String>),
    Shutdown,
}

/// One live multi-token generation between worker iterations.
struct ActiveDecode {
    id: u64,
    session: DecodeSession,
    /// Tokens still to emit.
    remaining: usize,
    /// Last sampled token — the next step's input.
    last: i32,
    bits: u32,
    int8: bool,
    enq: Instant,
    prefill_ms: f64,
    decode_ms: f64,
    batch_size: usize,
}

/// Client handle; the worker thread dies when this is dropped (after a
/// `shutdown()` or implicitly via channel close + queue drain).
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Server {
    /// Boot a PJRT-backed worker.  The PJRT engine is *not* `Send` (Rc +
    /// raw pointers), so the worker thread constructs its own from
    /// `artifacts_dir`; the quantized model registry is plain data and
    /// moves in.
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        model: QuantizedModel,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (boot_tx, boot_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("mq-serve-worker".into())
            .spawn(move || {
                // The boot ack is sent only after BOTH the engine and the
                // preset lookup succeed, so a bad preset name surfaces as
                // an error from `start()` instead of a dead worker behind
                // an opaque closed-channel error.
                let engine = match Engine::new(&artifacts_dir) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let preset = match engine.manifest().preset(&cfg.preset) {
                    Ok(p) => p.clone(),
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let _ = boot_tx.send(Ok(()));
                worker_loop(Backend::Pjrt(engine), preset, model, cfg, rx)
            })
            .context("spawning serve worker")?;
        boot_rx.recv().context("worker boot")??;
        Ok(Server {
            tx,
            worker: Some(worker),
        })
    }

    /// Boot a **host-backed** worker: whole requests — including
    /// multi-token generations — are answered by the incremental decode
    /// engine from cached forward plans, with no artifacts directory, no
    /// PJRT, and no f32 weight set for lazily-built precisions.  `preset`
    /// supplies the model dimensions and batch buckets that the manifest
    /// would otherwise provide.
    pub fn start_host(
        preset: PresetInfo,
        model: QuantizedModel,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::Builder::new()
            .name("mq-serve-worker".into())
            .spawn(move || worker_loop(Backend::Host, preset, model, cfg, rx))
            .context("spawning host serve worker")?;
        Ok(Server {
            tx,
            worker: Some(worker),
        })
    }

    /// Submit a request; returns the channel its response events arrive
    /// on — one [`Response`] per generated token, the last with `done`
    /// set (single-token requests get exactly one, `done` event).
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("server worker is gone"))?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait for the **final** event (the
    /// complete token stream rides in [`Response::tokens`]).
    pub fn infer(&self, req: Request) -> Result<Response> {
        let rx = self.submit(req)?;
        loop {
            let r = rx.recv().context("waiting for response")?;
            if r.done {
                return Ok(r);
            }
        }
    }

    pub fn metrics_report(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Report(tx))
            .map_err(|_| anyhow::anyhow!("server worker is gone"))?;
        rx.recv().context("waiting for metrics")
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    backend: Backend,
    preset: PresetInfo,
    model: QuantizedModel,
    cfg: ServerConfig,
    rx: Receiver<Msg>,
) {
    let seq = preset.model.seq_len;
    let vocab = preset.model.vocab;
    let mut batcher = DynamicBatcher::new(preset.fwd_batch_sizes.clone(), cfg.max_wait_ms);
    let mut store = WeightStore::new();
    let mut waiters: BTreeMap<u64, Sender<Response>> = BTreeMap::new();
    let mut metrics = Metrics::default();
    let mut active: Vec<ActiveDecode> = Vec::new();

    // Warm state at boot (build latency is free there).  Host: dense f32
    // forward plans; PJRT: dense f32 weight sets.  Every other precision
    // is built lazily by paging in r-bit payloads — `32/r`× fewer resident
    // weight bytes than a dense set, shared across every plan that uses
    // the precision.  The host backend also loads the persisted
    // activation-clip calibration before any plan exists, so int8 plans
    // bake the fixed thresholds in from the first request.
    match &backend {
        Backend::Host => {
            if let Some(path) = &cfg.calibration {
                match ActCalibration::load(path) {
                    Ok(c) => store.set_calibration(Some(Arc::new(c))),
                    Err(e) => eprintln!("serve worker: calibration {path:?}: {e:#}"),
                }
            }
            for &b in &cfg.warm_bits {
                if let Err(e) = store.plan_warm(&model, &preset.model, b, &mut metrics) {
                    eprintln!("serve worker: warm plan int{b}: {e:#}");
                }
            }
        }
        Backend::Pjrt(_) => {
            for &b in &cfg.warm_bits {
                if let Err(e) = store.build_warm(&model, b, &mut metrics) {
                    eprintln!("serve worker: materialize int{b}: {e:#}");
                }
            }
        }
    }

    let mut running = true;
    // Shutdown flush: `drain_all` empties every queue at once, so the
    // batches it returns must all be executed — parking them here (instead
    // of taking the first and dropping the rest, which silently lost the
    // other precisions' requests) keeps every waiter answered.  Live decode
    // sessions likewise keep the loop alive until their streams finish.
    let mut drained: std::collections::VecDeque<ReadyBatch> = std::collections::VecDeque::new();
    while running || batcher.pending() > 0 || !drained.is_empty() || !active.is_empty() {
        // Decode priority: advance every live session one token before
        // admitting new work.
        step_active(&mut active, &mut waiters, &mut metrics);
        // With live sessions the poll must not block — their next tokens
        // are due; otherwise wait out the batch window.
        let timeout = if active.is_empty() {
            Duration::from_micros((cfg.max_wait_ms * 500.0) as u64 + 100)
        } else {
            Duration::ZERO
        };
        if running {
            match rx.recv_timeout(timeout) {
                Ok(Msg::Submit(req, tx)) => {
                    // Validate up front: rejecting a bad request here (the
                    // dropped sender surfaces as a recv error on the
                    // client) keeps it out of a batch, so it cannot fail
                    // innocent batchmates or stall a decode stream.
                    // Only the first `seq` tokens reach the forward pass
                    // (prompts truncate), so tokens in the clipped tail
                    // must not fail a request they cannot affect.
                    let host = matches!(backend, Backend::Host);
                    let bad_token = req
                        .prompt
                        .iter()
                        .take(seq)
                        .find(|&&t| t < 0 || t as usize >= vocab)
                        .copied();
                    if let Some(bad) = bad_token {
                        eprintln!(
                            "serve worker: request {}: token {bad} outside vocab [0, {vocab}) — rejected",
                            req.id
                        );
                        drop(tx);
                    } else if req.max_new_tokens == 0 || req.max_new_tokens > seq {
                        // 0 would produce an empty stream; anything past
                        // the position capacity can never be served and
                        // would pin a decode slot for nothing.
                        eprintln!(
                            "serve worker: request {}: max_new_tokens {} outside [1, {seq}] — rejected",
                            req.id, req.max_new_tokens
                        );
                        drop(tx);
                    } else if let Err(e) = req.sampling.validate() {
                        eprintln!("serve worker: request {}: {e:#} — rejected", req.id);
                        drop(tx);
                    } else if req.int8_acts && !host {
                        eprintln!(
                            "serve worker: request {}: int8 activations need the host backend — rejected",
                            req.id
                        );
                        drop(tx);
                    } else if !host && !matches!(req.sampling, Sampling::Greedy) {
                        // PJRT's respond path is argmax-only; rejecting is
                        // honest, silently serving greedy is not.
                        eprintln!(
                            "serve worker: request {}: temperature sampling needs the host backend — rejected",
                            req.id
                        );
                        drop(tx);
                    } else if req.max_new_tokens > 1 && !host {
                        eprintln!(
                            "serve worker: request {}: multi-token generation needs the host backend (PJRT has no KV cache) — rejected",
                            req.id
                        );
                        drop(tx);
                    } else {
                        waiters.insert(req.id, tx);
                        batcher.push(req);
                    }
                }
                Ok(Msg::Report(tx)) => {
                    let _ = tx.send(metrics.report());
                }
                Ok(Msg::Shutdown) => running = false,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => running = false,
            }
        }
        // Prefetch: build plans / page in payloads for precisions that
        // already have queued work, so the build is off the batch critical
        // path.
        match &backend {
            Backend::Host => {
                for b in batcher.queued_precisions() {
                    let r = if cfg.warm_bits.contains(&b) {
                        store.plan_warm(&model, &preset.model, b, &mut metrics)
                    } else {
                        store.plan_packed(&model, &preset.model, b, None, &mut metrics)
                    };
                    if let Err(e) = r {
                        eprintln!("serve worker: plan int{b}: {e:#}");
                    }
                }
                for b in batcher.queued_int8_precisions() {
                    if let Err(e) =
                        store.plan_packed(&model, &preset.model, b, Some(cfg.act_quant), &mut metrics)
                    {
                        eprintln!("serve worker: int8 plan int{b}: {e:#}");
                    }
                }
            }
            Backend::Pjrt(_) => {
                for b in batcher.queued_precisions() {
                    if !store.contains(b) {
                        if let Err(e) = store.build_paged(&model, b, &mut metrics) {
                            eprintln!("serve worker: page-in int{b}: {e:#}");
                        }
                    }
                }
            }
        }
        let ready = if running {
            batcher.pop_ready(Instant::now())
        } else {
            if drained.is_empty() {
                drained.extend(batcher.drain_all());
            }
            drained.pop_front()
        };
        if let Some(batch) = ready {
            let member_ids: Vec<u64> = batch.requests.iter().map(|(r, _)| r.id).collect();
            let result = match &backend {
                Backend::Pjrt(engine) => {
                    if !store.contains(batch.bits) {
                        if let Err(e) = store.build_paged(&model, batch.bits, &mut metrics) {
                            eprintln!("serve worker: page-in int{}: {e:#}", batch.bits);
                        }
                    }
                    execute_batch_pjrt(
                        engine,
                        &cfg.preset,
                        seq,
                        vocab,
                        &store,
                        &model,
                        batch,
                        &mut waiters,
                        &mut metrics,
                    )
                }
                Backend::Host => execute_batch_host(
                    &preset,
                    &cfg,
                    &mut store,
                    &model,
                    batch,
                    &mut waiters,
                    &mut metrics,
                    &mut active,
                ),
            };
            if let Err(e) = result {
                eprintln!("serve worker: batch failed: {e:#}");
                // Close the batch members' response channels: clients get a
                // recv error instead of hanging forever on a batch a single
                // malformed request (e.g. an out-of-vocab token) poisoned.
                for id in member_ids {
                    waiters.remove(&id);
                }
            }
        }
    }
}

/// Advance every live decode session one token: feed back its last sampled
/// token through the KV-cached step, sample the next, stream the event.
/// Finished (or abandoned — client hung up) sessions are retired, and the
/// KV-residency gauge is refreshed from what stays live.
fn step_active(
    active: &mut Vec<ActiveDecode>,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) {
    let mut i = 0;
    while i < active.len() {
        // Client hung up mid-stream → free the session (and its KV page).
        if !waiters.contains_key(&active[i].id) {
            active.remove(i);
            continue;
        }
        let a = &mut active[i];
        let t0 = Instant::now();
        if let Err(e) = a.session.advance(a.last) {
            eprintln!("serve worker: request {}: decode step failed: {e:#}", a.id);
            waiters.remove(&a.id);
            active.remove(i);
            continue;
        }
        let step_ms = t0.elapsed().as_secs_f64() * 1e3;
        a.decode_ms += step_ms;
        metrics.record_decode_step(a.bits, step_ms);
        let (tok, logit) = a.session.sample();
        a.last = tok;
        a.remaining -= 1;
        // Capacity can end a stream before max_new_tokens: the event is
        // marked done so the client never waits on tokens that cannot come.
        let done = a.remaining == 0 || !a.session.can_advance();
        // The full stream rides only on the final event — intermediate
        // events carry their token in `next_token`, so an n-token stream
        // costs O(n) copies, not O(n²).
        let resp = Response {
            id: a.id,
            next_token: tok,
            logit,
            tokens: if done {
                a.session.generated().to_vec()
            } else {
                Vec::new()
            },
            done,
            bits: a.bits,
            int8_acts: a.int8,
            queue_ms: 0.0,
            compute_ms: step_ms,
            prefill_ms: a.prefill_ms,
            decode_ms: a.decode_ms,
            batch_size: a.batch_size,
        };
        if done {
            metrics.record(a.enq.elapsed().as_secs_f64() * 1e3, a.bits, a.batch_size);
            if let Some(tx) = waiters.remove(&a.id) {
                let _ = tx.send(resp);
            }
            active.remove(i);
            continue;
        }
        let alive = waiters.get(&a.id).is_some_and(|tx| tx.send(resp).is_ok());
        if !alive {
            waiters.remove(&a.id);
            active.remove(i);
            continue;
        }
        i += 1;
    }
    metrics.set_kv_bytes(active.iter().map(|a| a.session.kv_bytes() as u64).sum());
}

/// Greedy-decode each request's next token from the batch logits and send
/// the responses.  `enq.elapsed()` is read **once** per request so the
/// reported `queue_ms` and the latency metric cannot drift apart; the
/// argmax is total-order ([`argmax_logit`]) so a NaN logit yields a
/// response instead of killing the worker thread.
#[allow(clippy::too_many_arguments)]
fn respond_greedy(
    logits: &[f32],
    t: usize, // positions per logits row (seq_len for PJRT)
    vocab: usize,
    batch_bits: u32,
    batch_int8: bool,
    requests: Vec<(Request, Instant)>,
    last_pos: &[usize],
    compute_ms: f64,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) {
    let n_req = requests.len();
    for (i, (req, enq)) in requests.into_iter().enumerate() {
        let row_start = (i * t + last_pos[i]) * vocab;
        let row = &logits[row_start..row_start + vocab];
        let (next_token, logit) = argmax_logit(row);
        let total_ms = enq.elapsed().as_secs_f64() * 1e3;
        let queue_ms = total_ms - compute_ms;
        metrics.record(total_ms, batch_bits, n_req);
        if let Some(tx) = waiters.remove(&req.id) {
            let _ = tx.send(Response {
                id: req.id,
                next_token,
                logit,
                tokens: vec![next_token],
                done: true,
                bits: batch_bits,
                int8_acts: batch_int8,
                queue_ms: queue_ms.max(0.0),
                compute_ms: compute_ms / n_req as f64,
                prefill_ms: compute_ms / n_req as f64,
                decode_ms: 0.0,
                batch_size: n_req,
            });
        }
    }
}

/// Pad-and-pack a batch's prompts into a `(rows, t)` token buffer; returns
/// the buffer and each request's last prompt position (an empty prompt
/// reads position 0 of the all-pad row — it round-trips instead of
/// erroring).  PJRT passes the fixed executable shape `(bucket, seq_len)`;
/// the host single-token fast path passes the tight
/// `(n_requests, longest prompt)`.
fn fill_tokens(batch: &ReadyBatch, rows: usize, t: usize) -> (Vec<i32>, Vec<usize>) {
    let mut tokens = vec![0i32; rows * t];
    let mut last_pos = vec![0usize; rows];
    for (i, (req, _)) in batch.requests.iter().enumerate() {
        let n = req.prompt.len().min(t);
        tokens[i * t..i * t + n].copy_from_slice(&req.prompt[..n]);
        last_pos[i] = n.saturating_sub(1);
    }
    (tokens, last_pos)
}

/// PJRT path: weight args as literals (dense sets convert resident
/// tensors; paged sets decode one tensor at a time from the r-bit payload)
/// into the `fwd_b{B}` executable.
#[allow(clippy::too_many_arguments)]
fn execute_batch_pjrt(
    engine: &Engine,
    preset: &str,
    seq: usize,
    vocab: usize,
    store: &WeightStore,
    model: &QuantizedModel,
    batch: ReadyBatch,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
) -> Result<()> {
    let bucket = batch.bucket;
    let (tokens, last_pos) = fill_tokens(&batch, bucket, seq);
    let mut args = store.batch_args(model, batch.bits)?;
    args.push(lit_i32(&[bucket, seq], &tokens)?);
    let t0 = Instant::now();
    let out = engine.run(preset, &format!("fwd_b{bucket}"), &args)?;
    let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
    metrics.record_batch(
        batch.bits,
        compute_ms,
        store.batch_weight_bytes(batch.bits) as u64,
    );
    let logits = &out[0]; // (bucket, seq, vocab)
    respond_greedy(
        &logits.data,
        seq,
        vocab,
        batch.bits,
        false,
        batch.requests,
        &last_pos,
        compute_ms,
        waiters,
        metrics,
    );
    Ok(())
}

/// Host path, two shapes under one cached forward plan:
///
/// * **All-single-token batch** — one batched fused forward over the whole
///   batch (tight `n_requests × longest-prompt`, no bucket padding): the
///   packed payload streams once per GEMM block across every batchmate,
///   exactly like the pre-decode host path.  Sampling is still
///   per-request.
/// * **Generation batch** — one [`DecodeSession`] per request (its own
///   tight prompt length, KV capture needs b = 1): the first token streams
///   immediately; sessions live on in `active` for the worker to step.
///   A request whose prefill fails is answered with a closed channel
///   without failing its batchmates.
///
/// `queue_ms` is measured to the batch's execution start for every member,
/// so a batchmate's prefill compute never shows up as phantom queueing.
#[allow(clippy::too_many_arguments)]
fn execute_batch_host(
    preset: &PresetInfo,
    cfg: &ServerConfig,
    store: &mut WeightStore,
    model: &QuantizedModel,
    batch: ReadyBatch,
    waiters: &mut BTreeMap<u64, Sender<Response>>,
    metrics: &mut Metrics,
    active: &mut Vec<ActiveDecode>,
) -> Result<()> {
    let bits = batch.bits;
    let int8 = if batch.int8 {
        Some(cfg.act_quant)
    } else {
        None
    };
    // Warm f32 traffic rides the dense plan; everything else (including
    // int8 at a warm precision) needs packed handles.
    let plan = if batch.int8 || !cfg.warm_bits.contains(&bits) {
        store.plan_packed(model, &preset.model, bits, int8, metrics)?
    } else {
        store.plan_warm(model, &preset.model, bits, metrics)?
    };
    let n_req = batch.requests.len();
    let batch_int8 = batch.int8;
    let batch_start = Instant::now();

    if batch.requests.iter().all(|(r, _)| r.max_new_tokens <= 1) {
        // Batched fast path: amortize one fused multi-row forward across
        // the whole batch.  Causal attention makes each request's
        // last-position logits identical to its own tight forward.
        let seq = preset.model.seq_len;
        let vocab = preset.model.vocab;
        let t = batch
            .requests
            .iter()
            .map(|(r, _)| r.prompt.len().min(seq))
            .max()
            .unwrap_or(1)
            .max(1);
        let (tokens, last_pos) = fill_tokens(&batch, n_req, t);
        let logits = plan.forward(&tokens, n_req, t)?;
        let compute_ms = batch_start.elapsed().as_secs_f64() * 1e3;
        metrics.record_batch(bits, compute_ms, plan.weight_bytes() as u64);
        metrics.record_prefill(bits, compute_ms, (n_req * t) as u64);
        for (i, (req, enq)) in batch.requests.into_iter().enumerate() {
            let row_start = (i * t + last_pos[i]) * vocab;
            let row = &logits.data[row_start..row_start + vocab];
            let mut rng = match req.sampling {
                Sampling::Temperature { seed, .. } => Rng::new(seed),
                Sampling::Greedy => Rng::new(0),
            };
            let (next_token, logit) = sample_logits(row, &req.sampling, &mut rng);
            let queue_ms = batch_start.saturating_duration_since(enq).as_secs_f64() * 1e3;
            metrics.record(enq.elapsed().as_secs_f64() * 1e3, bits, n_req);
            if let Some(tx) = waiters.remove(&req.id) {
                let _ = tx.send(Response {
                    id: req.id,
                    next_token,
                    logit,
                    tokens: vec![next_token],
                    done: true,
                    bits,
                    int8_acts: batch_int8,
                    queue_ms,
                    compute_ms: compute_ms / n_req as f64,
                    prefill_ms: compute_ms / n_req as f64,
                    decode_ms: 0.0,
                    batch_size: n_req,
                });
            }
        }
        return Ok(());
    }

    let mut batch_ms = 0.0f64;
    for (req, enq) in batch.requests {
        let queue_ms = batch_start.saturating_duration_since(enq).as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let mut session = match DecodeSession::with_budget(
            plan.clone(),
            &req.prompt,
            req.sampling,
            req.max_new_tokens,
        ) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve worker: request {}: prefill failed: {e:#}", req.id);
                waiters.remove(&req.id);
                continue;
            }
        };
        let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
        batch_ms += prefill_ms;
        metrics.record_prefill(bits, prefill_ms, session.prompt_len() as u64);
        let (tok, logit) = session.sample();
        let done = req.max_new_tokens <= 1 || !session.can_advance();
        let resp = Response {
            id: req.id,
            next_token: tok,
            logit,
            tokens: if done {
                session.generated().to_vec()
            } else {
                Vec::new()
            },
            done,
            bits,
            int8_acts: batch_int8,
            queue_ms,
            compute_ms: prefill_ms,
            prefill_ms,
            decode_ms: 0.0,
            batch_size: n_req,
        };
        if done {
            metrics.record(enq.elapsed().as_secs_f64() * 1e3, bits, n_req);
            if let Some(tx) = waiters.remove(&req.id) {
                let _ = tx.send(resp);
            }
        } else {
            let alive = waiters.get(&req.id).is_some_and(|tx| tx.send(resp).is_ok());
            if !alive {
                waiters.remove(&req.id);
                continue;
            }
            active.push(ActiveDecode {
                id: req.id,
                session,
                remaining: req.max_new_tokens - 1,
                last: tok,
                bits,
                int8: batch_int8,
                enq,
                prefill_ms,
                decode_ms: 0.0,
                batch_size: n_req,
            });
        }
    }
    metrics.record_batch(bits, batch_ms, plan.weight_bytes() as u64);
    Ok(())
}
