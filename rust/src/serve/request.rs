//! Request / response types for the elastic-precision server.

use crate::runtime::Sampling;

/// What precision the client demands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecisionReq {
    /// A specific sliced bit-width (2/3/4/6/8).
    Bits(u32),
    /// "Best quality" — int8.
    Best,
    /// "Cheapest" — int2.
    Cheapest,
}

impl PrecisionReq {
    pub fn bits(&self) -> u32 {
        match self {
            PrecisionReq::Bits(b) => *b,
            PrecisionReq::Best => 8,
            PrecisionReq::Cheapest => 2,
        }
    }
}

/// One inference request: a token prompt + precision demand + generation
/// parameters.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub precision: PrecisionReq,
    /// Host-serving path only: quantize the quantized-layer inputs to
    /// symmetric int8 (one scale per token row) and run the integer-domain
    /// GEMV end-to-end (weights *and* activations quantized).  Requests
    /// with and without the flag never share a batch, and a request's
    /// logits never depend on its batchmates.  The PJRT backend rejects
    /// flagged requests at submit (response channel closes) rather than
    /// silently serving them as f32.
    pub int8_acts: bool,
    /// How many tokens to generate (≥ 1).  The worker validates at submit:
    /// 0 and values past the model's position capacity (`seq_len`) are
    /// rejected so a malformed request can never stall a decode batch.
    /// Values > 1 need the host backend (PJRT has no KV cache) and stream
    /// one [`Response`] per token; generation also ends early — with
    /// `done` set — if the KV cache's position capacity fills first.
    pub max_new_tokens: usize,
    /// Greedy (default) or seeded-temperature sampling; validated at
    /// submit ([`Sampling::validate`]).
    pub sampling: Sampling,
    /// Host backend only: serve this request under a Mix'n'Match
    /// **per-layer** bit map (layer *l* gets `per_layer[l]`, layers past
    /// the end the last entry — the registry's clamp) instead of the
    /// uniform `precision`.  Requests sharing a map decode together in one
    /// scheduler group; the map's handles are `Arc`-shared with the
    /// uniform precisions already paged in.  [`Response::bits`] and the
    /// per-precision metrics attribute this traffic to the map's
    /// **maximum** bit-width (the `precision` field does not describe
    /// what ran).  Validated at submit (empty maps and bit-widths outside
    /// [1, 8] are rejected); PJRT rejects the field outright.
    pub per_layer: Option<Vec<u32>>,
}

impl Request {
    /// Plain single-token greedy f32-activation request (the common case).
    pub fn new(id: u64, prompt: Vec<i32>, precision: PrecisionReq) -> Self {
        Request {
            id,
            prompt,
            precision,
            int8_acts: false,
            max_new_tokens: 1,
            sampling: Sampling::Greedy,
            per_layer: None,
        }
    }

    /// Multi-token generation request.
    pub fn generate(
        id: u64,
        prompt: Vec<i32>,
        precision: PrecisionReq,
        max_new_tokens: usize,
        sampling: Sampling,
    ) -> Self {
        Request {
            max_new_tokens,
            sampling,
            ..Request::new(id, prompt, precision)
        }
    }
}

/// One streamed token event + serving telemetry.
///
/// A request produces `max_new_tokens` of these on its response channel
/// (fewer if the KV cache's position capacity fills first); the last one
/// carries `done = true` and the complete `tokens` vector.
/// [`crate::serve::Server::infer`] drains to the final event for callers
/// who only want the finished result.
///
/// Under self-speculative serving ([`crate::serve::SpeculativeConfig`])
/// one scheduler round may deliver several of these at once — the tokens
/// a verify window accepted — with `compute_ms` the round's per-token
/// share.  The events themselves are indistinguishable from plain
/// decode's: same tokens, same logits, one event per token.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// The token this event produced.
    pub next_token: i32,
    /// Logit of that token under the serving precision.
    pub logit: f32,
    /// The complete generated stream — populated on the final (`done`)
    /// event only; intermediate events carry their token in `next_token`
    /// (so an n-token stream costs O(n) copies, not O(n²)).
    pub tokens: Vec<i32>,
    /// Last event of the stream.
    pub done: bool,
    pub bits: u32,
    /// Whether the integer-activation path served this request.
    pub int8_acts: bool,
    /// Queue + batch wait, ms.
    pub queue_ms: f64,
    /// Execution share attributed to this event, ms (PJRT or host).
    pub compute_ms: f64,
    /// This request's prefill compute, ms (host decode path; PJRT reports
    /// its batch share).
    pub prefill_ms: f64,
    /// Cumulative decode-step compute for this request so far, ms.
    pub decode_ms: f64,
    /// Size of the batch this request rode in (prefill batch).
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_bits() {
        assert_eq!(PrecisionReq::Best.bits(), 8);
        assert_eq!(PrecisionReq::Cheapest.bits(), 2);
        assert_eq!(PrecisionReq::Bits(3).bits(), 3);
    }

    #[test]
    fn default_request_is_single_token_greedy() {
        let r = Request::new(1, vec![1, 2], PrecisionReq::Best);
        assert_eq!(r.max_new_tokens, 1);
        assert_eq!(r.sampling, Sampling::Greedy);
        let g = Request::generate(
            2,
            vec![3],
            PrecisionReq::Cheapest,
            8,
            Sampling::Temperature { temp: 0.9, seed: 7 },
        );
        assert_eq!(g.max_new_tokens, 8);
        assert!(matches!(g.sampling, Sampling::Temperature { .. }));
    }
}
