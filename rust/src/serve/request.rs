//! Request / response types for the elastic-precision server.

/// What precision the client demands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecisionReq {
    /// A specific sliced bit-width (2/3/4/6/8).
    Bits(u32),
    /// "Best quality" — int8.
    Best,
    /// "Cheapest" — int2.
    Cheapest,
}

impl PrecisionReq {
    pub fn bits(&self) -> u32 {
        match self {
            PrecisionReq::Bits(b) => *b,
            PrecisionReq::Best => 8,
            PrecisionReq::Cheapest => 2,
        }
    }
}

/// One inference request: a token prompt + precision demand.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub precision: PrecisionReq,
    /// Host-serving path only: quantize the quantized-layer inputs to
    /// symmetric int8 (one scale per token row) and run the integer-domain
    /// GEMV end-to-end (weights *and* activations quantized).  Requests
    /// with and without the flag never share a batch, and a request's
    /// logits never depend on its batchmates.  The PJRT backend rejects
    /// flagged requests at submit (response channel closes) rather than
    /// silently serving them as f32.
    pub int8_acts: bool,
}

impl Request {
    /// Plain f32-activation request (the common case).
    pub fn new(id: u64, prompt: Vec<i32>, precision: PrecisionReq) -> Self {
        Request {
            id,
            prompt,
            precision,
            int8_acts: false,
        }
    }
}

/// Next-token result + serving telemetry.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub next_token: i32,
    /// Greedy-decode logit of the chosen token.
    pub logit: f32,
    pub bits: u32,
    /// Whether the integer-activation path served this request.
    pub int8_acts: bool,
    /// Queue + batch wait, ms.
    pub queue_ms: f64,
    /// Execution share attributed to this request, ms (PJRT or host).
    pub compute_ms: f64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_bits() {
        assert_eq!(PrecisionReq::Best.bits(), 8);
        assert_eq!(PrecisionReq::Cheapest.bits(), 2);
        assert_eq!(PrecisionReq::Bits(3).bits(), 3);
    }
}
