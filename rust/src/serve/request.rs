//! Request / response types for the elastic-precision server.

/// What precision the client demands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecisionReq {
    /// A specific sliced bit-width (2/3/4/6/8).
    Bits(u32),
    /// "Best quality" — int8.
    Best,
    /// "Cheapest" — int2.
    Cheapest,
}

impl PrecisionReq {
    pub fn bits(&self) -> u32 {
        match self {
            PrecisionReq::Bits(b) => *b,
            PrecisionReq::Best => 8,
            PrecisionReq::Cheapest => 2,
        }
    }
}

/// One inference request: a token prompt + precision demand.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub precision: PrecisionReq,
}

/// Next-token result + serving telemetry.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub next_token: i32,
    /// Greedy-decode logit of the chosen token.
    pub logit: f32,
    pub bits: u32,
    /// Queue + batch wait, ms.
    pub queue_ms: f64,
    /// PJRT execution share attributed to this request, ms.
    pub compute_ms: f64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_bits() {
        assert_eq!(PrecisionReq::Best.bits(), 8);
        assert_eq!(PrecisionReq::Cheapest.bits(), 2);
        assert_eq!(PrecisionReq::Bits(3).bits(), 3);
    }
}
