//! Deployment planner (paper §5.4): given a weight-memory budget and the
//! measured accuracy of each configuration, pick the best deployable model.
//!
//! Candidates: homogeneous slices (int8/6/4/3/2, optional EP) and
//! Pyramid Mix'n'Match assignments.  The paper's motivating case — "the
//! budget fits int3 but the hardware only supports int2/int4" — falls out
//! naturally: a Pyramid mix of {2, 4, 8} wins the int3-sized budget.

use crate::mixnmatch::strategy::{assignments_for, compositions, Strategy};
use crate::model::{PrecisionAssignment, QuantizedModel};

/// A candidate deployment with measured-or-estimated quality.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    pub label: String,
    pub assign: PrecisionAssignment,
    pub storage_bytes: usize,
    pub bits_per_param: f64,
    /// Estimated accuracy (from the accuracy table the caller supplies).
    pub accuracy: f64,
}

/// Enumerate candidates and pick the most accurate plan under `budget_bytes`.
///
/// `accuracy_of` maps a candidate's bits/param to expected accuracy —
/// callers use the measured Mix'n'Match curve (Fig. 2) or a coarse table.
/// `hardware_bits` restricts which homogeneous precisions the target can
/// execute (e.g. [8, 4, 2] when there is no int3/int6 kernel).
pub fn plan_deployment(
    model: &QuantizedModel,
    n_layers: usize,
    budget_bytes: usize,
    hardware_bits: &[u32],
    accuracy_of: impl Fn(&PrecisionAssignment, f64) -> f64,
) -> Option<DeploymentPlan> {
    let mut best: Option<DeploymentPlan> = None;
    let mut consider = |label: String, assign: PrecisionAssignment| {
        let bytes = model.storage_bytes(&assign);
        if bytes > budget_bytes {
            return;
        }
        let bpp = model.bits_per_param(&assign);
        let acc = accuracy_of(&assign, bpp);
        let cand = DeploymentPlan {
            label,
            assign,
            storage_bytes: bytes,
            bits_per_param: bpp,
            accuracy: acc,
        };
        let better = match &best {
            None => true,
            Some(b) => {
                cand.accuracy > b.accuracy
                    || (cand.accuracy == b.accuracy && cand.storage_bytes < b.storage_bytes)
            }
        };
        if better {
            best = Some(cand);
        }
    };

    for &bits in hardware_bits {
        consider(
            format!("uniform-int{bits}"),
            PrecisionAssignment::uniform(bits),
        );
        consider(
            format!("uniform-int{bits}-ep"),
            PrecisionAssignment::Uniform {
                bits,
                extra_precision: true,
            },
        );
    }
    // Mix'n'Match only over hardware-supported {2,4,8} subsets
    let can_mix = [2u32, 4, 8]
        .iter()
        .all(|b| hardware_bits.contains(b));
    if can_mix {
        for comp in compositions(n_layers) {
            let bits = assignments_for(Strategy::Pyramid, comp, n_layers);
            consider(
                format!("pyramid-{comp:?}"),
                PrecisionAssignment::PerLayer {
                    bits,
                    extra_precision: false,
                },
            );
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::model::registry::QuantizedTensor;
    use crate::model::Tensor;
    use std::collections::BTreeMap;

    fn toy_model(layers: usize) -> QuantizedModel {
        let mut rng = Rng::new(1);
        let mut params = BTreeMap::new();
        let mut quantized = BTreeMap::new();
        let mut param_order = Vec::new();
        let mut quantized_order = Vec::new();
        for l in 0..layers {
            let name = format!("layer{l}.ffn.w_in");
            let data: Vec<f32> = (0..64 * 32).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let t = Tensor::new(vec![64, 32], data).unwrap();
            params.insert(name.clone(), t.clone());
            quantized.insert(
                name.clone(),
                QuantizedTensor::from_weight(t, None, None, None).unwrap(),
            );
            param_order.push(name.clone());
            quantized_order.push(name);
        }
        QuantizedModel::from_parts(params, quantized, param_order, quantized_order)
    }

    #[test]
    fn tight_budget_forces_low_bits() {
        let m = toy_model(4);
        let int8_bytes = m.storage_bytes(&PrecisionAssignment::uniform(8));
        let int2_bytes = m.storage_bytes(&PrecisionAssignment::uniform(2));
        // budget below int4 → must pick an int2-ish plan
        let budget = int2_bytes + (int8_bytes - int2_bytes) / 8;
        let plan = plan_deployment(&m, 4, budget, &[8, 4, 2], |_, bpp| 0.5 + 0.05 * bpp)
            .expect("some plan fits");
        assert!(plan.storage_bytes <= budget);
        assert!(plan.bits_per_param < 4.0, "{}", plan.bits_per_param);
    }

    #[test]
    fn mixnmatch_beats_uniform_under_int3_budget() {
        let m = toy_model(4);
        // budget ≈ int3 model, hardware without int3 support
        let int2 = m.storage_bytes(&PrecisionAssignment::uniform(2));
        let int4 = m.storage_bytes(&PrecisionAssignment::uniform(4));
        let budget = (int2 + int4) / 2;
        let plan = plan_deployment(&m, 4, budget, &[8, 4, 2], |_, bpp| bpp)
            .expect("plan exists");
        // with accuracy == bits/param, the winner must use the budget better
        // than uniform int2 (2.0)
        assert!(plan.accuracy > 2.0, "{plan:?}");
    }

    #[test]
    fn impossible_budget_returns_none() {
        let m = toy_model(2);
        assert!(plan_deployment(&m, 2, 4, &[8, 4, 2], |_, _| 1.0).is_none());
    }

    #[test]
    fn hardware_restriction_respected() {
        let m = toy_model(3);
        let big = m.storage_bytes(&PrecisionAssignment::uniform(8)) * 2;
        let plan = plan_deployment(&m, 3, big, &[4], |_, bpp| bpp).unwrap();
        // only int4 available → uniform int4 wins
        assert!(plan.label.contains("int4"), "{}", plan.label);
    }
}
