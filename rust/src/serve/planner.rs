//! Deployment planner (paper §5.4): given a weight-memory budget and the
//! measured accuracy of each configuration, pick the best deployable model.
//!
//! Candidates: homogeneous slices (int8/6/4/3/2, optional EP) and
//! Pyramid Mix'n'Match assignments.  The paper's motivating case — "the
//! budget fits int3 but the hardware only supports int2/int4" — falls out
//! naturally: a Pyramid mix of {2, 4, 8} wins the int3-sized budget.
//!
//! This module also hosts the **elastic precision planner**
//! ([`ElasticPlanner`]): the runtime twin of the deployment decision.
//! Where [`plan_deployment`] picks a precision once per install, the
//! elastic planner watches load watermarks (resident KV bytes, prefill
//! queue depth) every scheduling round and asks for **mid-stream** shifts:
//! under pressure, live sessions of the highest uniform precision drop one
//! rung down the MatQuant ladder (the nested payload makes the lower-bit
//! plan free to page — it is an MSB-prefix view of the already-resident
//! int8 masters); once pressure clears, displaced sessions return to their
//! native precision.  Decisions are pure functions of the observed load,
//! so policy is unit-testable without a scheduler.

use crate::mixnmatch::strategy::{assignments_for, compositions, Strategy};
use crate::model::{PrecisionAssignment, QuantizedModel};
use crate::MATQUANT_BITS;

/// A candidate deployment with measured-or-estimated quality.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    pub label: String,
    pub assign: PrecisionAssignment,
    pub storage_bytes: usize,
    pub bits_per_param: f64,
    /// Estimated accuracy (from the accuracy table the caller supplies).
    pub accuracy: f64,
}

/// Enumerate candidates and pick the most accurate plan under `budget_bytes`.
///
/// `accuracy_of` maps a candidate's bits/param to expected accuracy —
/// callers use the measured Mix'n'Match curve (Fig. 2) or a coarse table.
/// `hardware_bits` restricts which homogeneous precisions the target can
/// execute (e.g. [8, 4, 2] when there is no int3/int6 kernel).
pub fn plan_deployment(
    model: &QuantizedModel,
    n_layers: usize,
    budget_bytes: usize,
    hardware_bits: &[u32],
    accuracy_of: impl Fn(&PrecisionAssignment, f64) -> f64,
) -> Option<DeploymentPlan> {
    let mut best: Option<DeploymentPlan> = None;
    let mut consider = |label: String, assign: PrecisionAssignment| {
        let bytes = model.storage_bytes(&assign);
        if bytes > budget_bytes {
            return;
        }
        let bpp = model.bits_per_param(&assign);
        let acc = accuracy_of(&assign, bpp);
        let cand = DeploymentPlan {
            label,
            assign,
            storage_bytes: bytes,
            bits_per_param: bpp,
            accuracy: acc,
        };
        let better = match &best {
            None => true,
            Some(b) => {
                cand.accuracy > b.accuracy
                    || (cand.accuracy == b.accuracy && cand.storage_bytes < b.storage_bytes)
            }
        };
        if better {
            best = Some(cand);
        }
    };

    for &bits in hardware_bits {
        consider(
            format!("uniform-int{bits}"),
            PrecisionAssignment::uniform(bits),
        );
        consider(
            format!("uniform-int{bits}-ep"),
            PrecisionAssignment::Uniform {
                bits,
                extra_precision: true,
            },
        );
    }
    // Mix'n'Match only over hardware-supported {2,4,8} subsets
    let can_mix = [2u32, 4, 8]
        .iter()
        .all(|b| hardware_bits.contains(b));
    if can_mix {
        for comp in compositions(n_layers) {
            let bits = assignments_for(Strategy::Pyramid, comp, n_layers);
            consider(
                format!("pyramid-{comp:?}"),
                PrecisionAssignment::PerLayer {
                    bits,
                    extra_precision: false,
                },
            );
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Elastic precision under load
// ---------------------------------------------------------------------------

/// Which way the elastic planner wants to move precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShiftDirection {
    /// Load above the high watermarks: push the highest uniform group one
    /// rung down the ladder.
    Down,
    /// Load below the low watermarks: restore displaced sessions to their
    /// native precision.
    Up,
}

/// Watermark policy for mid-stream precision shifting.
///
/// A `Down` shift fires when **either** high watermark is breached; an `Up`
/// shift only when **both** low watermarks hold (hysteresis — the gap
/// between the high and low marks is what prevents flapping, together with
/// [`ElasticConfig::cooldown_rounds`]).
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Resident KV bytes at/above which a downshift fires.
    pub kv_high_bytes: u64,
    /// Resident KV bytes at/below which upshifts become eligible.
    pub kv_low_bytes: u64,
    /// Pending prefill-queue depth at/above which a downshift fires.
    pub queue_high: usize,
    /// Queue depth at/below which upshifts become eligible.
    pub queue_low: usize,
    /// The precision ladder, highest first (default [`MATQUANT_BITS`] =
    /// `[8, 4, 2]` — the slice widths the nested payload serves for free).
    pub ladder: Vec<u32>,
    /// Rounds that must pass after a shift before the next one.
    pub cooldown_rounds: u64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            kv_high_bytes: u64::MAX,
            kv_low_bytes: u64::MAX,
            queue_high: usize::MAX,
            queue_low: usize::MAX,
            ladder: MATQUANT_BITS.to_vec(),
            cooldown_rounds: 8,
        }
    }
}

impl ElasticConfig {
    /// The next rung below `bits` on the ladder (`None` at the bottom or
    /// for off-ladder precisions below every rung).
    pub fn next_down(&self, bits: u32) -> Option<u32> {
        self.ladder.iter().copied().filter(|&b| b < bits).max()
    }
}

/// Watermark-driven shift policy: pure decisions from observed load, with
/// cooldown bookkeeping.  The scheduler applies the mechanics
/// ([`crate::serve::Scheduler::shift_uniform`] /
/// [`crate::serve::Scheduler::shift_up_natives`]); this type only decides.
#[derive(Debug, Clone)]
pub struct ElasticPlanner {
    pub cfg: ElasticConfig,
    last_shift_round: Option<u64>,
}

impl ElasticPlanner {
    pub fn new(cfg: ElasticConfig) -> Self {
        ElasticPlanner {
            cfg,
            last_shift_round: None,
        }
    }

    /// Decide at round `round` under the observed load.  `None` while the
    /// cooldown holds or while load sits between the watermarks (the
    /// hysteresis band).
    pub fn decide(&self, round: u64, kv_bytes: u64, queue_depth: usize) -> Option<ShiftDirection> {
        if let Some(last) = self.last_shift_round {
            if round.saturating_sub(last) < self.cfg.cooldown_rounds {
                return None;
            }
        }
        if kv_bytes >= self.cfg.kv_high_bytes || queue_depth >= self.cfg.queue_high {
            return Some(ShiftDirection::Down);
        }
        if kv_bytes <= self.cfg.kv_low_bytes && queue_depth <= self.cfg.queue_low {
            return Some(ShiftDirection::Up);
        }
        None
    }

    /// Record that a shift was applied at `round` (starts the cooldown).
    pub fn note_shift(&mut self, round: u64) {
        self.last_shift_round = Some(round);
    }

    /// Whether self-speculative decode may run under the observed load:
    /// only strictly below BOTH high watermarks.  Speculation spends
    /// exactly what a breached watermark says is exhausted — `k`
    /// provisional KV rows per member and extra draft compute — so the
    /// serving worker suspends it the moment a downshift would be on the
    /// table ([`crate::serve::Scheduler::suspend_speculation`]).  No
    /// cooldown or hysteresis here: suspension is a pause, not a shift,
    /// and may flap freely with the load.
    pub fn speculation_allowed(&self, kv_bytes: u64, queue_depth: usize) -> bool {
        kv_bytes < self.cfg.kv_high_bytes && queue_depth < self.cfg.queue_high
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::model::registry::QuantizedTensor;
    use crate::model::Tensor;
    use std::collections::BTreeMap;

    fn toy_model(layers: usize) -> QuantizedModel {
        let mut rng = Rng::new(1);
        let mut params = BTreeMap::new();
        let mut quantized = BTreeMap::new();
        let mut param_order = Vec::new();
        let mut quantized_order = Vec::new();
        for l in 0..layers {
            let name = format!("layer{l}.ffn.w_in");
            let data: Vec<f32> = (0..64 * 32).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let t = Tensor::new(vec![64, 32], data).unwrap();
            params.insert(name.clone(), t.clone());
            quantized.insert(
                name.clone(),
                QuantizedTensor::from_weight(t, None, None, None).unwrap(),
            );
            param_order.push(name.clone());
            quantized_order.push(name);
        }
        QuantizedModel::from_parts(params, quantized, param_order, quantized_order)
    }

    #[test]
    fn tight_budget_forces_low_bits() {
        let m = toy_model(4);
        let int8_bytes = m.storage_bytes(&PrecisionAssignment::uniform(8));
        let int2_bytes = m.storage_bytes(&PrecisionAssignment::uniform(2));
        // budget below int4 → must pick an int2-ish plan
        let budget = int2_bytes + (int8_bytes - int2_bytes) / 8;
        let plan = plan_deployment(&m, 4, budget, &[8, 4, 2], |_, bpp| 0.5 + 0.05 * bpp)
            .expect("some plan fits");
        assert!(plan.storage_bytes <= budget);
        assert!(plan.bits_per_param < 4.0, "{}", plan.bits_per_param);
    }

    #[test]
    fn mixnmatch_beats_uniform_under_int3_budget() {
        let m = toy_model(4);
        // budget ≈ int3 model, hardware without int3 support
        let int2 = m.storage_bytes(&PrecisionAssignment::uniform(2));
        let int4 = m.storage_bytes(&PrecisionAssignment::uniform(4));
        let budget = (int2 + int4) / 2;
        let plan = plan_deployment(&m, 4, budget, &[8, 4, 2], |_, bpp| bpp)
            .expect("plan exists");
        // with accuracy == bits/param, the winner must use the budget better
        // than uniform int2 (2.0)
        assert!(plan.accuracy > 2.0, "{plan:?}");
    }

    #[test]
    fn impossible_budget_returns_none() {
        let m = toy_model(2);
        assert!(plan_deployment(&m, 2, 4, &[8, 4, 2], |_, _| 1.0).is_none());
    }

    #[test]
    fn hardware_restriction_respected() {
        let m = toy_model(3);
        let big = m.storage_bytes(&PrecisionAssignment::uniform(8)) * 2;
        let plan = plan_deployment(&m, 3, big, &[4], |_, bpp| bpp).unwrap();
        // only int4 available → uniform int4 wins
        assert!(plan.label.contains("int4"), "{}", plan.label);
    }

    fn elastic_cfg() -> ElasticConfig {
        ElasticConfig {
            kv_high_bytes: 1000,
            kv_low_bytes: 200,
            queue_high: 8,
            queue_low: 1,
            ladder: vec![8, 4, 2],
            cooldown_rounds: 4,
        }
    }

    #[test]
    fn elastic_watermarks_drive_direction() {
        let p = ElasticPlanner::new(elastic_cfg());
        // either high watermark fires a downshift
        assert_eq!(p.decide(0, 1000, 0), Some(ShiftDirection::Down));
        assert_eq!(p.decide(0, 0, 8), Some(ShiftDirection::Down));
        // both low marks must hold for an upshift
        assert_eq!(p.decide(0, 200, 1), Some(ShiftDirection::Up));
        assert_eq!(p.decide(0, 200, 2), None, "queue above low mark");
        assert_eq!(p.decide(0, 500, 0), None, "hysteresis band is quiet");
    }

    #[test]
    fn elastic_cooldown_suppresses_consecutive_shifts() {
        let mut p = ElasticPlanner::new(elastic_cfg());
        assert!(p.decide(10, 5000, 0).is_some());
        p.note_shift(10);
        for r in 10..14 {
            assert_eq!(p.decide(r, 5000, 0), None, "round {r} inside cooldown");
        }
        assert_eq!(p.decide(14, 5000, 0), Some(ShiftDirection::Down));
    }

    #[test]
    fn speculation_gated_by_high_watermarks() {
        let p = ElasticPlanner::new(elastic_cfg());
        assert!(p.speculation_allowed(0, 0));
        assert!(p.speculation_allowed(999, 7), "just under both marks");
        assert!(!p.speculation_allowed(1000, 0), "KV at the high mark");
        assert!(!p.speculation_allowed(0, 8), "queue at the high mark");
        // The hysteresis band suppresses SHIFTS but not speculation — a
        // pause is free to flap with the load.
        assert!(p.speculation_allowed(500, 4));
    }

    #[test]
    fn elastic_ladder_steps_one_rung() {
        let cfg = elastic_cfg();
        assert_eq!(cfg.next_down(8), Some(4));
        assert_eq!(cfg.next_down(4), Some(2));
        assert_eq!(cfg.next_down(2), None, "bottom rung");
        assert_eq!(cfg.next_down(6), Some(4), "off-ladder width snaps down");
        assert_eq!(cfg.next_down(1), None);
        // default ladder is the MatQuant slice set
        assert_eq!(ElasticConfig::default().ladder, vec![8, 4, 2]);
    }
}
