//! The N-worker pool behind the TCP front door.
//!
//! Topology: every worker thread owns its own [`Scheduler`] (continuous
//! batching, paged KV, self-speculative decode) and its own
//! [`ElasticPlanner`], while ALL workers share
//!
//! * one [`WeightStore`] behind a mutex — [`crate::runtime::ForwardPlan`]s
//!   resolve once per [`PlanKey`] fleet-wide (the store is only touched at
//!   admission and on elastic shifts, never inside a decode round);
//! * one [`crate::runtime::PagePool`] — every scheduler is built with
//!   [`Scheduler::with_pool`], so the KV admission budget
//!   ([`ServerConfig::kv_capacity_bytes`]) is a *fleet* budget measured
//!   against truly resident pages, and prefix-sharing (copy-on-write page
//!   adoption) works across workers;
//! * one admission queue — submits land here and workers pull their
//!   assignments between rounds.
//!
//! Dispatch is **precision-affine**: requests resolving to the same
//! [`PlanKey`] route to the same worker (first key sighting picks the
//! least-loaded worker), keeping step-round groups dense — ten int4
//! streams on one worker share each round's fused GEMM; spread over four
//! workers they would quadruple the payload streaming per token.  The
//! queue is **budget-aware**: a worker only takes an entry when the
//! shared pool has headroom for its page-rounded KV projection, so a
//! burst parks in the queue instead of thrashing admission inside a
//! scheduler.
//!
//! Failure semantics — nothing is ever silently dropped:
//!
//! * [`WorkerPool::begin_drain`] — new submits fail fast with
//!   [`SubmitError::Draining`]; queued + live work finishes, then workers
//!   exit.
//! * [`WorkerPool::kill_worker`] — the victim's *queued* (never
//!   prefilled) requests re-enter the shared queue, carrying their
//!   original enqueue time, and complete on surviving workers; its *live*
//!   streams get a terminal error event (their KV pages lived in the dead
//!   scheduler); its pages return to the shared pool when the scheduler
//!   drops.
//! * [`WorkerPool::shutdown`] — drain, join, then explicitly fail
//!   whatever could not be served (e.g. every worker was killed first).

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::model::{PresetInfo, QuantizedModel};
use crate::quant::ActCalibration;
use crate::serve::metrics::Metrics;
use crate::serve::planner::ElasticPlanner;
use crate::serve::request::{Request, Response};
use crate::serve::scheduler::{projected_kv_bytes, Scheduler, SchedulerConfig};
use crate::serve::server::{apply_elastic, prepare_submit, spec_slots_for, ServerConfig};
use crate::serve::weights::{PlanKey, WeightStore};
use crate::Result;

/// Where a stream's events go.  The TCP listener implements this over a
/// connection's outbox; in-process callers use [`ChannelSink`].  Exactly
/// one terminal signal is delivered per accepted request: a `done`
/// [`Response`] through [`EventSink::event`], or one
/// [`EventSink::fail`].
pub trait EventSink: Send {
    /// Deliver one token event.  Returning `false` means the client is
    /// gone — the stream will be retired and pruned.
    fn event(&mut self, resp: &Response) -> bool;
    /// Deliver a terminal error (worker death, failed plan swap,
    /// validation rejection) — the stream is over.
    fn fail(&mut self, msg: &str);
    /// Synchronous pre-queue rejection: the submitter reports the error
    /// out-of-band (HTTP status line, `Err` return), so the sink must go
    /// quiet — a TCP sink that emitted an in-band error chunk here would
    /// corrupt the connection with stream framing no head was sent for.
    fn rejected(&mut self) {}
}

/// [`EventSink`] over an mpsc channel — the in-process path.  A terminal
/// failure is signalled by dropping the sender: the receiver sees a recv
/// error exactly as with [`crate::serve::Server`]'s host path.
pub struct ChannelSink(pub Sender<Response>);

impl EventSink for ChannelSink {
    fn event(&mut self, resp: &Response) -> bool {
        self.0.send(resp.clone()).is_ok()
    }
    fn fail(&mut self, _msg: &str) {
        // Dropping the sender (when self drops) closes the channel; the
        // blocked client unblocks with a recv error.
    }
}

/// Why a submit was refused *synchronously* — the caller finds out
/// immediately, never by timeout.
#[derive(Debug)]
pub enum SubmitError {
    /// [`WorkerPool::begin_drain`] has run; the pool accepts no new work.
    Draining,
    /// The request can never be served (duplicate in-flight id, no live
    /// workers left).
    Rejected(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Draining => write!(f, "server draining"),
            SubmitError::Rejected(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Pool knobs: worker count plus the per-worker serving configuration
/// (shared verbatim with the single-worker [`crate::serve::Server`] so a
/// fleet of one is configured exactly like the host path).
#[derive(Debug, Clone)]
pub struct PoolConfig {
    pub workers: usize,
    pub server: ServerConfig,
}

/// One queued request: its sink, original enqueue time (TTFT counts from
/// here, not from when a worker picks it up), affinity key, assigned
/// worker, and page-rounded KV projection for the budget gate.
struct QueueEntry {
    req: Request,
    sink: Box<dyn EventSink>,
    enq: Instant,
    key: PlanKey,
    worker: usize,
    projected: u64,
}

struct QueueState {
    entries: VecDeque<QueueEntry>,
    /// PlanKey → worker that serves it (precision affinity).
    affinity: BTreeMap<PlanKey, usize>,
    /// Requests assigned to each worker (queued + owned) — the
    /// least-loaded pick for a first-seen key.
    loads: Vec<usize>,
    /// Workers that have exited (killed or drained) — their queued
    /// entries are up for rehoming.
    dead: Vec<bool>,
    /// Kill orders not yet observed by their worker.
    kills: Vec<bool>,
    /// Ids queued or live anywhere in the fleet — duplicate submits are
    /// rejected exactly as on the single-worker path.
    in_flight: BTreeSet<u64>,
    draining: bool,
}

struct PoolShared {
    q: Mutex<QueueState>,
    cv: Condvar,
    pool: crate::runtime::PagePool,
    store: Mutex<WeightStore>,
    model: QuantizedModel,
    preset: PresetInfo,
    cfg: ServerConfig,
    /// Per-worker metrics, merged on demand ([`Metrics::merge`]) into the
    /// fleet view — workers never contend on a shared metrics lock inside
    /// a round.
    metrics: Vec<Mutex<Metrics>>,
    /// Server-assigned request ids (TCP clients that do not pin one).
    /// Starts high so client-pinned small ids never collide.
    next_id: AtomicU64,
}

// The whole point of the pool: everything a worker touches is shareable.
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<PoolShared>();
};

fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Classify a request exactly as [`prepare_submit`] will: reporting
/// width, plan key (affinity), and page-rounded KV projection (budget
/// gate).  Kept in lock-step via [`spec_slots_for`].
fn classify(cfg: &ServerConfig, preset: &PresetInfo, req: &Request) -> (u32, PlanKey, u64) {
    let bits = match &req.per_layer {
        Some(map) if !map.is_empty() => *map.iter().max().expect("non-empty"),
        _ => req.precision.bits(),
    };
    let key = if let Some(map) = &req.per_layer {
        PlanKey::PerLayer {
            bits: map.clone(),
            int8: req.int8_acts,
        }
    } else if req.int8_acts || !cfg.warm_bits.contains(&bits) {
        PlanKey::Packed {
            bits,
            int8: req.int8_acts,
        }
    } else {
        PlanKey::Warm(bits)
    };
    let projected = projected_kv_bytes(
        &preset.model,
        req.prompt.len(),
        req.max_new_tokens,
        spec_slots_for(cfg, req, bits),
        &cfg.kv,
    );
    (bits, key, projected)
}

/// Handle to a running worker fleet.  Clones share the fleet; shutdown is
/// explicit ([`WorkerPool::shutdown`]), never drop-driven, because any
/// clone (e.g. the one the TCP listener holds) may outlive another.
#[derive(Clone)]
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl WorkerPool {
    /// Boot `cfg.workers` worker threads over one shared weight store and
    /// one shared page pool.  Warm plans and the activation calibration
    /// build once, before any worker starts.
    pub fn start(preset: PresetInfo, model: QuantizedModel, cfg: PoolConfig) -> Result<WorkerPool> {
        let workers = cfg.workers.max(1);
        let server_cfg = cfg.server;
        let pool = crate::runtime::PagePool::new(server_cfg.kv, server_cfg.kv_capacity_bytes);
        let mut store = WeightStore::new();
        let mut boot_metrics = Metrics::default();
        if let Some(path) = &server_cfg.calibration {
            match ActCalibration::load(path) {
                Ok(c) => store.set_calibration(Some(Arc::new(c))),
                Err(e) => eprintln!("pool: calibration {path:?}: {e:#}"),
            }
        }
        for &b in &server_cfg.warm_bits {
            if let Err(e) = store.plan_warm(&model, &preset.model, b, &mut boot_metrics) {
                eprintln!("pool: warm plan int{b}: {e:#}");
            }
        }
        let mut metrics = Vec::with_capacity(workers);
        metrics.push(Mutex::new(boot_metrics)); // boot plan-build bytes land on worker 0
        for _ in 1..workers {
            metrics.push(Mutex::new(Metrics::default()));
        }
        let shared = Arc::new(PoolShared {
            q: Mutex::new(QueueState {
                entries: VecDeque::new(),
                affinity: BTreeMap::new(),
                loads: vec![0; workers],
                dead: vec![false; workers],
                kills: vec![false; workers],
                in_flight: BTreeSet::new(),
                draining: false,
            }),
            cv: Condvar::new(),
            pool,
            store: Mutex::new(store),
            model,
            preset,
            cfg: server_cfg,
            metrics,
            next_id: AtomicU64::new(1 << 48),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let s = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mq-pool-worker-{i}"))
                    .spawn(move || worker_loop(s, i))
                    .context("spawning pool worker")?,
            );
        }
        Ok(WorkerPool {
            shared,
            threads: Arc::new(Mutex::new(handles)),
        })
    }

    /// Enqueue a request with an arbitrary sink.  Fails *synchronously*
    /// when the pool is draining, the id is already in flight, or no live
    /// worker remains — the caller can answer the client immediately
    /// instead of letting it hang.
    pub fn submit_with_sink(
        &self,
        req: Request,
        mut sink: Box<dyn EventSink>,
    ) -> std::result::Result<(), SubmitError> {
        let s = &self.shared;
        let (_bits, key, projected) = classify(&s.cfg, &s.preset, &req);
        // Mirror `prepare_submit`: a request whose page-rounded KV
        // projection alone exceeds the fleet budget can never pass the
        // take-time gate (resident + projected <= cap fails even at
        // resident = 0) — enqueueing it would park the client forever
        // and wedge drain (the assigned worker never exits).
        if let Some(cap) = s.cfg.kv_capacity_bytes {
            if projected > cap {
                sink.rejected();
                return Err(SubmitError::Rejected(format!(
                    "projected KV {projected}B exceeds the {cap}B budget"
                )));
            }
        }
        let mut q = lock(&s.q);
        if q.draining {
            drop(q);
            sink.rejected();
            return Err(SubmitError::Draining);
        }
        if q.in_flight.contains(&req.id) {
            drop(q);
            sink.rejected();
            return Err(SubmitError::Rejected(format!(
                "request id {} already in flight",
                req.id
            )));
        }
        let worker = match q.affinity.get(&key) {
            Some(&w) if !q.dead[w] && !q.kills[w] => w,
            _ => {
                let picked = (0..q.loads.len())
                    .filter(|&w| !q.dead[w] && !q.kills[w])
                    .min_by_key(|&w| q.loads[w]);
                match picked {
                    Some(w) => w,
                    None => {
                        drop(q);
                        sink.rejected();
                        return Err(SubmitError::Rejected("no live workers".into()));
                    }
                }
            }
        };
        q.affinity.insert(key.clone(), worker);
        q.loads[worker] += 1;
        q.in_flight.insert(req.id);
        q.entries.push_back(QueueEntry {
            req,
            sink,
            enq: Instant::now(),
            key,
            worker,
            projected,
        });
        drop(q);
        s.cv.notify_all();
        Ok(())
    }

    /// Submit with a channel sink; mirrors [`crate::serve::Server::submit`]
    /// — one [`Response`] per token, the last with `done`, and a closed
    /// channel (recv error) on terminal failure.
    pub fn submit(&self, req: Request) -> std::result::Result<Receiver<Response>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit_with_sink(req, Box::new(ChannelSink(tx)))?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait for the final event.
    pub fn infer(&self, req: Request) -> Result<Response> {
        let rx = self.submit(req).map_err(|e| anyhow::anyhow!("{e}"))?;
        loop {
            let r = rx.recv().context("waiting for pool response")?;
            if r.done {
                return Ok(r);
            }
        }
    }

    /// Stop accepting work.  Every submit from this point on fails fast
    /// with [`SubmitError::Draining`]; queued and live work still
    /// completes, after which workers exit.
    pub fn begin_drain(&self) {
        lock(&self.shared.q).draining = true;
        self.shared.cv.notify_all();
    }

    /// Whether a drain has begun.
    pub fn draining(&self) -> bool {
        lock(&self.shared.q).draining
    }

    /// Order worker `idx` to die before its next round.  Its queued
    /// requests re-enter the shared queue; its live streams get terminal
    /// error events; its KV pages return to the shared pool.
    pub fn kill_worker(&self, idx: usize) {
        let mut q = lock(&self.shared.q);
        if idx < q.kills.len() {
            q.kills[idx] = true;
        }
        drop(q);
        self.shared.cv.notify_all();
    }

    /// Workers that have not exited (or been ordered to).
    pub fn live_workers(&self) -> usize {
        let q = lock(&self.shared.q);
        (0..q.dead.len()).filter(|&w| !q.dead[w] && !q.kills[w]).count()
    }

    pub fn workers(&self) -> usize {
        self.shared.metrics.len()
    }

    /// The worker a request would currently route to (tests).
    pub fn route_of(&self, req: &Request) -> Option<usize> {
        let (_b, key, _p) = classify(&self.shared.cfg, &self.shared.preset, req);
        lock(&self.shared.q).affinity.get(&key).copied()
    }

    /// Handle to the fleet-shared KV page pool (gauges in tests/benches).
    pub fn page_pool(&self) -> crate::runtime::PagePool {
        self.shared.pool.clone()
    }

    /// Server-assigned id for a client that did not pin one.
    pub fn next_request_id(&self) -> u64 {
        self.shared.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Merge every worker's metrics into one fleet view; the KV gauge is
    /// re-read from the shared pool (the single source of truth all
    /// workers gauge against).
    pub fn fleet_metrics(&self) -> Metrics {
        let mut fleet = Metrics::default();
        for m in &self.shared.metrics {
            fleet.merge(&lock(m));
        }
        fleet.set_kv_bytes(self.shared.pool.resident_bytes());
        fleet
    }

    pub fn metrics_report(&self) -> String {
        self.fleet_metrics().report()
    }

    /// Drain and join the fleet.  Whatever could not be served (every
    /// worker died before the queue emptied) is failed explicitly — no
    /// sink is ever silently dropped.
    pub fn shutdown(&self) -> Result<()> {
        self.begin_drain();
        let handles: Vec<JoinHandle<()>> = lock(&self.threads).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        let leftovers: Vec<QueueEntry> = {
            let mut q = lock(&self.shared.q);
            let left: Vec<QueueEntry> = q.entries.drain(..).collect();
            for e in &left {
                q.in_flight.remove(&e.req.id);
            }
            left
        };
        for mut e in leftovers {
            e.sink
                .fail("server shut down before the request was served");
        }
        Ok(())
    }
}

impl PoolShared {
    /// A worker finished (served or failed) requests it owned: release
    /// their ids and its load share, and wake budget-gated takers — the
    /// pages those streams held are free now.
    fn finish(&self, ids: &[u64], worker: usize) {
        if ids.is_empty() {
            return;
        }
        let mut q = lock(&self.q);
        for id in ids {
            q.in_flight.remove(id);
        }
        q.loads[worker] = q.loads[worker].saturating_sub(ids.len());
        drop(q);
        self.cv.notify_all();
    }
}

enum Pulled {
    /// Kill order observed.
    Kill,
    /// Draining and nothing left for this worker — exit gracefully.
    Exit,
    /// Assigned entries whose KV projection fits the shared pool *now*
    /// (possibly empty when called non-blocking).
    Work(Vec<QueueEntry>),
}

/// Pull this worker's queue assignments.  Budget gate: an entry is taken
/// only if the shared pool's resident bytes plus everything taken this
/// call leaves room for its projection — otherwise it stays queued (the
/// scheduler would only re-defer it internally, but then its KV pressure
/// would be invisible to the other workers' admission).  Entries assigned
/// to dead workers are rehomed to the caller.  Blocks (bounded by the
/// batch window) only when `may_block`.
fn take_assigned(shared: &PoolShared, idx: usize, may_block: bool) -> Pulled {
    let mut q = lock(&shared.q);
    loop {
        if q.kills[idx] {
            return Pulled::Kill;
        }
        let cap = shared.cfg.kv_capacity_bytes;
        let mut projected_sum = 0u64;
        let mut taken = Vec::new();
        let mut mine_gated = false;
        let mut i = 0;
        while i < q.entries.len() {
            let assigned = q.entries[i].worker;
            let mine = assigned == idx || q.dead[assigned];
            if !mine {
                i += 1;
                continue;
            }
            let fits = cap.map_or(true, |c| {
                shared
                    .pool
                    .resident_bytes()
                    .saturating_add(projected_sum)
                    .saturating_add(q.entries[i].projected)
                    <= c
            });
            if !fits {
                mine_gated = true;
                i += 1;
                continue;
            }
            projected_sum += q.entries[i].projected;
            let mut e = q.entries.remove(i).expect("index in bounds");
            if e.worker != idx {
                let old = e.worker;
                q.loads[old] = q.loads[old].saturating_sub(1);
                q.loads[idx] += 1;
                q.affinity.insert(e.key.clone(), idx);
                e.worker = idx;
            }
            taken.push(e);
        }
        if !taken.is_empty() || !may_block {
            return Pulled::Work(taken);
        }
        if q.draining && !mine_gated {
            // No new submits can arrive and nothing queued (or
            // rehomeable) belongs to this worker: done.
            return Pulled::Exit;
        }
        let timeout = Duration::from_micros((shared.cfg.max_wait_ms * 1000.0) as u64 + 100);
        q = match shared.cv.wait_timeout(q, timeout) {
            Ok((g, _)) => g,
            Err(p) => p.into_inner().0,
        };
    }
}

/// One pool worker: pull assignments, admit them through the SAME
/// validation/plan-resolution path as the single-worker server
/// ([`prepare_submit`]), then run scheduling rounds — prune, speculation
/// gate, round, elastic — exactly like the host loop, over this worker's
/// private scheduler and metrics.
fn worker_loop(shared: Arc<PoolShared>, idx: usize) {
    let seq = shared.preset.model.seq_len;
    let vocab = shared.preset.model.vocab;
    let mut sched = Scheduler::with_pool(
        SchedulerConfig {
            max_prefills_per_round: shared.cfg.max_prefills_per_round,
            kv_capacity_bytes: shared.cfg.kv_capacity_bytes,
            kv: shared.cfg.kv,
        },
        shared.pool.clone(),
    );
    let mut elastic = shared.cfg.elastic.clone().map(ElasticPlanner::new);
    let mut waiters: BTreeMap<u64, Box<dyn EventSink>> = BTreeMap::new();

    loop {
        let mut done_ids: Vec<u64> = Vec::new();
        match take_assigned(&shared, idx, !sched.has_work()) {
            Pulled::Kill => {
                die(&shared, idx, sched, waiters);
                return;
            }
            Pulled::Exit => {
                let mut q = lock(&shared.q);
                q.dead[idx] = true;
                drop(q);
                shared.cv.notify_all();
                return;
            }
            Pulled::Work(batch) => {
                if !batch.is_empty() {
                    // Lock order everywhere: queue (released) → store →
                    // metrics.
                    let mut store = lock(&shared.store);
                    let mut metrics = lock(&shared.metrics[idx]);
                    for entry in batch {
                        let QueueEntry {
                            req, mut sink, enq, ..
                        } = entry;
                        match prepare_submit(
                            &req,
                            seq,
                            vocab,
                            &shared.cfg,
                            &shared.model,
                            &shared.preset,
                            &mut store,
                            &mut sched,
                            &mut metrics,
                        ) {
                            Ok(p) => {
                                let int8 = req.int8_acts;
                                waiters.insert(req.id, sink);
                                sched.submit(p.key, p.plan, p.bits, int8, req, enq);
                            }
                            Err(msg) => {
                                sink.fail(&msg);
                                done_ids.push(req.id);
                            }
                        }
                    }
                }
            }
        }
        if !sched.has_work() {
            shared.finish(&done_ids, idx);
            continue;
        }
        // Clients that hung up free their streams (and KV pages) now.
        sched.prune(&|id| waiters.contains_key(&id));
        {
            let mut metrics = lock(&shared.metrics[idx]);
            metrics.set_kv_bytes(sched.resident_kv_bytes());
            if let Some(planner) = elastic.as_ref() {
                sched.suspend_speculation(!planner.speculation_allowed(
                    sched.resident_kv_bytes(),
                    sched.pending_prefills(),
                ));
            }
            let outcome = sched.run_round(&mut metrics, &mut |id, resp| {
                if resp.done {
                    if let Some(mut s) = waiters.remove(&id) {
                        let _ = s.event(&resp);
                    }
                    done_ids.push(id);
                    false
                } else {
                    let alive = waiters.get_mut(&id).is_some_and(|s| s.event(&resp));
                    if !alive {
                        waiters.remove(&id);
                        done_ids.push(id);
                    }
                    alive
                }
            });
            for id in outcome.failed {
                if let Some(mut s) = waiters.remove(&id) {
                    s.fail("stream failed mid-round");
                }
                done_ids.push(id);
            }
        }
        if let Some(planner) = elastic.as_mut() {
            let mut store = lock(&shared.store);
            let mut metrics = lock(&shared.metrics[idx]);
            for id in apply_elastic(
                planner,
                &mut sched,
                &mut store,
                &shared.model,
                &shared.preset,
                &shared.cfg,
                &mut metrics,
            ) {
                if let Some(mut s) = waiters.remove(&id) {
                    s.fail("stream could not survive a precision shift");
                }
                done_ids.push(id);
            }
            metrics.set_kv_bytes(sched.resident_kv_bytes());
        }
        shared.finish(&done_ids, idx);
    }
}

/// Kill-order teardown: requeue what never started, error what did, give
/// the pages back (scheduler drop), and only then mark the slot dead so
/// survivors rehome the requeued entries.
fn die(
    shared: &PoolShared,
    idx: usize,
    mut sched: Scheduler,
    mut waiters: BTreeMap<u64, Box<dyn EventSink>>,
) {
    // Queued-but-never-prefilled requests keep their sink and their
    // original enqueue time (their TTFT honestly includes this detour).
    let mut requeue: Vec<(Request, Instant, Box<dyn EventSink>)> = Vec::new();
    for (req, enq) in sched.drain_pending() {
        if let Some(sink) = waiters.remove(&req.id) {
            requeue.push((req, enq, sink));
        }
    }
    // Live streams cannot move — their KV pages live in this scheduler.
    let mut failed_ids = Vec::new();
    for (id, mut sink) in std::mem::take(&mut waiters) {
        sink.fail("worker died mid-stream");
        failed_ids.push(id);
    }
    // Scheduler drop releases every session's pages to the shared pool
    // BEFORE survivors see the rehomed entries, so the freed budget is
    // visible to their take gate.
    drop(sched);

    let mut q = lock(&shared.q);
    q.dead[idx] = true;
    q.kills[idx] = false;
    q.loads[idx] = 0;
    for id in &failed_ids {
        q.in_flight.remove(id);
    }
    let any_alive = (0..q.dead.len()).any(|w| !q.dead[w] && !q.kills[w]);
    let mut orphans: Vec<Box<dyn EventSink>> = Vec::new();
    for (req, enq, sink) in requeue {
        if any_alive {
            let (_b, key, projected) = classify(&shared.cfg, &shared.preset, &req);
            // Leave `worker` pointing at the dead slot: any live worker's
            // take gate rehomes it (and takes over the affinity).
            q.entries.push_back(QueueEntry {
                req,
                sink,
                enq,
                key,
                worker: idx,
                projected,
            });
        } else {
            q.in_flight.remove(&req.id);
            orphans.push(sink);
        }
    }
    drop(q);
    // Sinks are failed outside the queue lock — a sink may do I/O.
    for mut sink in orphans {
        sink.fail("worker died with no survivors to take the request");
    }
    shared.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::PrecisionReq;

    fn cfg() -> ServerConfig {
        ServerConfig {
            warm_bits: vec![8],
            ..ServerConfig::default()
        }
    }

    #[test]
    fn classify_matches_the_server_plan_key_rules() {
        let preset = crate::model::testing::toy_transformer_preset(crate::model::ModelDims {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 16,
            seq_len: 64,
            quantize_attn: false,
        });
        let c = cfg();
        let warm = Request::new(1, vec![1, 2], PrecisionReq::Bits(8));
        assert!(matches!(
            classify(&c, &preset, &warm).1,
            PlanKey::Warm(8)
        ));
        let packed = Request::new(2, vec![1, 2], PrecisionReq::Bits(4));
        assert!(matches!(
            classify(&c, &preset, &packed).1,
            PlanKey::Packed { bits: 4, int8: false }
        ));
        let mut int8 = Request::new(3, vec![1, 2], PrecisionReq::Bits(8));
        int8.int8_acts = true;
        // int8 at a warm precision still needs the packed plan.
        assert!(matches!(
            classify(&c, &preset, &int8).1,
            PlanKey::Packed { bits: 8, int8: true }
        ));
        let mut per_layer = Request::new(4, vec![1, 2], PrecisionReq::Bits(8));
        per_layer.per_layer = Some(vec![2, 4, 8]);
        let (bits, key, _) = classify(&c, &preset, &per_layer);
        assert_eq!(bits, 8, "per-layer traffic groups under the map maximum");
        assert!(matches!(key, PlanKey::PerLayer { .. }));
        // Projection grows with the generation budget.
        let short = Request::generate(5, vec![1; 4], PrecisionReq::Bits(4), 1, crate::runtime::Sampling::Greedy);
        let long = Request::generate(6, vec![1; 4], PrecisionReq::Bits(4), 64, crate::runtime::Sampling::Greedy);
        assert!(classify(&c, &preset, &long).2 > classify(&c, &preset, &short).2);
    }
}
