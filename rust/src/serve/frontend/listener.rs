//! The async connection layer: one thread runs a `poll(2)` readiness
//! loop over a non-blocking [`TcpListener`] and every live connection —
//! no per-connection threads, no async runtime, no new crates.  The only
//! platform surface is `poll(2)` itself, bound by a four-line FFI
//! declaration (`libc` is already in every Rust process's link line).
//!
//! Data flow:
//!
//! ```text
//!   accept → Conn.rbuf → codec::parse_http_request → route
//!     POST /v1/generate → WorkerPool::submit_with_sink(TcpSink)
//!       accepted  → chunked head into Conn.wbuf; worker threads push
//!                   token chunks into ConnHandle.outbox and wake the
//!                   loop (UnixStream pair); the loop moves outbox →
//!                   wbuf → socket
//!       rejected  → 400/503 + JSON error, synchronously — a client
//!                   never hangs on a request the pool will not serve
//!     GET /metrics → fleet-merged Metrics::report
//!     GET /healthz → {"ok":true}
//! ```
//!
//! Connection lifecycle: keep-alive; one *streaming* request at a time
//! per connection (a pipelined second request waits in `rbuf` until the
//! stream's final chunk is queued).  A hangup mid-stream flips the
//! handle's `alive` flag — the worker's next `event()` push returns
//! `false` and the scheduler retires and prunes the stream, freeing its
//! KV pages.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Context;

use super::codec;
use super::pool::{EventSink, SubmitError, WorkerPool};
use crate::serve::request::Response;
use crate::Result;

mod sys {
    //! Minimal `poll(2)` binding — the one readiness syscall the loop
    //! needs, vendored instead of pulled from a crate.
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    }

    /// Wait for readiness on `fds` (revents filled in place).  Errors
    /// (EINTR) are indistinguishable from "nothing ready" to the caller,
    /// which is exactly how the loop treats both.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> i32 {
        unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms) }
    }
}

/// Wakes the readiness loop from worker threads: one byte down a
/// non-blocking socketpair the loop polls alongside its TCP fds.  A full
/// pipe is fine — the loop is already guaranteed to wake.
pub(crate) struct Waker {
    tx: UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// The cross-thread half of a connection: worker threads (via
/// [`TcpSink`]) push encoded bytes into `outbox` and wake the loop; the
/// loop owns the socket and everything else.
pub(crate) struct ConnHandle {
    alive: AtomicBool,
    /// A chunked response is in flight: gates pipelined request parsing,
    /// connection close, and the sink-drop error path.
    streaming: AtomicBool,
    outbox: Mutex<VecDeque<Vec<u8>>>,
    waker: Arc<Waker>,
}

impl ConnHandle {
    fn new(waker: Arc<Waker>) -> ConnHandle {
        ConnHandle {
            alive: AtomicBool::new(true),
            streaming: AtomicBool::new(false),
            outbox: Mutex::new(VecDeque::new()),
            waker,
        }
    }

    /// Queue bytes for the socket; `false` once the peer is gone.
    fn push(&self, bytes: Vec<u8>) -> bool {
        if !self.alive.load(Ordering::Acquire) {
            return false;
        }
        self.outbox
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push_back(bytes);
        self.waker.wake();
        true
    }

    fn is_streaming(&self) -> bool {
        self.streaming.load(Ordering::Acquire)
    }

    fn set_streaming(&self, on: bool) {
        self.streaming.store(on, Ordering::Release);
        if !on {
            self.waker.wake(); // the loop may now parse a pipelined request
        }
    }

    fn outbox_is_empty(&self) -> bool {
        self.outbox
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .is_empty()
    }
}

/// [`EventSink`] over a connection: encodes each [`Response`] as one
/// chunk, terminates the stream on `done`/failure, and — if dropped
/// without either (e.g. the whole pool was torn down) — emits a terminal
/// error chunk so the client is never left hanging on a half-open
/// stream.
pub(crate) struct TcpSink {
    conn: Arc<ConnHandle>,
    id: u64,
    finished: bool,
}

impl TcpSink {
    pub fn new(conn: Arc<ConnHandle>, id: u64) -> TcpSink {
        TcpSink {
            conn,
            id,
            finished: false,
        }
    }

    fn terminate(&mut self, msg: &str) {
        if self.finished {
            return;
        }
        self.finished = true;
        if self
            .conn
            .push(codec::encode_chunk(&codec::error_json(self.id, msg)))
        {
            self.conn.push(codec::final_chunk().to_vec());
        }
        self.conn.set_streaming(false);
    }
}

impl EventSink for TcpSink {
    fn event(&mut self, resp: &Response) -> bool {
        if self.finished {
            return false;
        }
        let ok = self.conn.push(codec::encode_chunk(&codec::event_json(resp)));
        if resp.done {
            self.finished = true;
            if ok {
                self.conn.push(codec::final_chunk().to_vec());
            }
            self.conn.set_streaming(false);
        }
        ok
    }

    fn fail(&mut self, msg: &str) {
        self.terminate(msg);
    }

    fn rejected(&mut self) {
        // Pre-queue rejection: the listener answers with an HTTP status;
        // in-band chunks would corrupt the connection.
        self.finished = true;
    }
}

impl Drop for TcpSink {
    fn drop(&mut self) {
        self.terminate("stream aborted");
    }
}

/// Loop-owned connection state.
struct Conn {
    stream: TcpStream,
    handle: Arc<ConnHandle>,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    close_after_flush: bool,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, waker: Arc<Waker>) -> Conn {
        Conn {
            stream,
            handle: Arc::new(ConnHandle::new(waker)),
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            close_after_flush: false,
            dead: false,
        }
    }
}

/// The TCP front door: bind, then a dedicated thread multiplexes every
/// connection over the shared [`WorkerPool`].
pub struct HttpFrontend {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
    thread: Option<JoinHandle<()>>,
    pool: WorkerPool,
}

impl HttpFrontend {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port in tests)
    /// and start the readiness loop over `pool`.
    pub fn bind(pool: WorkerPool, addr: &str) -> Result<HttpFrontend> {
        let listener = TcpListener::bind(addr).context("binding front door")?;
        listener
            .set_nonblocking(true)
            .context("non-blocking listener")?;
        let addr = listener.local_addr().context("front door addr")?;
        let (wake_tx, wake_rx) = UnixStream::pair().context("wake channel")?;
        wake_tx.set_nonblocking(true).context("wake tx")?;
        wake_rx.set_nonblocking(true).context("wake rx")?;
        let waker = Arc::new(Waker { tx: wake_tx });
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let waker = Arc::clone(&waker);
            let stop = Arc::clone(&stop);
            let pool = pool.clone();
            std::thread::Builder::new()
                .name("mq-frontend".into())
                .spawn(move || event_loop(listener, wake_rx, waker, stop, pool))
                .context("spawning frontend loop")?
        };
        Ok(HttpFrontend {
            addr,
            stop,
            waker,
            thread: Some(thread),
            pool,
        })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Stop accepting connections, close the loop, then drain the worker
    /// pool ([`WorkerPool::shutdown`] — in-flight streams finish first).
    pub fn shutdown(mut self) -> Result<()> {
        self.stop_loop();
        self.pool.shutdown()
    }

    fn stop_loop(&mut self) {
        self.stop.store(true, Ordering::Release);
        self.waker.wake();
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpFrontend {
    fn drop(&mut self) {
        // The loop thread must not outlive the handle; the pool is NOT
        // drained here — other clones may still own it (explicit
        // `shutdown()` drains).
        self.stop_loop();
    }
}

fn event_loop(
    listener: TcpListener,
    mut wake_rx: UnixStream,
    waker: Arc<Waker>,
    stop: Arc<AtomicBool>,
    pool: WorkerPool,
) {
    let mut conns: Vec<Conn> = Vec::new();
    while !stop.load(Ordering::Acquire) {
        // Interest set: listener + waker + every conn.  Outboxes move
        // into wbufs first so write interest is accurate.
        let mut fds = Vec::with_capacity(2 + conns.len());
        fds.push(sys::PollFd {
            fd: listener.as_raw_fd(),
            events: sys::POLLIN,
            revents: 0,
        });
        fds.push(sys::PollFd {
            fd: wake_rx.as_raw_fd(),
            events: sys::POLLIN,
            revents: 0,
        });
        for c in &mut conns {
            drain_outbox(c);
            let mut events = sys::POLLIN;
            if !c.wbuf.is_empty() {
                events |= sys::POLLOUT;
            }
            fds.push(sys::PollFd {
                fd: c.stream.as_raw_fd(),
                events,
                revents: 0,
            });
        }
        sys::poll_fds(&mut fds, 50);
        if fds[1].revents & sys::POLLIN != 0 {
            let mut buf = [0u8; 256];
            loop {
                match wake_rx.read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => continue,
                }
            }
        }
        let n_old = fds.len() - 2;
        for i in 0..n_old {
            let revents = fds[2 + i].revents;
            let c = &mut conns[i];
            if revents & (sys::POLLIN | sys::POLLERR | sys::POLLHUP) != 0 {
                read_into(c);
            }
            if !c.dead && !c.close_after_flush && !c.handle.is_streaming() {
                // A stream may have finished since the top-of-loop drain.
                // Its final chunks were pushed before the streaming flag
                // cleared (Release store, Acquire load above), so drain
                // them into wbuf NOW — parsing a pipelined request first
                // would append its response ahead of those still-queued
                // chunks and emit out-of-order bytes on the wire.
                drain_outbox(c);
                parse_and_route(c, &pool);
            }
            // A worker may have queued chunks during routing: pick them
            // up now rather than a poll cycle later.
            drain_outbox(c);
            flush(c);
        }
        if fds[0].revents & sys::POLLIN != 0 {
            loop {
                match listener.accept() {
                    Ok((s, _peer)) => {
                        if s.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = s.set_nodelay(true);
                        conns.push(Conn::new(s, Arc::clone(&waker)));
                    }
                    Err(_) => break, // WouldBlock or transient accept error
                }
            }
        }
        conns.retain(|c| {
            let done_closing = c.close_after_flush
                && c.wbuf.is_empty()
                && c.handle.outbox_is_empty()
                && !c.handle.is_streaming();
            if c.dead || done_closing {
                // Workers discover the hangup on their next push.
                c.handle.alive.store(false, Ordering::Release);
                false
            } else {
                true
            }
        });
    }
    // Loop teardown: flag every connection dead so in-flight sinks
    // return false and their streams retire.
    for c in &conns {
        c.handle.alive.store(false, Ordering::Release);
    }
}

/// Move worker-queued bytes into the loop-owned write buffer (FIFO — the
/// stream head always precedes the first token chunk because it entered
/// `wbuf` directly at accept time).
fn drain_outbox(c: &mut Conn) {
    let mut outbox = c.handle.outbox.lock().unwrap_or_else(|p| p.into_inner());
    while let Some(bytes) = outbox.pop_front() {
        c.wbuf.extend_from_slice(&bytes);
    }
}

fn read_into(c: &mut Conn) {
    let mut tmp = [0u8; 4096];
    loop {
        match c.stream.read(&mut tmp) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => {
                c.rbuf.extend_from_slice(&tmp[..n]);
                if c.rbuf.len() > codec::MAX_HEADER_BYTES + codec::MAX_BODY_BYTES {
                    c.dead = true; // unbounded peer; cut it off
                    return;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
}

fn flush(c: &mut Conn) {
    while !c.wbuf.is_empty() {
        match c.stream.write(&c.wbuf) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => {
                c.wbuf.drain(..n);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
}

/// Parse as many complete requests as the buffer holds, stopping at a
/// streaming response (events must not interleave with a second
/// response) or a protocol error (400 + close).
fn parse_and_route(c: &mut Conn, pool: &WorkerPool) {
    loop {
        match codec::parse_http_request(&mut c.rbuf) {
            Ok(Some(req)) => {
                route(c, req, pool);
                if c.handle.is_streaming() || c.close_after_flush {
                    return;
                }
            }
            Ok(None) => return,
            Err(msg) => {
                c.wbuf
                    .extend_from_slice(&codec::error_response(400, "Bad Request", &msg));
                c.close_after_flush = true;
                return;
            }
        }
    }
}

fn route(c: &mut Conn, req: codec::HttpRequest, pool: &WorkerPool) {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/generate") => {
            let parsed = codec::request_from_json(&req.body, pool.next_request_id());
            let r = match parsed {
                Ok(r) => r,
                Err(msg) => {
                    c.wbuf
                        .extend_from_slice(&codec::error_response(400, "Bad Request", &msg));
                    return;
                }
            };
            let id = r.id;
            let sink = TcpSink::new(Arc::clone(&c.handle), id);
            // Streaming is flagged BEFORE the submit: the instant the
            // entry is queued a worker may serve and finish it, and its
            // end-of-stream clear must not race a later set.
            c.handle.set_streaming(true);
            match pool.submit_with_sink(r, Box::new(sink)) {
                Ok(()) => {
                    // Head first — token chunks queue behind it in the
                    // outbox and land in wbuf strictly later.
                    c.wbuf.extend_from_slice(codec::stream_head());
                }
                Err(e) => {
                    c.handle.set_streaming(false);
                    let (status, reason) = match &e {
                        SubmitError::Draining => (503, "Service Unavailable"),
                        SubmitError::Rejected(_) => (400, "Bad Request"),
                    };
                    c.wbuf.extend_from_slice(&codec::error_response(
                        status,
                        reason,
                        &e.to_string(),
                    ));
                }
            }
        }
        ("GET", "/healthz") => {
            c.wbuf.extend_from_slice(&codec::simple_response(
                200,
                "OK",
                "application/json",
                "{\"ok\":true}",
            ));
        }
        ("GET", "/metrics") => {
            let report = pool.metrics_report();
            c.wbuf
                .extend_from_slice(&codec::simple_response(200, "OK", "text/plain", &report));
        }
        _ => {
            c.wbuf.extend_from_slice(&codec::error_response(
                404,
                "Not Found",
                &format!("no route for {} {}", req.method, req.path),
            ));
        }
    }
}
