//! Wire codec for the TCP front door: a deliberately small HTTP/1.1
//! subset (enough for `POST /v1/generate` + `GET /metrics|/healthz`) and
//! the NDJSON-over-chunked-transfer stream format — one chunk per
//! generated token, a final chunk carrying the whole token vector and
//! timings, then the zero-length terminator.
//!
//! Everything here is pure byte/string transformation: no sockets, no
//! locks, no threads — the listener's readiness loop and the loadgen's
//! blocking client both drive it, and the unit tests exercise round-trips
//! without any I/O at all.
//!
//! Request body (`POST /v1/generate`, `Content-Length` required):
//!
//! ```json
//! {"prompt": [1, 2, 3], "bits": 4, "int8": false,
//!  "per_layer": [8, 4, 2], "max_new_tokens": 8,
//!  "temperature": 0.8, "seed": 7}
//! ```
//!
//! Only `prompt` is mandatory.  `bits` defaults to 8; `per_layer`
//! overrides it (the map's maximum becomes the reported width, exactly as
//! on the in-process path); omitting `temperature` means greedy decode.
//! Clients may pin an `id`, but in-flight ids must be unique — the server
//! otherwise assigns one.
//!
//! Response: `200 OK` + `Transfer-Encoding: chunked`, each chunk one JSON
//! line.  Mid-stream events carry `{id, token, logit, bits, int8,
//! done:false}`; the final event adds `tokens`, `queue_ms`, `prefill_ms`,
//! `decode_ms`, `batch`; a terminal failure arrives in-band as
//! `{id, error, done:true}`.  Pre-stream rejections are plain HTTP
//! status responses (400 malformed / 503 draining) with a JSON error
//! body — a client never hangs on a request the server will not serve.

use std::collections::BTreeMap;
use std::io::{BufRead, Read, Write};

use crate::runtime::Sampling;
use crate::serve::request::{PrecisionReq, Request, Response};
use crate::util::json::Json;

/// Cap on the header block of one request — a peer that streams an
/// unbounded request line must exhaust its own socket, not our memory.
pub const MAX_HEADER_BYTES: usize = 64 * 1024;
/// Cap on a request body (prompts are token-id arrays; 8 MiB of JSON is
/// ~1M tokens — far past any model window this repo serves).
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// One parsed HTTP request off a connection's read buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Incremental HTTP/1.1 request parse off the front of `buf`.
///
/// * `Ok(None)` — the buffer does not yet hold a complete request; read
///   more bytes and call again (nothing is consumed).
/// * `Ok(Some(req))` — one complete request; its bytes are drained from
///   `buf` (pipelined follow-ups stay put).
/// * `Err(msg)` — the peer sent something we will never accept (oversized
///   headers/body, chunked request body, malformed request line); the
///   connection should answer 400 and close.
pub fn parse_http_request(buf: &mut Vec<u8>) -> Result<Option<HttpRequest>, String> {
    let Some(head_end) = find_subslice(buf, b"\r\n\r\n") else {
        if buf.len() > MAX_HEADER_BYTES {
            return Err("header block exceeds 64KiB".into());
        }
        return Ok(None);
    };
    if head_end > MAX_HEADER_BYTES {
        return Err("header block exceeds 64KiB".into());
    }
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(format!("malformed request line {request_line:?}"));
    }
    let mut content_length = 0usize;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        if name == "content-length" {
            content_length = value
                .parse::<usize>()
                .map_err(|_| format!("bad content-length {value:?}"))?;
        } else if name == "transfer-encoding" {
            // We stream chunked *responses*; chunked *requests* are out of
            // scope for a token-array API and rejecting beats misparsing.
            return Err("chunked request bodies are not supported".into());
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(format!("body of {content_length}B exceeds 8MiB"));
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + content_length {
        return Ok(None);
    }
    let body = buf[body_start..body_start + content_length].to_vec();
    buf.drain(..body_start + content_length);
    Ok(Some(HttpRequest { method, path, body }))
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Decode a `POST /v1/generate` JSON body into a [`Request`].
/// `fallback_id` is the server-assigned id used when the client does not
/// pin its own.  Shape errors come back as the 400 body text.
pub fn request_from_json(body: &[u8], fallback_id: u64) -> Result<Request, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let j = Json::parse(text).map_err(|e| format!("bad JSON body: {e:#}"))?;
    let prompt: Vec<i32> = j
        .get("prompt")
        .and_then(|p| p.as_arr())
        .map_err(|e| format!("prompt: {e:#}"))?
        .iter()
        .map(|t| t.as_f64().map(|v| v as i32))
        .collect::<crate::Result<_>>()
        .map_err(|e| format!("prompt: {e:#}"))?;
    let id = match j.opt("id") {
        Some(v) => v.as_f64().map_err(|e| format!("id: {e:#}"))? as u64,
        None => fallback_id,
    };
    let bits = match j.opt("bits") {
        Some(v) => v.as_u32().map_err(|e| format!("bits: {e:#}"))?,
        None => 8,
    };
    let int8_acts = match j.opt("int8") {
        Some(v) => v.as_bool().map_err(|e| format!("int8: {e:#}"))?,
        None => false,
    };
    let per_layer = match j.opt("per_layer") {
        Some(v) => Some(
            v.as_arr()
                .map_err(|e| format!("per_layer: {e:#}"))?
                .iter()
                .map(|b| b.as_u32())
                .collect::<crate::Result<Vec<u32>>>()
                .map_err(|e| format!("per_layer: {e:#}"))?,
        ),
        None => None,
    };
    let max_new_tokens = match j.opt("max_new_tokens") {
        Some(v) => v.as_usize().map_err(|e| format!("max_new_tokens: {e:#}"))?,
        None => 1,
    };
    let sampling = match j.opt("temperature") {
        Some(v) => {
            let temp = v.as_f64().map_err(|e| format!("temperature: {e:#}"))? as f32;
            let seed = match j.opt("seed") {
                Some(s) => s.as_f64().map_err(|e| format!("seed: {e:#}"))? as u64,
                None => 0,
            };
            Sampling::Temperature { temp, seed }
        }
        None => Sampling::Greedy,
    };
    Ok(Request {
        id,
        prompt,
        precision: PrecisionReq::Bits(bits),
        int8_acts,
        max_new_tokens,
        sampling,
        per_layer,
    })
}

/// One streamed token event as a JSON line.  The final event additionally
/// carries the accumulated token vector and the request's timings, so a
/// client that only reads the last line still gets the whole answer —
/// mirroring the in-process path where the `done` [`Response`] is
/// self-contained.
pub fn event_json(resp: &Response) -> String {
    let mut entries = vec![
        ("id", Json::Num(resp.id as f64)),
        ("token", Json::Num(resp.next_token as f64)),
        ("logit", Json::Num(resp.logit as f64)),
        ("bits", Json::Num(resp.bits as f64)),
        ("int8", Json::Bool(resp.int8_acts)),
        ("done", Json::Bool(resp.done)),
    ];
    if resp.done {
        entries.push((
            "tokens",
            Json::Arr(resp.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        ));
        entries.push(("queue_ms", Json::Num(resp.queue_ms)));
        entries.push(("prefill_ms", Json::Num(resp.prefill_ms)));
        entries.push(("decode_ms", Json::Num(resp.decode_ms)));
        entries.push(("batch", Json::Num(resp.batch_size as f64)));
    }
    Json::obj(entries).to_string()
}

/// A terminal in-band error event — the stream's last chunk when a
/// request dies after headers were already committed (worker death,
/// failed plan swap, validation rejection inside the worker).
pub fn error_json(id: u64, msg: &str) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::str(msg)),
        ("done", Json::Bool(true)),
    ])
    .to_string()
}

/// Frame one NDJSON line as an HTTP/1.1 chunk (the newline rides inside
/// the chunk so `lines()`-style clients work unframed too).
pub fn encode_chunk(line: &str) -> Vec<u8> {
    let mut data = Vec::with_capacity(line.len() + 16);
    data.extend_from_slice(format!("{:x}\r\n", line.len() + 1).as_bytes());
    data.extend_from_slice(line.as_bytes());
    data.push(b'\n');
    data.extend_from_slice(b"\r\n");
    data
}

/// The zero-length terminating chunk.
pub fn final_chunk() -> &'static [u8] {
    b"0\r\n\r\n"
}

/// Response head for a token stream: committed once the request is
/// accepted into the shared queue, before the first token exists.
pub fn stream_head() -> &'static [u8] {
    b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: keep-alive\r\n\r\n"
}

/// A complete non-streaming response (rejections, `/metrics`,
/// `/healthz`, 404s) with `Content-Length` so keep-alive framing holds.
pub fn simple_response(status: u16, reason: &str, content_type: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// JSON error body for a pre-stream rejection (400/503).
pub fn error_response(status: u16, reason: &str, msg: &str) -> Vec<u8> {
    let body = Json::obj(vec![("error", Json::str(msg))]).to_string();
    simple_response(status, reason, "application/json", &body)
}

// ---------------------------------------------------------------------------
// Client side (blocking) — used by the loadgen and the conformance tests.
// ---------------------------------------------------------------------------

/// Serialize a generate request body; the inverse of
/// [`request_from_json`] minus the server-side defaults.
pub fn request_to_json(req: &Request) -> String {
    let mut entries = vec![(
        "prompt",
        Json::Arr(req.prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
    )];
    entries.push(("id", Json::Num(req.id as f64)));
    entries.push(("bits", Json::Num(req.precision.bits() as f64)));
    entries.push(("int8", Json::Bool(req.int8_acts)));
    entries.push(("max_new_tokens", Json::Num(req.max_new_tokens as f64)));
    if let Some(map) = &req.per_layer {
        entries.push((
            "per_layer",
            Json::Arr(map.iter().map(|&b| Json::Num(b as f64)).collect()),
        ));
    }
    if let Sampling::Temperature { temp, seed } = req.sampling {
        entries.push(("temperature", Json::Num(temp as f64)));
        entries.push(("seed", Json::Num(seed as f64)));
    }
    Json::obj(entries).to_string()
}

/// Write one `POST /v1/generate` over a blocking stream.
pub fn write_generate(w: &mut impl Write, body: &str) -> std::io::Result<()> {
    write!(
        w,
        "POST /v1/generate HTTP/1.1\r\nHost: mq\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    w.flush()
}

/// Write one bodyless GET over a blocking stream.
pub fn write_get(w: &mut impl Write, path: &str) -> std::io::Result<()> {
    write!(w, "GET {path} HTTP/1.1\r\nHost: mq\r\n\r\n")?;
    w.flush()
}

/// Blocking read of a response head: status code + lowercased headers.
/// Leaves the reader positioned at the first body byte.
pub fn read_response_head(
    r: &mut impl BufRead,
) -> std::io::Result<(u16, BTreeMap<String, String>)> {
    let status_line = read_crlf_line(r)?;
    let status = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| bad_data(format!("bad status line {status_line:?}")))?;
    let mut headers = BTreeMap::new();
    loop {
        let line = read_crlf_line(r)?;
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            headers.insert(
                name.trim().to_ascii_lowercase(),
                value.trim().to_string(),
            );
        }
    }
    Ok((status, headers))
}

/// Blocking read of one chunked-transfer chunk: `Ok(Some(line))` per
/// event (trailing newline stripped), `Ok(None)` at the terminator.
pub fn read_chunk(r: &mut impl BufRead) -> std::io::Result<Option<String>> {
    let size_line = read_crlf_line(r)?;
    let size = usize::from_str_radix(size_line.trim(), 16)
        .map_err(|_| bad_data(format!("bad chunk size {size_line:?}")))?;
    if size == 0 {
        let _ = read_crlf_line(r)?; // trailing CRLF after the 0 chunk
        return Ok(None);
    }
    let mut data = vec![0u8; size];
    r.read_exact(&mut data)?;
    let mut crlf = [0u8; 2];
    r.read_exact(&mut crlf)?;
    let mut text = String::from_utf8_lossy(&data).into_owned();
    if text.ends_with('\n') {
        text.pop();
    }
    Ok(Some(text))
}

/// Blocking read of a `Content-Length` body (the non-streaming
/// responses).
pub fn read_body(r: &mut impl BufRead, headers: &BTreeMap<String, String>) -> std::io::Result<String> {
    let len = headers
        .get("content-length")
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(String::from_utf8_lossy(&body).into_owned())
}

fn read_crlf_line(r: &mut impl BufRead) -> std::io::Result<String> {
    let mut line = String::new();
    let n = r.read_line(&mut line)?;
    if n == 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "connection closed mid-response",
        ));
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(line)
}

fn bad_data(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn post_bytes(body: &str) -> Vec<u8> {
        format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .into_bytes()
    }

    #[test]
    fn http_parse_is_incremental_and_pipelined() {
        let body = r#"{"prompt":[1,2]}"#;
        let full = post_bytes(body);
        // Byte-at-a-time arrival: no prefix parses early, the full buffer
        // parses exactly once.
        let mut buf = Vec::new();
        for (i, &b) in full.iter().enumerate() {
            buf.push(b);
            let parsed = parse_http_request(&mut buf).unwrap();
            if i + 1 < full.len() {
                assert!(parsed.is_none(), "parsed early at byte {i}");
            } else {
                let req = parsed.expect("complete request must parse");
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/v1/generate");
                assert_eq!(req.body, body.as_bytes());
            }
        }
        assert!(buf.is_empty(), "consumed request must drain the buffer");
        // Pipelined: two requests back-to-back parse in order, leaving
        // the second intact after the first.
        let mut buf = post_bytes(body);
        buf.extend_from_slice(b"GET /healthz HTTP/1.1\r\n\r\n");
        let first = parse_http_request(&mut buf).unwrap().unwrap();
        assert_eq!(first.method, "POST");
        let second = parse_http_request(&mut buf).unwrap().unwrap();
        assert_eq!((second.method.as_str(), second.path.as_str()), ("GET", "/healthz"));
        assert!(buf.is_empty());
    }

    #[test]
    fn http_parse_rejects_hostile_input() {
        // Chunked request bodies: unsupported, must error not hang.
        let mut buf =
            b"POST /v1/generate HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
        assert!(parse_http_request(&mut buf).is_err());
        // A header block that never terminates must be cut off at the cap.
        let mut buf = vec![b'A'; MAX_HEADER_BYTES + 1];
        assert!(parse_http_request(&mut buf).is_err());
        // Garbage request line.
        let mut buf = b"NONSENSE\r\n\r\n".to_vec();
        assert!(parse_http_request(&mut buf).is_err());
    }

    #[test]
    fn request_json_round_trips_every_field() {
        let req = Request {
            id: 42,
            prompt: vec![3, 1, 4],
            precision: PrecisionReq::Bits(4),
            int8_acts: true,
            max_new_tokens: 7,
            sampling: Sampling::Temperature { temp: 0.5, seed: 9 },
            per_layer: Some(vec![8, 4, 2]),
        };
        let body = request_to_json(&req);
        let back = request_from_json(body.as_bytes(), 999).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.prompt, vec![3, 1, 4]);
        assert_eq!(back.precision.bits(), 4);
        assert!(back.int8_acts);
        assert_eq!(back.max_new_tokens, 7);
        assert_eq!(back.per_layer, Some(vec![8, 4, 2]));
        match back.sampling {
            Sampling::Temperature { temp, seed } => {
                assert!((temp - 0.5).abs() < 1e-6);
                assert_eq!(seed, 9);
            }
            other => panic!("sampling did not round-trip: {other:?}"),
        }
        // Defaults: bits=8, greedy, one token, server-assigned id.
        let min = request_from_json(br#"{"prompt":[0]}"#, 7).unwrap();
        assert_eq!(min.id, 7);
        assert_eq!(min.precision.bits(), 8);
        assert_eq!(min.max_new_tokens, 1);
        assert!(matches!(min.sampling, Sampling::Greedy));
        assert!(min.per_layer.is_none());
        // Malformed bodies answer with a reason, not a panic.
        assert!(request_from_json(b"not json", 0).is_err());
        assert!(request_from_json(br#"{"bits":8}"#, 0).is_err());
    }

    #[test]
    fn chunk_frames_round_trip_through_the_client_reader() {
        let resp = Response {
            id: 5,
            next_token: 17,
            logit: 1.25,
            tokens: vec![17, 3],
            done: true,
            bits: 4,
            int8_acts: false,
            queue_ms: 1.5,
            compute_ms: 2.0,
            prefill_ms: 0.5,
            decode_ms: 1.0,
            batch_size: 2,
        };
        let mut wire = Vec::new();
        wire.extend_from_slice(&encode_chunk(&event_json(&resp)));
        wire.extend_from_slice(&encode_chunk(&error_json(6, "gone")));
        wire.extend_from_slice(final_chunk());
        let mut r = std::io::BufReader::new(&wire[..]);
        let first = read_chunk(&mut r).unwrap().unwrap();
        let j = Json::parse(&first).unwrap();
        assert_eq!(j.get("id").unwrap().as_f64().unwrap() as u64, 5);
        assert_eq!(j.get("token").unwrap().as_f64().unwrap() as i32, 17);
        assert!(j.get("done").unwrap().as_bool().unwrap());
        assert_eq!(
            j.get("tokens").unwrap().as_arr().unwrap().len(),
            2,
            "final event carries the full token vector"
        );
        let second = read_chunk(&mut r).unwrap().unwrap();
        let j = Json::parse(&second).unwrap();
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "gone");
        assert!(read_chunk(&mut r).unwrap().is_none(), "terminator ends the stream");
    }

    #[test]
    fn response_heads_parse_back() {
        let wire = simple_response(503, "Service Unavailable", "application/json", "{}");
        let mut r = std::io::BufReader::new(&wire[..]);
        let (status, headers) = read_response_head(&mut r).unwrap();
        assert_eq!(status, 503);
        assert_eq!(read_body(&mut r, &headers).unwrap(), "{}");
        let mut r = std::io::BufReader::new(stream_head());
        let (status, headers) = read_response_head(&mut r).unwrap();
        assert_eq!(status, 200);
        assert_eq!(headers.get("transfer-encoding").map(String::as_str), Some("chunked"));
    }
}
