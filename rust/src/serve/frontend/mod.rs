//! The scale-out front door: a TCP/HTTP streaming interface over an
//! N-worker serving fleet.
//!
//! ```text
//!   TCP listener (poll(2) readiness loop, non-blocking sockets)
//!     → codec (HTTP/1.1 subset; NDJSON chunks, one per token)
//!       → shared admission queue (PlanKey affinity, fleet KV budget,
//!         drain-aware, duplicate-id fencing)
//!         → per-worker Scheduler + ElasticPlanner
//!           (shared WeightStore plans, shared PagePool pages)
//!           → streamed chunks back through the connection outbox
//! ```
//!
//! Module split:
//!
//! * [`codec`] — pure bytes↔types: incremental HTTP request parsing,
//!   chunk framing, request/event JSON, and the blocking client-side
//!   readers the loadgen and tests use.
//! * [`pool`] — the worker fleet: shared admission queue with
//!   precision-affinity dispatch and a PagePool-budget take gate,
//!   graceful drain, worker-death rebalance, fleet-merged metrics.
//! * [`listener`] — the readiness loop owning every socket; worker
//!   threads reach a connection only through its thread-safe outbox.
//!
//! The serving semantics (validation, plan resolution, speculation
//! arming, elastic shifting) are the SAME code paths as the in-process
//! [`crate::serve::Server`] host backend — `prepare_submit` and
//! `apply_elastic` are shared — so a response streamed over TCP is
//! byte-identical (token ids, done flags) to the same request served
//! in-process.
//!
//! Unix-only (`poll(2)`, `AsRawFd`, `UnixStream` wake channel); gated at
//! the `serve` module with `#[cfg(unix)]`.

pub mod codec;
pub mod listener;
pub mod pool;

pub use listener::HttpFrontend;
pub use pool::{ChannelSink, EventSink, PoolConfig, SubmitError, WorkerPool};
