//! Per-precision weight storage for the serving worker: warm precisions
//! keep a decoded f32 set resident (latency-optimal); lazily-built
//! precisions **page in the r-bit payloads** (`pack_sliced` codes + overlay
//! + scales) instead of decoding the int8 master into a full f32 weight
//! set (memory-optimal — `32/r`× fewer resident weight bytes).
//!
//! A paged set is decoded one tensor at a time only while literal arguments
//! for a PJRT batch execution are being built (the transient peak is a
//! single tensor, immediately converted and dropped); the **host serving
//! path** ([`crate::runtime::HostForward`], via
//! [`WeightStore::forward_weights`]) consumes the handles directly through
//! the fused matmul kernels ([`crate::model::PackedWeight::matmul_into`] /
//! [`crate::model::PackedWeight::matmul_i8_into`]) with no decode at all —
//! an entire request is answered while only payload bytes are resident.
//!
//! Response identity across the dense/paged switch is structural: the
//! decoded payload is bit-for-bit identical to
//! [`crate::model::QuantizedTensor::materialize`] (enforced by
//! `tests/kernel_conformance.rs` and `tests/serving.rs`), so the literals —
//! and therefore the responses — cannot differ.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::anyhow;

use super::metrics::Metrics;
use crate::model::{
    packed_payload_bytes, PackedWeight, PrecisionAssignment, QuantizedModel, Tensor,
};
use crate::runtime::{lit_tensor, ForwardWeights};
use crate::Result;

/// One per-precision weight set.
pub enum WeightSet {
    /// Warm build: the full decoded f32 weight + bias tensors.
    Dense {
        weights: Vec<Tensor>,
        biases: Vec<Tensor>,
    },
    /// Lazy build: r-bit payload handles per quantized tensor; f32 exists
    /// only transiently during literal conversion.
    Paged {
        packed: BTreeMap<String, PackedWeight>,
        payload_bytes: usize,
    },
}

impl WeightSet {
    /// Resident weight bytes of this set (f32 bytes for dense, payload
    /// bytes for paged) — the per-batch "weight bytes touched" figure.
    pub fn resident_bytes(&self) -> usize {
        match self {
            WeightSet::Dense { weights, biases } => weights
                .iter()
                .map(|t| t.len() * 4)
                .chain(biases.iter().map(|t| t.len() * 4))
                .sum(),
            WeightSet::Paged { payload_bytes, .. } => *payload_bytes,
        }
    }
}

/// Shared packed-payload build: derive the r-bit handles and record the
/// page-in (bytes + latency) in `metrics`.  Both the lazy `Paged` sets and
/// the int8 sibling builds go through here so their builds cannot drift.
fn build_packed_set(
    model: &QuantizedModel,
    bits: u32,
    metrics: &mut Metrics,
) -> Result<(BTreeMap<String, PackedWeight>, usize)> {
    let t0 = Instant::now();
    let packed = model.packed_weights(bits, false)?;
    let payload_bytes = packed_payload_bytes(&packed);
    metrics.record_page_in(
        bits,
        payload_bytes as u64,
        t0.elapsed().as_secs_f64() * 1e3,
    );
    Ok((packed, payload_bytes))
}

/// The worker's precision → weight-set map.
#[derive(Default)]
pub struct WeightStore {
    sets: BTreeMap<u32, WeightSet>,
    /// Packed-handle builds living *beside* a dense warm set at the same
    /// precision: the int8-activation host path needs payload handles, and
    /// a warm precision only has f32 tensors.  Keyed by bits; built on
    /// demand by [`WeightStore::ensure_packed`].
    packed_siblings: BTreeMap<u32, BTreeMap<String, PackedWeight>>,
}

impl WeightStore {
    pub fn new() -> Self {
        WeightStore::default()
    }

    pub fn contains(&self, bits: u32) -> bool {
        self.sets.contains_key(&bits)
    }

    /// Whether the set at `bits` is paged (`None` if absent).
    pub fn is_paged(&self, bits: u32) -> Option<bool> {
        self.sets
            .get(&bits)
            .map(|s| matches!(s, WeightSet::Paged { .. }))
    }

    /// Resident payload bytes of a paged set (`None` if absent or dense).
    pub fn payload_bytes(&self, bits: u32) -> Option<usize> {
        match self.sets.get(&bits) {
            Some(WeightSet::Paged { payload_bytes, .. }) => Some(*payload_bytes),
            _ => None,
        }
    }

    /// Warm build: decode the full f32 weight set now (boot-time
    /// precisions, where build latency is free and serve latency is not).
    pub fn build_warm(
        &mut self,
        model: &QuantizedModel,
        bits: u32,
        metrics: &mut Metrics,
    ) -> Result<()> {
        if self.contains(bits) {
            return Ok(());
        }
        let t0 = Instant::now();
        let (weights, biases) = model.materialize(&PrecisionAssignment::uniform(bits))?;
        metrics.record_materialize(bits, t0.elapsed().as_secs_f64() * 1e3);
        self.sets.insert(bits, WeightSet::Dense { weights, biases });
        Ok(())
    }

    /// Lazy build: page in the r-bit payloads — no f32 weight set is
    /// allocated or kept; the resident cost is `payload_bytes` (recorded
    /// in `metrics` as the page-in byte counter).  Smoothed models decode
    /// one tensor transiently during the build so the folded bias is
    /// bit-identical to a warm build's.
    pub fn build_paged(
        &mut self,
        model: &QuantizedModel,
        bits: u32,
        metrics: &mut Metrics,
    ) -> Result<()> {
        if self.contains(bits) {
            return Ok(());
        }
        let (packed, payload_bytes) = build_packed_set(model, bits, metrics)?;
        self.sets.insert(
            bits,
            WeightSet::Paged {
                packed,
                payload_bytes,
            },
        );
        Ok(())
    }

    /// Weight bytes a batch execution at `bits` touches (for the metrics
    /// bytes counter); 0 if the set is absent.
    pub fn batch_weight_bytes(&self, bits: u32) -> usize {
        self.sets.get(&bits).map_or(0, |s| s.resident_bytes())
    }

    /// Guarantee packed payload handles exist at `bits` for the
    /// int8-activation host path.  A paged set already is one; a dense warm
    /// set gets a sibling packed build (cached, page-in recorded in
    /// `metrics`) so warm precisions keep serving f32 requests from the
    /// dense tensors while int8 requests stream the payloads.
    pub fn ensure_packed(
        &mut self,
        model: &QuantizedModel,
        bits: u32,
        metrics: &mut Metrics,
    ) -> Result<()> {
        if matches!(self.sets.get(&bits), Some(WeightSet::Paged { .. }))
            || self.packed_siblings.contains_key(&bits)
        {
            return Ok(());
        }
        let (packed, _) = build_packed_set(model, bits, metrics)?;
        self.packed_siblings.insert(bits, packed);
        Ok(())
    }

    /// Borrowed weight view for the host forward pass
    /// ([`crate::runtime::HostForward`]).
    ///
    /// * `int8 == None` — dense sets serve the f32 reference path, paged
    ///   sets serve fused packed matmuls.
    /// * `int8 == Some(cfg)` — requires packed handles: the paged set's
    ///   own, or the sibling build from [`WeightStore::ensure_packed`].
    pub fn forward_weights(
        &self,
        bits: u32,
        int8: Option<crate::quant::ActQuantConfig>,
    ) -> Result<ForwardWeights<'_>> {
        if let Some(cfg) = int8 {
            let packed = match self.sets.get(&bits) {
                Some(WeightSet::Paged { packed, .. }) => packed,
                _ => self.packed_siblings.get(&bits).ok_or_else(|| {
                    anyhow!("int8 activations at int{bits} need a packed build — call ensure_packed first")
                })?,
            };
            return Ok(ForwardWeights::Packed {
                packed,
                int8: Some(cfg),
            });
        }
        match self.sets.get(&bits) {
            None => Err(anyhow!("no weight set for int{bits}")),
            Some(WeightSet::Dense { weights, biases }) => Ok(ForwardWeights::Dense {
                weights: weights.as_slice(),
                biases: biases.as_slice(),
            }),
            Some(WeightSet::Paged { packed, .. }) => Ok(ForwardWeights::Packed {
                packed,
                int8: None,
            }),
        }
    }

    /// Weight bytes a *host* forward at `bits` touches: payload bytes for
    /// packed execution (including int8-on-warm sibling builds), resident
    /// f32 bytes for the dense reference path.
    pub fn host_batch_weight_bytes(&self, bits: u32, int8: bool) -> usize {
        if int8 {
            if let Some(WeightSet::Paged { payload_bytes, .. }) = self.sets.get(&bits) {
                return *payload_bytes;
            }
            return self
                .packed_siblings
                .get(&bits)
                .map_or(0, packed_payload_bytes);
        }
        self.batch_weight_bytes(bits)
    }

    /// Build the weight + bias literal arguments for one batch execution,
    /// in artifact order (weights in `param_order`, then biases in
    /// `quantized_order`).  Dense sets convert their resident tensors;
    /// paged sets decode **one tensor at a time** through the fused
    /// packed-domain kernel — the peak transient f32 footprint is a single
    /// weight tensor, never a weight set.
    pub fn batch_args(&self, model: &QuantizedModel, bits: u32) -> Result<Vec<xla::Literal>> {
        match self.sets.get(&bits) {
            None => Err(anyhow!("no weight set for int{bits}")),
            Some(WeightSet::Dense { weights, biases }) => {
                let mut args = Vec::with_capacity(weights.len() + biases.len());
                for w in weights {
                    args.push(lit_tensor(w)?);
                }
                for b in biases {
                    args.push(lit_tensor(b)?);
                }
                Ok(args)
            }
            Some(WeightSet::Paged { packed, .. }) => {
                let mut args =
                    Vec::with_capacity(model.param_order.len() + model.quantized_order.len());
                for name in &model.param_order {
                    if let Some(pw) = packed.get(name) {
                        let (w, _) = pw.decode()?;
                        args.push(lit_tensor(&w)?);
                    } else {
                        let t = model
                            .params
                            .get(name)
                            .ok_or_else(|| anyhow!("missing param {name}"))?;
                        args.push(lit_tensor(t)?);
                    }
                }
                for name in &model.quantized_order {
                    let pw = packed
                        .get(name)
                        .ok_or_else(|| anyhow!("missing packed weight {name}"))?;
                    let bias = pw
                        .bias
                        .clone()
                        .unwrap_or_else(|| vec![0.0; pw.d_out]);
                    args.push(lit_tensor(&Tensor::new(vec![bias.len()], bias)?)?);
                }
                Ok(args)
            }
        }
    }
}
