//! Per-precision weight storage for the serving worker: warm precisions
//! keep a decoded f32 set resident (latency-optimal); lazily-built
//! precisions **page in the r-bit payloads** (`pack_sliced` codes + overlay
//! + scales) instead of decoding the int8 master into a full f32 weight
//! set (memory-optimal — `32/r`× fewer resident weight bytes).
//!
//! A paged set is decoded one tensor at a time only while literal arguments
//! for a PJRT batch execution are being built (the transient peak is a
//! single tensor, immediately converted and dropped); the **host serving
//! path** consumes packed handles directly through the fused matmul
//! kernels ([`crate::model::PackedWeight::matmul_into`] /
//! [`crate::model::PackedWeight::matmul_i8_into`]) with no decode at all —
//! an entire request is answered while only payload bytes are resident.
//!
//! Response identity across the dense/paged switch is structural: the
//! decoded payload is bit-for-bit identical to
//! [`crate::model::QuantizedTensor::materialize`] (enforced by
//! `tests/kernel_conformance.rs` and `tests/serving.rs`), so the literals —
//! and therefore the responses — cannot differ.
//!
//! The **host decode path** serves from cached [`ForwardPlan`]s instead of
//! raw weight sets: [`WeightStore::plan_warm`] /
//! [`WeightStore::plan_packed`] / [`WeightStore::plan_per_layer`] resolve
//! the model once per precision spec ([`PlanKey`]) and hand out shared
//! `Arc`s.  All packed plans draw their payload handles from one per-bits
//! handle store, so switching precision mid-traffic, toggling int8
//! activations, or composing a Mix'n'Match assignment reuses paged
//! payloads rather than rebuilding them; persisted activation-clip
//! calibration ([`WeightStore::set_calibration`]) is baked into int8 plans
//! as fixed-clip quantizers at build time.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::anyhow;

use super::metrics::Metrics;
use crate::model::manifest::ModelDims;
use crate::model::{PackedWeight, PrecisionAssignment, QuantizedModel, Tensor};
use crate::quant::{ActCalibration, ActQuantConfig};
use crate::runtime::{arc_packed, compose_per_layer, lit_tensor, plan_params, ForwardPlan};
use crate::Result;

/// One per-precision weight set.
pub enum WeightSet {
    /// Warm build: the full decoded f32 weight + bias tensors.
    Dense {
        weights: Vec<Tensor>,
        biases: Vec<Tensor>,
    },
    /// Lazy build: r-bit payload handles per quantized tensor, `Arc`-shared
    /// with the store's per-bits handle map (the same handles every packed
    /// [`ForwardPlan`] resolves against — ONE payload build per precision,
    /// whichever path asks first); f32 exists only transiently during
    /// literal conversion.
    Paged {
        packed: BTreeMap<String, Arc<PackedWeight>>,
        payload_bytes: usize,
    },
}

impl WeightSet {
    /// Resident weight bytes of this set (f32 bytes for dense, payload
    /// bytes for paged) — the per-batch "weight bytes touched" figure.
    pub fn resident_bytes(&self) -> usize {
        match self {
            WeightSet::Dense { weights, biases } => weights
                .iter()
                .map(|t| t.len() * 4)
                .chain(biases.iter().map(|t| t.len() * 4))
                .sum(),
            WeightSet::Paged { payload_bytes, .. } => *payload_bytes,
        }
    }
}

/// Cache key for one [`ForwardPlan`] — the precision spec the plan was
/// resolved for.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub enum PlanKey {
    /// Dense f32 plan at a warm precision (f32-exact reference numerics).
    Warm(u32),
    /// Packed plan at a uniform precision, f32 or int8 activations.
    Packed { bits: u32, int8: bool },
    /// Packed plan under a per-layer Mix'n'Match assignment.
    PerLayer { bits: Vec<u32>, int8: bool },
}

/// The worker's precision → weight-set map, plus the **forward-plan cache**
/// the host decode path serves from: one resolved [`ForwardPlan`] per
/// precision spec, sharing packed payload handles (and the non-quantized
/// parameter `Arc`s) across plans — switching `r` mid-traffic, toggling
/// int8, or serving a Mix'n'Match assignment reuses the paged payloads
/// instead of rebuilding them.
#[derive(Default)]
pub struct WeightStore {
    sets: BTreeMap<u32, WeightSet>,
    /// Shared packed handle sets per uniform precision — the payload store
    /// behind every packed plan (uniform and per-layer compose from here).
    handles: BTreeMap<u32, BTreeMap<String, Arc<PackedWeight>>>,
    /// Shared non-quantized parameter handles (embed/pos/norms/head),
    /// built once, `Arc`-cloned into every packed plan.
    params: Option<BTreeMap<String, Arc<Tensor>>>,
    /// Cached forward plans per precision spec.
    plans: BTreeMap<PlanKey, Arc<ForwardPlan>>,
    /// Persisted per-layer activation clips; baked into int8 plans at
    /// build time ([`WeightStore::set_calibration`]).
    calibration: Option<Arc<ActCalibration>>,
}

impl WeightStore {
    pub fn new() -> Self {
        WeightStore::default()
    }

    pub fn contains(&self, bits: u32) -> bool {
        self.sets.contains_key(&bits)
    }

    /// Whether the set at `bits` is paged (`None` if absent).
    pub fn is_paged(&self, bits: u32) -> Option<bool> {
        self.sets
            .get(&bits)
            .map(|s| matches!(s, WeightSet::Paged { .. }))
    }

    /// Resident payload bytes of a paged set (`None` if absent or dense).
    pub fn payload_bytes(&self, bits: u32) -> Option<usize> {
        match self.sets.get(&bits) {
            Some(WeightSet::Paged { payload_bytes, .. }) => Some(*payload_bytes),
            _ => None,
        }
    }

    /// Warm build: decode the full f32 weight set now (boot-time
    /// precisions, where build latency is free and serve latency is not).
    pub fn build_warm(
        &mut self,
        model: &QuantizedModel,
        bits: u32,
        metrics: &mut Metrics,
    ) -> Result<()> {
        if self.contains(bits) {
            return Ok(());
        }
        let t0 = Instant::now();
        let (weights, biases) = model.materialize(&PrecisionAssignment::uniform(bits))?;
        metrics.record_materialize(bits, t0.elapsed().as_secs_f64() * 1e3);
        self.sets.insert(bits, WeightSet::Dense { weights, biases });
        Ok(())
    }

    /// Lazy build: page in the r-bit payloads — no f32 weight set is
    /// allocated or kept; the resident cost is `payload_bytes` (recorded
    /// in `metrics` as the page-in byte counter).  Smoothed models decode
    /// one tensor transiently during the build so the folded bias is
    /// bit-identical to a warm build's.
    ///
    /// The payload comes from the shared per-bits handle store
    /// ([`WeightStore::ensure_handles`]): if the host decode path already
    /// resolved a packed plan at `bits`, this is a pure `Arc` clone —
    /// zero new payload bytes, zero extra page-in events (and vice versa:
    /// a later `plan_packed` at `bits` reuses this build).
    pub fn build_paged(
        &mut self,
        model: &QuantizedModel,
        bits: u32,
        metrics: &mut Metrics,
    ) -> Result<()> {
        if self.contains(bits) {
            return Ok(());
        }
        self.ensure_handles(model, bits, metrics)?;
        let packed = self.handles[&bits].clone();
        let payload_bytes = packed.values().map(|p| p.payload_bytes()).sum();
        self.sets.insert(
            bits,
            WeightSet::Paged {
                packed,
                payload_bytes,
            },
        );
        Ok(())
    }

    /// Weight bytes a batch execution at `bits` touches (for the metrics
    /// bytes counter); 0 if the set is absent.
    pub fn batch_weight_bytes(&self, bits: u32) -> usize {
        self.sets.get(&bits).map_or(0, |s| s.resident_bytes())
    }

    /// Install (or clear) the persisted activation-clip calibration
    /// ([`crate::quant::calibration`]).  Clips are baked into int8 plans at
    /// build time, so the cached plans are dropped — call this at boot,
    /// before traffic.
    pub fn set_calibration(&mut self, cal: Option<Arc<ActCalibration>>) {
        self.calibration = cal;
        self.plans.clear();
    }

    pub fn calibration(&self) -> Option<&ActCalibration> {
        self.calibration.as_deref()
    }

    /// Cached plans currently resident.
    pub fn plan_count(&self) -> usize {
        self.plans.len()
    }

    pub fn has_plan(&self, key: &PlanKey) -> bool {
        self.plans.contains_key(key)
    }

    fn ensure_params(&mut self, model: &QuantizedModel) {
        if self.params.is_none() {
            self.params = Some(plan_params(model));
        }
    }

    /// Page in the shared handle set at `bits`.  Handles are **nested**:
    /// each is an MSB-prefix bit-slice view of the tensor's `Arc`-shared
    /// int8 master ([`crate::model::QuantizedModel::packed_views`]), so the
    /// store holds ONE payload per tensor no matter how many precisions are
    /// resident.  The first precision paged in records the master bytes
    /// (what the views actually stream); **every later precision records
    /// zero new page-in bytes** — it is an `Arc` clone of bytes already
    /// resident — and the compact per-r payload a non-nested build would
    /// have paged instead is credited to the savings counter
    /// ([`Metrics::page_in_saved_bytes`]).
    ///
    /// This remains the ONE payload build per precision: the PJRT `Paged`
    /// sets ([`build_paged`]) and every packed [`ForwardPlan`] draw `Arc`s
    /// from this store, and `shift_uniform` plan swaps are pure pointer
    /// moves between plans that already share it.
    ///
    /// [`build_paged`]: WeightStore::build_paged
    pub fn ensure_handles(
        &mut self,
        model: &QuantizedModel,
        bits: u32,
        metrics: &mut Metrics,
    ) -> Result<()> {
        if self.handles.contains_key(&bits) {
            return Ok(());
        }
        let first = self.handles.is_empty();
        let t0 = Instant::now();
        let packed = arc_packed(model.packed_views(bits, false)?);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        if first {
            let payload: usize = packed.values().map(|p| p.payload_bytes()).sum();
            metrics.record_page_in(bits, payload as u64, ms);
        } else {
            let saved: usize = packed.values().map(|p| p.compact_payload_bytes()).sum();
            metrics.record_page_in(bits, 0, ms);
            metrics.record_page_in_saved(bits, saved as u64);
        }
        self.handles.insert(bits, packed);
        Ok(())
    }

    /// The dense f32 plan at a warm precision: materialize once at boot
    /// (recorded like any warm build), serve f32-exact reference numerics
    /// from then on.
    pub fn plan_warm(
        &mut self,
        model: &QuantizedModel,
        dims: &ModelDims,
        bits: u32,
        metrics: &mut Metrics,
    ) -> Result<Arc<ForwardPlan>> {
        let key = PlanKey::Warm(bits);
        if let Some(p) = self.plans.get(&key) {
            return Ok(p.clone());
        }
        let t0 = Instant::now();
        let (weights, biases) = model.materialize(&PrecisionAssignment::uniform(bits))?;
        let plan = Arc::new(ForwardPlan::from_dense(dims, model, weights, biases)?);
        metrics.record_materialize(bits, t0.elapsed().as_secs_f64() * 1e3);
        self.plans.insert(key, plan.clone());
        Ok(plan)
    }

    /// The packed plan at a uniform precision (f32 or int8 activations).
    /// Payload handles are shared with every other plan at `bits`, so the
    /// int8 sibling of an f32 plan (or vice versa) costs only the resolve.
    pub fn plan_packed(
        &mut self,
        model: &QuantizedModel,
        dims: &ModelDims,
        bits: u32,
        int8: Option<ActQuantConfig>,
        metrics: &mut Metrics,
    ) -> Result<Arc<ForwardPlan>> {
        let key = PlanKey::Packed {
            bits,
            int8: int8.is_some(),
        };
        if let Some(p) = self.plans.get(&key) {
            return Ok(p.clone());
        }
        self.ensure_handles(model, bits, metrics)?;
        self.ensure_params(model);
        let packed = &self.handles[&bits];
        let params = self.params.as_ref().expect("params ensured above");
        let plan = Arc::new(ForwardPlan::from_packed(
            dims,
            model,
            params,
            packed,
            int8,
            self.calibration.as_deref(),
        )?);
        self.plans.insert(key, plan.clone());
        Ok(plan)
    }

    /// The packed plan under a per-layer Mix'n'Match assignment (e.g. from
    /// [`crate::mixnmatch::sensitivity::suggest_assignment`]): each layer's
    /// handles are `Arc`-shared with the uniform set at that layer's
    /// precision, so a mixed plan pages in only the precisions it actually
    /// uses.
    pub fn plan_per_layer(
        &mut self,
        model: &QuantizedModel,
        dims: &ModelDims,
        assign: &[u32],
        int8: Option<ActQuantConfig>,
        metrics: &mut Metrics,
    ) -> Result<Arc<ForwardPlan>> {
        let key = PlanKey::PerLayer {
            bits: assign.to_vec(),
            int8: int8.is_some(),
        };
        if let Some(p) = self.plans.get(&key) {
            return Ok(p.clone());
        }
        let mut distinct = assign.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        for &b in &distinct {
            self.ensure_handles(model, b, metrics)?;
        }
        self.ensure_params(model);
        let packed = compose_per_layer(model, &self.handles, assign)?;
        let params = self.params.as_ref().expect("params ensured above");
        let mut plan = ForwardPlan::from_packed(
            dims,
            model,
            params,
            &packed,
            int8,
            self.calibration.as_deref(),
        )?;
        plan.per_layer = Some(assign.to_vec());
        let plan = Arc::new(plan);
        self.plans.insert(key, plan.clone());
        Ok(plan)
    }

    /// Build the weight + bias literal arguments for one batch execution,
    /// in artifact order (weights in `param_order`, then biases in
    /// `quantized_order`).  Dense sets convert their resident tensors;
    /// paged sets decode **one tensor at a time** through the fused
    /// packed-domain kernel — the peak transient f32 footprint is a single
    /// weight tensor, never a weight set.
    pub fn batch_args(&self, model: &QuantizedModel, bits: u32) -> Result<Vec<xla::Literal>> {
        match self.sets.get(&bits) {
            None => Err(anyhow!("no weight set for int{bits}")),
            Some(WeightSet::Dense { weights, biases }) => {
                let mut args = Vec::with_capacity(weights.len() + biases.len());
                for w in weights {
                    args.push(lit_tensor(w)?);
                }
                for b in biases {
                    args.push(lit_tensor(b)?);
                }
                Ok(args)
            }
            Some(WeightSet::Paged { packed, .. }) => {
                let mut args =
                    Vec::with_capacity(model.param_order.len() + model.quantized_order.len());
                for name in &model.param_order {
                    if let Some(pw) = packed.get(name) {
                        let (w, _) = pw.decode()?;
                        args.push(lit_tensor(&w)?);
                    } else {
                        let t = model
                            .params
                            .get(name)
                            .ok_or_else(|| anyhow!("missing param {name}"))?;
                        args.push(lit_tensor(t.as_ref())?);
                    }
                }
                for name in &model.quantized_order {
                    let pw = packed
                        .get(name)
                        .ok_or_else(|| anyhow!("missing packed weight {name}"))?;
                    let bias = pw
                        .bias
                        .clone()
                        .unwrap_or_else(|| vec![0.0; pw.d_out]);
                    args.push(lit_tensor(&Tensor::new(vec![bias.len()], bias)?)?);
                }
                Ok(args)
            }
        }
    }
}
