//! The continuous-batching decode scheduler — the engine that turns the
//! incremental decoder into a **multi-tenant** server.
//!
//! Before this module the host worker stepped each live [`DecodeSession`]
//! alone: N concurrent streams cost N fused matvec sweeps per token, so
//! the paged-payload wins of the packed data flow evaporated exactly under
//! load.  The scheduler groups live sessions by [`PlanKey`] — the full
//! precision spec: uniform bits ± int8 activations ± a Mix'n'Match
//! per-layer map — and advances each group in **step rounds**:
//!
//! ```text
//!   Scheduler
//!     ├─ group int4           ─ round ─► ONE blocked fused GEMM per
//!     │    live: [s1, s2, s3]           linear across all members' current
//!     │    pending: [r9]                tokens (payload streamed once per
//!     ├─ group int2+i8                  GEMM block per ROUND), then each
//!     │    live: [s4]                   member's single query attends its
//!     └─ group mix[8/4/2]               OWN KvCache
//!          pending: [r7, r8]
//! ```
//!
//! * **Step rounds** ([`ForwardPlan::decode_step_batch`] via
//!   [`crate::runtime::advance_sessions`]): every op processes member rows
//!   independently, so each member's token stream is **bit-identical** to
//!   the stream a solo session produces — round composition can change
//!   cost, never answers (`cargo test --test scheduler`).
//! * **Batched prefill** ([`DecodeSession::prefill_many`] →
//!   [`ForwardPlan::prefill_batch`]): admitted requests of a group prefill
//!   as one ragged fused pass instead of b=1 each, capturing K/V per
//!   sequence.  The first sampled token streams immediately, then the new
//!   sessions join their group's next round — **mid-stream admission**.
//! * **Fairness + KV pressure**: at most
//!   [`SchedulerConfig::max_prefills_per_round`] prefills are admitted per
//!   round, distributed round-robin across groups (one per group per turn,
//!   rotating the starting group every round) so a hot precision cannot
//!   starve the others.  When [`SchedulerConfig::kv_capacity_bytes`] is
//!   set, a prefill whose projected KV pages would push **actually
//!   resident** pool bytes past the budget is **deferred** (kept queued,
//!   FIFO within its group) rather than admitted — live streams are never
//!   evicted to make room.
//! * **Paged KV** (`PagePool → block table → paged attend`,
//!   [`crate::runtime::kv`]): the scheduler owns one [`PagePool`] sized by
//!   [`SchedulerConfig::kv`] ([`Scheduler::pool`]); every admitted
//!   session's [`crate::runtime::KvCache`] is a block table mapping pages
//!   from it lazily, and [`Scheduler::resident_kv_bytes`] reports the
//!   pool's actual residency — pages in use, not per-stream capacity.
//!   Admission is therefore page-granular: a stream's *projection* is its
//!   page-rounded full capacity ([`projected_kv_bytes`]), but what it
//!   *holds* grows page by page, so streams admit against real usage
//!   instead of whole-stream reservations.  (Allocation itself is soft:
//!   live streams always run to completion; the budget is an admission
//!   watermark, and transient overshoot from concurrent growth is bounded
//!   by the live streams' projections.)  Pending requests whose prompt
//!   shares a page-aligned prefix with a live member prefill **only the
//!   suffix** and map the donor's pages copy-on-write
//!   ([`DecodeSession::prefill_shared`]) — shared physical pages count
//!   once in the pool gauge.
//! * **Failure containment**: a round that errors falls back to solo
//!   steps, retiring only the members that actually fail; a batched
//!   prefill that errors falls back to solo prefills the same way.  A
//!   member whose KV/position capacity fills mid-round ends its own stream
//!   (`done`, truncated) while the round's other members keep stepping.
//! * **Elastic precision shifts** ([`Scheduler::shift_uniform`] /
//!   [`Scheduler::shift_up_natives`], driven by the serving worker's
//!   [`crate::serve::ElasticPlanner`]): under KV/queue pressure a whole
//!   uniform packed group — live sessions AND queued requests — moves one
//!   ladder rung down; once both low watermarks hold, displaced streams
//!   return to their native precision.  A live session's plan swap is
//!   geometry-checked ([`DecodeSession::switch_plan`]) and keeps its KV
//!   rows, so a shift costs no recompute — and, because every precision is
//!   an MSB-prefix view of the one nested payload, no new weight bytes.
//! * **Self-speculative rounds** ([`Scheduler::set_speculation`] →
//!   [`crate::runtime::speculative_round`]): a configured group's greedy
//!   members draft `k−1` tokens per round with the low-bit MSB-prefix
//!   rung of their own payload, verify the whole window in ONE batched
//!   target pass, commit the longest agreeing prefix, and roll rejected
//!   K/V rows back ([`crate::runtime::KvCache::truncate_to`]).  Emitted
//!   streams stay bit-identical to plain decode — only tokens/round moves
//!   (`spec=[...]` in [`Metrics::report`]).  Windows are atomic within a
//!   round, so elastic shifts never land mid-speculation; the planner
//!   suspends speculation entirely while a high watermark is breached
//!   ([`Scheduler::suspend_speculation`]) because draft slots cost `k`
//!   provisional KV rows per member (projected at admission by
//!   [`projected_kv_bytes`]).  Temperature streams always take the plain
//!   path so their seeded sampling never perturbs.
//!
//! The scheduler is deliberately free of channels and threads: the serving
//! worker ([`crate::serve::Server::start_host`]) owns it and calls
//! [`Scheduler::run_round`] in its loop, passing a sink that forwards each
//! [`Response`] event to the right client.  That keeps the interleave
//! policy testable without a server — `tests/scheduler.rs` drives rounds
//! directly and compares every stream against solo sessions bit for bit.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use super::metrics::Metrics;
use super::request::{Request, Response};
use super::weights::PlanKey;
use crate::model::manifest::ModelDims;
use crate::runtime::{
    advance_sessions, speculative_round, DecodeSession, ForwardPlan, KvConfig, PagePool, Sampling,
};

/// Projected resident KV bytes for one request's session — mirrors
/// [`DecodeSession::with_budget`]'s cache sizing exactly (prompt +
/// max_new − 1 positions, clamped to the model window), **page-rounded**
/// under the pool geometry `kv`: each layer holds
/// `ceil(capacity / page_size)` pages of [`KvConfig::page_bytes`] each.
/// `spec_slots` is the `k` provisional positions a self-speculative
/// group's sessions additionally reserve (the verify window's K/V rows
/// exist before acceptance decides their fate, so admission must hold
/// budget for them up front) — 0 for a plain group.  Admission holds the
/// [`SchedulerConfig::kv_capacity_bytes`] budget against `resident pool
/// bytes + this figure`, and the server rejects at submit any request
/// whose projection exceeds the budget **on its own** — such a request
/// could never be admitted and would otherwise sit deferred forever.
pub fn projected_kv_bytes(
    dims: &ModelDims,
    prompt_len: usize,
    max_new_tokens: usize,
    spec_slots: usize,
    kv: &KvConfig,
) -> u64 {
    let seq = dims.seq_len;
    let prompt = prompt_len.clamp(1, seq);
    let capacity = prompt
        .saturating_add(max_new_tokens.saturating_sub(1))
        .saturating_add(spec_slots)
        .min(seq);
    let pages = capacity.div_ceil(kv.page_size);
    (dims.n_layers as u64) * (pages as u64) * (kv.page_bytes(dims.d_model) as u64)
}

/// Scheduling policy knobs (see the module docs).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Fairness cap: prefills admitted per round across all groups,
    /// distributed round-robin (minimum 1).
    pub max_prefills_per_round: usize,
    /// KV admission budget in bytes against the shared page pool's
    /// **resident** bytes; `None` means unbounded.  Prefills whose
    /// page-rounded projection would exceed it are deferred, never
    /// admitted over budget, and live streams are never evicted.
    pub kv_capacity_bytes: Option<u64>,
    /// Page-pool geometry for every session's KV cache: page size in
    /// token rows and row dtype (f32, or int8 with per-row scales).
    pub kv: KvConfig,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_prefills_per_round: 4,
            kv_capacity_bytes: None,
            kv: KvConfig::default(),
        }
    }
}

/// A request admitted to a group's prefill queue.
struct Pending {
    req: Request,
    enq: Instant,
    /// The uniform bit-width the request originally resolved to — where an
    /// elastic upshift returns it ([`Scheduler::shift_up_natives`]).
    native_bits: u32,
}

/// One live stream between rounds.
struct Live {
    id: u64,
    session: DecodeSession,
    /// Tokens still to emit.
    remaining: usize,
    /// Last sampled token — the next round's input.
    last: i32,
    enq: Instant,
    prefill_ms: f64,
    decode_ms: f64,
    /// Width of the prefill round this request rode in.
    batch_size: usize,
    /// The precision the request asked for; a group holding members whose
    /// `native_bits` exceeds its own width is serving **displaced**
    /// (downshifted) streams.
    native_bits: u32,
}

/// One precision group: a shared plan, its live round members, and its
/// FIFO prefill queue.
struct Group {
    plan: Arc<ForwardPlan>,
    bits: u32,
    int8: bool,
    live: Vec<Live>,
    pending: VecDeque<Pending>,
}

/// What one [`Scheduler::run_round`] did.
#[derive(Debug, Default)]
pub struct RoundOutcome {
    /// Live sessions stepped this round (across all groups).
    pub stepped: usize,
    /// Requests prefilled (admitted) this round.
    pub prefilled: usize,
    /// Requests that failed mid-round — the caller closes their response
    /// channels (their sink was never sent a `done` event).
    pub failed: Vec<u64>,
}

/// What to do with a live member after its per-step bookkeeping.
enum Fate {
    Alive,
    Retire,
}

/// What one elastic precision shift moved (see
/// [`Scheduler::shift_uniform`] / [`Scheduler::shift_up_natives`]).
#[derive(Debug, Default)]
pub struct ShiftReport {
    /// Live sessions whose plan pointer was swapped mid-stream.
    pub moved_live: usize,
    /// Queued (not yet prefilled) requests re-homed to the new group.
    pub moved_pending: usize,
    /// Streams that could not survive the shift — the caller closes their
    /// response channels, mirroring [`RoundOutcome::failed`].
    pub failed: Vec<u64>,
}

impl ShiftReport {
    pub fn moved(&self) -> usize {
        self.moved_live + self.moved_pending
    }
}

/// Load snapshot of one uniform packed group — what the elastic policy
/// ranks to pick a downshift candidate.
#[derive(Debug, Clone, Copy)]
pub struct UniformGroupLoad {
    pub bits: u32,
    pub int8: bool,
    pub live: usize,
    pub pending: usize,
}

/// Self-speculative configuration for one target group: the draft-rung
/// plan (an MSB-prefix view of the same payload), its width, and the
/// verify-window size `k`.
struct SpecPlan {
    draft: Arc<ForwardPlan>,
    draft_bits: u32,
    k: usize,
}

/// The continuous-batching engine (see the module docs).
pub struct Scheduler {
    cfg: SchedulerConfig,
    groups: BTreeMap<PlanKey, Group>,
    /// Self-speculative decode per target group
    /// ([`Scheduler::set_speculation`]): greedy members of a configured
    /// group run draft/verify rounds instead of plain single-token steps.
    spec: BTreeMap<PlanKey, SpecPlan>,
    /// Pause switch ([`Scheduler::suspend_speculation`]) — the elastic
    /// planner flips it under KV/queue pressure, because a speculative
    /// round holds `k` provisional K/V rows per member and drafts cost
    /// extra compute that pressure rounds cannot spare.
    spec_suspended: bool,
    /// Monotone round counter — rotates the admission starting group.
    round: u64,
    /// The shared KV page pool every admitted session draws from.
    pool: PagePool,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let pool = PagePool::new(cfg.kv, cfg.kv_capacity_bytes);
        Scheduler {
            cfg,
            groups: BTreeMap::new(),
            spec: BTreeMap::new(),
            spec_suspended: false,
            round: 0,
            pool,
        }
    }

    /// Build a scheduler over an **externally owned** page pool instead of
    /// a private one — the fleet mode: every `serve::frontend` worker's
    /// scheduler draws pages from ONE shared pool, so the
    /// [`SchedulerConfig::kv_capacity_bytes`] admission watermark holds
    /// against fleet-wide residency, not per-worker residency.  The pool's
    /// geometry wins over `cfg.kv` (sessions must allocate pages the pool
    /// actually hands out).
    pub fn with_pool(mut cfg: SchedulerConfig, pool: PagePool) -> Self {
        cfg.kv = pool.cfg();
        Scheduler {
            cfg,
            groups: BTreeMap::new(),
            spec: BTreeMap::new(),
            spec_suspended: false,
            round: 0,
            pool,
        }
    }

    /// Pull every queued (not yet prefilled) request out of every group —
    /// the rebalance path when this scheduler's worker dies or drains:
    /// the extracted requests re-enter the fleet's shared admission queue
    /// and complete on a surviving worker.  Live streams are untouched
    /// (they either finish here or are failed explicitly by the caller);
    /// groups left with no members are dropped.
    pub fn drain_pending(&mut self) -> Vec<(Request, Instant)> {
        let mut out = Vec::new();
        for g in self.groups.values_mut() {
            for p in g.pending.drain(..) {
                out.push((p.req, p.enq));
            }
        }
        self.groups
            .retain(|_, g| !g.live.is_empty() || !g.pending.is_empty());
        out
    }

    /// The shared KV page pool (residency, recycling, and sharing gauges).
    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// Enable self-speculative decode for the target group `key`: greedy
    /// members draft `k − 1` tokens per round with `draft` (the
    /// `draft_bits` MSB-prefix rung of the same nested payload) and verify
    /// the whole window in one batched target pass.  `k < 2` clears the
    /// entry instead (a 1-wide window IS plain decode).  Temperature
    /// members of the group always take the plain path — their seeded
    /// `Rng` stream must consume exactly one draw per emitted token.
    pub fn set_speculation(&mut self, key: PlanKey, draft: Arc<ForwardPlan>, draft_bits: u32, k: usize) {
        if k >= 2 {
            self.spec.insert(
                key,
                SpecPlan {
                    draft,
                    draft_bits,
                    k,
                },
            );
        } else {
            self.spec.remove(&key);
        }
    }

    /// Drop the speculative configuration for `key` (members fall back to
    /// plain rounds from the next round on; no in-flight state to unwind —
    /// speculation windows are atomic within a round).
    pub fn clear_speculation(&mut self, key: &PlanKey) {
        self.spec.remove(key);
    }

    /// Pause (`true`) or resume (`false`) all speculative decode without
    /// dropping the per-group configuration — the elastic planner's lever
    /// while a watermark is breached.
    pub fn suspend_speculation(&mut self, suspend: bool) {
        self.spec_suspended = suspend;
    }

    /// Whether speculation is currently paused.
    pub fn speculation_suspended(&self) -> bool {
        self.spec_suspended
    }

    /// The provisional draft slots (`k`) admission must reserve for a
    /// request joining group `key` — 0 when the group does not speculate
    /// or the request samples with temperature (temperature streams never
    /// enter a speculation window).
    fn spec_slots(&self, key: &PlanKey, sampling: &Sampling) -> usize {
        match (self.spec.get(key), sampling) {
            (Some(sp), Sampling::Greedy) => sp.k,
            _ => 0,
        }
    }

    /// Queue a validated request for admission into its precision group.
    /// `key` and `plan` come from the worker's
    /// [`crate::serve::WeightStore`] (one resolved plan per key); the
    /// request joins the group's FIFO prefill queue and will be admitted
    /// by a future round under the fairness/KV policy.
    pub fn submit(
        &mut self,
        key: PlanKey,
        plan: Arc<ForwardPlan>,
        bits: u32,
        int8: bool,
        req: Request,
        enq: Instant,
    ) {
        let g = self.groups.entry(key).or_insert_with(|| Group {
            plan: plan.clone(),
            bits,
            int8,
            live: Vec::new(),
            pending: VecDeque::new(),
        });
        if !Arc::ptr_eq(&g.plan, &plan) && g.live.is_empty() && g.pending.is_empty() {
            // The store rebuilt the plan (e.g. calibration reload) while
            // the group sat idle — adopt the new plan; with members in
            // flight keep the old one so rounds never mix plans.
            g.plan = plan;
        }
        g.pending.push_back(Pending {
            req,
            enq,
            native_bits: bits,
        });
    }

    /// Monotone round counter (the elastic planner's clock).
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Load snapshot of every uniform [`PlanKey::Packed`] group — Warm and
    /// per-layer groups are excluded because they never shift (a dense f32
    /// plan has no ladder and a Mix'n'Match map is already a per-layer
    /// precision decision).
    pub fn uniform_groups(&self) -> Vec<UniformGroupLoad> {
        self.groups
            .iter()
            .filter_map(|(k, g)| match k {
                PlanKey::Packed { bits, int8 } => Some(UniformGroupLoad {
                    bits: *bits,
                    int8: *int8,
                    live: g.live.len(),
                    pending: g.pending.len(),
                }),
                _ => None,
            })
            .collect()
    }

    /// **Elastic downshift**: move every live session AND queued request of
    /// the uniform group `(from_bits, int8)` to the `(to_bits, int8)` group
    /// served by `to_plan` — mid-stream, between rounds.
    ///
    /// Each live member's KV rows stay valid (cached K/V are f32
    /// activations of already-processed positions); the regroup is a plan
    /// pointer swap ([`DecodeSession::switch_plan`]) plus a map move, so a
    /// shift costs no recompute and — under the nested payload — no weight
    /// paging.  If the destination group already has members in flight,
    /// their plan wins (rounds never mix plan pointers); `to_plan` is
    /// adopted only by an empty or fresh group.  A member that cannot
    /// switch (geometry mismatch — not expected on one model) lands in
    /// [`ShiftReport::failed`].
    pub fn shift_uniform(
        &mut self,
        from_bits: u32,
        int8: bool,
        to_bits: u32,
        to_plan: Arc<ForwardPlan>,
    ) -> ShiftReport {
        let mut report = ShiftReport::default();
        let from_key = PlanKey::Packed {
            bits: from_bits,
            int8,
        };
        let Some(src) = self.groups.remove(&from_key) else {
            return report;
        };
        let dst_key = PlanKey::Packed {
            bits: to_bits,
            int8,
        };
        let dst = self.groups.entry(dst_key).or_insert_with(|| Group {
            plan: to_plan.clone(),
            bits: to_bits,
            int8,
            live: Vec::new(),
            pending: VecDeque::new(),
        });
        if dst.live.is_empty() && dst.pending.is_empty() {
            dst.plan = to_plan;
        }
        let plan = dst.plan.clone();
        for mut l in src.live {
            match l.session.switch_plan(plan.clone()) {
                Ok(()) => {
                    report.moved_live += 1;
                    dst.live.push(l);
                }
                Err(e) => {
                    eprintln!(
                        "serve scheduler: request {}: int{from_bits}→int{to_bits} shift failed: {e:#}",
                        l.id
                    );
                    report.failed.push(l.id);
                }
            }
        }
        report.moved_pending = src.pending.len();
        dst.pending.extend(src.pending);
        report
    }

    /// **Elastic upshift**: return every displaced stream and queued
    /// request (member `native_bits` above its group's width) straight to
    /// its native uniform group — not rung-by-rung, so a session pushed
    /// int8→int4→int2 under sustained pressure recovers in one shift.
    ///
    /// `resolve` supplies the destination plan per `(bits, int8)` (the
    /// worker's [`crate::serve::WeightStore`] lookup — a cache hit for any
    /// precision that served before).  If a destination plan cannot be
    /// built, its members stay displaced (still serving, still correct)
    /// rather than failing.
    pub fn shift_up_natives(
        &mut self,
        resolve: &mut dyn FnMut(u32, bool) -> Option<Arc<ForwardPlan>>,
    ) -> ShiftReport {
        let mut report = ShiftReport::default();
        // Phase 1: pull displaced members out of their downshifted groups
        // (remembering the source so a failed resolve can re-park them).
        let mut live_moves: Vec<(PlanKey, u32, bool, Live)> = Vec::new();
        let mut pending_moves: Vec<(PlanKey, u32, bool, Pending)> = Vec::new();
        for (key, g) in self.groups.iter_mut() {
            let int8 = match key {
                PlanKey::Packed { int8, .. } => *int8,
                _ => continue,
            };
            let bits = g.bits;
            let mut i = 0;
            while i < g.live.len() {
                if g.live[i].native_bits > bits {
                    let l = g.live.remove(i);
                    live_moves.push((key.clone(), l.native_bits, int8, l));
                } else {
                    i += 1;
                }
            }
            let drained: Vec<Pending> = g.pending.drain(..).collect();
            for p in drained {
                if p.native_bits > bits {
                    pending_moves.push((key.clone(), p.native_bits, int8, p));
                } else {
                    g.pending.push_back(p);
                }
            }
        }
        // Phase 2: restore into native groups, one resolve per destination.
        let mut plans: BTreeMap<(u32, bool), Option<Arc<ForwardPlan>>> = BTreeMap::new();
        let mut plan_for = |bits: u32, int8: bool| -> Option<Arc<ForwardPlan>> {
            plans
                .entry((bits, int8))
                .or_insert_with(|| resolve(bits, int8))
                .clone()
        };
        for (src_key, bits, int8, mut l) in live_moves {
            let Some(plan) = plan_for(bits, int8) else {
                self.repark_live(src_key, l);
                continue;
            };
            let plan = self.dest_plan(bits, int8, plan);
            match l.session.switch_plan(plan) {
                Ok(()) => {
                    report.moved_live += 1;
                    self.dest_group(bits, int8).live.push(l);
                }
                Err(e) => {
                    eprintln!(
                        "serve scheduler: request {}: upshift to int{bits} failed: {e:#}",
                        l.id
                    );
                    report.failed.push(l.id);
                }
            }
        }
        for (src_key, bits, int8, p) in pending_moves {
            let Some(plan) = plan_for(bits, int8) else {
                if let Some(g) = self.groups.get_mut(&src_key) {
                    g.pending.push_back(p);
                }
                continue;
            };
            let _ = self.dest_plan(bits, int8, plan); // ensure the group exists
            report.moved_pending += 1;
            self.dest_group(bits, int8).pending.push_back(p);
        }
        self.groups
            .retain(|_, g| !g.live.is_empty() || !g.pending.is_empty());
        report
    }

    /// The destination group for a shift, created on demand.  Only called
    /// after a plan for `(bits, int8)` resolved, so the placeholder plan is
    /// always replaced before use via [`Scheduler::dest_plan`].
    fn dest_group(&mut self, bits: u32, int8: bool) -> &mut Group {
        self.groups
            .get_mut(&PlanKey::Packed { bits, int8 })
            .expect("dest_plan created the group")
    }

    /// Resolve which plan pointer incoming shifted members must adopt:
    /// the destination group's own plan when it has members in flight
    /// (rounds never mix pointers), the freshly resolved one otherwise.
    fn dest_plan(&mut self, bits: u32, int8: bool, resolved: Arc<ForwardPlan>) -> Arc<ForwardPlan> {
        let g = self
            .groups
            .entry(PlanKey::Packed { bits, int8 })
            .or_insert_with(|| Group {
                plan: resolved.clone(),
                bits,
                int8,
                live: Vec::new(),
                pending: VecDeque::new(),
            });
        if g.live.is_empty() && g.pending.is_empty() {
            g.plan = resolved;
        }
        g.plan.clone()
    }

    /// Put a live member back where it came from after a failed upshift
    /// resolve (its group entry still exists — members were only drained).
    fn repark_live(&mut self, src_key: PlanKey, l: Live) {
        if let Some(g) = self.groups.get_mut(&src_key) {
            g.live.push(l);
        }
    }

    /// Whether any stream is live or any request awaits a prefill slot.
    pub fn has_work(&self) -> bool {
        self.groups
            .values()
            .any(|g| !g.live.is_empty() || !g.pending.is_empty())
    }

    /// Live streams across all groups.
    pub fn live_sessions(&self) -> usize {
        self.groups.values().map(|g| g.live.len()).sum()
    }

    /// Requests still queued for a prefill slot.
    pub fn pending_prefills(&self) -> usize {
        self.groups.values().map(|g| g.pending.len()).sum()
    }

    /// Resident KV bytes — the pool's actually checked-out pages (shared
    /// pages counted once), NOT the sum of live-session capacities.  This
    /// is the figure admission holds under
    /// [`SchedulerConfig::kv_capacity_bytes`]: a young stream pins only
    /// the pages it has mapped so far, so admission tracks real usage.
    pub fn resident_kv_bytes(&self) -> u64 {
        self.pool.resident_bytes()
    }

    /// Drop streams and queued requests whose client vanished (`alive`
    /// returns false) — their KV pages free immediately instead of being
    /// stepped to completion for nobody.
    pub fn prune(&mut self, alive: &dyn Fn(u64) -> bool) {
        for g in self.groups.values_mut() {
            g.live.retain(|l| alive(l.id));
            g.pending.retain(|p| alive(p.req.id));
        }
        self.groups
            .retain(|_, g| !g.live.is_empty() || !g.pending.is_empty());
    }

    /// Run one scheduling round: step every group's live sessions as one
    /// batched GEMM round each, then admit pending prefills under the
    /// fairness cap and KV budget (batched per group).  `sink` receives
    /// every [`Response`] event (intermediate and final) and returns
    /// whether the client still listens; events after a `false` retire the
    /// stream.  Failed requests are reported in the outcome instead of
    /// receiving events.
    pub fn run_round(
        &mut self,
        metrics: &mut Metrics,
        sink: &mut dyn FnMut(u64, Response) -> bool,
    ) -> RoundOutcome {
        let mut out = RoundOutcome::default();
        self.step_groups(metrics, sink, &mut out);
        self.admit(metrics, sink, &mut out);
        metrics.set_kv_bytes(self.resident_kv_bytes());
        metrics.set_kv_pool(
            self.pool.resident_pages() as u64,
            self.pool.shared_bytes(),
            self.pool.cow_breaks(),
        );
        self.groups
            .retain(|_, g| !g.live.is_empty() || !g.pending.is_empty());
        self.round = self.round.wrapping_add(1);
        out
    }

    /// Decode phase: one batched step round per group with live members.
    ///
    /// A group with a speculative configuration splits its members per
    /// round: greedy members whose stream can still absorb a ≥2-token
    /// window run ONE [`speculative_round`] at the common window width
    /// (the minimum of every eligible member's open window, remaining
    /// budget, and the configured `k` — windows are atomic, so elastic
    /// shifts, which run between rounds, can never land mid-window);
    /// everyone else — temperature streams, members on their last token —
    /// takes the plain batched step.  A failed speculative round rolls
    /// back completely ([`speculative_round`]'s containment contract) and
    /// its members re-run in the plain step, so speculation can slow a
    /// round but never lose one.
    fn step_groups(
        &mut self,
        metrics: &mut Metrics,
        sink: &mut dyn FnMut(u64, Response) -> bool,
        out: &mut RoundOutcome,
    ) {
        for (key, g) in self.groups.iter_mut() {
            if g.live.is_empty() {
                continue;
            }
            // Partition: which members speculate this round, and how wide.
            let mut spec_mask = vec![false; g.live.len()];
            let mut k_eff = 0usize;
            let sp = if self.spec_suspended {
                None
            } else {
                self.spec.get(key)
            };
            if let Some(sp) = sp {
                k_eff = sp.k;
                let mut any = false;
                for (i, l) in g.live.iter().enumerate() {
                    let w = sp.k.min(l.remaining).min(l.session.spec_window());
                    if matches!(l.session.sampling(), Sampling::Greedy) && w >= 2 {
                        spec_mask[i] = true;
                        k_eff = k_eff.min(w);
                        any = true;
                    }
                }
                if !any || k_eff < 2 {
                    spec_mask.iter_mut().for_each(|b| *b = false);
                    k_eff = 0;
                }
            }
            // Retirement is deferred to one sweep so the two sub-rounds
            // never invalidate each other's member indices.
            let mut retire = vec![false; g.live.len()];

            // Speculative sub-round.
            if k_eff >= 2 {
                let sp = sp.expect("spec config checked above");
                let draft = sp.draft.clone();
                let draft_bits = sp.draft_bits;
                let tokens: Vec<i32> = g
                    .live
                    .iter()
                    .zip(&spec_mask)
                    .filter(|(_, &m)| m)
                    .map(|(l, _)| l.last)
                    .collect();
                let t0 = Instant::now();
                let res = {
                    let mut refs: Vec<&mut DecodeSession> = g
                        .live
                        .iter_mut()
                        .zip(&spec_mask)
                        .filter(|(_, &m)| m)
                        .map(|(l, _)| &mut l.session)
                        .collect();
                    speculative_round(&mut refs, &draft, &tokens, k_eff)
                };
                match res {
                    Ok(rounds) => {
                        let round_ms = t0.elapsed().as_secs_f64() * 1e3;
                        let members = rounds.len();
                        let emitted: usize = rounds.iter().map(|r| r.emitted.len()).sum();
                        let drafted: u64 = rounds.iter().map(|r| r.drafted as u64).sum();
                        let accepted: u64 = rounds.iter().map(|r| r.accepted as u64).sum();
                        // Bytes streamed this round: the draft payload once
                        // per draft step plus the target payload once for
                        // the batched verify — the figure that makes the
                        // draft/verify cost comparable in operand bytes.
                        let bytes = g.plan.weight_bytes() as u64
                            + (k_eff as u64 - 1) * draft.weight_bytes() as u64;
                        metrics.record_round(g.bits, members, round_ms, bytes);
                        metrics.record_spec_round(g.bits, drafted, accepted, emitted as u64);
                        out.stepped += members;
                        // Per-token share: a speculative round's cost
                        // amortizes over every token it emitted.
                        let share = round_ms / emitted.max(1) as f64;
                        let mut ri = 0usize;
                        for (i, l) in g.live.iter_mut().enumerate() {
                            if !spec_mask[i] {
                                continue;
                            }
                            let r = &rounds[ri];
                            ri += 1;
                            for _ in 0..r.emitted.len() {
                                metrics.record_decode_step(g.bits, share);
                            }
                            if let Fate::Retire =
                                Self::emit_spec(g.bits, g.int8, l, &r.emitted, share, metrics, sink)
                            {
                                retire[i] = true;
                            }
                        }
                    }
                    Err(e) => {
                        // Containment: the round rolled itself back — the
                        // members are exactly where they started, so they
                        // simply join this round's plain step below.
                        eprintln!(
                            "serve scheduler: int{draft_bits}-draft/int{} speculative round failed ({e:#}); falling back to plain",
                            g.bits
                        );
                        spec_mask.iter_mut().for_each(|b| *b = false);
                    }
                }
            }

            // Plain sub-round: everyone the speculative pass did not step.
            let plain: Vec<usize> = (0..g.live.len()).filter(|&i| !spec_mask[i]).collect();
            if !plain.is_empty() {
                let m = plain.len();
                let tokens: Vec<i32> = plain.iter().map(|&i| g.live[i].last).collect();
                let t0 = Instant::now();
                let stepped = {
                    let mut refs: Vec<&mut DecodeSession> = g
                        .live
                        .iter_mut()
                        .zip(&spec_mask)
                        .filter(|(_, &m)| !m)
                        .map(|(l, _)| &mut l.session)
                        .collect();
                    advance_sessions(&mut refs, &tokens)
                };
                match stepped {
                    Ok(()) => {
                        let round_ms = t0.elapsed().as_secs_f64() * 1e3;
                        metrics.record_round(g.bits, m, round_ms, g.plan.weight_bytes() as u64);
                        out.stepped += m;
                        let share = round_ms / m as f64;
                        for &i in &plain {
                            metrics.record_decode_step(g.bits, share);
                            let fate = Self::emit_sampled(
                                g.bits,
                                g.int8,
                                &mut g.live[i],
                                share,
                                metrics,
                                sink,
                            );
                            if let Fate::Retire = fate {
                                retire[i] = true;
                            }
                        }
                    }
                    Err(e) => {
                        // Containment: a member that cannot step (validated
                        // away in normal operation) must not stall the
                        // round's other members — retry solo, retiring only
                        // the members that actually fail.
                        eprintln!(
                            "serve scheduler: int{} step round failed ({e:#}); retrying members solo",
                            g.bits
                        );
                        for &i in &plain {
                            let l = &mut g.live[i];
                            let t1 = Instant::now();
                            match l.session.advance(l.last) {
                                Ok(()) => {
                                    let ms = t1.elapsed().as_secs_f64() * 1e3;
                                    metrics.record_round(
                                        g.bits,
                                        1,
                                        ms,
                                        g.plan.weight_bytes() as u64,
                                    );
                                    metrics.record_decode_step(g.bits, ms);
                                    out.stepped += 1;
                                    if let Fate::Retire =
                                        Self::emit_sampled(g.bits, g.int8, l, ms, metrics, sink)
                                    {
                                        retire[i] = true;
                                    }
                                }
                                Err(e) => {
                                    eprintln!(
                                        "serve scheduler: request {}: decode step failed: {e:#}",
                                        l.id
                                    );
                                    out.failed.push(l.id);
                                    retire[i] = true;
                                }
                            }
                        }
                    }
                }
            }

            // One retirement sweep, indices computed before any removal.
            let mut fates = retire.into_iter();
            g.live.retain(|_| !fates.next().expect("one fate per member"));
        }
    }

    /// Stream the tokens one speculative round emitted for one member —
    /// the multi-token sibling of [`Scheduler::emit_sampled`].  The round
    /// already committed the tokens to the session ([`speculative_round`]
    /// pushes them and leaves `logits` at the last accepted row), so this
    /// only does the bookkeeping: one [`Response`] event per token,
    /// `remaining` decrements, retirement on completion/truncation/hangup.
    fn emit_spec(
        bits: u32,
        int8: bool,
        l: &mut Live,
        emitted: &[(i32, f32)],
        share_ms: f64,
        metrics: &mut Metrics,
        sink: &mut dyn FnMut(u64, Response) -> bool,
    ) -> Fate {
        let n = emitted.len();
        for (j, &(tok, logit)) in emitted.iter().enumerate() {
            l.decode_ms += share_ms;
            l.last = tok;
            l.remaining = l.remaining.saturating_sub(1);
            // The window never exceeds the member's remaining budget, so
            // `remaining` can only hit 0 on the window's last token; the
            // capacity check matters on the last token alone (earlier
            // tokens' rows are already committed).
            let done = l.remaining == 0 || (j + 1 == n && !l.session.can_advance());
            let resp = Response {
                id: l.id,
                next_token: tok,
                logit,
                tokens: if done {
                    l.session.generated().to_vec()
                } else {
                    Vec::new()
                },
                done,
                bits,
                int8_acts: int8,
                queue_ms: 0.0,
                compute_ms: share_ms,
                prefill_ms: l.prefill_ms,
                decode_ms: l.decode_ms,
                batch_size: l.batch_size,
            };
            if done {
                metrics.record(share_ms, bits, l.batch_size);
                let _ = sink(l.id, resp);
                return Fate::Retire;
            }
            if !sink(l.id, resp) {
                return Fate::Retire;
            }
        }
        Fate::Alive
    }

    /// Shared post-step bookkeeping for one member whose logits just
    /// advanced: sample the next token, stream the event, retire the
    /// stream when finished (`remaining` exhausted or capacity truncation)
    /// or when the client hung up.
    fn emit_sampled(
        bits: u32,
        int8: bool,
        l: &mut Live,
        step_ms: f64,
        metrics: &mut Metrics,
        sink: &mut dyn FnMut(u64, Response) -> bool,
    ) -> Fate {
        l.decode_ms += step_ms;
        let (tok, logit) = l.session.sample();
        l.last = tok;
        l.remaining = l.remaining.saturating_sub(1);
        // Capacity can end a stream before max_new_tokens (KV truncation):
        // the event is marked done so the client never waits on tokens
        // that cannot come — and only THIS member ends; the round's other
        // members keep stepping.
        let done = l.remaining == 0 || !l.session.can_advance();
        let resp = Response {
            id: l.id,
            next_token: tok,
            logit,
            tokens: if done {
                l.session.generated().to_vec()
            } else {
                Vec::new()
            },
            done,
            bits,
            int8_acts: int8,
            queue_ms: 0.0,
            compute_ms: step_ms,
            prefill_ms: l.prefill_ms,
            decode_ms: l.decode_ms,
            batch_size: l.batch_size,
        };
        if done {
            // The latency sample is the round's actual step cost, NOT
            // `l.enq.elapsed()` — that is the stream's AGE, which made a
            // long-lived stream's decode percentiles climb monotonically
            // with its lifetime instead of measuring step work.  The
            // enqueue-to-first-token figure still lands via the prefill
            // path ([`Scheduler::start_stream`]), where it is a genuine
            // time-to-first-token.
            metrics.record(step_ms, bits, l.batch_size);
            let _ = sink(l.id, resp);
            return Fate::Retire;
        }
        if sink(l.id, resp) {
            Fate::Alive
        } else {
            Fate::Retire
        }
    }

    /// Admission phase: pick up to `max_prefills_per_round` pending
    /// requests round-robin across groups (FIFO within a group, deferring
    /// a group whose queue head would blow the KV budget), then prefill
    /// each group's admitted set as one ragged batched pass.
    fn admit(
        &mut self,
        metrics: &mut Metrics,
        sink: &mut dyn FnMut(u64, Response) -> bool,
        out: &mut RoundOutcome,
    ) {
        let keys: Vec<PlanKey> = self
            .groups
            .iter()
            .filter(|(_, g)| !g.pending.is_empty())
            .map(|(k, _)| k.clone())
            .collect();
        if keys.is_empty() {
            return;
        }
        let budget = self.cfg.max_prefills_per_round.max(1);
        let mut resident = self.resident_kv_bytes();
        let mut admit: BTreeMap<PlanKey, usize> = BTreeMap::new();
        let start = (self.round as usize) % keys.len();
        let mut stalled = vec![false; keys.len()];
        let mut taken = 0usize;
        let mut cursor = 0usize;
        while taken < budget && stalled.iter().any(|&s| !s) {
            let ki = (start + cursor) % keys.len();
            cursor += 1;
            if stalled[ki] {
                continue;
            }
            let g = &self.groups[&keys[ki]];
            let n_admitted = admit.get(&keys[ki]).copied().unwrap_or(0);
            match g.pending.get(n_admitted) {
                None => stalled[ki] = true,
                Some(p) => {
                    let projected = projected_kv_bytes(
                        &g.plan.dims,
                        p.req.prompt.len(),
                        p.req.max_new_tokens,
                        self.spec_slots(&keys[ki], &p.req.sampling),
                        &self.cfg.kv,
                    );
                    let fits = match self.cfg.kv_capacity_bytes {
                        None => true,
                        Some(cap) => resident.saturating_add(projected) <= cap,
                    };
                    if fits {
                        *admit.entry(keys[ki].clone()).or_insert(0) += 1;
                        resident += projected;
                        taken += 1;
                    } else {
                        // KV pressure: defer this group's queue head (and
                        // everything behind it — FIFO) to a later round;
                        // never evict a live stream to make room.
                        stalled[ki] = true;
                    }
                }
            }
        }
        let pool = self.pool.clone();
        for (key, n) in admit {
            // Sessions of a speculating group reserve `k` extra cache
            // positions — the provisional verify-window rows a speculative
            // round holds before acceptance — so the budget passed to the
            // prefill matches what admission just projected.  Temperature
            // requests never speculate and get the plain budget.
            let spec_k = self.spec.get(&key).map_or(0, |s| s.k);
            let budget_for = |sampling: &Sampling, max_new: usize| -> usize {
                match sampling {
                    Sampling::Greedy => max_new.saturating_add(spec_k),
                    _ => max_new,
                }
            };
            let g = self.groups.get_mut(&key).expect("admitted group exists");
            let plan = g.plan.clone();
            let bits = g.bits;
            let int8 = g.int8;
            let drained: Vec<Pending> = g.pending.drain(..n).collect();
            // Copy-on-write prefix sharing: a pending request whose prompt
            // shares a page-aligned prefix with a live member of this group
            // (one whose prompt K/V was computed on this very plan) adopts
            // the donor's physical pages and prefills only the suffix in
            // one window pass.  Misses — and any shared-prefill error —
            // fall through to the plain batched prefill below.
            let ps = pool.cfg().page_size;
            let mut batch: Vec<Pending> = Vec::with_capacity(drained.len());
            for p in drained {
                let hit = Self::share_candidate(g, &p.req.prompt, plan.dims.seq_len, ps);
                let Some((di, shared)) = hit else {
                    batch.push(p);
                    continue;
                };
                let t1 = Instant::now();
                let res = DecodeSession::prefill_shared(
                    &plan,
                    &p.req.prompt,
                    p.req.sampling,
                    budget_for(&p.req.sampling, p.req.max_new_tokens),
                    &pool,
                    &g.live[di].session,
                    shared,
                );
                match res {
                    Ok(session) => {
                        let ms = t1.elapsed().as_secs_f64() * 1e3;
                        metrics.record_batch(bits, ms, plan.weight_bytes() as u64);
                        let suffix = session.prompt_len().saturating_sub(shared);
                        metrics.record_prefill(bits, ms, suffix as u64);
                        Self::start_stream(g, bits, int8, p, session, ms, 1, t1, metrics, sink, out);
                    }
                    Err(e) => {
                        eprintln!(
                            "serve scheduler: request {}: shared prefill failed ({e:#}); \
                             retrying without sharing",
                            p.req.id
                        );
                        batch.push(p);
                    }
                }
            }
            let m = batch.len();
            if m == 0 {
                continue;
            }
            let t0 = Instant::now();
            let prefilled = {
                let specs: Vec<(&[i32], crate::runtime::Sampling, usize)> = batch
                    .iter()
                    .map(|p| {
                        (
                            p.req.prompt.as_slice(),
                            p.req.sampling,
                            budget_for(&p.req.sampling, p.req.max_new_tokens),
                        )
                    })
                    .collect();
                DecodeSession::prefill_many_pooled(&plan, &specs, Some(&pool))
            };
            match prefilled {
                Ok(sessions) => {
                    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
                    // One ragged fused pass for the whole admitted set:
                    // the payload was streamed once per GEMM block, so the
                    // bytes-touched counter grows once per BATCH.
                    metrics.record_batch(bits, total_ms, plan.weight_bytes() as u64);
                    let share = total_ms / m as f64;
                    for (p, session) in batch.into_iter().zip(sessions) {
                        metrics.record_prefill(bits, share, session.prompt_len() as u64);
                        Self::start_stream(
                            g, bits, int8, p, session, share, m, t0, metrics, sink, out,
                        );
                    }
                }
                Err(e) => {
                    eprintln!(
                        "serve scheduler: int{bits} batched prefill failed ({e:#}); retrying solo"
                    );
                    for p in batch {
                        let t1 = Instant::now();
                        match DecodeSession::with_budget_pooled(
                            plan.clone(),
                            &p.req.prompt,
                            p.req.sampling,
                            budget_for(&p.req.sampling, p.req.max_new_tokens),
                            Some(&pool),
                        ) {
                            Ok(session) => {
                                let ms = t1.elapsed().as_secs_f64() * 1e3;
                                metrics.record_batch(bits, ms, plan.weight_bytes() as u64);
                                metrics.record_prefill(bits, ms, session.prompt_len() as u64);
                                Self::start_stream(
                                    g, bits, int8, p, session, ms, 1, t1, metrics, sink, out,
                                );
                            }
                            Err(e) => {
                                eprintln!(
                                    "serve scheduler: request {}: prefill failed: {e:#}",
                                    p.req.id
                                );
                                out.failed.push(p.req.id);
                            }
                        }
                    }
                }
            }
        }
    }

    /// The best live donor for a prompt about to prefill into `g`: the
    /// member sharing the longest page-aligned common token prefix (at
    /// least one whole page, and strictly shorter than the prompt — the
    /// suffix window must produce the first logits row) whose prompt K/V
    /// was computed on this group's plan (an elastically shifted member's
    /// rows belong to a different precision and are never adopted) and
    /// whose cache still holds the prefix rows.  Returns the donor's index
    /// in `g.live` and the shared row count.
    fn share_candidate(g: &Group, prompt: &[i32], seq: usize, ps: usize) -> Option<(usize, usize)> {
        if prompt.is_empty() {
            return None;
        }
        let plen = prompt.len().min(seq);
        let mut best: Option<(usize, usize)> = None;
        for (i, l) in g.live.iter().enumerate() {
            if !Arc::ptr_eq(l.session.prefix_plan(), &g.plan) {
                continue;
            }
            let dp = l.session.prompt_tokens();
            let mut common = 0usize;
            while common < plen && common < dp.len() && prompt[common] == dp[common] {
                common += 1;
            }
            let shared = common.min(plen - 1) / ps * ps;
            if shared >= ps
                && l.session.positions() >= shared
                && best.map_or(true, |(_, s)| shared > s)
            {
                best = Some((i, shared));
            }
        }
        best
    }

    /// Post-prefill bookkeeping for one admitted request: sample the first
    /// token, stream the event, and either finish the request (single
    /// token / immediate truncation) or enlist it as a live round member.
    #[allow(clippy::too_many_arguments)]
    fn start_stream(
        g: &mut Group,
        bits: u32,
        int8: bool,
        p: Pending,
        session: DecodeSession,
        prefill_ms: f64,
        batch_size: usize,
        batch_start: Instant,
        metrics: &mut Metrics,
        sink: &mut dyn FnMut(u64, Response) -> bool,
        out: &mut RoundOutcome,
    ) {
        out.prefilled += 1;
        let queue_ms = batch_start.saturating_duration_since(p.enq).as_secs_f64() * 1e3;
        let mut live = Live {
            id: p.req.id,
            session,
            remaining: p.req.max_new_tokens.max(1),
            last: 0,
            enq: p.enq,
            prefill_ms,
            decode_ms: 0.0,
            batch_size,
            native_bits: p.native_bits,
        };
        let (tok, logit) = live.session.sample();
        // Submit → first sampled token: the TTFT sample the SLO report is
        // built on, recorded for every stream (finished-at-prefill or not)
        // and kept separate from the per-step decode latency counters.
        metrics.record_ttft(bits, p.enq.elapsed().as_secs_f64() * 1e3);
        live.last = tok;
        live.remaining -= 1;
        let done = live.remaining == 0 || !live.session.can_advance();
        let resp = Response {
            id: live.id,
            next_token: tok,
            logit,
            tokens: if done {
                live.session.generated().to_vec()
            } else {
                Vec::new()
            },
            done,
            bits,
            int8_acts: int8,
            queue_ms,
            compute_ms: prefill_ms,
            prefill_ms,
            decode_ms: 0.0,
            batch_size,
        };
        if done {
            metrics.record(p.enq.elapsed().as_secs_f64() * 1e3, bits, batch_size);
            let _ = sink(live.id, resp);
        } else if sink(live.id, resp) {
            g.live.push(live);
        }
    }
}
