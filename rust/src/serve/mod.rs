//! Elastic-precision serving (paper §5.4): one stored int8 model, every
//! request chooses its accuracy/latency/memory point — and how many tokens
//! to generate.
//!
//! Architecture (vLLM-router-like, scaled to one host):
//!
//! ```text
//!   client → [Router] → validation at submit
//!          → [WeightStore]: cached ForwardPlans per precision spec
//!            (dense f32 for warm bits, paged r-bit payloads otherwise,
//!            optional Mix'n'Match per-layer maps; payload handles AND
//!            non-quantized param Arcs shared across plans) + persisted
//!            int8 activation-clip calibration
//!          → backend (worker thread owns it) → streamed responses
//!
//!   PJRT backend (Server::start):
//!     per-(precision, act-mode) queues → [DynamicBatcher] →
//!     WeightStore ─ batch_args (paged: decode 1 tensor at a time) ─►
//!     bucketed `fwd_b{B}` executables ─► logits (single token)
//!
//!   Host backend (Server::start_host — no artifacts, no PJRT):
//!     WeightStore ─► ForwardPlan (resolved once per PlanKey) ─►
//!     [Scheduler] continuous batching: live DecodeSessions grouped by
//!     PlanKey step in ROUNDS — one blocked fused GEMM per layer across
//!     every member's current token (payload streamed once per GEMM block
//!     per round), each member's single query attending its own KvCache;
//!     admitted requests prefill as one ragged fused batch and join their
//!     group's next round (mid-stream admission, round-robin fairness cap,
//!     KV-pressure-aware deferral) ─► streamed Response events (one per
//!     token, last marked done), any r ∈ {1..8}; f32 weight tensors never
//!     exist on paged precisions.
//!     KV is **paged** (`PagePool → block table → paged attend`): the
//!     scheduler owns one [`crate::runtime::PagePool`] of fixed-size K/V
//!     pages (ServerConfig { kv }: f32 pages by default — bit-identical to
//!     a contiguous cache — or int8 rows + per-row scales for ~4× KV
//!     density), each session's KvCache maps pages lazily as it grows and
//!     recycles them on eviction/rollback, admission defers on
//!     *page-rounded projections vs actually-resident pool bytes*, and a
//!     pending prompt sharing a page-aligned prefix with a live stream
//!     adopts the donor's pages copy-on-write and prefills only the suffix
//!     (pool occupancy, shared bytes, and CoW breaks land in
//!     Metrics::report `kv=[...]`).
//!     Request { int8_acts } additionally quantizes the quantized-layer
//!     inputs (quant::activations; fixed per-layer thresholds when a
//!     calibration file is loaded) and reduces in the integer domain
//!     (kernels i8→i32 GEMV).  Request { max_new_tokens, sampling } picks
//!     the generation length and the greedy / seeded-temperature sampler;
//!     Request { per_layer } serves a Mix'n'Match assignment; all
//!     generation parameters are validated at submit.
//!
//!   Elastic precision (ServerConfig { elastic }): an [`ElasticPlanner`]
//!     watches KV residency and queue depth after every round; on a high
//!     watermark the busiest uniform packed group shifts one ladder rung
//!     down (live sessions keep their KV rows — the plan swap is an Arc
//!     pointer swap), and once both low watermarks hold, displaced
//!     sessions shift back up to their native precision.  Because every
//!     precision is an MSB-prefix view of the one nested payload, the
//!     shift pages in zero new weight bytes when the master is resident.
//!
//!   Scale-out front door (serve::frontend, unix-only): the same host
//!     workers behind a real socket, scaled to N —
//!
//!       TCP listener (poll(2) readiness loop, non-blocking sockets)
//!         → codec (HTTP/1.1 subset, chunked NDJSON — one event/token)
//!           → shared admission queue (PlanKey affinity, fleet-global
//!             PagePool budget gate, graceful drain, death rebalance)
//!             → per-worker Scheduler + ElasticPlanner
//!               → streamed chunks through the connection outbox
//!
//!     Workers share the Arc'd WeightStore plan cache and ONE PagePool
//!     budget; validation/plan-resolution/speculation/elastic are the
//!     same code paths as Server::start_host, so a TCP stream is
//!     byte-identical to the in-process answer.  `matquant serve` boots
//!     it; `matquant loadgen` replays deterministic Poisson traces with
//!     per-precision mixes against it and reports p50/p99 TTFT / TPOT,
//!     tokens/sec, and SLO attainment:
//!
//!       matquant serve --addr 127.0.0.1:8701 --workers 2
//!       curl -N -d '{"prompt":[1,2,3],"bits":4,"max_new_tokens":8}' \
//!            http://127.0.0.1:8701/v1/generate
//!       matquant loadgen --self-host --workers 2 --requests 64 \
//!                        --rate 100 --mix "8:70,4:20,2:10"
//!
//!   Self-speculative decode (ServerConfig { speculative }): greedy
//!     streams in uniform packed groups draft k−1 tokens per round with
//!     the low-bit MSB-prefix rung of their OWN payload (int2 by
//!     default — a free draft model, zero extra weight bytes), verify the
//!     whole window in one batched target-precision pass, commit the
//!     longest agreeing prefix, and roll rejected K/V rows back
//!     (KvCache::truncate_to).  Emitted tokens are bit-identical to plain
//!     decode; accept-rate and tokens/round land in Metrics::report
//!     (`spec=[...]`).  The elastic planner pauses speculation while a
//!     high watermark is breached (draft slots cost KV headroom).
//! ```

pub mod batcher;
#[cfg(unix)]
pub mod frontend;
pub mod metrics;
pub mod planner;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod weights;

pub use batcher::DynamicBatcher;
#[cfg(unix)]
pub use frontend::{HttpFrontend, PoolConfig, WorkerPool};
pub use metrics::Metrics;
pub use planner::{
    plan_deployment, DeploymentPlan, ElasticConfig, ElasticPlanner, ShiftDirection,
};
pub use request::{PrecisionReq, Request, Response};
pub use scheduler::{
    projected_kv_bytes, RoundOutcome, Scheduler, SchedulerConfig, ShiftReport, UniformGroupLoad,
};
pub use server::{Server, ServerConfig, SpeculativeConfig};
pub use weights::{PlanKey, WeightSet, WeightStore};

// Generation-parameter types live with the decode engine; re-exported here
// because requests carry them.  Likewise the KV page-pool geometry, which
// `ServerConfig { kv }` / `SchedulerConfig { kv }` select.
pub use crate::runtime::{KvConfig, KvDtype, PagePool, Sampling};
