//! Elastic-precision serving (paper §5.4): one stored int8 model, every
//! request chooses its accuracy/latency/memory point.
//!
//! Architecture (vLLM-router-like, scaled to one host):
//!
//! ```text
//!   client → [Router] → per-(precision, act-mode) queues → [DynamicBatcher]
//!          → [WeightStore]: warm dense f32 sets + lazily *paged* r-bit
//!            payloads (pack_sliced codes, no f32 weight set)
//!          → backend (worker thread owns it) → responses via channels
//!
//!   PJRT backend (Server::start):
//!     WeightStore ─ batch_args (paged: decode 1 tensor at a time) ─►
//!     bucketed `fwd_b{B}` executables ─► logits
//!
//!   Host backend (Server::start_host — no artifacts, no PJRT):
//!     WeightStore ─► PackedWeight handles ─► runtime::HostForward
//!       (embedding → per-layer fused packed matmuls + attention/residual
//!        glue → logits), any r ∈ {1..8}; f32 weight tensors never exist.
//!     Request { int8_acts } additionally quantizes the quantized-layer
//!     inputs (quant::activations, absmax / histogram clip) and reduces
//!     in the integer domain (kernels i8→i32 GEMV).
//! ```

pub mod batcher;
pub mod metrics;
pub mod planner;
pub mod request;
pub mod server;
pub mod weights;

pub use batcher::DynamicBatcher;
pub use metrics::Metrics;
pub use planner::{plan_deployment, DeploymentPlan};
pub use request::{PrecisionReq, Request, Response};
pub use server::{Server, ServerConfig};
pub use weights::{WeightSet, WeightStore};
