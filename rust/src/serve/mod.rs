//! Elastic-precision serving (paper §5.4): one stored int8 model, every
//! request chooses its accuracy/latency/memory point.
//!
//! Architecture (vLLM-router-like, scaled to one host):
//!   client → [Router] → per-precision queues → [DynamicBatcher]
//!          → [WeightStore]: warm dense f32 sets + lazily *paged* r-bit
//!            payloads (pack_sliced codes, no f32 weight set)
//!          → bucketed `fwd_b{B}` PJRT executables (worker thread owns the
//!            Engine, which is not Send) → responses via channels.

pub mod batcher;
pub mod metrics;
pub mod planner;
pub mod request;
pub mod server;
pub mod weights;

pub use batcher::DynamicBatcher;
pub use metrics::Metrics;
pub use planner::{plan_deployment, DeploymentPlan};
pub use request::{PrecisionReq, Request, Response};
pub use server::{Server, ServerConfig};
pub use weights::{WeightSet, WeightStore};
