//! Elastic-precision serving (paper §5.4): one stored int8 model, every
//! request chooses its accuracy/latency/memory point — and how many tokens
//! to generate.
//!
//! Architecture (vLLM-router-like, scaled to one host):
//!
//! ```text
//!   client → [Router] → per-(precision, act-mode) queues → [DynamicBatcher]
//!          → [WeightStore]: cached ForwardPlans per precision spec
//!            (dense f32 for warm bits, paged r-bit payloads otherwise,
//!            optional Mix'n'Match per-layer maps; payload handles shared
//!            across plans) + persisted int8 activation-clip calibration
//!          → backend (worker thread owns it) → streamed responses
//!
//!   PJRT backend (Server::start):
//!     WeightStore ─ batch_args (paged: decode 1 tensor at a time) ─►
//!     bucketed `fwd_b{B}` executables ─► logits (single token)
//!
//!   Host backend (Server::start_host — no artifacts, no PJRT):
//!     WeightStore ─► ForwardPlan (resolved once per precision) ─►
//!     DecodeSession: prefill once (batched fused packed kernels, K/V
//!     recorded into the KvCache) ─► KV-cached decode steps, one O(n)
//!     single-query attention + fused matvecs per token ─► streamed
//!     Response events (one per token, last marked done), any r ∈ {1..8};
//!     f32 weight tensors never exist on paged precisions.
//!     Request { int8_acts } additionally quantizes the quantized-layer
//!     inputs (quant::activations; fixed per-layer thresholds when a
//!     calibration file is loaded) and reduces in the integer domain
//!     (kernels i8→i32 GEMV).  Request { max_new_tokens, sampling } picks
//!     the generation length and the greedy / seeded-temperature sampler;
//!     all generation parameters are validated at submit.
//! ```

pub mod batcher;
pub mod metrics;
pub mod planner;
pub mod request;
pub mod server;
pub mod weights;

pub use batcher::DynamicBatcher;
pub use metrics::Metrics;
pub use planner::{plan_deployment, DeploymentPlan};
pub use request::{PrecisionReq, Request, Response};
pub use server::{Server, ServerConfig};
pub use weights::{PlanKey, WeightSet, WeightStore};

// Generation-parameter types live with the decode engine; re-exported here
// because requests carry them.
pub use crate::runtime::Sampling;
