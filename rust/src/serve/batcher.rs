//! Dynamic micro-batching for the **PJRT backend**: group pending requests
//! by precision **and** activation mode, flush on size or age, pad to the
//! nearest exported batch bucket.  f32- and int8-activation requests at
//! the same bit-width never share a batch (their numerics differ), so the
//! queue key is `(bits, int8_acts)`.
//!
//! The host backend does not use this batcher: its queueing, prefill
//! batching, and decode interleave all live in the continuous-batching
//! [`crate::serve::Scheduler`], which groups by the full plan spec
//! ([`crate::serve::PlanKey`], including per-layer maps) and steps live
//! streams in batched GEMM rounds.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use super::request::Request;

/// A batch ready to execute.
#[derive(Debug)]
pub struct ReadyBatch {
    pub bits: u32,
    /// Whether every request in this batch asked for int8 activations.
    pub int8: bool,
    pub requests: Vec<(Request, Instant)>,
    /// Bucketed batch size (≥ requests.len()).
    pub bucket: usize,
}

/// Precision-aware micro-batcher.
#[derive(Debug)]
pub struct DynamicBatcher {
    queues: BTreeMap<(u32, bool), Vec<(Request, Instant)>>,
    pub max_batch: usize,
    pub max_wait_ms: f64,
    buckets: Vec<usize>,
}

impl DynamicBatcher {
    pub fn new(buckets: Vec<usize>, max_wait_ms: f64) -> Self {
        let max_batch = buckets.iter().copied().max().unwrap_or(1);
        DynamicBatcher {
            queues: BTreeMap::new(),
            max_batch,
            max_wait_ms,
            buckets,
        }
    }

    pub fn push(&mut self, req: Request) {
        let key = (req.precision.bits(), req.int8_acts);
        self.queues
            .entry(key)
            .or_default()
            .push((req, Instant::now()));
    }

    pub fn pending(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// Precisions with at least one queued request — the worker's page-in
    /// prefetch hint: payloads for these can be built while the batch
    /// window is still open, keeping lazy builds off the critical path.
    /// (Deduplicated across activation modes — paging is per-precision.)
    pub fn queued_precisions(&self) -> Vec<u32> {
        self.queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(&(b, _), _)| b)
            .collect::<BTreeSet<u32>>()
            .into_iter()
            .collect()
    }

    /// Smallest exported bucket that fits `n` (or the max bucket).
    pub fn bucket_for(&self, n: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .unwrap_or(self.max_batch)
    }

    /// Pop a batch if any queue is full or its oldest entry exceeded the
    /// wait window.  Full queues win; ties break toward the oldest.
    pub fn pop_ready(&mut self, now: Instant) -> Option<ReadyBatch> {
        let mut candidate: Option<((u32, bool), bool, f64)> = None; // (key, full, age)
        for (&key, q) in &self.queues {
            if q.is_empty() {
                continue;
            }
            let full = q.len() >= self.max_batch;
            let age = now.duration_since(q[0].1).as_secs_f64() * 1e3;
            let ready = full || age >= self.max_wait_ms;
            if !ready {
                continue;
            }
            let better = match candidate {
                None => true,
                Some((_, cfull, cage)) => (full && !cfull) || (full == cfull && age > cage),
            };
            if better {
                candidate = Some((key, full, age));
            }
        }
        let (key, _, _) = candidate?;
        // A vanished queue (unknown precision) yields no batch instead of
        // panicking the worker thread.
        let q = self.queues.get_mut(&key)?;
        let take = q.len().min(self.max_batch);
        let requests: Vec<_> = q.drain(..take).collect();
        let bucket = self.bucket_for(requests.len());
        Some(ReadyBatch {
            bits: key.0,
            int8: key.1,
            requests,
            bucket,
        })
    }

    /// Flush everything regardless of age (shutdown path).
    pub fn drain_all(&mut self) -> Vec<ReadyBatch> {
        let mut out = Vec::new();
        let keys: Vec<(u32, bool)> = self.queues.keys().copied().collect();
        let buckets = self.buckets.clone();
        let max_batch = self.max_batch;
        let bucket_for = |n: usize| {
            buckets
                .iter()
                .copied()
                .filter(|&b| b >= n)
                .min()
                .unwrap_or(max_batch)
        };
        for key in keys {
            let Some(q) = self.queues.get_mut(&key) else {
                continue;
            };
            while !q.is_empty() {
                let take = q.len().min(max_batch);
                let requests: Vec<_> = q.drain(..take).collect();
                let bucket = bucket_for(requests.len());
                out.push(ReadyBatch {
                    bits: key.0,
                    int8: key.1,
                    requests,
                    bucket,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::PrecisionReq;

    fn req(id: u64, bits: u32) -> Request {
        Request::new(id, vec![1, 2, 3], PrecisionReq::Bits(bits))
    }

    fn req_i8(id: u64, bits: u32) -> Request {
        Request {
            int8_acts: true,
            ..req(id, bits)
        }
    }

    #[test]
    fn bucket_selection() {
        let b = DynamicBatcher::new(vec![1, 2, 4, 8, 16], 5.0);
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(3), 4);
        assert_eq!(b.bucket_for(9), 16);
        assert_eq!(b.bucket_for(40), 16);
    }

    #[test]
    fn full_queue_pops_immediately() {
        let mut b = DynamicBatcher::new(vec![1, 2, 4], 1000.0);
        for i in 0..4 {
            b.push(req(i, 4));
        }
        let ready = b.pop_ready(Instant::now()).expect("full queue ready");
        assert_eq!(ready.bits, 4);
        assert!(!ready.int8);
        assert_eq!(ready.requests.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn young_partial_queue_waits() {
        let mut b = DynamicBatcher::new(vec![1, 2, 4], 1000.0);
        b.push(req(0, 2));
        assert!(b.pop_ready(Instant::now()).is_none());
    }

    #[test]
    fn old_partial_queue_flushes() {
        let mut b = DynamicBatcher::new(vec![1, 2, 4], 0.0);
        b.push(req(0, 2));
        let ready = b.pop_ready(Instant::now()).expect("aged queue ready");
        assert_eq!(ready.requests.len(), 1);
        assert_eq!(ready.bucket, 1);
    }

    #[test]
    fn precisions_never_mix() {
        let mut b = DynamicBatcher::new(vec![1, 2, 4], 0.0);
        b.push(req(0, 2));
        b.push(req(1, 8));
        let first = b.pop_ready(Instant::now()).unwrap();
        assert!(first.requests.iter().all(|(r, _)| r.precision.bits() == first.bits));
        let second = b.pop_ready(Instant::now()).unwrap();
        assert_ne!(first.bits, second.bits);
    }

    #[test]
    fn activation_modes_never_mix() {
        // Same bit-width, different activation mode → two separate batches.
        let mut b = DynamicBatcher::new(vec![1, 2, 4], 0.0);
        b.push(req(0, 4));
        b.push(req_i8(1, 4));
        b.push(req(2, 4));
        let first = b.pop_ready(Instant::now()).unwrap();
        let second = b.pop_ready(Instant::now()).unwrap();
        assert_eq!(first.bits, 4);
        assert_eq!(second.bits, 4);
        assert_ne!(first.int8, second.int8);
        for batch in [&first, &second] {
            assert!(batch
                .requests
                .iter()
                .all(|(r, _)| r.int8_acts == batch.int8));
        }
        assert_eq!(b.pending(), 0);
        // prefetch hints dedupe across activation modes (paging is
        // per-precision)
        let mut b2 = DynamicBatcher::new(vec![1, 2, 4], 1000.0);
        b2.push(req(0, 4));
        b2.push(req_i8(1, 4));
        assert_eq!(b2.queued_precisions(), vec![4]);
    }

    #[test]
    fn queued_precisions_tracks_pending_work() {
        let mut b = DynamicBatcher::new(vec![1, 2, 4], 1000.0);
        assert!(b.queued_precisions().is_empty());
        b.push(req(0, 2));
        b.push(req(1, 8));
        b.push(req(2, 2));
        assert_eq!(b.queued_precisions(), vec![2, 8]);
        // popping a full queue clears its entry
        let mut b2 = DynamicBatcher::new(vec![1, 2, 4], 0.0);
        b2.push(req(0, 4));
        let _ = b2.pop_ready(Instant::now()).unwrap();
        assert!(b2.queued_precisions().is_empty());
    }

    #[test]
    fn drain_all_empties() {
        let mut b = DynamicBatcher::new(vec![1, 2, 4], 1000.0);
        for i in 0..9 {
            b.push(req(i, if i % 2 == 0 { 2 } else { 8 }));
        }
        let batches = b.drain_all();
        assert_eq!(b.pending(), 0);
        assert_eq!(batches.iter().map(|x| x.requests.len()).sum::<usize>(), 9);
    }
}
