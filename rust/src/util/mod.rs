//! Dependency-free utility substrates: JSON, CLI parsing, bench timing.
//!
//! The build is fully offline (only `xla` + `anyhow` are vendored), so the
//! coordinator ships its own minimal JSON codec, argument parser, and
//! benchmark harness instead of serde/clap/criterion.

pub mod bench;
pub mod cli;
pub mod json;

pub use json::Json;
