//! Micro-benchmark harness (criterion substitute, offline build).
//!
//! Warms up, then runs timed batches until a wall-clock budget is spent;
//! reports mean / p50 / p99 per-iteration times and derived throughput.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    /// Items/sec given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.2} us/iter  p50 {:>10.2}  p99 {:>10.2}  ({} iters)",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p99_ns / 1e3,
            self.iters
        )
    }
}

/// Run `f` repeatedly for ~`budget`; returns timing stats.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // warmup: a few calls or 10% of budget
    let warm_start = Instant::now();
    for _ in 0..3 {
        f();
        if warm_start.elapsed() > budget / 10 {
            break;
        }
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples_ns.len() < 5 {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if samples_ns.len() >= 1_000_000 {
            break;
        }
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n as u64,
        mean_ns: mean,
        p50_ns: samples_ns[n / 2],
        p99_ns: samples_ns[(n * 99 / 100).min(n - 1)],
    }
}

/// Default per-case budget, overridable via `MQ_BENCH_MS`.
pub fn default_budget() -> Duration {
    let ms = std::env::var("MQ_BENCH_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300u64);
    Duration::from_millis(ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
    }
}
