//! Minimal JSON codec (parse + serialize) — enough for `manifest.json`,
//! `goldens.json`, checkpoint metadata, and experiment reports.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null); numbers are f64 (all our payloads fit).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, ensure, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- accessors --------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object (wanted key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_u32(&self) -> Result<u32> {
        Ok(self.as_f64()? as u32)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| Ok(v.as_f64()? as f32))
            .collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ----- constructors ------------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ----- parsing -----------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        ensure!(pos == bytes.len(), "trailing garbage at byte {pos}");
        Ok(v)
    }

    // ----- serialization -------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    ensure!(*pos < b.len(), "unexpected end of input");
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => parse_num(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    ensure!(
        b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes(),
        "expected {lit} at byte {pos}",
        pos = *pos
    );
    *pos += lit.len();
    Ok(())
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // {
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos).context("object key")?;
        skip_ws(b, pos);
        ensure!(*pos < b.len() && b[*pos] == b':', "expected ':' at {pos}", pos = *pos);
        *pos += 1;
        let val = parse_value(b, pos)?;
        m.insert(key, val);
        skip_ws(b, pos);
        ensure!(*pos < b.len(), "unterminated object");
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            c => bail!("unexpected {:?} in object at {pos}", c as char, pos = *pos),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // [
    let mut a = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(a));
    }
    loop {
        a.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        ensure!(*pos < b.len(), "unterminated array");
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(a));
            }
            c => bail!("unexpected {:?} in array at {pos}", c as char, pos = *pos),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    ensure!(*pos < b.len() && b[*pos] == b'"', "expected string at {pos}", pos = *pos);
    *pos += 1;
    let mut s = String::new();
    loop {
        ensure!(*pos < b.len(), "unterminated string");
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                ensure!(*pos < b.len(), "bad escape");
                match b[*pos] {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        ensure!(*pos + 4 < b.len(), "bad \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let cp = u32::from_str_radix(hex, 16)?;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let start = *pos;
                let len = utf8_len(b[start]);
                ensure!(start + len <= b.len(), "bad utf-8");
                s.push_str(std::str::from_utf8(&b[start..start + len])?);
                *pos += len;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    let n: f64 = text
        .parse()
        .with_context(|| format!("bad number {text:?} at byte {start}"))?;
    Ok(Json::Num(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c").unwrap(), &Json::Bool(false));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"nested":{"x":-3}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v, Json::Str("Aé".into()));
    }

    #[test]
    fn f32_vec_helper() {
        let v = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.5]);
    }
}
