//! Tiny CLI argument parser (clap substitute, offline build).
//!
//! Supports `--flag`, `--key value`, and positional arguments.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from raw argv (skipping the binary name).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects an integer, got {v:?}"),
            },
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects an integer, got {v:?}"),
            },
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects a float, got {v:?}"),
            },
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn mixed_args() {
        // NB: a bare `--flag` followed by a non-dashed token is parsed as
        // `--key value` (inherent ambiguity) — put flags last or use `=`.
        let a = parse("train extra --preset tiny --steps=200 --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("preset"), Some("tiny"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 200);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
    }

    #[test]
    fn bad_int_errors() {
        let a = parse("--steps abc");
        assert!(a.get_usize("steps", 0).is_err());
    }
}
