//! Experiment runners — one function per paper table/figure (DESIGN.md
//! experiment index).  Training runs are cached as checkpoints under
//! `--cache-dir` keyed by the spec label, so tables that share models
//! (e.g. the MatQuant-OmniQuant model appears in T1, T7, Fig 1c, Fig 2)
//! train once.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context};

use super::config::{Mode, Objective, TrainSpec};
use super::trainer::train;
use crate::eval::tables::{pct, pplx, TableBuilder};
use crate::eval::{task_suite, Evaluator};
use crate::mixnmatch::strategy::{assignments_for, compositions, STRATEGIES};
use crate::mixnmatch::{pareto_frontier, Point};
use crate::model::{Checkpoint, PrecisionAssignment, QuantizedModel, Tensor};
use crate::quant;
use crate::runtime::Engine;
use crate::util::cli::Args;
use crate::Result;

/// Shared experiment context.
pub struct ExperimentCtx<'e> {
    pub engine: &'e Engine,
    pub preset: String,
    pub steps: u64,
    /// FP pretraining steps for the shared base checkpoint (the
    /// Gemma/Mistral stand-in all methods fine-tune / calibrate).
    pub pretrain_steps: u64,
    pub seed: u64,
    pub probes: usize,
    pub eval_batches: usize,
    pub cache_dir: PathBuf,
}

/// A trained + registered model ready to evaluate at any precision.
pub struct TrainedModel {
    pub model: QuantizedModel,
    pub final_losses: Vec<f32>,
}

#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub task_avg: f64,
    pub log_pplx: f64,
    pub bits_per_param: f64,
}

impl<'e> ExperimentCtx<'e> {
    pub fn from_args(engine: &'e Engine, args: &Args) -> Result<Self> {
        let steps = args.get_u64("steps", 120)?;
        Ok(ExperimentCtx {
            engine,
            preset: args.get_or("preset", "tiny").to_string(),
            steps,
            pretrain_steps: args.get_u64("pretrain-steps", steps * 4)?,
            seed: args.get_u64("seed", 42)?,
            probes: args.get_usize("probes", 25)?,
            eval_batches: args.get_usize("eval-batches", 6)?,
            cache_dir: PathBuf::from(args.get_or("cache-dir", "checkpoints/cache")),
        })
    }

    fn spec(&self, mode: Mode, objective: Objective) -> TrainSpec {
        let mut s = TrainSpec::new(&self.preset, mode, objective, self.steps);
        s.seed = self.seed;
        s
    }

    /// The shared FP base checkpoint (trained once, cached).
    pub fn pretrained_ckpt(&self) -> Result<PathBuf> {
        let mut spec = TrainSpec::new(&self.preset, Mode::Qat, Objective::Fp, self.pretrain_steps);
        spec.seed = self.seed;
        let path = self.cache_dir.join(format!("{}.mqck", spec.label()));
        if !path.exists() {
            eprintln!("[experiment] pretraining base model {}", spec.label());
            let out = train(self.engine, &spec).context("fp pretraining")?;
            let mut ck = Checkpoint::new(spec.meta_json());
            for (n, t) in &out.params {
                ck.insert(n.clone(), t.clone());
            }
            ck.save(&path)?;
            eprintln!(
                "[experiment] base model loss {:.4} -> {:.4}",
                out.loss_history[0][0],
                out.tail_loss(0, 5)
            );
        }
        Ok(path)
    }

    /// Train (or load from cache) and build the quantized registry.  Every
    /// run starts from the shared pretrained base (paper setting).
    pub fn trained(&self, mode: Mode, objective: Objective) -> Result<TrainedModel> {
        let mut spec = self.spec(mode, objective);
        spec.init_ckpt = Some(self.pretrained_ckpt()?);
        let path = self.cache_dir.join(format!("{}.mqck", spec.label()));
        let preset_info = self.engine.manifest().preset(&self.preset)?;
        let (params, aux, final_losses) = if path.exists() {
            let ck = Checkpoint::load(&path)?;
            let mut params = BTreeMap::new();
            let mut aux = BTreeMap::new();
            let mut losses = Vec::new();
            for (name, t) in &ck.tensors {
                if let Some(a) = name.strip_prefix("aux:") {
                    aux.insert(a.to_string(), t.clone());
                } else if name == "final_losses" {
                    losses = t.data.clone();
                } else {
                    params.insert(name.clone(), t.clone());
                }
            }
            (params, aux, losses)
        } else {
            eprintln!("[experiment] training {}", spec.label());
            let out =
                train(self.engine, &spec).with_context(|| format!("training {}", spec.label()))?;
            let mut ck = Checkpoint::new(spec.meta_json());
            for (n, t) in &out.params {
                ck.insert(n.clone(), t.clone());
            }
            if let Some(aux) = &out.aux {
                for (n, t) in aux {
                    ck.insert(format!("aux:{n}"), t.clone());
                }
            }
            let losses = out.loss_history.last().cloned().unwrap_or_default();
            ck.insert(
                "final_losses",
                Tensor::new(vec![losses.len()], losses.clone())?,
            );
            ck.save(&path)?;
            (out.params, out.aux.unwrap_or_default(), losses)
        };
        let model = QuantizedModel::build(
            preset_info,
            &params,
            if aux.is_empty() { None } else { Some(&aux) },
        )?;
        Ok(TrainedModel {
            model,
            final_losses,
        })
    }

    /// Evaluate a model under a precision assignment.
    pub fn eval_assign(
        &self,
        model: &QuantizedModel,
        assign: &PrecisionAssignment,
    ) -> Result<EvalResult> {
        let ev = Evaluator::new(self.engine, &self.preset)?;
        let (weights, biases) = model.materialize(assign)?;
        let session = ev.session(&weights, &biases)?;
        let log_pplx = ev.log_perplexity(
            &session,
            self.seed,
            self.seed ^ 0xEAA1,
            self.eval_batches,
        )?;
        let report = task_suite(
            &ev,
            &weights,
            &biases,
            self.seed,
            self.seed ^ 0x9999,
            self.probes,
        )?;
        Ok(EvalResult {
            task_avg: report.avg,
            log_pplx,
            bits_per_param: model.bits_per_param(assign),
        })
    }

    fn uniform(&self, bits: u32) -> PrecisionAssignment {
        PrecisionAssignment::uniform(bits)
    }

    fn uniform_ep(&self, bits: u32) -> PrecisionAssignment {
        PrecisionAssignment::Uniform {
            bits,
            extra_precision: true,
        }
    }

    // ------------------------------------------------------------------
    // Tables
    // ------------------------------------------------------------------

    pub fn run_table(&self, which: &str) -> Result<String> {
        match which {
            "1" => self.table_main(Mode::Omni, "Table 1 | MatQuant with OmniQuant"),
            "2" => self.table_main(Mode::Qat, "Table 2 | MatQuant with QAT"),
            "3" => self.table_lambda(),
            "4" => self.table_codistill(),
            "5" => self.table_single_precision(),
            "6" => self.table_attn(),
            "7" => self.table_extra_precision(),
            "8" => self.table_ep_codistill(),
            other => bail!("unknown table {other:?} (1-8)"),
        }
    }

    /// Tables 1 & 2: Baseline vs MatQuant vs Sliced-int8 across int8/4/2
    /// plus interpolated int6/int3.
    fn table_main(&self, mode: Mode, title: &str) -> Result<String> {
        let mat = self.trained(mode, Objective::matquant_default())?;
        let base8 = self.trained(mode, Objective::Direct { bits: 8 })?;
        let mut table = TableBuilder::new(
            title,
            &["Data type", "Method", "Task Avg.", "log pplx", "bits/param"],
        );
        let fp = self.eval_assign(&mat.model, &PrecisionAssignment::Fp)?;
        table.row(&[
            "bfloat16".into(),
            "".into(),
            pct(fp.task_avg),
            pplx(fp.log_pplx),
            "32".into(),
        ]);
        for &bits in &[8u32, 4, 2, 6, 3] {
            if bits != 8 {
                let sliced = self.eval_assign(&base8.model, &self.uniform(bits))?;
                table.row(&[
                    format!("int{bits}"),
                    "Sliced int8".into(),
                    pct(sliced.task_avg),
                    pplx(sliced.log_pplx),
                    format!("{bits}"),
                ]);
            }
            let baseline = self.trained(mode, Objective::Direct { bits })?;
            let b = self.eval_assign(&baseline.model, &self.uniform(bits))?;
            table.row(&[
                format!("int{bits}"),
                "Baseline".into(),
                pct(b.task_avg),
                pplx(b.log_pplx),
                format!("{bits}"),
            ]);
            let m = self.eval_assign(&mat.model, &self.uniform(bits))?;
            table.row(&[
                format!("int{bits}"),
                "MatQuant".into(),
                pct(m.task_avg),
                pplx(m.log_pplx),
                format!("{bits}"),
            ]);
        }
        Ok(table.render())
    }

    /// Table 3: λ re-weighting ablation (OmniQuant base).
    fn table_lambda(&self) -> Result<String> {
        let mut table = TableBuilder::new(
            "Table 3 | λ re-weighting (OmniQuant base)",
            &["Data type", "Weightings", "Task Avg.", "log pplx"],
        );
        let weightings: [[f32; 3]; 4] = [
            [0.1, 0.1, 1.0],
            [0.2, 0.2, 1.0],
            [0.3, 0.3, 1.0],
            [0.4, 0.4, 1.0],
        ];
        let mut models = Vec::new();
        for w in weightings {
            models.push((w, self.trained(Mode::Omni, Objective::matquant(w))?));
        }
        for &bits in &[8u32, 4, 2] {
            for (w, m) in &models {
                let r = self.eval_assign(&m.model, &self.uniform(bits))?;
                table.row(&[
                    format!("int{bits}"),
                    format!("({}, {}, {})", w[0], w[1], w[2]),
                    pct(r.task_avg),
                    pplx(r.log_pplx),
                ]);
            }
        }
        Ok(table.render())
    }

    /// Table 4: co-distillation configs, OmniQuant + QAT.
    fn table_codistill(&self) -> Result<String> {
        let configs: [(&str, [f32; 3], [f32; 3]); 4] = [
            ("[8, 4, 2]", [0.1, 0.1, 1.0], [0.0, 0.0, 0.0]),
            ("[8, 4, 8->2]", [0.1, 0.1, 0.0], [0.0, 0.0, 1.0]),
            ("[8, 4, 2, 8->2]", [0.1, 0.1, 1.0], [0.0, 0.0, 1.0]),
            ("[8, 4, 2, 8->4;2]", [0.1, 0.1, 1.0], [0.0, 1.0, 1.0]),
        ];
        let mut table = TableBuilder::new(
            "Table 4 | Co-distillation (int8 teacher)",
            &["Base", "Data type", "Config", "Task Avg.", "log pplx"],
        );
        for mode in [Mode::Omni, Mode::Qat] {
            for (label, lam, wd) in &configs {
                let m = self.trained(
                    mode,
                    Objective::Matquant {
                        lambdas: *lam,
                        wdist: *wd,
                        extra_precision: false,
                    },
                )?;
                for &bits in &[8u32, 4, 2] {
                    let r = self.eval_assign(&m.model, &self.uniform(bits))?;
                    table.row(&[
                        mode.as_str().into(),
                        format!("int{bits}"),
                        label.to_string(),
                        pct(r.task_avg),
                        pplx(r.log_pplx),
                    ]);
                }
            }
        }
        Ok(table.render())
    }

    /// Table 5: Single-Precision MatQuant at int2.
    fn table_single_precision(&self) -> Result<String> {
        let mut table = TableBuilder::new(
            "Table 5 | Single-Precision MatQuant (int2)",
            &["Base", "Method", "Task Avg.", "log pplx"],
        );
        for mode in [Mode::Omni, Mode::Qat] {
            let base = self.trained(mode, Objective::Direct { bits: 2 })?;
            let sp = self.trained(mode, Objective::single_precision())?;
            let mat = self.trained(mode, Objective::matquant_default())?;
            for (name, tm) in [("Baseline", &base), ("S.P. MatQuant", &sp), ("MatQuant", &mat)] {
                let r = self.eval_assign(&tm.model, &self.uniform(2))?;
                table.row(&[
                    mode.as_str().into(),
                    name.into(),
                    pct(r.task_avg),
                    pplx(r.log_pplx),
                ]);
            }
        }
        Ok(table.render())
    }

    /// Table 6: FFN + Attention quantization (QAT, `tiny_attn` preset).
    fn table_attn(&self) -> Result<String> {
        let sub = ExperimentCtx {
            engine: self.engine,
            preset: "tiny_attn".into(),
            steps: self.steps,
            pretrain_steps: self.pretrain_steps,
            seed: self.seed,
            probes: self.probes,
            eval_batches: self.eval_batches,
            cache_dir: self.cache_dir.clone(),
        };
        let mat = sub.trained(Mode::Qat, Objective::matquant_default())?;
        let sp = sub.trained(Mode::Qat, Objective::single_precision())?;
        let base8 = sub.trained(Mode::Qat, Objective::Direct { bits: 8 })?;
        let mut table = TableBuilder::new(
            "Table 6 | FFN + Attention quantization (QAT)",
            &["Data type", "Method", "Task Avg.", "log pplx"],
        );
        let fp = sub.eval_assign(&mat.model, &PrecisionAssignment::Fp)?;
        table.row(&[
            "bfloat16".into(),
            "".into(),
            pct(fp.task_avg),
            pplx(fp.log_pplx),
        ]);
        for &bits in &[8u32, 4, 2, 6, 3] {
            if bits != 8 {
                let sliced = sub.eval_assign(&base8.model, &sub.uniform(bits))?;
                table.row(&[
                    format!("int{bits}"),
                    "Sliced int8".into(),
                    pct(sliced.task_avg),
                    pplx(sliced.log_pplx),
                ]);
            }
            // the paper reports baseline int2/int3 as unstable ("-"); we
            // train them anyway and print whatever happens
            match sub
                .trained(Mode::Qat, Objective::Direct { bits })
                .and_then(|m| sub.eval_assign(&m.model, &sub.uniform(bits)))
            {
                Ok(b) if b.log_pplx.is_finite() => {
                    table.row(&[
                        format!("int{bits}"),
                        "Baseline".into(),
                        pct(b.task_avg),
                        pplx(b.log_pplx),
                    ]);
                }
                _ => {
                    table.row(&[
                        format!("int{bits}"),
                        "Baseline".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                }
            }
            if bits == 2 || bits == 3 {
                let r = sub.eval_assign(&sp.model, &sub.uniform(bits))?;
                table.row(&[
                    format!("int{bits}"),
                    "S.P. MatQuant".into(),
                    pct(r.task_avg),
                    pplx(r.log_pplx),
                ]);
            }
            let m = sub.eval_assign(&mat.model, &sub.uniform(bits))?;
            table.row(&[
                format!("int{bits}"),
                "MatQuant".into(),
                pct(m.task_avg),
                pplx(m.log_pplx),
            ]);
        }
        Ok(table.render())
    }

    /// Table 7: Extra-Precision MatQuant (Eq. 8), OmniQuant base.
    fn table_extra_precision(&self) -> Result<String> {
        let mat = self.trained(Mode::Omni, Objective::matquant_default())?;
        let ep = self.trained(
            Mode::Omni,
            Objective::Matquant {
                lambdas: [1.0, 1.0, 1.0], // paper Appendix B: EP uses (1,1,1)
                wdist: [0.0; 3],
                extra_precision: true,
            },
        )?;
        let mut table = TableBuilder::new(
            "Table 7 | Extra-Precision MatQuant (OmniQuant)",
            &["Avg. Bits", "Method", "Task Avg.", "log pplx"],
        );
        for &bits in &[8u32, 4, 2, 6, 3] {
            let rm = self.eval_assign(&mat.model, &self.uniform(bits))?;
            table.row(&[
                format!("{bits}"),
                "MatQuant".into(),
                pct(rm.task_avg),
                pplx(rm.log_pplx),
            ]);
            let re = self.eval_assign(&ep.model, &self.uniform_ep(bits))?;
            table.row(&[
                format!("{:.3}", re.bits_per_param),
                "Extra-Precision MatQuant".into(),
                pct(re.task_avg),
                pplx(re.log_pplx),
            ]);
        }
        Ok(table.render())
    }

    /// Table 8 / Table 30: E.P. co-distillation + int2 method summary.
    fn table_ep_codistill(&self) -> Result<String> {
        let configs: [(&str, [f32; 3], [f32; 3]); 3] = [
            ("[8, 4, 2]", [1.0, 1.0, 1.0], [0.0, 0.0, 0.0]),
            ("[8, 4, 8->2]", [1.0, 1.0, 0.0], [0.0, 0.0, 1.0]),
            ("[8, 4, 2, 8->2]", [1.0, 1.0, 1.0], [0.0, 0.0, 1.0]),
        ];
        let mut table = TableBuilder::new(
            "Table 8 | Extra-Precision co-distillation (OmniQuant, int2-EP)",
            &["Config", "Avg. Bits", "Task Avg.", "log pplx"],
        );
        for (label, lam, wd) in &configs {
            let m = self.trained(
                Mode::Omni,
                Objective::Matquant {
                    lambdas: *lam,
                    wdist: *wd,
                    extra_precision: true,
                },
            )?;
            let r = self.eval_assign(&m.model, &self.uniform_ep(2))?;
            table.row(&[
                label.to_string(),
                format!("{:.3}", r.bits_per_param),
                pct(r.task_avg),
                pplx(r.log_pplx),
            ]);
        }
        // int2 method summary (Table 30 shape)
        let base = self.trained(Mode::Omni, Objective::Direct { bits: 2 })?;
        let sp = self.trained(Mode::Omni, Objective::single_precision())?;
        let mat = self.trained(Mode::Omni, Objective::matquant_default())?;
        for (name, tm) in [
            ("OmniQuant baseline", &base),
            ("S.P. MatQuant", &sp),
            ("MatQuant", &mat),
        ] {
            let r = self.eval_assign(&tm.model, &self.uniform(2))?;
            table.row(&[
                name.to_string(),
                format!("{:.3}", r.bits_per_param),
                pct(r.task_avg),
                pplx(r.log_pplx),
            ]);
        }
        Ok(table.render())
    }

    // ------------------------------------------------------------------
    // Figures
    // ------------------------------------------------------------------

    pub fn run_figure(&self, which: &str) -> Result<String> {
        match which {
            "1c" => self.fig_histograms(),
            "2" => self.fig_mixnmatch(false),
            "3" => self.fig_mixnmatch(true),
            other => bail!("unknown figure {other:?} (1c, 2, 3)"),
        }
    }

    /// Fig 1c: right-shifted quantized weight distributions.
    fn fig_histograms(&self) -> Result<String> {
        let mat = self.trained(Mode::Omni, Objective::matquant_default())?;
        let base = self.trained(Mode::Omni, Objective::Direct { bits: 8 })?;
        let mut out = String::from("### Fig 1c | Quantized weight distributions (OmniQuant)\n");
        for bits in [2u32, 4] {
            out += &format!("\n-- int{bits} codes --\n");
            for (label, tm) in [("Baseline", &base), ("MatQuant", &mat)] {
                let mut hist = vec![0u64; 1 << bits];
                let mut mean_num = 0.0f64;
                let mut total = 0u64;
                for qt in tm.model.quantized.values() {
                    let h = qt.sliced_histogram(bits);
                    for (i, c) in h.iter().enumerate() {
                        hist[i] += c;
                        mean_num += (i as f64) * (*c as f64);
                        total += c;
                    }
                }
                let mean = mean_num / total.max(1) as f64;
                out += &format!("{label} (mean bucket {mean:.3}):\n");
                out += &quant::render_histogram(&hist, 40);
            }
        }
        out += "\nExpected shape: MatQuant histograms shifted toward higher buckets.\n";
        Ok(out)
    }

    /// Fig 2 (and Fig 3 with `ep`): Mix'n'Match accuracy-vs-bits sweep.
    fn fig_mixnmatch(&self, ep: bool) -> Result<String> {
        let mat = if ep {
            self.trained(
                Mode::Omni,
                Objective::Matquant {
                    lambdas: [1.0, 1.0, 1.0],
                    wdist: [0.0; 3],
                    extra_precision: true,
                },
            )?
        } else {
            self.trained(Mode::Omni, Objective::matquant_default())?
        };
        let layers = self.engine.manifest().preset(&self.preset)?.model.n_layers;
        let comps = compositions(layers);
        let mut points = Vec::new();
        let mut strategy_mean: BTreeMap<&str, (f64, usize)> = BTreeMap::new();
        for comp in &comps {
            for s in STRATEGIES {
                let bits = assignments_for(s, *comp, layers);
                let assign = PrecisionAssignment::PerLayer {
                    bits,
                    extra_precision: ep,
                };
                let r = self.eval_assign(&mat.model, &assign)?;
                points.push(Point {
                    label: format!("{}-{comp:?}", s.name()),
                    bits_per_param: r.bits_per_param,
                    accuracy: r.task_avg,
                    log_pplx: r.log_pplx,
                });
                let e = strategy_mean.entry(s.name()).or_insert((0.0, 0));
                e.0 += r.task_avg;
                e.1 += 1;
                // skip redundant strategy repeats for homogeneous comps
                if comp.0 == layers || comp.1 == layers || comp.2 == layers {
                    break;
                }
            }
        }
        let frontier = pareto_frontier(&points);
        let title = if ep { "Fig 3" } else { "Fig 2" };
        let mut out = format!(
            "### {title} | Mix'n'Match accuracy-vs-bits ({} points)\n",
            points.len()
        );
        out += &crate::mixnmatch::pareto::render_curve(&points, 64, 16);
        out += "\nPareto frontier:\n";
        for p in &frontier {
            out += &format!(
                "  {:>28}  bits/param {:.3}  acc {:.2}%  log_pplx {:.3}\n",
                p.label,
                p.bits_per_param,
                p.accuracy * 100.0,
                p.log_pplx
            );
        }
        out += "\nMean Task Avg. by strategy (expect pyramid highest):\n";
        for (s, (sum, n)) in &strategy_mean {
            out += &format!("  {s:>18}: {:.2}%\n", sum / *n as f64 * 100.0);
        }
        Ok(out)
    }
}
