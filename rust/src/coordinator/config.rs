//! Experiment configuration: which base algorithm, which objective, which
//! λ / co-distillation weights — one `TrainSpec` per table row.

use crate::util::Json;

/// Base quantization algorithm (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Quantization-Aware Training: updates model weights, CE loss (Eq. 2).
    Qat,
    /// OmniQuant: updates only auxiliary γ/β/δ/s, layer-wise reconstruction
    /// loss (Eq. 5).
    Omni,
}

impl Mode {
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Qat => "qat",
            Mode::Omni => "omni",
        }
    }
}

/// Training objective (paper §3.2 / §5).
#[derive(Debug, Clone, PartialEq)]
pub enum Objective {
    /// MatQuant joint loss over R = (8, 4, 2) with λ weights (Eq. 7), plus
    /// optional co-distillation weights (Table 4: distill r-bit from int8)
    /// and the Extra-Precision slicing variant (Eq. 8, Table 7).
    ///
    /// Single-Precision MatQuant (Table 5) is `lambdas = [0, 0, 1]`.
    Matquant {
        lambdas: [f32; 3],
        wdist: [f32; 3],
        extra_precision: bool,
    },
    /// Explicitly-trained per-bit baseline (the paper's "Baseline" rows).
    Direct { bits: u32 },
    /// Full-precision pretraining — produces the base checkpoint the other
    /// objectives fine-tune / calibrate (the paper's Gemma/Mistral stand-in).
    Fp,
}

impl Objective {
    pub fn matquant(lambdas: [f32; 3]) -> Self {
        Objective::Matquant {
            lambdas,
            wdist: [0.0; 3],
            extra_precision: false,
        }
    }

    /// The paper's default λ = (0.1, 0.1, 1.0) (Appendix B).
    pub fn matquant_default() -> Self {
        Self::matquant([0.1, 0.1, 1.0])
    }

    /// Single-Precision MatQuant: loss only on the sliced int2 model.
    pub fn single_precision() -> Self {
        Self::matquant([0.0, 0.0, 1.0])
    }

    /// Artifact name suffix this objective executes.
    pub fn artifact(&self, mode: Mode) -> String {
        match self {
            Objective::Matquant {
                extra_precision, ..
            } => format!(
                "train_{}_mat{}",
                mode.as_str(),
                if *extra_precision { "_ep" } else { "" }
            ),
            Objective::Direct { bits } => format!("train_{}_direct_b{}", mode.as_str(), bits),
            Objective::Fp => "train_fp".to_string(),
        }
    }
}

/// One training run.
#[derive(Debug, Clone)]
pub struct TrainSpec {
    pub preset: String,
    pub mode: Mode,
    pub objective: Objective,
    pub steps: u64,
    /// Seed for init + data stream.
    pub seed: u64,
    /// Log losses every `log_every` steps (0 = never).
    pub log_every: u64,
    /// Start from a pretrained checkpoint instead of fresh init (the
    /// paper's setting: QAT fine-tunes, OmniQuant calibrates, a base model).
    pub init_ckpt: Option<std::path::PathBuf>,
}

impl TrainSpec {
    pub fn new(preset: &str, mode: Mode, objective: Objective, steps: u64) -> Self {
        TrainSpec {
            preset: preset.to_string(),
            mode,
            objective,
            steps,
            seed: 42,
            log_every: 0,
            init_ckpt: None,
        }
    }

    /// Compact run label for logs / checkpoints.
    pub fn label(&self) -> String {
        let obj = match &self.objective {
            Objective::Matquant {
                lambdas,
                wdist,
                extra_precision,
            } => {
                let mut s = format!("mat[{},{},{}]", lambdas[0], lambdas[1], lambdas[2]);
                if wdist.iter().any(|&w| w > 0.0) {
                    s += &format!("+dist[{},{},{}]", wdist[0], wdist[1], wdist[2]);
                }
                if *extra_precision {
                    s += "+ep";
                }
                s
            }
            Objective::Direct { bits } => format!("direct_b{bits}"),
            Objective::Fp => "fp".to_string(),
        };
        let pre = if self.init_ckpt.is_some() { "-pre" } else { "" };
        format!(
            "{}-{}-{}-s{}{}",
            self.preset,
            self.mode.as_str(),
            obj,
            self.steps,
            pre
        )
    }

    pub fn meta_json(&self) -> String {
        Json::obj(vec![
            ("preset", Json::str(&self.preset)),
            ("mode", Json::str(self.mode.as_str())),
            ("label", Json::str(self.label())),
            ("steps", Json::Num(self.steps as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(
            Objective::matquant_default().artifact(Mode::Qat),
            "train_qat_mat"
        );
        assert_eq!(
            Objective::Matquant {
                lambdas: [1.0; 3],
                wdist: [0.0; 3],
                extra_precision: true
            }
            .artifact(Mode::Omni),
            "train_omni_mat_ep"
        );
        assert_eq!(
            Objective::Direct { bits: 3 }.artifact(Mode::Qat),
            "train_qat_direct_b3"
        );
    }

    #[test]
    fn labels_distinguish_runs(){
        let a = TrainSpec::new("tiny", Mode::Qat, Objective::matquant_default(), 10).label();
        let b = TrainSpec::new("tiny", Mode::Qat, Objective::single_precision(), 10).label();
        let c = TrainSpec::new("tiny", Mode::Omni, Objective::Direct { bits: 2 }, 10).label();
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn meta_is_valid_json() {
        let spec = TrainSpec::new("tiny", Mode::Qat, Objective::matquant_default(), 5);
        assert!(Json::parse(&spec.meta_json()).is_ok());
    }
}
