//! The train loop: host-side parameter/optimizer state, PJRT step calls.
//!
//! One step = assemble literals (params, [aux,] m, v, step, tokens[, λ,
//! wdist]) → execute the train artifact → read back updated state + losses.
//! The state round-trips through the host every step; at our model scale
//! the PJRT compute dominates (see EXPERIMENTS.md §Perf for the numbers
//! and the literal-reuse optimization).

use std::collections::BTreeMap;

use anyhow::{ensure, Context};

use super::config::{Mode, Objective, TrainSpec};
use crate::data::{Batcher, Corpus};
use crate::model::{PresetInfo, Tensor};
use crate::runtime::{lit_i32, lit_scalar_i32, lit_tensor, Engine};
use crate::Result;

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// Final model parameters (manifest order names).
    pub params: BTreeMap<String, Tensor>,
    /// Final OmniQuant aux (None for QAT).
    pub aux: Option<BTreeMap<String, Tensor>>,
    /// Per-step loss vectors (3 entries for MatQuant runs — int8/4/2 — or 1
    /// for direct runs).
    pub loss_history: Vec<Vec<f32>>,
    pub spec_label: String,
}

impl TrainOutcome {
    /// Final loss for the `i`-th tracked precision.
    pub fn final_loss(&self, i: usize) -> f32 {
        self.loss_history
            .last()
            .and_then(|l| l.get(i))
            .copied()
            .unwrap_or(f32::NAN)
    }

    /// Mean of the last `k` losses (smoother readout).
    pub fn tail_loss(&self, i: usize, k: usize) -> f32 {
        let n = self.loss_history.len();
        let take = k.min(n).max(1);
        let sum: f32 = self.loss_history[n - take..]
            .iter()
            .filter_map(|l| l.get(i))
            .sum();
        sum / take as f32
    }
}

/// OmniQuant aux init mirrors `model.init_aux`: γ_raw = β_raw = 4 (σ ≈
/// 0.982 ≈ no clipping), s_raw = 0 (s = 1), δ = 0.
pub fn init_aux(preset: &PresetInfo) -> BTreeMap<String, Tensor> {
    preset
        .aux
        .iter()
        .map(|(name, shape)| {
            let v = if name.ends_with("gamma_raw") || name.ends_with("beta_raw") {
                4.0
            } else {
                0.0
            };
            (name.clone(), Tensor::full(shape.clone(), v))
        })
        .collect()
}

/// Initialize model parameters on device via the `init` artifact.
pub fn init_params(
    engine: &Engine,
    preset_name: &str,
    seed: i32,
) -> Result<BTreeMap<String, Tensor>> {
    let preset = engine.manifest().preset(preset_name)?.clone();
    let out = engine
        .run(preset_name, "init", &[lit_scalar_i32(seed)])
        .context("running init artifact")?;
    ensure!(out.len() == preset.params.len(), "init output arity");
    Ok(preset
        .params
        .iter()
        .map(|(n, _)| n.clone())
        .zip(out)
        .collect())
}

/// Run one training job to completion.
pub fn train(engine: &Engine, spec: &TrainSpec) -> Result<TrainOutcome> {
    let preset = engine.manifest().preset(&spec.preset)?.clone();
    let names: Vec<String> = preset.params.iter().map(|(n, _)| n.clone()).collect();
    let aux_names: Vec<String> = preset.aux.iter().map(|(n, _)| n.clone()).collect();
    let artifact = spec.objective.artifact(spec.mode);
    let t1 = preset.model.seq_len + 1;
    let b = preset.train_batch;

    let mut params: Vec<Tensor> = match &spec.init_ckpt {
        Some(path) => {
            let ck = crate::model::Checkpoint::load(path)
                .with_context(|| format!("loading pretrained init {path:?}"))?;
            names
                .iter()
                .map(|n| ck.get(n).map(|t| t.clone()))
                .collect::<Result<_>>()?
        }
        None => {
            let map = init_params(engine, &spec.preset, spec.seed as i32)?;
            names.iter().map(|n| map[n].clone()).collect()
        }
    };
    let mut aux: Vec<Tensor> = if spec.mode == Mode::Omni {
        let map = init_aux(&preset);
        aux_names.iter().map(|n| map[n].clone()).collect()
    } else {
        Vec::new()
    };
    // optimizer state covers what the step updates: weights (QAT) or aux
    // (OmniQuant)
    let opt_shapes: Vec<&Tensor> = match spec.mode {
        Mode::Qat => params.iter().collect(),
        Mode::Omni => aux.iter().collect(),
    };
    let m: Vec<Tensor> = opt_shapes
        .iter()
        .map(|t| Tensor::zeros(t.shape.clone()))
        .collect();
    let v: Vec<Tensor> = m.clone();

    let mut batcher = Batcher::new(Corpus::new(spec.seed), spec.seed ^ 0xDA7A, b, t1);
    let (lambdas, wdist, has_lam) = match &spec.objective {
        Objective::Matquant {
            lambdas, wdist, ..
        } => (*lambdas, *wdist, true),
        Objective::Direct { .. } | Objective::Fp => ([0.0; 3], [0.0; 3], false),
    };

    // ---- upload state to device once; it stays resident across steps ----
    // (EXPERIMENTS.md §Perf: avoids re-serializing every parameter every
    // step.  Artifacts lowered with untupled outputs chain buffers
    // directly; tuple-rooted artifacts fall back to one host round trip.)
    let nu = m.len();
    let mut state: Vec<xla::PjRtBuffer> = Vec::with_capacity(params.len() + 3 * nu);
    for p in &params {
        state.push(engine.to_buffer(lit_tensor(p)?)?);
    }
    if spec.mode == Mode::Omni {
        for a in &aux {
            state.push(engine.to_buffer(lit_tensor(a)?)?);
        }
    }
    for t in m.iter().chain(v.iter()) {
        state.push(engine.to_buffer(lit_tensor(t)?)?);
    }
    let lam_buf = engine.to_buffer(lit_tensor(&Tensor::new(vec![3], lambdas.to_vec())?)?)?;
    let wd_buf = engine.to_buffer(lit_tensor(&Tensor::new(vec![3], wdist.to_vec())?)?)?;
    // frozen model params for OmniQuant (inputs, never updated)
    let frozen = if spec.mode == Mode::Omni { params.len() } else { 0 };

    let mut loss_history = Vec::with_capacity(spec.steps as usize);
    for step in 0..spec.steps {
        let tokens = batcher.next_block();
        let step_buf = engine.to_buffer(lit_scalar_i32(step as i32))?;
        let tok_buf = engine.to_buffer(lit_i32(&[b, t1], &tokens)?)?;
        let mut args: Vec<&xla::PjRtBuffer> = state.iter().collect();
        args.push(&step_buf);
        args.push(&tok_buf);
        if has_lam {
            args.push(&lam_buf);
            args.push(&wd_buf);
        }

        let out = engine.run_b(&spec.preset, &artifact, &args)?;
        // outputs: updated (params|aux), m, v, losses
        let mut new_bufs: Vec<xla::PjRtBuffer> = if out.len() == 1 {
            // legacy tuple-rooted artifact: host round trip
            let lit = out[0].to_literal_sync()?;
            let parts = lit.to_tuple().context("decomposing train-step tuple")?;
            parts
                .into_iter()
                .map(|l| engine.to_buffer(l))
                .collect::<Result<_>>()?
        } else {
            out
        };
        ensure!(new_bufs.len() == 3 * nu + 1, "train step output arity");
        let losses = engine.fetch(&new_bufs.pop().unwrap())?.data;
        // keep frozen params (omni) + splice updated state
        state.truncate(frozen);
        state.extend(new_bufs);
        if spec.log_every > 0 && step % spec.log_every == 0 {
            eprintln!("[{}] step {step:>5} losses {:?}", spec.label(), &losses);
        }
        loss_history.push(losses);
    }

    // ---- fetch final state back to host ----------------------------------
    let updated: Vec<Tensor> = state[frozen..frozen + nu]
        .iter()
        .map(|b| engine.fetch(b))
        .collect::<Result<_>>()?;
    match spec.mode {
        Mode::Qat => params = updated,
        Mode::Omni => aux = updated,
    }

    Ok(TrainOutcome {
        params: names.into_iter().zip(params).collect(),
        aux: if spec.mode == Mode::Omni {
            Some(aux_names.into_iter().zip(aux).collect())
        } else {
            None
        },
        loss_history,
        spec_label: spec.label(),
    })
}
