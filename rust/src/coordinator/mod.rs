//! The training orchestrator (L3): drives the AOT train-step executables,
//! owns optimizer state between steps, runs the paper's experiment grid.

pub mod config;
pub mod experiments;
pub mod trainer;

pub use config::{Mode, Objective, TrainSpec};
pub use trainer::{train, TrainOutcome};
