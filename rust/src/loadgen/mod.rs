//! Trace-driven load generator for the TCP front door: Poisson arrivals,
//! sampled prompt/output lengths, a configurable per-precision traffic
//! mix, and hundreds of concurrent blocking-client streams — reporting
//! p50/p99 TTFT, p50/p99 per-token latency (TPOT), tokens/sec, and SLO
//! attainment, overall and per mix entry.
//!
//! The generator measures what a *client* sees: TTFT is send-to-first-
//! chunk over the real socket (connection, HTTP framing, queueing, and
//! prefill included), TPOT is the gap between successive token chunks.
//! The server's own [`crate::serve::Metrics`] TTFT counter measures
//! submit-to-first-token inside the worker; comparing the two isolates
//! the front-door overhead.
//!
//! Everything is deterministic under a fixed [`TraceConfig::seed`]
//! except wall-clock timing itself: the same seed replays the same
//! arrival times, prompts, lengths, and precision choices.
//!
//! Unix-only, like the frontend it drives.

use std::io::BufReader;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::data::Rng;
use crate::runtime::Sampling;
use crate::serve::frontend::codec;
use crate::serve::request::{PrecisionReq, Request};
use crate::util::json::Json;

/// One precision class in the traffic mix.
#[derive(Debug, Clone)]
pub struct MixEntry {
    /// Relative weight (fractions of the total across entries).
    pub weight: f64,
    pub bits: u32,
    pub int8_acts: bool,
    pub per_layer: Option<Vec<u32>>,
}

impl MixEntry {
    pub fn uniform(weight: f64, bits: u32) -> MixEntry {
        MixEntry {
            weight,
            bits,
            int8_acts: false,
            per_layer: None,
        }
    }

    /// Row label, e.g. `int8`, `int4+a8`, `int8+pl`.
    pub fn label(&self) -> String {
        let mut s = format!("int{}", self.bits);
        if self.int8_acts {
            s.push_str("+a8");
        }
        if self.per_layer.is_some() {
            s.push_str("+pl");
        }
        s
    }
}

/// The trace shape: how much traffic, how fast, how long, at which
/// precisions.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub seed: u64,
    /// Total requests in the trace.
    pub requests: usize,
    /// Mean arrival rate in requests/second (exponential inter-arrivals
    /// — a Poisson process).
    pub arrival_rate: f64,
    /// Prompt length sampled uniformly from this inclusive range (token
    /// ids sampled from `[0, vocab)`).
    pub prompt_len: (usize, usize),
    /// Output length sampled uniformly from this inclusive range.
    pub max_new_tokens: (usize, usize),
    /// Vocabulary to sample prompt tokens from (the serving model's).
    pub vocab: usize,
    /// Traffic mix; weights need not sum to anything in particular.
    pub mix: Vec<MixEntry>,
    /// SLO: time-to-first-token at or under this attains.
    pub ttft_slo_ms: f64,
    /// SLO: mean per-token gap at or under this attains.
    pub tpot_slo_ms: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 7,
            requests: 64,
            arrival_rate: 100.0,
            prompt_len: (4, 12),
            max_new_tokens: (2, 6),
            vocab: 64,
            // The paper-motivated default: most traffic at int8, a tail
            // sliced down the nested payload.
            mix: vec![
                MixEntry::uniform(0.7, 8),
                MixEntry::uniform(0.2, 4),
                MixEntry::uniform(0.1, 2),
            ],
            ttft_slo_ms: 250.0,
            tpot_slo_ms: 100.0,
        }
    }
}

/// One request in a materialized trace: when it arrives and what it asks.
#[derive(Debug, Clone)]
pub struct PlannedRequest {
    pub start_ms: f64,
    pub mix_index: usize,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Materialize the trace deterministically from the seed: arrival times
/// (exponential gaps), prompts, lengths, and mix choices.
pub fn build_trace(cfg: &TraceConfig) -> Vec<PlannedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let total_weight: f64 = cfg.mix.iter().map(|m| m.weight).sum();
    let mut at_ms = 0.0f64;
    let mut out = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        // Exponential inter-arrival; clamp the uniform draw away from 0
        // so ln() stays finite.
        let u = rng.f64().max(1e-12);
        at_ms += -u.ln() / cfg.arrival_rate.max(1e-9) * 1e3;
        let mix_index = {
            let mut pick = rng.f64() * total_weight;
            let mut idx = cfg.mix.len() - 1;
            for (i, m) in cfg.mix.iter().enumerate() {
                if pick < m.weight {
                    idx = i;
                    break;
                }
                pick -= m.weight;
            }
            idx
        };
        let (lo, hi) = cfg.prompt_len;
        let plen = lo + rng.below(hi.saturating_sub(lo) + 1);
        let prompt: Vec<i32> = (0..plen.max(1))
            .map(|_| rng.below(cfg.vocab.max(1)) as i32)
            .collect();
        let (glo, ghi) = cfg.max_new_tokens;
        let gen = (glo + rng.below(ghi.saturating_sub(glo) + 1)).max(1);
        out.push(PlannedRequest {
            start_ms: at_ms,
            mix_index,
            prompt,
            max_new_tokens: gen,
        });
    }
    out
}

/// What one stream observed, client-side.
#[derive(Debug, Clone)]
struct StreamOutcome {
    mix_index: usize,
    /// Some(ms) once the first token chunk arrived.
    ttft_ms: Option<f64>,
    /// Gaps between successive token chunks.
    gaps_ms: Vec<f64>,
    tokens: usize,
    error: Option<String>,
}

/// Aggregate latency row (overall, or one mix entry).
#[derive(Debug, Clone)]
pub struct LatencyRow {
    pub label: String,
    pub requests: usize,
    pub completed: usize,
    pub tokens: usize,
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub tpot_p50_ms: f64,
    pub tpot_p99_ms: f64,
    /// Fraction of *issued* requests that completed AND met both SLOs.
    pub slo_attainment: f64,
}

/// The full report for one trace run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub overall: LatencyRow,
    pub per_mix: Vec<LatencyRow>,
    pub wall_ms: f64,
    pub tokens_per_sec: f64,
    pub errors: usize,
}

/// Nearest-rank percentile over unsorted samples, mirroring
/// [`crate::serve::Metrics`]' percentile semantics (0.0 on empty).
fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn summarize(
    label: String,
    outcomes: &[&StreamOutcome],
    cfg: &TraceConfig,
) -> LatencyRow {
    let requests = outcomes.len();
    let completed = outcomes.iter().filter(|o| o.error.is_none()).count();
    let tokens: usize = outcomes.iter().map(|o| o.tokens).sum();
    let ttfts: Vec<f64> = outcomes.iter().filter_map(|o| o.ttft_ms).collect();
    let gaps: Vec<f64> = outcomes.iter().flat_map(|o| o.gaps_ms.iter().copied()).collect();
    let attained = outcomes
        .iter()
        .filter(|o| {
            o.error.is_none()
                && o.ttft_ms.is_some_and(|t| t <= cfg.ttft_slo_ms)
                && (o.gaps_ms.is_empty() || {
                    let mean = o.gaps_ms.iter().sum::<f64>() / o.gaps_ms.len() as f64;
                    mean <= cfg.tpot_slo_ms
                })
        })
        .count();
    LatencyRow {
        label,
        requests,
        completed,
        tokens,
        ttft_p50_ms: percentile(&ttfts, 50.0),
        ttft_p99_ms: percentile(&ttfts, 99.0),
        tpot_p50_ms: percentile(&gaps, 50.0),
        tpot_p99_ms: percentile(&gaps, 99.0),
        slo_attainment: if requests == 0 {
            0.0
        } else {
            attained as f64 / requests as f64
        },
    }
}

/// Drive one stream: connect at its arrival time, POST, time the chunks.
fn run_stream(addr: &str, cfg: &TraceConfig, planned: &PlannedRequest, id: u64) -> StreamOutcome {
    let entry = &cfg.mix[planned.mix_index];
    let mut outcome = StreamOutcome {
        mix_index: planned.mix_index,
        ttft_ms: None,
        gaps_ms: Vec::new(),
        tokens: 0,
        error: None,
    };
    let mut req = Request::generate(
        id,
        planned.prompt.clone(),
        PrecisionReq::Bits(entry.bits),
        planned.max_new_tokens,
        Sampling::Greedy,
    );
    req.int8_acts = entry.int8_acts;
    req.per_layer = entry.per_layer.clone();
    let body = codec::request_to_json(&req);
    let run = || -> std::io::Result<(Option<f64>, Vec<f64>, usize, Option<String>)> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let mut writer = stream.try_clone()?;
        let sent_at = Instant::now();
        codec::write_generate(&mut writer, &body)?;
        let mut reader = BufReader::new(stream);
        let (status, headers) = codec::read_response_head(&mut reader)?;
        if status != 200 {
            let body = codec::read_body(&mut reader, &headers).unwrap_or_default();
            return Ok((None, Vec::new(), 0, Some(format!("HTTP {status}: {body}"))));
        }
        let mut ttft = None;
        let mut gaps = Vec::new();
        let mut tokens = 0usize;
        let mut last_at = sent_at;
        let mut err = None;
        while let Some(line) = codec::read_chunk(&mut reader)? {
            let now = Instant::now();
            match Json::parse(&line) {
                Ok(event) => {
                    if let Some(e) = event.opt("error") {
                        err = Some(
                            e.as_str().unwrap_or("stream error").to_string(),
                        );
                    } else {
                        tokens += 1;
                        // Latency accounting is per *token* event only —
                        // an in-band error chunk (e.g. worker death
                        // before any token) must not contribute a fake
                        // TTFT/gap sample to the percentiles.
                        if err.is_none() {
                            if ttft.is_none() {
                                ttft = Some(now.duration_since(sent_at).as_secs_f64() * 1e3);
                            } else {
                                gaps.push(now.duration_since(last_at).as_secs_f64() * 1e3);
                            }
                            last_at = now;
                        }
                    }
                }
                Err(e) => err = Some(format!("bad event JSON: {e:#}")),
            }
        }
        Ok((ttft, gaps, tokens, err))
    };
    match run() {
        Ok((ttft, gaps, tokens, err)) => {
            outcome.ttft_ms = ttft;
            outcome.gaps_ms = gaps;
            outcome.tokens = tokens;
            outcome.error = err;
        }
        Err(e) => outcome.error = Some(format!("{e}")),
    }
    outcome
}

/// Replay the trace against a front door at `addr` (one OS thread per
/// concurrent stream — arrivals overlap exactly as the Poisson clock
/// dictates) and aggregate the report.
pub fn run_trace(addr: &str, cfg: &TraceConfig) -> crate::Result<LoadReport> {
    anyhow::ensure!(!cfg.mix.is_empty(), "traffic mix must have at least one entry");
    let planned = build_trace(cfg);
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(planned.len());
    for (i, p) in planned.into_iter().enumerate() {
        let addr = addr.to_string();
        let cfg = cfg.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("mq-loadgen-{i}"))
                .spawn(move || {
                    let due = Duration::from_secs_f64(p.start_ms / 1e3);
                    let elapsed = t0.elapsed();
                    if due > elapsed {
                        std::thread::sleep(due - elapsed);
                    }
                    run_stream(&addr, &cfg, &p, i as u64 + 1)
                })
                .context("spawning loadgen stream")?,
        );
    }
    let outcomes: Vec<StreamOutcome> = handles
        .into_iter()
        .map(|h| {
            h.join().unwrap_or_else(|_| StreamOutcome {
                mix_index: 0,
                ttft_ms: None,
                gaps_ms: Vec::new(),
                tokens: 0,
                error: Some("stream thread panicked".into()),
            })
        })
        .collect();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let all: Vec<&StreamOutcome> = outcomes.iter().collect();
    let overall = summarize("all".into(), &all, cfg);
    let per_mix = cfg
        .mix
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let subset: Vec<&StreamOutcome> =
                outcomes.iter().filter(|o| o.mix_index == i).collect();
            summarize(m.label(), &subset, cfg)
        })
        .collect();
    let tokens_per_sec = if wall_ms > 0.0 {
        overall.tokens as f64 / (wall_ms / 1e3)
    } else {
        0.0
    };
    let errors = outcomes.iter().filter(|o| o.error.is_some()).count();
    Ok(LoadReport {
        overall,
        per_mix,
        wall_ms,
        tokens_per_sec,
        errors,
    })
}

impl LatencyRow {
    fn render(&self) -> String {
        format!(
            "{:<10} n={:<4} ok={:<4} tok={:<6} ttft p50/p99 = {:.2}/{:.2} ms  tpot p50/p99 = {:.2}/{:.2} ms  slo={:.1}%",
            self.label,
            self.requests,
            self.completed,
            self.tokens,
            self.ttft_p50_ms,
            self.ttft_p99_ms,
            self.tpot_p50_ms,
            self.tpot_p99_ms,
            self.slo_attainment * 100.0
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("requests", Json::Num(self.requests as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("ttft_p50_ms", Json::Num(self.ttft_p50_ms)),
            ("ttft_p99_ms", Json::Num(self.ttft_p99_ms)),
            ("tpot_p50_ms", Json::Num(self.tpot_p50_ms)),
            ("tpot_p99_ms", Json::Num(self.tpot_p99_ms)),
            ("slo_attainment", Json::Num(self.slo_attainment)),
        ])
    }
}

impl LoadReport {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "trace: wall={:.1}ms  tokens/s={:.1}  errors={}\n",
            self.wall_ms, self.tokens_per_sec, self.errors
        ));
        s.push_str(&self.overall.render());
        s.push('\n');
        for row in &self.per_mix {
            s.push_str(&row.render());
            s.push('\n');
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wall_ms", Json::Num(self.wall_ms)),
            ("tokens_per_sec", Json::Num(self.tokens_per_sec)),
            ("errors", Json::Num(self.errors as f64)),
            ("overall", self.overall.to_json()),
            (
                "per_mix",
                Json::Arr(self.per_mix.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_and_well_formed() {
        let cfg = TraceConfig {
            requests: 50,
            vocab: 32,
            ..TraceConfig::default()
        };
        let a = build_trace(&cfg);
        let b = build_trace(&cfg);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.start_ms, y.start_ms, "same seed, same arrivals");
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.mix_index, y.mix_index);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        let mut last = 0.0;
        for p in &a {
            assert!(p.start_ms >= last, "arrivals are monotone");
            last = p.start_ms;
            assert!(!p.prompt.is_empty());
            assert!(p.prompt.iter().all(|&t| t >= 0 && (t as usize) < 32));
            assert!(p.max_new_tokens >= 1);
            assert!(p.mix_index < cfg.mix.len());
        }
        // Mix shares roughly track the weights (70/20/10 over 50 draws:
        // the dominant class must dominate).
        let counts = a.iter().fold([0usize; 3], |mut acc, p| {
            acc[p.mix_index] += 1;
            acc
        });
        assert!(counts[0] > counts[2], "70% class outdraws 10% class: {counts:?}");
        // Different seed, different trace.
        let c = build_trace(&TraceConfig { seed: 8, ..cfg });
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt || x.start_ms != y.start_ms),
            "seed must matter"
        );
    }

    #[test]
    fn percentiles_and_slo_accounting() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&samples, 50.0) - 50.0).abs() <= 1.0);
        assert!((percentile(&samples, 99.0) - 99.0).abs() <= 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);

        let cfg = TraceConfig {
            ttft_slo_ms: 10.0,
            tpot_slo_ms: 5.0,
            ..TraceConfig::default()
        };
        let good = StreamOutcome {
            mix_index: 0,
            ttft_ms: Some(8.0),
            gaps_ms: vec![4.0, 4.0],
            tokens: 3,
            error: None,
        };
        let slow_first_token = StreamOutcome {
            ttft_ms: Some(50.0),
            ..good.clone()
        };
        let failed = StreamOutcome {
            error: Some("worker died".into()),
            ..good.clone()
        };
        let rows = [&good, &slow_first_token, &failed];
        let row = summarize("all".into(), &rows, &cfg);
        assert_eq!(row.requests, 3);
        assert_eq!(row.completed, 2);
        assert!((row.slo_attainment - 1.0 / 3.0).abs() < 1e-9);
    }
}
