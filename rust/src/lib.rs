//! # MatQuant — Matryoshka Quantization (ICML 2025) reproduction
//!
//! A three-layer Rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas, build-time)** — fake-quantization, MSB-slicing, and fused
//!   dequant-matmul kernels (`python/compile/kernels/`).
//! * **L2 (JAX, build-time)** — a decoder-only transformer with MatQuant's
//!   multi-precision joint objective, lowered once to HLO text artifacts
//!   (`python/compile/`, `make artifacts`).
//! * **L3 (this crate, run-time)** — the coordinator: PJRT runtime, the
//!   nested-integer quant algebra, synthetic corpus + probe-task evaluation,
//!   the training orchestrator regenerating every paper table, layer-wise
//!   Mix'n'Match, and an elastic-precision serving stack.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `matquant` binary is self-contained.
//!
//! ## Serving-time dequantization
//!
//! The scalar quant algebra lives in [`quant`] and defines the semantics
//! (bit-for-bit identical to `python/compile/kernels/ref.py`).  The serving
//! hot path does **not** run it: [`kernels`] provides single-pass fused
//! dequantization straight from the packed bitstream + overflow overlay +
//! per-channel scales to f32 weights, and fused dequant×matmul
//! ([`kernels::matmul`]) that never materializes the weights at all —
//! wired through [`model::registry::QuantizedTensor::materialize`], the
//! [`model::PackedWeight`] payload handles, the server's warm (dense) /
//! lazy (**paged** r-bit payload) weight builds in [`serve::weights`], the
//! host packed-linear engine path, and the Mix'n'Match sweeps + layer
//! sensitivity probes.  Conformance: `cargo test --test kernel_conformance`
//! (bit-for-bit dequant, property-based matmul); throughput:
//! `cargo bench --bench quant_hot_paths`.
//!
//! ## Host serving path (no PJRT)
//!
//! [`runtime::forward`] executes the **whole model** on the host; the
//! serving worker ([`serve::Server::start_host`]) answers end-to-end
//! requests — including multi-token generations — with no artifacts and no
//! PJRT, through the incremental decode engine:
//!
//! ```text
//!   WeightStore ─► ForwardPlan (cached per precision spec: pre-resolved
//!                  PackedWeight/dense handles + reusable scratch,
//!                  optional Mix'n'Match per-layer bits; non-quantized
//!                  params Arc-shared with the registry)
//!              ─► Scheduler (continuous batching): live sessions grouped
//!                  by plan spec, stepped in ROUNDS — one blocked fused
//!                  GEMM per layer across all members; ragged batched
//!                  prefills, mid-stream admission, KV-pressure deferral
//!              ─► DecodeSession (KvCache) ─► streamed tokens
//!   (paged r-bit payloads; f32 weight tensors never exist)
//! ```
//!
//! Quantized matmuls stream the fused packed-domain kernels at any
//! r ∈ {1..8}; requests flagged `int8_acts` also quantize the layer inputs
//! per token row ([`quant::activations`] — or against persisted per-layer
//! calibrated clips, [`quant::calibration`]) and reduce through the
//! i8→i32 integer GEMV, so weights *and* activations stay in the quantized
//! domain.  `Request { max_new_tokens, sampling }` picks the generation
//! length and greedy / seeded-temperature sampling; responses stream one
//! event per token.  Conformance against the dense f32 reference forward:
//! `cargo test --test forward`; KV-cached decode vs full re-forward
//! bit-identity: `cargo test --test decode`; batched rounds / ragged
//! prefill vs solo sessions: `cargo test --test scheduler`; throughput
//! (prefill, per-step decode, and scheduler rounds vs per-session
//! stepping at 1/4/16 concurrent streams):
//! `cargo bench --bench quant_hot_paths`.
//!
//! ## Scale-out front door (TCP serving + load harness)
//!
//! [`serve::frontend`] puts an async multi-worker fleet behind a real
//! socket with zero new dependencies: a hand-rolled non-blocking
//! HTTP/1.1 + chunked-NDJSON codec, a `poll(2)` readiness loop, and N
//! workers (each its own Scheduler + ElasticPlanner) sharing the cached
//! WeightStore plans, one fleet-global PagePool budget, and a
//! precision-affinity admission queue with graceful drain and
//! worker-death rebalance.  [`loadgen`] replays deterministic
//! Poisson-arrival traces with per-precision traffic mixes against it
//! and reports p50/p99 TTFT, p50/p99 per-token latency, tokens/sec, and
//! SLO attainment.  `matquant serve` / `matquant loadgen --self-host`
//! run it from the CLI; conformance (TCP byte-identity vs the
//! in-process host backend, drain, worker death):
//! `cargo test --test frontend`.  Unix-only.
//!
//! ## Post-training accuracy: the MatGPTQ solver
//!
//! [`quant::solver`] refines the int8 masters purely post-training:
//! `calibrate → Gram → nested-MSB GPTQ → outlier sweep → nested
//! payload`.  Per-linear input Grams accumulate through the live plan
//! ([`runtime::ForwardPlan::accumulate_grams`]), a dampened Cholesky
//! factor turns them into error-feedback weights, and each column's
//! int8 code is re-chosen to minimize the Hessian-weighted error of its
//! *nested slices* at rungs {2, 4, 8} — so one refined master improves
//! every precision the serving path slices from it, with zero serving
//! changes ([`model::QuantizedModel::solve_refined`]).  The Eq. 8
//! outlier-budget sweep ([`quant::solver::sweep_outlier_budgets`])
//! lands the paper's ≈2.05-bit point.  Quality is judged on the
//! distilled decode metric ([`eval::distill_decode_log_perplexity`]):
//! students are scored on rows sampled from the int8 teacher, so
//! cross-entropy decomposes as entropy + KL and its ordering tracks
//! weight fidelity even on random-init toy models.  `matquant solve`
//! runs the pipeline from the CLI; `cargo test --test solver` proves
//! bit-exact serving per rung and the solver-beats-minmax int2
//! comparison.
//!
//! ## Build
//!
//! The build is fully offline: `anyhow` and `xla` resolve to vendored path
//! crates under `rust/vendor/` (the `xla` entry is a pure-Rust stub of the
//! PJRT surface; swap in the real bindings to execute artifacts).
//! `cargo build --release && cargo test -q` is the tier-1 gate and runs
//! with no network and no artifacts.

// The seed codebase favors explicit index loops over iterator chains in the
// numeric kernels; keep clippy's default style lints from fighting that.
#![allow(
    clippy::inherent_to_string,
    clippy::needless_range_loop,
    clippy::type_complexity
)]

pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kernels;
#[cfg(unix)]
pub mod loadgen;
pub mod mixnmatch;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// The master bit-width `c` in `S(q^c, r)` — everything nests inside int8.
pub const MASTER_BITS: u32 = 8;

/// Bit-widths the paper explicitly trains (`R = {8, 4, 2}`).
pub const MATQUANT_BITS: [u32; 3] = [8, 4, 2];

/// All evaluated bit-widths, including interpolated int6 / int3.
pub const ALL_BITS: [u32; 5] = [8, 6, 4, 3, 2];
