//! `matquant` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info                          — artifacts / presets / platform summary
//!   train [--preset P] [...]      — one training run + checkpoint
//!   eval --ckpt F [--bits B]      — evaluate a checkpoint at a precision
//!   experiment --table N | --fig F — regenerate a paper table/figure
//!   solve [...]                   — MatGPTQ post-training solver demo
//!   serve-demo [...]              — elastic-precision serving demo
//!   serve [...]                   — multi-worker TCP front door (unix)
//!   loadgen [...]                 — trace-driven load harness (unix)

use anyhow::{bail, Context, Result};
use matquant::coordinator::{experiments, train, Mode, Objective, TrainSpec};
use matquant::model::{
    manifest::default_artifacts_dir, Checkpoint, PrecisionAssignment, QuantizedModel,
};
use matquant::runtime::Engine;
use matquant::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("info");
    match cmd {
        "info" => info(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "experiment" => cmd_experiment(&args),
        "solve" => cmd_solve(&args),
        "serve-demo" => cmd_serve_demo(&args),
        #[cfg(unix)]
        "serve" => cmd_serve(&args),
        #[cfg(unix)]
        "loadgen" => cmd_loadgen(&args),
        other => {
            bail!(
                "unknown command {other:?} (try: info, train, eval, experiment, solve, serve-demo, serve, loadgen)"
            )
        }
    }
}

fn engine() -> Result<Engine> {
    Engine::new(default_artifacts_dir())
}

fn info(_args: &Args) -> Result<()> {
    let engine = engine()?;
    println!("platform: {}", engine.platform());
    for name in engine.manifest().preset_names() {
        let p = engine.manifest().preset(name)?;
        println!(
            "preset {name}: {} params ({} quantized tensors, {} quantized params), d={} L={} T={}",
            p.n_model_params(),
            p.quantized.len(),
            p.n_quantized_params(),
            p.model.d_model,
            p.model.n_layers,
            p.model.seq_len,
        );
        println!(
            "  artifacts: {}",
            engine.manifest().artifact_names(name).join(", ")
        );
    }
    Ok(())
}

fn parse_spec(args: &Args) -> Result<TrainSpec> {
    let preset = args.get_or("preset", "tiny").to_string();
    let mode = match args.get_or("mode", "qat") {
        "qat" => Mode::Qat,
        "omni" => Mode::Omni,
        m => bail!("unknown mode {m:?}"),
    };
    let objective = match args.get_or("objective", "matquant") {
        "matquant" => Objective::Matquant {
            lambdas: [
                args.get_f32("l8", 0.1)?,
                args.get_f32("l4", 0.1)?,
                args.get_f32("l2", 1.0)?,
            ],
            wdist: [
                args.get_f32("d8", 0.0)?,
                args.get_f32("d4", 0.0)?,
                args.get_f32("d2", 0.0)?,
            ],
            extra_precision: args.has_flag("ep"),
        },
        "sp" => Objective::single_precision(),
        "direct" => Objective::Direct {
            bits: args.get_usize("bits", 8)? as u32,
        },
        o => bail!("unknown objective {o:?}"),
    };
    let mut spec = TrainSpec::new(&preset, mode, objective, args.get_u64("steps", 100)?);
    spec.seed = args.get_u64("seed", 42)?;
    spec.log_every = args.get_u64("log-every", 20)?;
    Ok(spec)
}

fn cmd_train(args: &Args) -> Result<()> {
    let engine = engine()?;
    let spec = parse_spec(args)?;
    println!("training {}", spec.label());
    let t0 = std::time::Instant::now();
    let out = train(&engine, &spec)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "done in {dt:.1}s ({:.0} ms/step); final losses {:?}",
        dt * 1e3 / spec.steps as f64,
        out.loss_history.last().unwrap()
    );
    let path = args.get_or("out", "checkpoints/last.mqck").to_string();
    let mut ck = Checkpoint::new(spec.meta_json());
    for (n, t) in &out.params {
        ck.insert(n.clone(), t.clone());
    }
    if let Some(aux) = &out.aux {
        for (n, t) in aux {
            ck.insert(format!("aux:{n}"), t.clone());
        }
    }
    ck.save(&path)?;
    println!("checkpoint: {path}");
    Ok(())
}

fn load_model(engine: &Engine, preset: &str, ckpt: &str) -> Result<QuantizedModel> {
    let ck = Checkpoint::load(ckpt)?;
    let preset_info = engine.manifest().preset(preset)?;
    let mut params = std::collections::BTreeMap::new();
    let mut aux = std::collections::BTreeMap::new();
    for (name, t) in &ck.tensors {
        if let Some(a) = name.strip_prefix("aux:") {
            aux.insert(a.to_string(), t.clone());
        } else {
            params.insert(name.clone(), t.clone());
        }
    }
    QuantizedModel::build(
        preset_info,
        &params,
        if aux.is_empty() { None } else { Some(&aux) },
    )
}

fn cmd_eval(args: &Args) -> Result<()> {
    let engine = engine()?;
    let preset = args.get_or("preset", "tiny");
    let ckpt = args.get("ckpt").context("--ckpt required")?;
    let model = load_model(&engine, preset, ckpt)?;
    let ev = matquant::eval::Evaluator::new(&engine, preset)?;
    let bits_arg = args.get_or("bits", "8");
    let assign = if bits_arg == "fp" {
        PrecisionAssignment::Fp
    } else {
        PrecisionAssignment::Uniform {
            bits: bits_arg.parse().context("--bits")?,
            extra_precision: args.has_flag("ep"),
        }
    };
    let (weights, biases) = model.materialize(&assign)?;
    let seed = args.get_u64("seed", 42)?;
    let session = ev.session(&weights, &biases)?;
    let pplx = ev.log_perplexity(
        &session,
        seed,
        seed ^ 0xEAA1,
        args.get_usize("eval-batches", 8)?,
    )?;
    let report = matquant::eval::task_suite(
        &ev,
        &weights,
        &biases,
        seed,
        seed ^ 0x9999,
        args.get_usize("probes", 25)?,
    )?;
    println!("bits={bits_arg} log_pplx={pplx:.3}");
    println!("{}", report.render());
    println!(
        "bits/param={:.3}  storage={} bytes",
        model.bits_per_param(&assign),
        model.storage_bytes(&assign)
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let engine = engine()?;
    let ctx = experiments::ExperimentCtx::from_args(&engine, args)?;
    if let Some(t) = args.get("table") {
        let out = ctx.run_table(t)?;
        println!("{out}");
    } else if let Some(f) = args.get("fig") {
        let out = ctx.run_figure(f)?;
        println!("{out}");
    } else {
        bail!("--table N or --fig F required (tables 1-8, figs 1c, 2, 3)");
    }
    Ok(())
}

/// `matquant solve`: the MatGPTQ post-training pipeline on a
/// self-contained toy model — calibrate input Grams on rows sampled from
/// the int8 teacher, re-round the int8 masters under the Hessian-weighted
/// nested-MSB objective, sweep Eq. 8 outlier budgets, and print
/// minmax-vs-solver quality at every rung plus the distilled decode-path
/// int2 comparison.  Needs no artifacts, no checkpoints, no PJRT.
///
/// ```text
/// matquant solve [--layers 2 --d-model 32 --seq-len 16]
///                [--calib-rows 24 --calib-seed 21 --damp 0.01]
///                [--l2 1.0 --l4 0.1 --l8 0.1] [--ep]
///                [--budgets 0,0.02,0.05,0.1,0.25] [--eval-rows 8]
///                [--eval-batches 1] [--mix-budget 4.0]
/// ```
fn cmd_solve(args: &Args) -> Result<()> {
    use matquant::eval::{distill_decode_log_perplexity, host_quality_table, sample_decode_rows};
    use matquant::mixnmatch::{solver_sensitivity, suggest_assignment};
    use matquant::quant::solver::{sweep_outlier_budgets, RungWeights, SolverConfig};
    use matquant::runtime::{arc_packed, plan_params, ForwardPlan, KvConfig};

    let dims = matquant::model::ModelDims {
        // The host evaluator scores the byte vocabulary, so 256 is the floor.
        vocab: args.get_usize("vocab", 256)?,
        d_model: args.get_usize("d-model", 32)?,
        n_layers: args.get_usize("layers", 2)?,
        n_heads: args.get_usize("heads", 4)?,
        d_ff: args.get_usize("d-ff", 64)?,
        seq_len: args.get_usize("seq-len", 16)?,
        quantize_attn: args.has_flag("quantize-attn"),
    };
    anyhow::ensure!(
        dims.d_model % dims.n_heads == 0,
        "--d-model must be divisible by --heads"
    );
    let (preset, model) =
        matquant::model::testing::toy_transformer(dims, args.get_u64("model-seed", 11)?);
    let dims = &preset.model;

    // 1. Calibration: pool per-linear Grams H = ΣXᵀX (captured after the
    //    smoothing fold) over rows *sampled from the int8 teacher itself*
    //    — the distribution the distilled decode metric in step 3 scores
    //    against, so calibration and eval share one distribution (the
    //    GPTQ protocol).
    let kv = KvConfig::f32_paged(args.get_usize("page-size", 8)?);
    let calib_seed = args.get_u64("calib-seed", 21)?;
    let n_calib = args.get_usize("calib-rows", 24)?.max(1);
    let b = args.get_usize("batch", 2)?;
    let t = dims.seq_len;
    let teacher = ForwardPlan::packed_uniform(dims, &model, 8, false, None, None)?;
    let rows = sample_decode_rows(&teacher, kv, calib_seed ^ 0xCA11B, n_calib)?;
    let mut grams = std::collections::BTreeMap::new();
    for row in &rows {
        teacher.accumulate_grams(&row[..t], 1, t, &mut grams)?;
    }
    println!(
        "calibrated {} grams over {n_calib} teacher-sampled rows of {t} tokens",
        grams.len()
    );

    // 2. MatGPTQ: nested-MSB rounding with error feedback.
    let cfg = SolverConfig {
        rung_weights: RungWeights {
            weights: vec![
                (2, args.get_f32("l2", 1.0)? as f64),
                (4, args.get_f32("l4", 0.1)? as f64),
                (8, args.get_f32("l8", 0.1)? as f64),
            ],
            extra_precision: args.has_flag("ep"),
        },
        damp_frac: args.get_f32("damp", 0.01)? as f64,
    };
    let (refined, report) = model.solve_refined(&grams, &cfg)?;
    println!("\n{}", report.render());
    for r in cfg.rung_weights.rungs() {
        println!(
            "rung int{r}: mean weighted rel err {:.5} (minmax) -> {:.5} (solved)",
            report.mean_base_rel(r),
            report.mean_solved_rel(r)
        );
    }

    // 3. Serving-path quality: the refined model drops into the same
    //    nested BitSliceView plans — minmax vs solver, per rung.
    let eval_batches = args.get_usize("eval-batches", 1)?;
    let (cseed, eseed) = (args.get_u64("corpus-seed", 11)?, args.get_u64("eval-seed", 12)?);
    let bits_list = [2u32, 4, 8];
    let ep = args.has_flag("ep");
    let base_table =
        host_quality_table(dims, &model, &bits_list, None, ep, b, cseed, eseed, eval_batches)?;
    let solved_table =
        host_quality_table(dims, &refined, &bits_list, None, ep, b, cseed, eseed, eval_batches)?;
    println!("minmax master:\n{}", base_table.render());
    println!("MatGPTQ master:\n{}", solved_table.render());

    // Decode-path int2 comparison on teacher-sampled rows (the acceptance
    // metric).  Against its own samples the int8 teacher is the optimal
    // predictor — students pay entropy + KL — so this CE is ordered by
    // weight fidelity, unlike corpus CE on a random-init toy model.
    let eval_rows = args.get_usize("eval-rows", 8)?;
    let self_ce = distill_decode_log_perplexity(&teacher, &teacher, kv, calib_seed, eval_rows)?;
    let d_base = distill_decode_log_perplexity(
        &teacher,
        &ForwardPlan::packed_uniform(dims, &model, 2, ep, None, None)?,
        kv,
        calib_seed,
        eval_rows,
    )?;
    let d_solved = distill_decode_log_perplexity(
        &teacher,
        &ForwardPlan::packed_uniform(dims, &refined, 2, ep, None, None)?,
        kv,
        calib_seed,
        eval_rows,
    )?;
    println!(
        "distilled decode log pplx (int8 teacher {self_ce:.4}): \
         minmax int2 {d_base:.4} -> solver int2 {d_solved:.4}"
    );

    // 4. Eq. 8 outlier-budget sweep at the int2 rung.
    let budgets: Vec<f64> = args
        .get_or("budgets", "0,0.02,0.05,0.1,0.25")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse::<f64>().map_err(|e| anyhow::anyhow!("--budgets: {e}")))
        .collect::<Result<_>>()?;
    let points = sweep_outlier_budgets(&refined, &grams, 2, &budgets)?;
    println!("outlier-budget sweep @ int2 (Eq. 8):");
    println!("  budget   eff bits  rel err   tensors w/ overlay");
    for p in &points {
        println!(
            "  {:<7.3}  {:<8.3}  {:<8.5}  {}",
            p.budget,
            p.effective_bits,
            p.rel_err,
            p.enabled.len()
        );
    }
    if let Some(best) = points.last() {
        // Prove the sweep point is servable, not just a score: run it.
        let views =
            matquant::quant::solver::packed_views_with_outliers(&refined, 2, &best.enabled)?;
        let plan = std::sync::Arc::new(ForwardPlan::from_packed(
            dims,
            &refined,
            &plan_params(&refined),
            &arc_packed(views),
            None,
            None,
        )?);
        let ll = matquant::eval::HostEvaluator::new(plan, b)?
            .log_perplexity(cseed, eseed, eval_batches)?;
        println!(
            "served sweep point (budget {:.3}): {:.3} effective bits, log pplx {ll:.4}",
            best.budget, best.effective_bits
        );
    }

    // 5. Solver residuals as Mix'n'Match curvature.
    let rows = solver_sensitivity(&report);
    let mix_budget = args.get_f32("mix-budget", 4.0)? as f64;
    let assign = suggest_assignment(&rows, dims.n_layers, mix_budget);
    println!("mix'n'match from solver residuals (avg budget {mix_budget}): {assign:?}");
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    use matquant::serve::{PrecisionReq, Request, Server, ServerConfig};
    let engine = engine()?;
    let preset = args.get_or("preset", "tiny").to_string();
    let model = match args.get("ckpt") {
        Some(ck) => load_model(&engine, &preset, ck)?,
        None => {
            // quick fresh model so the demo is self-contained
            let params = matquant::coordinator::trainer::init_params(&engine, &preset, 1)?;
            QuantizedModel::build(engine.manifest().preset(&preset)?, &params, None)?
        }
    };
    let seq = engine.manifest().preset(&preset)?.model.seq_len;
    drop(engine);
    let server = Server::start(
        default_artifacts_dir(),
        model,
        ServerConfig {
            preset: preset.clone(),
            max_wait_ms: args.get_f32("wait-ms", 2.0)? as f64,
            warm_bits: vec![8, 4, 2],
            ..ServerConfig::default()
        },
    )?;
    let n = args.get_usize("requests", 64)?;
    let mut corpus_rng = matquant::data::Rng::new(7);
    let corpus = matquant::data::Corpus::new(7);
    let mut rxs = Vec::new();
    for id in 0..n as u64 {
        let bits = [2u32, 4, 8][corpus_rng.below(3)];
        let prompt = corpus.sequence(&mut corpus_rng, seq.min(32));
        rxs.push(server.submit(Request::new(id, prompt, PrecisionReq::Bits(bits)))?);
    }
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv()?;
        ok += 1;
        if resp.id < 4 {
            println!(
                "req {} int{}: next_token={} batch={} queue={:.2}ms compute={:.2}ms",
                resp.id,
                resp.bits,
                resp.next_token,
                resp.batch_size,
                resp.queue_ms,
                resp.compute_ms
            );
        }
    }
    println!("{ok}/{n} responses");
    println!("{}", server.metrics_report()?);
    server.shutdown()?;
    Ok(())
}

/// A self-contained toy transformer built from dims flags — no artifacts
/// needed, so `serve` / `loadgen --self-host` run anywhere the crate
/// builds.
#[cfg(unix)]
fn toy_model_from_args(
    args: &Args,
) -> Result<(matquant::model::PresetInfo, QuantizedModel)> {
    let dims = matquant::model::ModelDims {
        vocab: args.get_usize("vocab", 64)?,
        d_model: args.get_usize("d-model", 32)?,
        n_layers: args.get_usize("layers", 2)?,
        n_heads: args.get_usize("heads", 4)?,
        d_ff: args.get_usize("d-ff", 64)?,
        seq_len: args.get_usize("seq-len", 128)?,
        quantize_attn: args.has_flag("quantize-attn"),
    };
    anyhow::ensure!(
        dims.d_model % dims.n_heads == 0,
        "--d-model must be divisible by --heads"
    );
    Ok(matquant::model::testing::toy_transformer(
        dims,
        args.get_u64("model-seed", 11)?,
    ))
}

#[cfg(unix)]
fn server_cfg_from_args(args: &Args) -> Result<matquant::serve::ServerConfig> {
    use matquant::serve::{ElasticConfig, ServerConfig, SpeculativeConfig};
    let kv_cap = args.get_u64("kv-cap", 0)?;
    let mut cfg = ServerConfig {
        preset: "toy".into(),
        max_wait_ms: args.get_f32("wait-ms", 2.0)? as f64,
        kv_capacity_bytes: if kv_cap > 0 { Some(kv_cap) } else { None },
        ..ServerConfig::default()
    };
    if args.has_flag("elastic") {
        let mut e = ElasticConfig::default();
        if kv_cap > 0 {
            e.kv_high_bytes = kv_cap * 3 / 4;
            e.kv_low_bytes = kv_cap / 2;
        }
        e.queue_high = args.get_usize("queue-high", 6)?;
        e.queue_low = args.get_usize("queue-low", 1)?;
        cfg.elastic = Some(e);
    }
    if args.has_flag("spec") {
        cfg.speculative = Some(SpeculativeConfig::default());
    }
    Ok(cfg)
}

/// `matquant serve`: the multi-worker TCP front door on a toy model.
///
/// ```text
/// matquant serve --addr 127.0.0.1:8701 --workers 2 [--elastic] [--spec]
///                [--kv-cap BYTES] [--duration-ms N]
/// curl -N -d '{"prompt":[1,2,3],"bits":4,"max_new_tokens":8}' \
///      http://127.0.0.1:8701/v1/generate
/// ```
#[cfg(unix)]
fn cmd_serve(args: &Args) -> Result<()> {
    use matquant::serve::frontend::HttpFrontend;
    use matquant::serve::frontend::{PoolConfig, WorkerPool};
    let (preset, model) = toy_model_from_args(args)?;
    let pool = WorkerPool::start(
        preset,
        model,
        PoolConfig {
            workers: args.get_usize("workers", 2)?,
            server: server_cfg_from_args(args)?,
        },
    )?;
    let frontend = HttpFrontend::bind(pool, args.get_or("addr", "127.0.0.1:8701"))?;
    println!("serving on http://{}", frontend.addr());
    println!("  POST /v1/generate (chunked NDJSON, one event per token)");
    println!("  GET  /healthz     GET /metrics");
    let duration_ms = args.get_u64("duration-ms", 0)?;
    if duration_ms > 0 {
        std::thread::sleep(std::time::Duration::from_millis(duration_ms));
        println!("{}", frontend.pool().metrics_report());
        frontend.shutdown()?;
    } else {
        // Run until killed; the Drop impl stops the listener thread.
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    Ok(())
}

/// Parse `--mix "8:70,4:20,2:10"`; an `i` suffix on the bits token
/// (`8i:20`) requests int8 activations for that class.
#[cfg(unix)]
fn parse_mix(spec: &str) -> Result<Vec<matquant::loadgen::MixEntry>> {
    let mut mix = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (bits_s, weight_s) = part
            .trim()
            .split_once(':')
            .with_context(|| format!("mix entry {part:?}: expected BITS:WEIGHT"))?;
        let (bits_s, int8) = match bits_s.strip_suffix('i') {
            Some(b) => (b, true),
            None => (bits_s, false),
        };
        let bits: u32 = bits_s
            .parse()
            .with_context(|| format!("mix entry {part:?}: bad bits"))?;
        let weight: f64 = weight_s
            .parse()
            .with_context(|| format!("mix entry {part:?}: bad weight"))?;
        anyhow::ensure!((1..=8).contains(&bits), "mix bits must be 1..=8");
        anyhow::ensure!(weight > 0.0, "mix weight must be positive");
        let mut entry = matquant::loadgen::MixEntry::uniform(weight, bits);
        entry.int8_acts = int8;
        mix.push(entry);
    }
    anyhow::ensure!(!mix.is_empty(), "--mix parsed to zero entries");
    Ok(mix)
}

/// `matquant loadgen`: replay a deterministic Poisson trace against a
/// front door and report TTFT/TPOT percentiles, tokens/sec, and SLO
/// attainment.
///
/// ```text
/// matquant loadgen --addr HOST:PORT --requests 64 --rate 50 \
///                  --mix "8:70,4:20,2:10" [--json-out report.json]
/// matquant loadgen --self-host --workers 2 --requests 16   # CI smoke
/// ```
#[cfg(unix)]
fn cmd_loadgen(args: &Args) -> Result<()> {
    use matquant::loadgen::{run_trace, TraceConfig};
    use matquant::serve::frontend::{HttpFrontend, PoolConfig, WorkerPool};
    let mut tcfg = TraceConfig {
        seed: args.get_u64("seed", 7)?,
        requests: args.get_usize("requests", 32)?,
        arrival_rate: args.get_f32("rate", 50.0)? as f64,
        prompt_len: (
            args.get_usize("prompt-lo", 4)?,
            args.get_usize("prompt-hi", 12)?,
        ),
        max_new_tokens: (args.get_usize("gen-lo", 2)?, args.get_usize("gen-hi", 6)?),
        vocab: args.get_usize("vocab", 64)?,
        mix: parse_mix(args.get_or("mix", "8:70,4:20,2:10"))?,
        ttft_slo_ms: args.get_f32("ttft-slo", 250.0)? as f64,
        tpot_slo_ms: args.get_f32("tpot-slo", 100.0)? as f64,
    };
    anyhow::ensure!(
        tcfg.prompt_len.0 <= tcfg.prompt_len.1 && tcfg.max_new_tokens.0 <= tcfg.max_new_tokens.1,
        "length ranges must be lo <= hi"
    );
    let report = if args.has_flag("self-host") {
        let (preset, model) = toy_model_from_args(args)?;
        tcfg.vocab = preset.model.vocab;
        anyhow::ensure!(
            tcfg.prompt_len.1 + tcfg.max_new_tokens.1 <= preset.model.seq_len,
            "prompt-hi + gen-hi must fit --seq-len"
        );
        let pool = WorkerPool::start(
            preset,
            model,
            PoolConfig {
                workers: args.get_usize("workers", 2)?,
                server: server_cfg_from_args(args)?,
            },
        )?;
        let frontend = HttpFrontend::bind(pool, "127.0.0.1:0")?;
        let addr = frontend.addr().to_string();
        println!("self-hosting {} workers on {addr}", frontend.pool().workers());
        let report = run_trace(&addr, &tcfg)?;
        println!("{}", frontend.pool().metrics_report());
        frontend.shutdown()?;
        report
    } else {
        let addr = args
            .get("addr")
            .context("--addr HOST:PORT required (or --self-host)")?;
        run_trace(addr, &tcfg)?
    };
    print!("{}", report.render());
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, report.to_json().to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("report: {path}");
    }
    Ok(())
}
