//! `matquant` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info                          — artifacts / presets / platform summary
//!   train [--preset P] [...]      — one training run + checkpoint
//!   eval --ckpt F [--bits B]      — evaluate a checkpoint at a precision
//!   experiment --table N | --fig F — regenerate a paper table/figure
//!   serve-demo [...]              — elastic-precision serving demo

use anyhow::{bail, Context, Result};
use matquant::coordinator::{experiments, train, Mode, Objective, TrainSpec};
use matquant::model::{
    manifest::default_artifacts_dir, Checkpoint, PrecisionAssignment, QuantizedModel,
};
use matquant::runtime::Engine;
use matquant::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("info");
    match cmd {
        "info" => info(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "experiment" => cmd_experiment(&args),
        "serve-demo" => cmd_serve_demo(&args),
        other => {
            bail!("unknown command {other:?} (try: info, train, eval, experiment, serve-demo)")
        }
    }
}

fn engine() -> Result<Engine> {
    Engine::new(default_artifacts_dir())
}

fn info(_args: &Args) -> Result<()> {
    let engine = engine()?;
    println!("platform: {}", engine.platform());
    for name in engine.manifest().preset_names() {
        let p = engine.manifest().preset(name)?;
        println!(
            "preset {name}: {} params ({} quantized tensors, {} quantized params), d={} L={} T={}",
            p.n_model_params(),
            p.quantized.len(),
            p.n_quantized_params(),
            p.model.d_model,
            p.model.n_layers,
            p.model.seq_len,
        );
        println!(
            "  artifacts: {}",
            engine.manifest().artifact_names(name).join(", ")
        );
    }
    Ok(())
}

fn parse_spec(args: &Args) -> Result<TrainSpec> {
    let preset = args.get_or("preset", "tiny").to_string();
    let mode = match args.get_or("mode", "qat") {
        "qat" => Mode::Qat,
        "omni" => Mode::Omni,
        m => bail!("unknown mode {m:?}"),
    };
    let objective = match args.get_or("objective", "matquant") {
        "matquant" => Objective::Matquant {
            lambdas: [
                args.get_f32("l8", 0.1)?,
                args.get_f32("l4", 0.1)?,
                args.get_f32("l2", 1.0)?,
            ],
            wdist: [
                args.get_f32("d8", 0.0)?,
                args.get_f32("d4", 0.0)?,
                args.get_f32("d2", 0.0)?,
            ],
            extra_precision: args.has_flag("ep"),
        },
        "sp" => Objective::single_precision(),
        "direct" => Objective::Direct {
            bits: args.get_usize("bits", 8)? as u32,
        },
        o => bail!("unknown objective {o:?}"),
    };
    let mut spec = TrainSpec::new(&preset, mode, objective, args.get_u64("steps", 100)?);
    spec.seed = args.get_u64("seed", 42)?;
    spec.log_every = args.get_u64("log-every", 20)?;
    Ok(spec)
}

fn cmd_train(args: &Args) -> Result<()> {
    let engine = engine()?;
    let spec = parse_spec(args)?;
    println!("training {}", spec.label());
    let t0 = std::time::Instant::now();
    let out = train(&engine, &spec)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "done in {dt:.1}s ({:.0} ms/step); final losses {:?}",
        dt * 1e3 / spec.steps as f64,
        out.loss_history.last().unwrap()
    );
    let path = args.get_or("out", "checkpoints/last.mqck").to_string();
    let mut ck = Checkpoint::new(spec.meta_json());
    for (n, t) in &out.params {
        ck.insert(n.clone(), t.clone());
    }
    if let Some(aux) = &out.aux {
        for (n, t) in aux {
            ck.insert(format!("aux:{n}"), t.clone());
        }
    }
    ck.save(&path)?;
    println!("checkpoint: {path}");
    Ok(())
}

fn load_model(engine: &Engine, preset: &str, ckpt: &str) -> Result<QuantizedModel> {
    let ck = Checkpoint::load(ckpt)?;
    let preset_info = engine.manifest().preset(preset)?;
    let mut params = std::collections::BTreeMap::new();
    let mut aux = std::collections::BTreeMap::new();
    for (name, t) in &ck.tensors {
        if let Some(a) = name.strip_prefix("aux:") {
            aux.insert(a.to_string(), t.clone());
        } else {
            params.insert(name.clone(), t.clone());
        }
    }
    QuantizedModel::build(
        preset_info,
        &params,
        if aux.is_empty() { None } else { Some(&aux) },
    )
}

fn cmd_eval(args: &Args) -> Result<()> {
    let engine = engine()?;
    let preset = args.get_or("preset", "tiny");
    let ckpt = args.get("ckpt").context("--ckpt required")?;
    let model = load_model(&engine, preset, ckpt)?;
    let ev = matquant::eval::Evaluator::new(&engine, preset)?;
    let bits_arg = args.get_or("bits", "8");
    let assign = if bits_arg == "fp" {
        PrecisionAssignment::Fp
    } else {
        PrecisionAssignment::Uniform {
            bits: bits_arg.parse().context("--bits")?,
            extra_precision: args.has_flag("ep"),
        }
    };
    let (weights, biases) = model.materialize(&assign)?;
    let seed = args.get_u64("seed", 42)?;
    let session = ev.session(&weights, &biases)?;
    let pplx = ev.log_perplexity(
        &session,
        seed,
        seed ^ 0xEAA1,
        args.get_usize("eval-batches", 8)?,
    )?;
    let report = matquant::eval::task_suite(
        &ev,
        &weights,
        &biases,
        seed,
        seed ^ 0x9999,
        args.get_usize("probes", 25)?,
    )?;
    println!("bits={bits_arg} log_pplx={pplx:.3}");
    println!("{}", report.render());
    println!(
        "bits/param={:.3}  storage={} bytes",
        model.bits_per_param(&assign),
        model.storage_bytes(&assign)
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let engine = engine()?;
    let ctx = experiments::ExperimentCtx::from_args(&engine, args)?;
    if let Some(t) = args.get("table") {
        let out = ctx.run_table(t)?;
        println!("{out}");
    } else if let Some(f) = args.get("fig") {
        let out = ctx.run_figure(f)?;
        println!("{out}");
    } else {
        bail!("--table N or --fig F required (tables 1-8, figs 1c, 2, 3)");
    }
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    use matquant::serve::{PrecisionReq, Request, Server, ServerConfig};
    let engine = engine()?;
    let preset = args.get_or("preset", "tiny").to_string();
    let model = match args.get("ckpt") {
        Some(ck) => load_model(&engine, &preset, ck)?,
        None => {
            // quick fresh model so the demo is self-contained
            let params = matquant::coordinator::trainer::init_params(&engine, &preset, 1)?;
            QuantizedModel::build(engine.manifest().preset(&preset)?, &params, None)?
        }
    };
    let seq = engine.manifest().preset(&preset)?.model.seq_len;
    drop(engine);
    let server = Server::start(
        default_artifacts_dir(),
        model,
        ServerConfig {
            preset: preset.clone(),
            max_wait_ms: args.get_f32("wait-ms", 2.0)? as f64,
            warm_bits: vec![8, 4, 2],
            ..ServerConfig::default()
        },
    )?;
    let n = args.get_usize("requests", 64)?;
    let mut corpus_rng = matquant::data::Rng::new(7);
    let corpus = matquant::data::Corpus::new(7);
    let mut rxs = Vec::new();
    for id in 0..n as u64 {
        let bits = [2u32, 4, 8][corpus_rng.below(3)];
        let prompt = corpus.sequence(&mut corpus_rng, seq.min(32));
        rxs.push(server.submit(Request::new(id, prompt, PrecisionReq::Bits(bits)))?);
    }
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv()?;
        ok += 1;
        if resp.id < 4 {
            println!(
                "req {} int{}: next_token={} batch={} queue={:.2}ms compute={:.2}ms",
                resp.id,
                resp.bits,
                resp.next_token,
                resp.batch_size,
                resp.queue_ms,
                resp.compute_ms
            );
        }
    }
    println!("{ok}/{n} responses");
    println!("{}", server.metrics_report()?);
    server.shutdown()?;
    Ok(())
}
