//! The downstream task suite — six multiple-choice probe families standing
//! in for ARC-c/ARC-e/BoolQ/HellaSwag/PIQA/Winogrande (DESIGN.md).
//!
//! Mechanism mirrors the paper's zero-shot evals: each probe presents a
//! prompt and 4 candidate continuations; the model's choice is the option
//! with the highest label log-likelihood; we report per-family accuracy
//! and the macro "Task Avg." used in every table.

use crate::data::corpus::{Corpus, Family, FAMILIES};
use crate::data::Rng;
use crate::model::Tensor;
use crate::Result;

use super::perplexity::Evaluator;

#[derive(Debug, Clone)]
pub struct TaskReport {
    /// (family, accuracy) pairs in FAMILIES order.
    pub per_family: Vec<(Family, f64)>,
    pub avg: f64,
    pub n_per_family: usize,
}

impl TaskReport {
    pub fn render(&self) -> String {
        let mut s = String::new();
        for (fam, acc) in &self.per_family {
            s += &format!("{fam:?}: {:.1}%  ", acc * 100.0);
        }
        s += &format!("| Avg: {:.2}%", self.avg * 100.0);
        s
    }
}

/// Evaluate the full probe suite.
///
/// `probes_per_family` probes × 4 options each are scored through the
/// `eval` artifact in batches of `train_batch` rows.
pub fn task_suite(
    ev: &Evaluator,
    weights: &[Tensor],
    biases: &[Tensor],
    corpus_seed: u64,
    probe_seed: u64,
    probes_per_family: usize,
) -> Result<TaskReport> {
    let session = ev.session(weights, biases)?;
    let corpus = Corpus::new(corpus_seed);
    let t1 = ev.preset.model.seq_len + 1;
    // prompt budget: leave room for the longest option (1 token here) and
    // keep probes comfortably within the context
    let prompt_len = (t1 - 4).min(48);
    let batch = ev.preset.train_batch;
    let mut per_family = Vec::new();

    for fam in FAMILIES {
        let mut rng = Rng::new(probe_seed ^ (fam as u64).wrapping_mul(0x9E37));
        let mut correct = 0usize;
        let mut pending: Vec<(Vec<i32>, usize, usize)> = Vec::new();
        let mut pending_probes: Vec<usize> = Vec::new(); // correct idx per probe
        let mut scores: Vec<f32> = Vec::new();

        let flush =
            |pending: &mut Vec<(Vec<i32>, usize, usize)>, scores: &mut Vec<f32>| -> Result<()> {
                if pending.is_empty() {
                    return Ok(());
                }
                let got = ev.score_rows(&session, pending)?;
                scores.extend(got);
                pending.clear();
                Ok(())
            };

        for _ in 0..probes_per_family {
            let probe = corpus.probe(fam, &mut rng, prompt_len);
            pending_probes.push(probe.correct);
            for opt in &probe.options {
                let mut row = probe.prompt.clone();
                let start = row.len();
                row.extend(opt);
                let end = row.len();
                debug_assert!(end <= t1);
                pending.push((row, start, end));
                if pending.len() == batch {
                    flush(&mut pending, &mut scores)?;
                }
            }
        }
        flush(&mut pending, &mut scores)?;

        // decode: 4 consecutive scores per probe
        for (pi, &correct_idx) in pending_probes.iter().enumerate() {
            let s = &scores[pi * 4..pi * 4 + 4];
            let argmax = s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax == correct_idx {
                correct += 1;
            }
        }
        per_family.push((fam, correct as f64 / probes_per_family as f64));
    }

    let avg = per_family.iter().map(|(_, a)| a).sum::<f64>() / per_family.len() as f64;
    Ok(TaskReport {
        per_family,
        avg,
        n_per_family: probes_per_family,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_render_contains_avg() {
        let r = TaskReport {
            per_family: vec![(Family::Cycle, 0.5), (Family::Markov, 0.25)],
            avg: 0.375,
            n_per_family: 8,
        };
        assert!(r.render().contains("37.50%"));
    }
}
