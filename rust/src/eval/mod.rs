//! Evaluation: perplexity + the six-probe downstream task suite + the
//! paper-style table renderer.

pub mod perplexity;
pub mod tables;
pub mod tasks;

pub use perplexity::Evaluator;
pub use tables::TableBuilder;
pub use tasks::{task_suite, TaskReport};
