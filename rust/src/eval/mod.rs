//! Evaluation: perplexity + the six-probe downstream task suite + the
//! paper-style table renderer.
//!
//! Two perplexity drivers over the same held-out stream: the PJRT
//! [`Evaluator`] (eval artifact, needs `make artifacts`) and the
//! artifact-free [`HostEvaluator`] (a [`crate::runtime::ForwardPlan`] per
//! precision spec, fused packed kernels — quality tables for every
//! r ∈ {1..8} ± Mix'n'Match run anywhere the server runs, see
//! [`host_quality_table`]).  [`decode_log_perplexity`] scores the same
//! stream through the KV-cached **decode path** instead, so paged-KV
//! storage choices (f32 vs int8 pages) get a quality number too.
//! [`distill_decode_log_perplexity`] scores a quantized student on rows
//! *sampled from an int8 teacher* ([`sample_decode_rows`]) — CE there is
//! entropy + KL(teacher‖student), so quality ordering tracks weight
//! fidelity even on random-init toy models, which is what the MatGPTQ
//! solver comparisons ([`crate::quant::solver`]) assert on.

pub mod perplexity;
pub mod tables;
pub mod tasks;

pub use perplexity::{
    decode_log_perplexity, distill_decode_log_perplexity, host_quality_table, sample_decode_rows,
    Evaluator, HostEvaluator,
};
pub use tables::{quality_table, TableBuilder};
pub use tasks::{task_suite, TaskReport};
