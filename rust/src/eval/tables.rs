//! Paper-style table rendering: fixed-width rows of
//! `Data type | Method | Task Avg. | log pplx.` matching the layout of
//! Tables 1–8, so experiment output is directly comparable to the paper.

use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct TableBuilder {
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableBuilder {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        TableBuilder {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.columns.len(), "column count");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    /// Render with per-column autosizing, the paper's `|` separators.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {c:<w$} |");
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.columns, &widths));
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Machine-readable companion (one JSON object per row).
    pub fn to_json_lines(&self) -> String {
        let mut out = String::new();
        for row in &self.rows {
            let obj = crate::util::Json::Obj(
                self.columns
                    .iter()
                    .zip(row)
                    .map(|(k, v)| {
                        let val = v
                            .parse::<f64>()
                            .map(crate::util::Json::Num)
                            .unwrap_or_else(|_| crate::util::Json::Str(v.clone()));
                        (k.clone(), val)
                    })
                    .collect(),
            );
            out.push_str(&obj.to_string());
            out.push('\n');
        }
        out
    }
}

/// The canonical quality-table layout (Tables 1–8's
/// `Data type | Method | log pplx.` columns plus the measured
/// effective-bits-per-weight — true packed storage over quantized param
/// count, so "2.05-bit" claims are a measurement, not an assertion) —
/// shared by the artifact suite and the host path
/// ([`crate::eval::perplexity::host_quality_table`]) so both render
/// directly comparable rows.
pub fn quality_table(title: impl Into<String>) -> TableBuilder {
    TableBuilder::new(title, &["Data type", "Method", "log pplx.", "eff. bits/w"])
}

/// Effective-bits formatting for the quality table's fourth column.
pub fn eff_bits(x: f64) -> String {
    format!("{x:.3}")
}

/// Format helpers matching the paper's number style.
pub fn pct(x: f64) -> String {
    format!("{:.2}", x * 100.0)
}

pub fn pplx(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TableBuilder::new("Table 1", &["Data type", "Method", "Task Avg."]);
        t.row_strs(&["int2", "MatQuant", "52.37"]);
        t.row_strs(&["int8", "Baseline", "68.25"]);
        let s = t.render();
        assert!(s.contains("### Table 1"));
        assert!(s.contains("| int2"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len()); // aligned
    }

    #[test]
    fn json_lines_parse() {
        let mut t = TableBuilder::new("T", &["a", "b"]);
        t.row_strs(&["x", "1.5"]);
        let jl = t.to_json_lines();
        let v = crate::util::Json::parse(jl.trim()).unwrap();
        assert_eq!(v.get("b").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = TableBuilder::new("T", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
