//! Log-perplexity over a held-out corpus stream — via the `eval` artifact
//! ([`Evaluator`], PJRT) or entirely on the host ([`HostEvaluator`], no
//! artifacts, no PJRT) — plus the option-scoring primitive the task
//! probes build on.
//!
//! The artifact takes pre-materialized weights (+ per-quantized-tensor
//! biases), so ONE compiled executable evaluates every precision and
//! Mix'n'Match assignment — that is the Matryoshka serving property.  The
//! host path makes the same property **artifact-free**: a
//! [`crate::runtime::ForwardPlan`] per precision spec evaluates straight
//! from the paged r-bit payloads through the fused packed kernels, so
//! quality tables for every r ∈ {1..8} (± per-layer Mix'n'Match maps) run
//! anywhere the serving path runs ([`host_quality_table`]).
//!
//! Perf: a [`WeightsSession`] converts the weight set to XLA literals
//! once; the task suite then reuses them across its ~150 eval executions
//! per configuration (see EXPERIMENTS.md §Perf).

use std::sync::Arc;

use anyhow::ensure;

use super::tables::{eff_bits, pplx, quality_table, TableBuilder};
use crate::data::{Batcher, Corpus, Rng, VOCAB};
use crate::model::manifest::ModelDims;
use crate::model::{PresetInfo, QuantizedModel, Tensor};
use crate::runtime::{
    lit_i32, lit_tensor, sample_logits, Engine, ForwardPlan, KvCache, KvConfig, PagePool, Sampling,
};
use crate::Result;

/// Evaluation driver bound to one engine + preset.
pub struct Evaluator<'e> {
    pub engine: &'e Engine,
    pub preset_name: String,
    pub preset: PresetInfo,
}

/// One materialized weight configuration, pre-converted to literals.
pub struct WeightsSession {
    prefix: Vec<xla::Literal>,
}

impl<'e> Evaluator<'e> {
    pub fn new(engine: &'e Engine, preset_name: &str) -> Result<Self> {
        let preset = engine.manifest().preset(preset_name)?.clone();
        Ok(Evaluator {
            engine,
            preset_name: preset_name.to_string(),
            preset,
        })
    }

    /// Convert a materialized (weights, biases) pair once.
    pub fn session(&self, weights: &[Tensor], biases: &[Tensor]) -> Result<WeightsSession> {
        ensure!(
            weights.len() == self.preset.params.len(),
            "weight count mismatch"
        );
        ensure!(
            biases.len() == self.preset.quantized.len(),
            "bias count mismatch"
        );
        let mut prefix = Vec::with_capacity(weights.len() + biases.len());
        for w in weights {
            prefix.push(lit_tensor(w)?);
        }
        for b in biases {
            prefix.push(lit_tensor(b)?);
        }
        Ok(WeightsSession { prefix })
    }

    fn run_eval(
        &self,
        session: &WeightsSession,
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32, Vec<f32>)> {
        let b = self.preset.train_batch;
        let t1 = self.preset.model.seq_len + 1;
        let t = self.preset.model.seq_len;
        ensure!(tokens.len() == b * t1, "tokens shape");
        ensure!(mask.len() == b * t, "mask shape");
        let mut args: Vec<&xla::Literal> = session.prefix.iter().collect();
        let tok_lit = lit_i32(&[b, t1], tokens)?;
        let mask_lit = lit_tensor(&Tensor::new(vec![b, t], mask.to_vec())?)?;
        args.push(&tok_lit);
        args.push(&mask_lit);
        let out = self.engine.run_refs(&self.preset_name, "eval", &args)?;
        ensure!(out.len() == 3, "eval output arity");
        Ok((out[0].data[0], out[1].data[0], out[2].data.clone()))
    }

    /// Mean log-perplexity (nats/token) over `n_batches` held-out batches.
    ///
    /// `eval_seed` must differ from the training stream seed; the corpus
    /// structure (Markov table) is shared via the corpus seed.
    pub fn log_perplexity(
        &self,
        session: &WeightsSession,
        corpus_seed: u64,
        eval_seed: u64,
        n_batches: usize,
    ) -> Result<f64> {
        let b = self.preset.train_batch;
        let t1 = self.preset.model.seq_len + 1;
        let t = self.preset.model.seq_len;
        let mut batcher = Batcher::new(Corpus::new(corpus_seed), eval_seed, b, t1);
        let ones = vec![1.0f32; b * t];
        let mut ce = 0.0f64;
        let mut count = 0.0f64;
        for _ in 0..n_batches {
            let tokens = batcher.next_block();
            let (ce_sum, mask_sum, _) = self.run_eval(session, &tokens, &ones)?;
            ce += ce_sum as f64;
            count += mask_sum as f64;
        }
        Ok(ce / count.max(1.0))
    }

    /// Score candidate continuations: for each row, the summed label
    /// log-likelihood over masked positions.  Rows beyond `rows.len()` in
    /// the fixed batch are padding.
    ///
    /// Each row = (tokens ≤ T+1 incl. the option, option span `[start,
    /// end)` in token indices).
    pub fn score_rows(
        &self,
        session: &WeightsSession,
        rows: &[(Vec<i32>, usize, usize)],
    ) -> Result<Vec<f32>> {
        let b = self.preset.train_batch;
        let t1 = self.preset.model.seq_len + 1;
        let t = self.preset.model.seq_len;
        ensure!(rows.len() <= b, "too many rows for eval batch");
        let mut tokens = vec![0i32; b * t1];
        let mut mask = vec![0.0f32; b * t];
        for (i, (row, start, end)) in rows.iter().enumerate() {
            ensure!(row.len() <= t1, "row too long: {}", row.len());
            ensure!(*start >= 1 && end <= &row.len(), "bad option span");
            tokens[i * t1..i * t1 + row.len()].copy_from_slice(row);
            // token at index j is predicted at label position j-1
            for j in *start..*end {
                mask[i * t + (j - 1)] = 1.0;
            }
        }
        let (_, _, seq_ll) = self.run_eval(session, &tokens, &mask)?;
        Ok(seq_ll[..rows.len()].to_vec())
    }
}

// ---------------------------------------------------------------------------
// Host path: perplexity with no artifacts and no PJRT
// ---------------------------------------------------------------------------

/// Artifact-free perplexity driver: the same held-out stream the PJRT
/// [`Evaluator`] consumes, scored from a host [`ForwardPlan`]'s logits —
/// on packed plans the weights stay r-bit payloads end to end, so quality
/// numbers come from **exactly the representation the server ships**.
pub struct HostEvaluator {
    plan: Arc<ForwardPlan>,
    batch: usize,
}

impl HostEvaluator {
    pub fn new(plan: Arc<ForwardPlan>, batch: usize) -> Result<Self> {
        ensure!(batch >= 1, "empty eval batch");
        ensure!(
            plan.dims.vocab >= VOCAB,
            "host eval needs the byte vocabulary: plan vocab {} < {VOCAB}",
            plan.dims.vocab
        );
        Ok(HostEvaluator { plan, batch })
    }

    /// Mean log-perplexity (nats/token) over `n_batches` held-out blocks —
    /// the host-path counterpart of [`Evaluator::log_perplexity`], same
    /// corpus/eval seeding contract.  Cross-entropy accumulates in f64
    /// with the max-subtracted stable softmax; a non-finite logits row
    /// (poisoned weights) surfaces as an infinite perplexity, never a
    /// panic.
    pub fn log_perplexity(
        &self,
        corpus_seed: u64,
        eval_seed: u64,
        n_batches: usize,
    ) -> Result<f64> {
        let b = self.batch;
        let t = self.plan.dims.seq_len;
        let v = self.plan.dims.vocab;
        let t1 = t + 1;
        let mut batcher = Batcher::new(Corpus::new(corpus_seed), eval_seed, b, t1);
        let mut inputs = vec![0i32; b * t];
        let mut ce = 0.0f64;
        let mut count = 0u64;
        for _ in 0..n_batches {
            let block = batcher.next_block();
            for bi in 0..b {
                inputs[bi * t..(bi + 1) * t].copy_from_slice(&block[bi * t1..bi * t1 + t]);
            }
            let logits = self.plan.forward(&inputs, b, t)?;
            for bi in 0..b {
                for ti in 0..t {
                    let label = block[bi * t1 + ti + 1] as usize;
                    let row = &logits.data[(bi * t + ti) * v..(bi * t + ti + 1) * v];
                    ce += cross_entropy_nats(row, label);
                    count += 1;
                }
            }
        }
        Ok(ce / count.max(1) as f64)
    }
}

/// Teacher-forced mean log-perplexity through the **decode path**: each
/// held-out row (batch 1, `n_rows` rows) is scored token by token with
/// [`ForwardPlan::decode_step_batch`] against a paged [`KvCache`] built
/// under `kv` — exactly the KV representation the server holds between
/// rounds.  The forward-path evaluators ([`HostEvaluator`],
/// [`host_quality_table`]) never read cached K/V, so this is the one that
/// judges KV storage quality: with f32 pages it reproduces the forward
/// path bit for bit (the decode step's position-by-position conformance
/// contract), and with [`KvConfig::int8`] pages it measures the quality
/// cost of storing K/V rows as int8 codes + per-row scales.
pub fn decode_log_perplexity(
    plan: Arc<ForwardPlan>,
    kv: KvConfig,
    corpus_seed: u64,
    eval_seed: u64,
    n_rows: usize,
) -> Result<f64> {
    ensure!(n_rows >= 1, "empty decode eval");
    ensure!(
        plan.dims.vocab >= VOCAB,
        "decode eval needs the byte vocabulary: plan vocab {} < {VOCAB}",
        plan.dims.vocab
    );
    let t = plan.dims.seq_len;
    let v = plan.dims.vocab;
    let pool = PagePool::unbounded(kv);
    let mut batcher = Batcher::new(Corpus::new(corpus_seed), eval_seed, 1, t + 1);
    let mut ce = 0.0f64;
    let mut count = 0u64;
    for _ in 0..n_rows {
        let block = batcher.next_block();
        let mut cache = KvCache::with_pool(plan.dims.n_layers, plan.dims.d_model, t, pool.clone());
        for ti in 0..t {
            let logits = plan.decode_step_batch(&block[ti..ti + 1], &[ti], &mut [&mut cache])?;
            ce += cross_entropy_nats(&logits[..v], block[ti + 1] as usize);
            count += 1;
        }
    }
    Ok(ce / count.max(1) as f64)
}

/// Sample `n_rows` token rows of length `seq_len + 1` from `plan`
/// through the decode path: each row starts from a seeded random token
/// and extends by temperature-1 softmax sampling of the plan's own
/// next-token logits ([`crate::runtime::sample_logits`]), position by
/// position against a paged KV cache.  Deterministic in
/// `(plan, kv, sample_seed)`.
///
/// These rows are the model's *own* output distribution — the corpus for
/// [`distill_decode_log_perplexity`], and the right calibration stream
/// for [`crate::runtime::ForwardPlan::accumulate_grams`] when the solver
/// will be judged on that metric (calibration and eval then share one
/// distribution, the GPTQ protocol).
pub fn sample_decode_rows(
    plan: &Arc<ForwardPlan>,
    kv: KvConfig,
    sample_seed: u64,
    n_rows: usize,
) -> Result<Vec<Vec<i32>>> {
    ensure!(n_rows >= 1, "empty sample request");
    let t = plan.dims.seq_len;
    let v = plan.dims.vocab;
    let pool = PagePool::unbounded(kv);
    let mut rng = Rng::new(sample_seed ^ 0xD15711);
    let sampling = Sampling::Temperature {
        temp: 1.0,
        seed: sample_seed,
    };
    let mut rows = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        let mut row = Vec::with_capacity(t + 1);
        row.push(rng.below(v) as i32);
        let mut cache = KvCache::with_pool(plan.dims.n_layers, plan.dims.d_model, t, pool.clone());
        for ti in 0..t {
            let logits = plan.decode_step_batch(&row[ti..ti + 1], &[ti], &mut [&mut cache])?;
            let (tok, _) = sample_logits(&logits[..v], &sampling, &mut rng);
            row.push(tok);
        }
        rows.push(row);
    }
    Ok(rows)
}

/// Teacher-forced mean log-perplexity of `student` on rows **sampled from
/// `teacher`** ([`sample_decode_rows`]), scored token by token through the
/// decode path.
///
/// Why a separate metric exists: on a random-init toy model, corpus
/// cross-entropy is *not* ordered by weight fidelity — the float weights
/// sit at no optimum of the corpus loss, so a larger quantization
/// perturbation can accidentally score better.  Against the teacher's own
/// samples the teacher is the optimal predictor (its expected score is
/// exactly the entropy), and any student pays entropy +
/// KL(teacher ‖ student) — a positive-semidefinite quadratic in logit
/// error.  Quality ordering between two quantizations of the same teacher
/// therefore tracks weight fidelity, which is what the MatGPTQ acceptance
/// comparison needs (`cargo test --test solver`).
pub fn distill_decode_log_perplexity(
    teacher: &Arc<ForwardPlan>,
    student: &Arc<ForwardPlan>,
    kv: KvConfig,
    sample_seed: u64,
    n_rows: usize,
) -> Result<f64> {
    ensure!(
        teacher.dims.vocab == student.dims.vocab
            && teacher.dims.seq_len == student.dims.seq_len
            && teacher.dims.n_layers == student.dims.n_layers
            && teacher.dims.d_model == student.dims.d_model,
        "distill eval needs teacher/student with matching shapes"
    );
    let t = student.dims.seq_len;
    let v = student.dims.vocab;
    let rows = sample_decode_rows(teacher, kv, sample_seed, n_rows)?;
    let pool = PagePool::unbounded(kv);
    let mut ce = 0.0f64;
    let mut count = 0u64;
    for row in &rows {
        let mut cache =
            KvCache::with_pool(student.dims.n_layers, student.dims.d_model, t, pool.clone());
        for ti in 0..t {
            let logits = student.decode_step_batch(&row[ti..ti + 1], &[ti], &mut [&mut cache])?;
            ce += cross_entropy_nats(&logits[..v], row[ti + 1] as usize);
            count += 1;
        }
    }
    Ok(ce / count.max(1) as f64)
}

/// `−log softmax(row)[label]`, max-subtracted, accumulated in f64.
fn cross_entropy_nats(row: &[f32], label: usize) -> f64 {
    let mut mx = f32::NEG_INFINITY;
    for &l in row {
        if l > mx {
            mx = l;
        }
    }
    if !mx.is_finite() {
        // All-NaN / all-(−inf) rows: no finite distribution exists.
        return f64::INFINITY;
    }
    let mut sum = 0.0f64;
    for &l in row {
        sum += ((l - mx) as f64).exp();
    }
    sum.ln() + mx as f64 - row[label] as f64
}

/// Paper-style quality rows (`Data type | Method | log pplx. |
/// eff. bits/w`) for every requested serving precision — and optionally a
/// Mix'n'Match per-layer assignment — computed **entirely on the host
/// path**: one packed [`ForwardPlan`] per row, fused r-bit kernels, no
/// artifacts, no PJRT.  This is Table 1–8's sweep made runnable anywhere
/// the server runs.
///
/// The effective-bits column is *measured* storage: true packed payload +
/// scales + (under `extra_precision`) the Eq. 8 outlier-overlay bytes
/// ([`QuantizedModel::storage_bytes`]), over the quantized parameter
/// count — so an Eq. 8 int2 row reads as its real ≈2.05 bits, never a
/// nominal 2.
#[allow(clippy::too_many_arguments)]
pub fn host_quality_table(
    dims: &ModelDims,
    model: &QuantizedModel,
    bits_list: &[u32],
    mixnmatch: Option<&[u32]>,
    extra_precision: bool,
    batch: usize,
    corpus_seed: u64,
    eval_seed: u64,
    n_batches: usize,
) -> Result<TableBuilder> {
    let mut table = quality_table("Host-path quality (artifact-free)");
    let n_q = model.quantized_params().max(1);
    let measured_bits = |assign: &crate::model::PrecisionAssignment| -> f64 {
        model.storage_bytes(assign) as f64 * 8.0 / n_q as f64
    };
    for &bits in bits_list {
        let plan = ForwardPlan::packed_uniform(dims, model, bits, extra_precision, None, None)?;
        let ll = HostEvaluator::new(plan, batch)?.log_perplexity(
            corpus_seed,
            eval_seed,
            n_batches,
        )?;
        let eb = measured_bits(&crate::model::PrecisionAssignment::Uniform {
            bits,
            extra_precision,
        });
        table.row(&[
            format!("int{bits}"),
            "MatQuant (host)".to_string(),
            pplx(ll),
            eff_bits(eb),
        ]);
    }
    if let Some(assign) = mixnmatch {
        let plan = ForwardPlan::packed_per_layer(dims, model, assign, extra_precision, None, None)?;
        let ll = HostEvaluator::new(plan, batch)?.log_perplexity(
            corpus_seed,
            eval_seed,
            n_batches,
        )?;
        let label = assign
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join("/");
        let eb = measured_bits(&crate::model::PrecisionAssignment::PerLayer {
            bits: assign.to_vec(),
            extra_precision,
        });
        table.row(&[
            format!("mix[{label}]"),
            "Mix'n'Match (host)".to_string(),
            pplx(ll),
            eff_bits(eb),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::ModelDims;
    use crate::model::testing::toy_transformer;

    fn eval_dims() -> ModelDims {
        // The host evaluator needs the full byte vocabulary; everything
        // else stays toy-sized.
        ModelDims {
            vocab: VOCAB,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            seq_len: 8,
            quantize_attn: false,
        }
    }

    #[test]
    fn host_perplexity_matches_dense_reference_at_full_bits() {
        let (preset, model) = toy_transformer(eval_dims(), 3);
        // The packed path decodes the same int8 weights bit-for-bit; only
        // the fused kernels' accumulation order differs from the dense
        // matmul, so the perplexities agree to accumulation tolerance —
        // far below the O(0.1) gaps a real bit-width defect produces.
        let packed =
            ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
        let dense = ForwardPlan::dense_uniform(&preset.model, &model, 8, false).unwrap();
        let a = HostEvaluator::new(packed, 2)
            .unwrap()
            .log_perplexity(11, 12, 1)
            .unwrap();
        let b = HostEvaluator::new(dense, 2)
            .unwrap()
            .log_perplexity(11, 12, 1)
            .unwrap();
        assert!(a.is_finite() && a > 0.0, "pplx {a}");
        assert!((a - b).abs() < 0.05, "packed {a} vs dense {b} int8 pplx");
        // determinism: same plan spec + same seeds → the same number, bit
        // for bit
        let packed2 =
            ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
        let again = HostEvaluator::new(packed2, 2)
            .unwrap()
            .log_perplexity(11, 12, 1)
            .unwrap();
        assert_eq!(a, again);
    }

    #[test]
    fn host_quality_table_sweeps_precisions_and_mixnmatch() {
        let (preset, model) = toy_transformer(eval_dims(), 5);
        let table = host_quality_table(
            &preset.model,
            &model,
            &[2, 8],
            Some(&[8u32, 2][..]),
            false,
            2,
            11,
            12,
            1,
        )
        .unwrap();
        let s = table.render();
        assert!(s.contains("int2"), "{s}");
        assert!(s.contains("int8"), "{s}");
        assert!(s.contains("mix[8/2]"), "{s}");
        assert!(s.contains("MatQuant (host)"), "{s}");
        assert!(s.contains("eff. bits/w"), "{s}");
        // every pplx + effective-bits cell parses as a finite number via
        // the JSON lines
        for line in table.to_json_lines().lines() {
            let v = crate::util::Json::parse(line).unwrap();
            let p = v.get("log pplx.").unwrap().as_f64().unwrap();
            assert!(p.is_finite() && p > 0.0, "{line}");
            let eb = v.get("eff. bits/w").unwrap().as_f64().unwrap();
            // toy tensors are tiny, so per-channel scale bytes dominate;
            // only the lower bound is meaningful at this scale
            assert!(eb.is_finite() && eb > 1.9, "{line}");
        }
    }

    #[test]
    fn effective_bits_column_measures_eq8_overlay() {
        // Under Eq. 8 the int2 row must report > 2 bits/w (payload +
        // scales + the overflow overlay), and more than the Eq. 6 row.
        let (preset, model) = toy_transformer(eval_dims(), 5);
        let read_int2 = |table: &TableBuilder| -> f64 {
            table
                .to_json_lines()
                .lines()
                .find(|l| l.contains("int2"))
                .map(|l| {
                    crate::util::Json::parse(l)
                        .unwrap()
                        .get("eff. bits/w")
                        .unwrap()
                        .as_f64()
                        .unwrap()
                })
                .unwrap()
        };
        let plain = host_quality_table(
            &preset.model, &model, &[2], None, false, 2, 11, 12, 1,
        )
        .unwrap();
        let ep = host_quality_table(
            &preset.model, &model, &[2], None, true, 2, 11, 12, 1,
        )
        .unwrap();
        let a = read_int2(&plain);
        let b = read_int2(&ep);
        assert!(a > 2.0, "scales alone push past 2.0: {a}");
        assert!(b > a, "Eq. 8 overlay must cost measured bits: {b} vs {a}");
        assert!(b - a < 1.0, "overlay cost should be fractional: {}", b - a);
    }

    #[test]
    fn decode_path_perplexity_matches_the_forward_path_on_f32_pages() {
        let (preset, model) = toy_transformer(eval_dims(), 3);
        let plan =
            ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
        // Same held-out blocks: batch-1 forward eval vs token-by-token
        // decode eval.  The decode step is bit-identical to the reference
        // forward position by position, and both sides accumulate CE in
        // the same order, so the means agree exactly — and the page size
        // cannot matter for f32 pages.
        let fwd = HostEvaluator::new(plan.clone(), 1)
            .unwrap()
            .log_perplexity(11, 12, 2)
            .unwrap();
        let paged = decode_log_perplexity(plan.clone(), KvConfig::f32_paged(3), 11, 12, 2).unwrap();
        let paged_wide =
            decode_log_perplexity(plan, KvConfig::f32_paged(16), 11, 12, 2).unwrap();
        assert!(fwd.is_finite() && fwd > 0.0, "pplx {fwd}");
        assert_eq!(fwd, paged, "decode-path f32 pages must be bit-identical");
        assert_eq!(paged, paged_wide, "page size must not change f32 results");
    }

    #[test]
    fn int8_kv_pages_cost_bounded_quality() {
        let (preset, model) = toy_transformer(eval_dims(), 3);
        let plan =
            ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
        let f32p = decode_log_perplexity(plan.clone(), KvConfig::f32_paged(4), 11, 12, 2).unwrap();
        let int8 = decode_log_perplexity(plan, KvConfig::int8(4), 11, 12, 2).unwrap();
        assert!(int8.is_finite() && int8 > 0.0, "pplx {int8}");
        // Per-row absmax K/V quantization is lossy but mild; a blow-up
        // here means scales are being dropped or misapplied somewhere in
        // the paged read path.
        assert!(
            (int8 - f32p).abs() < 1.0,
            "int8 KV {int8} vs f32 KV {f32p} nats"
        );
    }

    #[test]
    fn cross_entropy_is_stable_and_nan_safe() {
        // uniform row: ce == ln(n)
        let row = [0.0f32; 4];
        let ce = cross_entropy_nats(&row, 1);
        assert!((ce - (4.0f64).ln()).abs() < 1e-9);
        // huge logits do not overflow the stable form
        let row = [1000.0f32, 999.0, -1000.0];
        assert!(cross_entropy_nats(&row, 0) < 0.32);
        // poisoned rows surface as +inf, never a panic
        assert!(cross_entropy_nats(&[f32::NAN, f32::NAN], 0).is_infinite());
        assert!(cross_entropy_nats(&[f32::NEG_INFINITY; 2], 1).is_infinite());
    }

    #[test]
    fn distill_eval_is_deterministic_and_teacher_optimal() {
        let (preset, model) = toy_transformer(eval_dims(), 9);
        let teacher =
            ForwardPlan::packed_uniform(&preset.model, &model, 8, false, None, None).unwrap();
        let student2 =
            ForwardPlan::packed_uniform(&preset.model, &model, 2, false, None, None).unwrap();
        let kv = KvConfig::f32_paged(4);
        let rows = sample_decode_rows(&teacher, kv, 31, 3).unwrap();
        assert_eq!(rows.len(), 3);
        for row in &rows {
            assert_eq!(row.len(), preset.model.seq_len + 1);
            assert!(row.iter().all(|&tok| tok >= 0 && (tok as usize) < VOCAB));
        }
        // same (plan, kv, seed) → the same rows and the same score
        let again = sample_decode_rows(&teacher, kv, 31, 3).unwrap();
        assert_eq!(rows, again);
        let self_ce = distill_decode_log_perplexity(&teacher, &teacher, kv, 31, 6).unwrap();
        let self_ce2 = distill_decode_log_perplexity(&teacher, &teacher, kv, 31, 6).unwrap();
        assert_eq!(self_ce, self_ce2);
        assert!(self_ce.is_finite() && self_ce > 0.0, "self CE {self_ce}");
        // On its own samples the teacher is the optimal predictor: an int2
        // truncation of the same masters pays entropy + KL on top.
        let int2_ce = distill_decode_log_perplexity(&teacher, &student2, kv, 31, 6).unwrap();
        assert!(
            self_ce <= int2_ce + 1e-9,
            "teacher {self_ce} must score ≤ its int2 student {int2_ce}"
        );
    }

    #[test]
    fn rejects_degenerate_eval_configs() {
        let (preset, model) = toy_transformer(eval_dims(), 7);
        let plan =
            ForwardPlan::packed_uniform(&preset.model, &model, 4, false, None, None).unwrap();
        assert!(HostEvaluator::new(plan, 0).is_err());
        // a vocab smaller than the byte corpus cannot score it
        let small = ModelDims {
            vocab: 32,
            ..eval_dims()
        };
        let (p2, m2) = toy_transformer(small, 7);
        let plan2 = ForwardPlan::packed_uniform(&p2.model, &m2, 4, false, None, None).unwrap();
        assert!(HostEvaluator::new(plan2, 2).is_err());
    }
}
