//! Log-perplexity over a held-out corpus stream via the `eval` artifact,
//! and the option-scoring primitive the task probes build on.
//!
//! The artifact takes pre-materialized weights (+ per-quantized-tensor
//! biases), so ONE compiled executable evaluates every precision and
//! Mix'n'Match assignment — that is the Matryoshka serving property.
//!
//! Perf: a [`WeightsSession`] converts the weight set to XLA literals
//! once; the task suite then reuses them across its ~150 eval executions
//! per configuration (see EXPERIMENTS.md §Perf).

use anyhow::ensure;

use crate::data::{Batcher, Corpus};
use crate::model::{PresetInfo, Tensor};
use crate::runtime::{lit_i32, lit_tensor, Engine};
use crate::Result;

/// Evaluation driver bound to one engine + preset.
pub struct Evaluator<'e> {
    pub engine: &'e Engine,
    pub preset_name: String,
    pub preset: PresetInfo,
}

/// One materialized weight configuration, pre-converted to literals.
pub struct WeightsSession {
    prefix: Vec<xla::Literal>,
}

impl<'e> Evaluator<'e> {
    pub fn new(engine: &'e Engine, preset_name: &str) -> Result<Self> {
        let preset = engine.manifest().preset(preset_name)?.clone();
        Ok(Evaluator {
            engine,
            preset_name: preset_name.to_string(),
            preset,
        })
    }

    /// Convert a materialized (weights, biases) pair once.
    pub fn session(&self, weights: &[Tensor], biases: &[Tensor]) -> Result<WeightsSession> {
        ensure!(
            weights.len() == self.preset.params.len(),
            "weight count mismatch"
        );
        ensure!(
            biases.len() == self.preset.quantized.len(),
            "bias count mismatch"
        );
        let mut prefix = Vec::with_capacity(weights.len() + biases.len());
        for w in weights {
            prefix.push(lit_tensor(w)?);
        }
        for b in biases {
            prefix.push(lit_tensor(b)?);
        }
        Ok(WeightsSession { prefix })
    }

    fn run_eval(
        &self,
        session: &WeightsSession,
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32, Vec<f32>)> {
        let b = self.preset.train_batch;
        let t1 = self.preset.model.seq_len + 1;
        let t = self.preset.model.seq_len;
        ensure!(tokens.len() == b * t1, "tokens shape");
        ensure!(mask.len() == b * t, "mask shape");
        let mut args: Vec<&xla::Literal> = session.prefix.iter().collect();
        let tok_lit = lit_i32(&[b, t1], tokens)?;
        let mask_lit = lit_tensor(&Tensor::new(vec![b, t], mask.to_vec())?)?;
        args.push(&tok_lit);
        args.push(&mask_lit);
        let out = self.engine.run_refs(&self.preset_name, "eval", &args)?;
        ensure!(out.len() == 3, "eval output arity");
        Ok((out[0].data[0], out[1].data[0], out[2].data.clone()))
    }

    /// Mean log-perplexity (nats/token) over `n_batches` held-out batches.
    ///
    /// `eval_seed` must differ from the training stream seed; the corpus
    /// structure (Markov table) is shared via the corpus seed.
    pub fn log_perplexity(
        &self,
        session: &WeightsSession,
        corpus_seed: u64,
        eval_seed: u64,
        n_batches: usize,
    ) -> Result<f64> {
        let b = self.preset.train_batch;
        let t1 = self.preset.model.seq_len + 1;
        let t = self.preset.model.seq_len;
        let mut batcher = Batcher::new(Corpus::new(corpus_seed), eval_seed, b, t1);
        let ones = vec![1.0f32; b * t];
        let mut ce = 0.0f64;
        let mut count = 0.0f64;
        for _ in 0..n_batches {
            let tokens = batcher.next_block();
            let (ce_sum, mask_sum, _) = self.run_eval(session, &tokens, &ones)?;
            ce += ce_sum as f64;
            count += mask_sum as f64;
        }
        Ok(ce / count.max(1.0))
    }

    /// Score candidate continuations: for each row, the summed label
    /// log-likelihood over masked positions.  Rows beyond `rows.len()` in
    /// the fixed batch are padding.
    ///
    /// Each row = (tokens ≤ T+1 incl. the option, option span `[start,
    /// end)` in token indices).
    pub fn score_rows(
        &self,
        session: &WeightsSession,
        rows: &[(Vec<i32>, usize, usize)],
    ) -> Result<Vec<f32>> {
        let b = self.preset.train_batch;
        let t1 = self.preset.model.seq_len + 1;
        let t = self.preset.model.seq_len;
        ensure!(rows.len() <= b, "too many rows for eval batch");
        let mut tokens = vec![0i32; b * t1];
        let mut mask = vec![0.0f32; b * t];
        for (i, (row, start, end)) in rows.iter().enumerate() {
            ensure!(row.len() <= t1, "row too long: {}", row.len());
            ensure!(*start >= 1 && end <= &row.len(), "bad option span");
            tokens[i * t1..i * t1 + row.len()].copy_from_slice(row);
            // token at index j is predicted at label position j-1
            for j in *start..*end {
                mask[i * t + (j - 1)] = 1.0;
            }
        }
        let (_, _, seq_ll) = self.run_eval(session, &tokens, &mask)?;
        Ok(seq_ll[..rows.len()].to_vec())
    }
}
