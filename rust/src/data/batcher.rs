//! Training batcher: turns the corpus stream into fixed-shape `(B, T+1)`
//! i32 token blocks matching the train-step artifact signature, and tracks
//! the token budget (the paper trains for a fixed number of tokens).

use super::corpus::Corpus;
use super::rng::Rng;

#[derive(Debug)]
pub struct Batcher {
    corpus: Corpus,
    rng: Rng,
    pub batch: usize,
    /// Sequence length *including* the shifted label position (T+1).
    pub block: usize,
    pub tokens_emitted: u64,
}

impl Batcher {
    pub fn new(corpus: Corpus, seed: u64, batch: usize, block: usize) -> Self {
        Batcher {
            corpus,
            rng: Rng::new(seed),
            batch,
            block,
            tokens_emitted: 0,
        }
    }

    /// Next `(B, T+1)` block, flattened row-major.
    pub fn next_block(&mut self) -> Vec<i32> {
        let out = self.corpus.batch(&mut self.rng, self.batch, self.block);
        self.tokens_emitted += (self.batch * (self.block - 1)) as u64;
        out
    }

    /// Steps needed to consume `budget` training tokens (paper: 10M/20M/100M;
    /// scaled down in our experiments).
    pub fn steps_for_token_budget(&self, budget: u64) -> u64 {
        let per_step = (self.batch * (self.block - 1)) as u64;
        budget.div_ceil(per_step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_shape_and_budget() {
        let mut b = Batcher::new(Corpus::new(1), 2, 4, 17);
        let blk = b.next_block();
        assert_eq!(blk.len(), 4 * 17);
        assert_eq!(b.tokens_emitted, 64);
        assert_eq!(b.steps_for_token_budget(640), 10);
        assert_eq!(b.steps_for_token_budget(641), 11);
    }

    #[test]
    fn blocks_differ() {
        let mut b = Batcher::new(Corpus::new(1), 2, 4, 17);
        assert_ne!(b.next_block(), b.next_block());
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Batcher::new(Corpus::new(1), 2, 4, 17);
        let mut b = Batcher::new(Corpus::new(1), 2, 4, 17);
        assert_eq!(a.next_block(), b.next_block());
    }
}
